"""Autotuner persistence + cost-model prior: store round-trip, fingerprint
invalidation, warm zero-probe rebuilds, probe-budget pruning, and the
dispatch/validation bugfixes that ride along (stale-mode ValueError,
capacity >= 1, fit fast path parity)."""
import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cp_als, fit_value, random_tensor
from repro.core.mttkrp import mttkrp_coo
from repro.engine import (
    CostModelPrior,
    EngineContext,
    PlanCache,
    TuningStore,
    WorkloadKey,
    build_engine,
)
from repro.engine import autotune as _autotune
from repro.engine.persist import StoredEntry

KW = dict(chunk_shape=(8, 8, 8), capacity=64)


def _key(st, rank=4, candidates=("alto", "chunked", "ref")):
    return WorkloadKey.from_tensor(st, rank, candidates)


def _probe_counter(monkeypatch):
    """Instrument _time_call: every probe the tuner performs is counted."""
    calls = []
    real = _autotune._time_call

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(_autotune, "_time_call", counting)
    return calls


# ---------------------------------------------------------------------------
# TuningStore units
# ---------------------------------------------------------------------------

def test_store_roundtrip(tmp_path):
    st = random_tensor((20, 16, 24), 400, seed=1)
    path = tmp_path / "autotune.json"
    key = _key(st)
    winners = {0: "alto", 1: "chunked", 2: "alto"}
    timings = {"alto": {0: 1e-3, 1: 2e-3, 2: 1.5e-3},
               "chunked": {0: 2e-3, 1: 1e-3, 2: 3e-3}}
    TuningStore(path).record(key, winners, timings, overall="alto",
                             warmup=1, reps=2)
    # a fresh store instance re-reads from disk
    entry = TuningStore(path).lookup(key)
    assert entry is not None
    assert entry.key == key
    assert entry.winners == winners
    assert entry.timings == timings
    assert entry.overall == "alto"
    # mode keys survive the str round-trip as ints
    assert all(isinstance(m, int) for m in entry.winners)
    assert all(isinstance(m, int)
               for per in entry.timings.values() for m in per)


def test_store_replaces_exact_key_and_survives_corruption(tmp_path):
    st = random_tensor((20, 16, 24), 400, seed=1)
    path = tmp_path / "autotune.json"
    key = _key(st)
    store = TuningStore(path)
    store.record(key, {0: "ref"}, {"ref": {0: 1.0}})
    store.record(key, {0: "alto"}, {"alto": {0: 0.5}})
    assert len(TuningStore(path)) == 1
    assert TuningStore(path).lookup(key).winners == {0: "alto"}
    # corrupt file → cold-start behaviour, not a crash
    path.write_text("{not json")
    assert TuningStore(path).lookup(key) is None
    # foreign schema version → ignored
    path.write_text(json.dumps({"version": 999, "entries": [1, 2]}))
    assert len(TuningStore(path)) == 0


def test_device_fingerprint_mismatch_invalidates(tmp_path):
    st = random_tensor((20, 16, 24), 400, seed=1)
    store = TuningStore(tmp_path / "autotune.json")
    key = _key(st)
    store.record(key, {0: "ref", 1: "ref", 2: "ref"}, {"ref": {0: 1.0, 1: 1.0, 2: 1.0}})
    other_device = dataclasses.replace(
        key, device=tuple(sorted({"backend": "tpu", "device_count": "8",
                                  "device_kind": "TPU v9",
                                  "jax": "99.0"}.items())))
    assert store.lookup(key) is not None
    assert store.lookup(other_device) is None


def test_near_fingerprint_tolerance_on_nnz(tmp_path):
    st = random_tensor((30, 24, 36), 700, seed=2)
    store = TuningStore(tmp_path / "autotune.json")
    store.record(_key(st), {0: "ref"}, {"ref": {0: 1.0}})
    # same shape/rank/candidates, nnz a few % off → near hit
    near = random_tensor((30, 24, 36), 730, seed=7)
    assert store.lookup(_key(near)) is not None
    # nnz 3x off → miss
    far = random_tensor((30, 24, 36), 2100, seed=7)
    assert store.lookup(_key(far)) is None
    # different rank → miss even with identical tensor stats
    assert store.lookup(_key(st, rank=9)) is None
    # different candidate set → miss (timings don't transfer)
    assert store.lookup(_key(st, candidates=("ref",))) is None


# ---------------------------------------------------------------------------
# Warm builds through build_engine
# ---------------------------------------------------------------------------

def test_warm_build_skips_probes_and_reuses_winners(tmp_path, monkeypatch):
    """Acceptance: the second build on an identical fingerprint performs
    zero timing probes and selects the first run's measured winners."""
    st = random_tensor((30, 24, 36), 700, seed=2)
    path = tmp_path / "autotune.json"
    cold = build_engine(st, "auto", 4, plans=PlanCache(),
                        store=TuningStore(path), **KW)
    assert cold.report.source == "measured"
    assert cold.report.n_probes > 0
    assert cold.report.store_path == str(path)

    calls = _probe_counter(monkeypatch)
    warm = build_engine(st, "auto", 4, plans=PlanCache(),
                        store=TuningStore(path), **KW)
    assert calls == []                      # zero _time_call probes
    assert warm.report.source == "persisted"
    assert warm.report.n_probes == 0
    assert warm.report.winners == cold.report.winners
    # floats round-trip JSON exactly (shortest-repr serialization)
    assert warm.report.timings == cold.report.timings
    # the warm engine dispatches to a working persisted winner
    rank = 4
    rng = np.random.default_rng(3)
    factors = tuple(jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
                    for d in st.shape)
    for mode in range(st.ndim):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=st.shape[mode])
        np.testing.assert_allclose(np.asarray(ref),
                                   np.asarray(warm(factors, mode)),
                                   rtol=1e-3, atol=1e-3)


def test_cp_als_auto_threads_store(tmp_path, monkeypatch):
    st = random_tensor((20, 16, 24), 400, seed=3)
    path = tmp_path / "autotune.json"
    r1 = cp_als(st, 4, n_iters=2, engine="auto", plans=PlanCache(),
                store=str(path), **KW)
    assert r1.engine.startswith("auto:")
    calls = _probe_counter(monkeypatch)
    r2 = cp_als(st, 4, n_iters=2, engine="auto", plans=PlanCache(),
                store=str(path), **KW)
    assert calls == []
    assert r2.engine == r1.engine
    np.testing.assert_allclose(r1.fit_history, r2.fit_history,
                               rtol=1e-5, atol=1e-6)


def test_warm_build_with_restricted_modes_serves_all_persisted_modes(
        tmp_path, monkeypatch):
    """A warm build that only *requested* mode 0 must still dispatch modes
    1..N-1 through the persisted winners — not die on a bare KeyError."""
    st = random_tensor((20, 16, 24), 400, seed=6)
    path = tmp_path / "autotune.json"
    build_engine(st, "auto", 4, plans=PlanCache(), store=TuningStore(path),
                 **KW)
    calls = _probe_counter(monkeypatch)
    warm = build_engine(st, "auto", 4, plans=PlanCache(),
                        store=TuningStore(path), autotune_modes=[0], **KW)
    assert calls == []
    factors = tuple(jnp.zeros((d, 4), jnp.float32) for d in st.shape)
    for mode in range(st.ndim):  # every persisted mode dispatches
        assert warm(factors, mode).shape == (st.shape[mode], 4)


def test_concurrent_saves_merge_per_fingerprint(tmp_path):
    """Two store handles on one path must not clobber each other's entries:
    last-writer-wins holds per fingerprint, not per file."""
    st_a = random_tensor((20, 16, 24), 400, seed=1)
    st_b = random_tensor((40, 32, 12), 900, seed=2)
    path = tmp_path / "autotune.json"
    a, b = TuningStore(path), TuningStore(path)
    a.lookup(_key(st_a))   # both lazily snapshot the (empty) file
    b.lookup(_key(st_b))
    a.record(_key(st_a), {0: "ref"}, {"ref": {0: 1.0}})
    b.record(_key(st_b), {0: "alto"}, {"alto": {0: 2.0}})
    fresh = TuningStore(path)
    assert fresh.lookup(_key(st_a)) is not None   # A's write survived B's
    assert fresh.lookup(_key(st_b)) is not None
    assert len(fresh) == 2


def test_racing_writers_under_widened_window_drop_nothing(tmp_path, monkeypatch):
    """Regression: save()'s read-merge-write used to run unlocked, so two
    writers that both read the file before either renamed would each
    publish a payload missing the other's fresh entry — the second rename
    silently dropped the first's work.  The advisory flock serializes the
    cycle; this test widens the read→rename window enough that the
    unlocked code loses deterministically."""
    import threading
    import time as _time

    st_a = random_tensor((20, 16, 24), 400, seed=1)
    st_b = random_tensor((40, 32, 12), 900, seed=2)
    path = tmp_path / "autotune.json"
    real_read = TuningStore._read_disk

    def slow_read(self):
        entries = real_read(self)
        _time.sleep(0.15)           # hold the stale snapshot a while
        return entries

    monkeypatch.setattr(TuningStore, "_read_disk", slow_read)
    a, b = TuningStore(path), TuningStore(path)
    ka, kb = _key(st_a), _key(st_b)
    threads = [
        threading.Thread(
            target=lambda: a.record(ka, {0: "ref"}, {"ref": {0: 1.0}})),
        threading.Thread(
            target=lambda: b.record(kb, {0: "alto"}, {"alto": {0: 2.0}})),
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    monkeypatch.setattr(TuningStore, "_read_disk", real_read)
    fresh = TuningStore(path)
    assert fresh.lookup(ka) is not None
    assert fresh.lookup(kb) is not None
    assert len(fresh) == 2


def test_nnz_tol_zero_store_keeps_adjacent_fingerprints(tmp_path):
    """A sweep store (nnz_tol=0) must treat nnz-band neighbours inside the
    default ±10% window as distinct: no warm-serving, no record()-time
    supersede, no save()-time shadow dedup."""
    st = random_tensor((30, 24, 36), 700, seed=2)
    near = random_tensor((30, 24, 36), 730, seed=7)   # within 10%
    path = tmp_path / "autotune.json"
    exact = TuningStore(path, nnz_tol=0.0)
    exact.record(_key(st), {0: "ref"}, {"ref": {0: 1.0}})
    assert exact.lookup(_key(near)) is None           # no near hit
    exact.record(_key(near), {0: "alto"}, {"alto": {0: 2.0}})
    assert len(TuningStore(path, nnz_tol=0.0)) == 2   # both survive the save
    assert exact.lookup(_key(st)).winners == {0: "ref"}
    assert exact.lookup(_key(near)).winners == {0: "alto"}
    # the same file read under the default policy near-matches again
    assert TuningStore(path).lookup(_key(near)) is not None
    with pytest.raises(ValueError, match="nnz_tol"):
        TuningStore(path, nnz_tol=-0.1)


def test_forget_drops_exactly_one_fingerprint(tmp_path):
    st_a = random_tensor((20, 16, 24), 400, seed=1)
    st_b = random_tensor((40, 32, 12), 900, seed=2)
    path = tmp_path / "autotune.json"
    store = TuningStore(path)
    store.record(_key(st_a), {0: "ref"}, {"ref": {0: 1.0}})
    store.record(_key(st_b), {0: "alto"}, {"alto": {0: 2.0}})
    assert store.forget(_key(st_a)) is True
    assert store.forget(_key(st_a)) is False          # already gone
    fresh = TuningStore(path)
    assert fresh.lookup(_key(st_a)) is None
    assert fresh.lookup(_key(st_b)) is not None


def test_capacity_is_part_of_the_fingerprint(tmp_path):
    """Schema v5: timings tuned under an explicitly-pinned chunk capacity
    must not serve the decider-default workload (or another capacity) —
    and pre-v5 entries (capacity absent in JSON) load as None."""
    st = random_tensor((20, 16, 24), 400, seed=1)
    store = TuningStore(tmp_path / "autotune.json")
    pinned = WorkloadKey.from_tensor(st, 4, ("ref",), capacity=64)
    store.record(pinned, {0: "ref"}, {"ref": {0: 1.0}})
    assert store.lookup(pinned) is not None
    assert store.lookup(WorkloadKey.from_tensor(st, 4, ("ref",))) is None
    assert store.lookup(dataclasses.replace(pinned, capacity=32)) is None
    # JSON round-trip without the field (a v4-era entry) → capacity=None
    d = pinned.to_json()
    del d["capacity"]
    assert WorkloadKey.from_json(d).capacity is None


def test_unbuildable_persisted_winner_falls_back_to_measurement(tmp_path):
    st = random_tensor((20, 16, 24), 400, seed=4)
    store = TuningStore(tmp_path / "autotune.json")
    cands = ["alto", "chunked", "ref"]
    key = WorkloadKey.from_tensor(st, 4, cands)
    store.record(key, {0: "gone_backend", 1: "ref", 2: "ref"},
                 {"gone_backend": {0: 1.0}, "ref": {0: 2.0, 1: 2.0, 2: 2.0}})
    eng = build_engine(st, "auto", 4, plans=PlanCache(), store=store,
                       candidates=cands, **KW)
    assert eng.report.source == "measured"   # stale entry → re-probed
    assert eng.report.n_probes > 0


# ---------------------------------------------------------------------------
# Cost-model prior + probe budget
# ---------------------------------------------------------------------------

def test_prior_order_is_a_deterministic_permutation():
    st = random_tensor((30, 24, 36), 700, seed=2)
    prior = CostModelPrior()
    cands = ["ref", "alto", "chunked", "hetero", "pallas"]
    order = prior.order(st, 4, cands)
    assert sorted(order) == sorted(cands)
    assert order == prior.order(st, 4, list(reversed(cands)))
    # interpret-mode pallas is penalized to the back of the field
    assert order[-1] == "pallas"


def test_max_probes_prunes_to_prior_topk(monkeypatch):
    st = random_tensor((30, 24, 36), 700, seed=2)
    cands = ["ref", "alto", "chunked", "hetero"]
    top2 = CostModelPrior().order(st, 4, cands, list(range(st.ndim)))[:2]
    calls = _probe_counter(monkeypatch)
    eng = build_engine(st, "auto", 4, plans=PlanCache(), candidates=cands,
                       max_probes=2, **KW)
    rep = eng.report
    assert rep.prior_order is not None
    assert rep.prior_order[:2] == top2
    assert set(rep.timings) <= set(top2)
    pruned = {n for n, why in rep.skipped.items() if "pruned" in why}
    assert pruned == set(cands) - set(top2)
    # the probe budget actually bounds measurement work
    assert len(calls) <= 2 * st.ndim
    # report invariant: every candidate is accounted for
    assert set(rep.timings) | set(rep.skipped) == set(cands)
    with pytest.raises(ValueError, match="max_probes"):
        build_engine(st, "auto", 4, plans=PlanCache(), candidates=cands,
                     max_probes=0, **KW)


# ---------------------------------------------------------------------------
# Ride-along bugfixes
# ---------------------------------------------------------------------------

def test_autotuned_engine_rejects_stale_mode_with_valueerror():
    """A mode index outside the tuned set must raise a ValueError naming the
    mode and the valid range — not a bare KeyError from the closure."""
    st = random_tensor((20, 16, 24), 300, seed=5)
    eng = build_engine(st, "auto", 3, plans=PlanCache(), **KW)
    factors = tuple(jnp.zeros((d, 3), jnp.float32) for d in st.shape)
    with pytest.raises(ValueError, match=r"mode 3.*valid modes: 0\.\.2"):
        eng(factors, 3)


def test_explicit_zero_capacity_rejected():
    st = random_tensor((20, 16, 24), 300, seed=5)
    with pytest.raises(ValueError, match="capacity must be >= 1"):
        EngineContext(st=st, rank=4, capacity=0)
    with pytest.raises(ValueError, match="capacity must be >= 1"):
        build_engine(st, "chunked", 4, chunk_shape=(8, 8, 8), capacity=0)
    # capacity=None still defers to the partition decider
    ctx = EngineContext(st=st, rank=4, plans=PlanCache())
    cs, cap = ctx.resolve_chunking()
    assert cap is None or cap >= 1


def test_fit_fast_path_matches_slow_path():
    """cp_als now reuses the last mode's MTTKRP for the fit inner product;
    it must agree with the explicit reconstruct_nnz slow path to ~1e-5."""
    st = random_tensor((18, 14, 16), 500, seed=12)
    res = cp_als(st, 5, n_iters=3, engine="ref", seed=13, track_diff=False)
    slow = fit_value(st, res.factors, res.lam)   # mlast=None → slow path
    assert abs(res.fit_history[-1] - slow) < 1e-5


def test_fit_fast_path_gated_off_for_approximate_engines():
    """Lossy (fixed-point) and lock-free engines must report the exact
    factors-only fit: kernel noise in the MTTKRP output never biases the
    accuracy metric (fig6's comparison depends on this)."""
    st = random_tensor((18, 14, 16), 500, seed=12)
    kw = dict(chunk_shape=(8, 8, 8), capacity=64, track_diff=False)
    for engine_kw in (dict(engine="fixed", fixed_preset="int7"),
                      dict(engine="chunked", lockfree_mode=True)):
        res = cp_als(st, 4, n_iters=2, seed=13, plans=PlanCache(),
                     **engine_kw, **kw)
        slow = fit_value(st, res.factors, res.lam)
        assert abs(res.fit_history[-1] - slow) < 1e-6, engine_kw
