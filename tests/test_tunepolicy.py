"""TunePolicy: the consolidated tuning API — new-style `tune=` calls are
warning-free, the nine legacy kwargs fold into a policy with exactly one
DeprecationWarning per call, mixing the two styles is an error, and unknown
kwargs fail fast with a nearest-match hint."""
import warnings

import pytest

from repro.core import cp_als, random_tensor
from repro.engine import TunePolicy, build_engine
from repro.engine.tunepolicy import TUNE_FIELDS, split_tune_kwargs

RANK = 4


@pytest.fixture(scope="module")
def st():
    return random_tensor((8, 7, 6), nnz=60, seed=0)


# ---------------------------------------------------------------------------
# policy construction + validation
# ---------------------------------------------------------------------------

def test_policy_is_frozen_and_normalizes_candidates():
    pol = TunePolicy(candidates=["chunked", "ref"])
    assert pol.candidates == ("chunked", "ref")
    with pytest.raises(AttributeError):
        pol.warmup = 3


@pytest.mark.parametrize(("kwargs", "match"), [
    (dict(max_probes=0), "max_probes must be >= 1"),
    (dict(elide_margin=0.5), "elide_margin is a slowdown factor"),
    (dict(accuracy_budget=0.0), "accuracy_budget is a max relative error"),
    (dict(reps=0), "reps"),
    (dict(warmup=-1), "warmup"),
    (dict(prior=42), "prior must be"),
])
def test_policy_validation_messages(kwargs, match):
    with pytest.raises((ValueError, TypeError), match=match):
        TunePolicy(**kwargs)


def test_split_tune_kwargs_pops_only_tune_fields():
    bag = dict(warmup=3, store=True, mem_bytes=1024)
    legacy = split_tune_kwargs(bag)
    assert legacy == dict(warmup=3, store=True)
    assert bag == dict(mem_bytes=1024)
    assert set(legacy) <= set(TUNE_FIELDS)


# ---------------------------------------------------------------------------
# resolve(): new style, legacy shims, mixing
# ---------------------------------------------------------------------------

def test_new_style_emits_no_warning(st):
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = cp_als(st, RANK, n_iters=1, engine="auto",
                     tune=TunePolicy(warmup=0, reps=1))
    assert res.tune_report is not None
    assert res.tune_report.warmup == 0


def test_legacy_kwargs_warn_exactly_once_per_call(st):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        res = cp_als(st, RANK, n_iters=1, engine="auto", warmup=0, reps=1)
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1, [str(w.message) for w in deps]
    msg = str(deps[0].message)
    assert "cp_als" in msg and "reps" in msg and "warmup" in msg
    assert "TunePolicy" in msg
    assert res.tune_report.warmup == 0 and res.tune_report.reps == 1


def test_legacy_kwargs_warn_on_build_engine_too(st):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = build_engine(st, "auto", RANK, warmup=0, reps=1)
    deps = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1
    assert "build_engine" in str(deps[0].message)
    assert eng.report.warmup == 0


def test_mixing_tune_and_legacy_raises(st):
    with pytest.raises(TypeError, match="both tune= and"):
        cp_als(st, RANK, n_iters=1, engine="auto",
               tune=TunePolicy(), warmup=0)


def test_tune_must_be_a_policy(st):
    with pytest.raises(TypeError, match="TunePolicy"):
        cp_als(st, RANK, n_iters=1, engine="auto", tune={"warmup": 0})


# ---------------------------------------------------------------------------
# unknown-kwarg validation (no more blind **engine_kwargs passthrough)
# ---------------------------------------------------------------------------

def test_unknown_kwarg_suggests_nearest(st):
    with pytest.raises(TypeError, match="did you mean 'max_probes'"):
        cp_als(st, RANK, n_iters=1, engine="auto", max_probe=2)


def test_unknown_kwarg_without_neighbour_still_names_caller(st):
    with pytest.raises(TypeError, match="cp_als"):
        cp_als(st, RANK, n_iters=1, engine="ref", definitely_not_a_kwarg=1)


def test_valid_engine_kwargs_still_pass(st):
    res = cp_als(st, RANK, n_iters=1, engine="chunked", mem_bytes=256 * 1024)
    assert res.engine == "chunked"


# ---------------------------------------------------------------------------
# cross-field constraints preserved from the loose-kwargs era
# ---------------------------------------------------------------------------

def test_budget_on_explicit_backend_still_rejected(st):
    with pytest.raises(ValueError, match="accuracy_budget only applies"):
        cp_als(st, RANK, n_iters=1, engine="chunked",
               tune=TunePolicy(accuracy_budget=0.2))


def test_calibrated_prior_needs_store(st):
    with pytest.raises(ValueError, match="needs a store"):
        build_engine(st, "auto", RANK,
                     tune=TunePolicy(prior="calibrated", store=None))
