"""Distributed correctness: multi-(host-)device runs in a subprocess so the
main pytest process keeps its single-device view (the dry-run owns the
512-device trick; tests use 8)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_distributed_mttkrp_matches_single_device():
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.core import random_tensor, DistributedMTTKRP
        from repro.core.chunking import chunk_tensor
        from repro.core.mttkrp import mttkrp_coo
        from repro.launch.mesh import make_mesh_compat
        st = random_tensor((40, 32, 48), 2000, seed=1)
        rank = 8
        rng = np.random.default_rng(2)
        factors = [jnp.asarray(rng.uniform(-1,1,(d,rank)).astype(np.float32))
                   for d in st.shape]
        ct = chunk_tensor(st, (8, 8, 8), capacity=32)
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        errs = []
        for reduce in ("psum", "psum_scatter"):
            d = DistributedMTTKRP(mesh, ct, rank, reduce=reduce)
            for mode in range(3):
                ref = mttkrp_coo(tuple(factors), jnp.asarray(st.coords),
                                 jnp.asarray(st.values), mode=mode,
                                 out_dim=st.shape[mode])
                out = np.asarray(d(factors, mode))[:st.shape[mode]]
                errs.append(float(np.max(np.abs(out - np.asarray(ref)))))
        print(json.dumps(errs))
    """))
    errs = json.loads(out.strip().splitlines()[-1])
    assert max(errs) < 1e-3, errs


def test_distributed_cpals_converges():
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.core import random_tensor, cp_als, DistributedMTTKRP
        from repro.core.chunking import chunk_tensor
        from repro.launch.mesh import make_mesh_compat
        st = random_tensor((32, 24, 40), 1500, seed=3)
        ct = chunk_tensor(st, (8, 8, 8), capacity=64)
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        engine = DistributedMTTKRP(mesh, ct, 6, reduce="psum")
        dist = cp_als(st, 6, n_iters=3,
                      engine=lambda f, m: jnp.asarray(engine(f, m))[:st.shape[m]],
                      seed=4)
        ref = cp_als(st, 6, n_iters=3, engine="ref", seed=4)
        print(json.dumps([dist.fit_history, ref.fit_history]))
    """))
    dist, ref = json.loads(out.strip().splitlines()[-1])
    np.testing.assert_allclose(dist, ref, rtol=1e-3, atol=1e-4)


def test_moe_ep_sharded_matches_single(trivial_mesh=None):
    out = run_with_devices(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.models.moe import MoEConfig, moe_init, moe_apply
        cfg = MoEConfig(d_model=32, d_ff=16, n_experts=8, top_k=2)
        p, _ = moe_init(jax.random.key(0), cfg)
        x = jax.random.normal(jax.random.key(1), (4, 16, 32)) * 0.5
        from repro.launch.mesh import make_mesh_compat
        mesh1 = make_mesh_compat((8, 1), ("data", "model"))
        mesh2 = make_mesh_compat((2, 4), ("data", "model"))
        o1 = moe_apply(p, cfg, x, mesh=mesh1, seq_sharded=False)
        o2 = moe_apply(p, cfg, x, mesh=mesh2, seq_sharded=False)
        o3 = moe_apply(p, cfg, x, mesh=mesh2, seq_sharded=True)
        err12 = float(jnp.max(jnp.abs(o1 - o2)))
        err13 = float(jnp.max(jnp.abs(o1 - o3)))
        print(json.dumps([err12, err13]))
    """))
    errs = json.loads(out.strip().splitlines()[-1])
    assert max(errs) < 1e-4, errs


def test_train_step_runs_sharded_and_checkpoint_roundtrip(tmp_path):
    out = run_with_devices(textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs import get_smoke_config
        from repro.models import LM
        from repro.launch.steps import make_ctx, make_train_step
        from repro.launch.shardings import init_shapes, param_shardings
        from repro.optim import AdamWConfig, adamw_init
        from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        cfg = get_smoke_config("qwen3_moe_30b_a3b")
        lm = LM(cfg)
        ctx = make_ctx(mesh, seq_sharded=True)
        params, _ = lm.init(jax.random.key(0))
        structs, specs = init_shapes(lm, jax.random.key(0))
        shardings = param_shardings(mesh, structs, specs)
        params = jax.tree.map(jax.device_put, params, shardings)
        opt_cfg = AdamWConfig(lr=1e-3)
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(lm, ctx, opt_cfg, grad_accum=2))
        batch = {{"tokens": jnp.ones((8, 32), jnp.int32)}}
        params, opt, l0 = step(params, opt, batch)
        params, opt, l1 = step(params, opt, batch)
        save_checkpoint(r"{tmp_path}", 2, {{"params": params}})
        st = latest_step(r"{tmp_path}")
        restored = restore_checkpoint(r"{tmp_path}", st, {{"params": params}},
                                      shardings={{"params": shardings}})
        same = jax.tree.all(jax.tree.map(
            lambda a, b: jnp.allclose(a, b), params, restored["params"]))
        print(json.dumps([float(l0), float(l1), bool(same), st]))
    """))
    l0, l1, same, st = json.loads(out.strip().splitlines()[-1])
    assert np.isfinite(l0)
    assert np.isfinite(l1)
    assert l1 < l0
    assert same
    assert st == 2
