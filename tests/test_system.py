"""End-to-end behaviour tests for the paper's system."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.core import cp_als, decide_partition, random_tensor


def test_end_to_end_decomposition_pipeline():
    """The paper's full pipeline: tensor → Fig.5 partition plan → chunked
    fixed-point CP-ALS → convergent decomposition."""
    st = random_tensor((64, 48, 80), 3000, seed=0)
    plan = decide_partition(st, rank=8, mem_bytes=64 * 1024, rank_axis=8)
    assert plan.capacity >= 1
    res = cp_als(st, 8, n_iters=3, engine="fixed", fixed_preset="int7",
                 chunk_shape=plan.chunk_shape, capacity=plan.capacity, seed=0)
    assert all(np.isfinite(f) for f in res.fit_history)
    assert res.diff_history[-1] <= res.diff_history[0] * 1.5


def test_all_archs_have_full_and_smoke_configs():
    for arch in ARCHS:
        full = get_config(arch)
        smoke = get_smoke_config(arch)
        assert full.family == smoke.family
        assert full.n_layers >= smoke.n_layers
        # smoke pattern exercises the same mixer set as the full pattern
        assert {s.mixer for s in smoke.pattern} == {s.mixer for s in full.pattern}


def test_dryrun_shape_registry_covers_assignment():
    from repro.launch.dryrun import SHAPES, should_skip
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}
    assert SHAPES["train_4k"] == dict(kind="train", seq=4096, batch=256)
    assert SHAPES["prefill_32k"] == dict(kind="prefill", seq=32768, batch=32)
    assert SHAPES["decode_32k"] == dict(kind="decode", seq=32768, batch=128)
    assert SHAPES["long_500k"] == dict(kind="decode", seq=524288, batch=1)
    # exactly the 6 pure-full-attention archs skip long_500k
    skips = [a for a in ARCHS if should_skip(get_config(a), "long_500k")]
    assert sorted(skips) == sorted([
        "qwen3_14b", "minitron_4b", "command_r_35b", "qwen3_moe_30b_a3b",
        "whisper_medium", "internvl2_1b"])


def test_serve_generation_end_to_end(trivial_mesh):
    from repro.launch.steps import generate, make_ctx
    from repro.models import LM
    cfg = get_smoke_config("qwen3_14b")
    lm = LM(cfg)
    ctx = make_ctx(trivial_mesh, seq_sharded=False)
    params, _ = lm.init(jax.random.key(0))
    prompts = jnp.ones((2, 8), jnp.int32)
    toks = generate(lm, params, ctx, prompts, gen=4)
    assert toks.shape == (2, 4)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))
