"""Elastic scaling: a checkpoint written under one device count restores and
trains correctly under a different one (launch/elastic.py)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, n: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_elastic_restore_across_device_counts(tmp_path):
    # phase 1: train 2 steps on 8 devices, checkpoint
    common = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.configs import get_smoke_config
        from repro.models import LM
        from repro.launch.steps import make_ctx, make_train_step
        from repro.optim import AdamWConfig, adamw_init
        cfg = get_smoke_config("qwen3_14b")
        lm = LM(cfg)
        opt_cfg = AdamWConfig(lr=1e-3)
        batch = {{"tokens": jnp.ones((4, 32), jnp.int32)}}
        ckpt_dir = r"{tmp_path}"
    """)
    out1 = _run(common + textwrap.dedent("""
        from repro.launch.mesh import make_local_mesh
        from repro.checkpoint import save_checkpoint
        mesh = make_local_mesh(n_model=2)   # 4×2 mesh
        ctx = make_ctx(mesh, seq_sharded=False)
        params, _ = lm.init(jax.random.key(0))
        opt = adamw_init(params, opt_cfg)
        step = jax.jit(make_train_step(lm, ctx, opt_cfg))
        for _ in range(2):
            params, opt, loss = step(params, opt, batch)
        save_checkpoint(ckpt_dir, 2, {"params": params, "opt": opt})
        print(json.dumps(float(loss)))
    """), n=8)
    loss8 = json.loads(out1.strip().splitlines()[-1])

    # phase 2: elastic_restore on 4 devices (simulating node loss), resume
    out2 = _run(common + textwrap.dedent("""
        from repro.launch.elastic import elastic_restore
        mesh, params, opt, start = elastic_restore(lm, ckpt_dir, opt_cfg,
                                                   n_model=2)  # 2×2 mesh
        assert start == 2
        ctx = make_ctx(mesh, seq_sharded=False)
        step = jax.jit(make_train_step(lm, ctx, opt_cfg))
        params, opt, loss = step(params, opt, batch)
        print(json.dumps(float(loss)))
    """), n=4)
    loss4 = json.loads(out2.strip().splitlines()[-1])
    assert np.isfinite(loss8)
    assert np.isfinite(loss4)
    # training continued from the restored state → loss keeps decreasing
    assert loss4 < loss8 + 0.05
