"""Static-analysis pass suite: per-rule good/bad fixtures, suppression
semantics, the cross-module invariant rules against scratch repo copies
(schema mutation without a version bump must fail), and the zero-findings
gate over the live tree — the same invocation the CI `analysis` job runs."""
import json
import shutil
import textwrap

import pytest

from repro.analysis import (
    check_source,
    engine as _engine,
    extract_schema,
    regen_manifest,
    register_rule,
    registered_rules,
    rule_table,
    run_analysis,
)
from repro.analysis.__main__ import main as cli_main
from repro.analysis.engine import parse_suppressions

REPO = _engine.default_root()


def _src(snippet: str) -> str:
    return textwrap.dedent(snippet).lstrip("\n")


# ---------------------------------------------------------------------------
# fixtures: every file rule must fire on its bad snippet and stay quiet on
# the good one
# ---------------------------------------------------------------------------

RETRACE_BAD_LOOP = _src("""
    import jax

    def tune(fns, xs):
        outs = []
        for f in fns:
            jf = jax.jit(f)
            outs.append(jf(xs))
        return outs
""")

RETRACE_BAD_BRANCH = _src("""
    import jax

    @jax.jit
    def mttkrp(coords, vals, mode):
        if mode == 0:
            return vals
        return vals * 2
""")

RETRACE_GOOD = _src("""
    import functools

    import jax

    @functools.partial(jax.jit, static_argnames=("mode",))
    def mttkrp(coords, vals, mode):
        if mode == 0:
            return vals
        return vals * 2

    def tune(fns, xs):
        jitted = [jax.jit(f) for f in fns]
        return [jf(xs) for jf in jitted]
""")

DICT_ORDER_BAD = _src("""
    _REGISTRY = {}

    def candidates():
        return [spec.name for spec in _REGISTRY.values()]
""")

DICT_ORDER_GOOD = _src("""
    _REGISTRY = {}

    def candidates():
        return [s.name for s in sorted(_REGISTRY.values(), key=lambda s: s.name)]

    def count():
        return len(_REGISTRY)
""")

HOST_SYNC_BAD = _src("""
    import jax
    import jax.numpy as jnp

    def probe(xs):
        total = 0.0
        for x in xs:
            total += float(jnp.sum(x))
        jax.block_until_ready(xs)
        return total
""")

HOST_SYNC_GOOD = _src("""
    import jax.numpy as jnp

    def probe(xs):
        total = jnp.zeros(())
        for x in xs:
            total = total + jnp.sum(x)
        return float(total)
""")

TRACER_LEAK_BAD = _src("""
    import jax

    class Stepper:
        @jax.jit
        def step(self, x):
            self.state = x * 2
            return self.state
""")

TRACER_LEAK_GOOD = _src("""
    import jax

    class Stepper:
        @jax.jit
        def step(self, x):
            return x * 2
""")

NONDET_BAD = _src("""
    import time

    import numpy as np

    def sample(n):
        created = time.time()
        return created, np.random.rand(n)
""")

NONDET_GOOD = _src("""
    import time

    import numpy as np

    def sample(n, seed=0):
        t0 = time.perf_counter()
        rng = np.random.default_rng(seed)
        return time.perf_counter() - t0, rng.random(n)
""")

TRACE_JIT_BAD = _src("""
    import jax

    from repro.obs.tracing import span

    @jax.jit
    def step(x):
        with span("kernel.step"):
            return x * 2
""")

TRACE_JIT_GOOD = _src("""
    import jax

    from repro.obs.tracing import span

    @jax.jit
    def _step(x):
        return x * 2

    def step(x):
        with span("kernel.step"):
            return _step(x)
""")

TRACE_JIT_BAD_METRIC = _src("""
    import jax

    from repro.obs.metrics import default_registry

    @jax.jit
    def step(x):
        default_registry.counter("steps").inc()
        return x * 2
""")

FIXTURES = [
    ("retrace-control", RETRACE_BAD_LOOP, RETRACE_GOOD),
    ("retrace-control", RETRACE_BAD_BRANCH, RETRACE_GOOD),
    ("dict-order-enumeration", DICT_ORDER_BAD, DICT_ORDER_GOOD),
    ("host-sync", HOST_SYNC_BAD, HOST_SYNC_GOOD),
    ("tracer-leak", TRACER_LEAK_BAD, TRACER_LEAK_GOOD),
    ("nondeterminism", NONDET_BAD, NONDET_GOOD),
    ("trace-in-jit", TRACE_JIT_BAD, TRACE_JIT_GOOD),
    ("trace-in-jit", TRACE_JIT_BAD_METRIC, TRACE_JIT_GOOD),
]


@pytest.mark.parametrize(("rule", "bad", "good"), FIXTURES,
                         ids=lambda v: v if isinstance(v, str) and "\n" not in v else "")
def test_rule_fires_on_bad_and_not_on_good(rule, bad, good):
    bad_hits = check_source(rule, bad)
    assert bad_hits, f"{rule} stayed quiet on its bad fixture"
    assert all(f.rule == rule for f in bad_hits)
    assert all(f.line > 0 and f.path.endswith(".py") for f in bad_hits)
    assert check_source(rule, good) == [], \
        f"{rule} false-positived on its good fixture"


def test_retrace_static_argnums_positional_mapping():
    src = _src("""
        import functools

        import jax

        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, mode):
            if mode:
                return x
            return -x
    """)
    assert check_source("retrace-control", src) == []


def test_host_sync_loop_context_in_message():
    hits = check_source("host-sync", HOST_SYNC_BAD)
    assert any("inside a loop" in f.message for f in hits)


# ---------------------------------------------------------------------------
# suppression semantics
# ---------------------------------------------------------------------------

def test_suppression_same_line_waives():
    src = HOST_SYNC_BAD.replace(
        "total += float(jnp.sum(x))",
        "total += float(jnp.sum(x))  # repro-lint: disable=host-sync -- probe readout")
    hits = check_source("host-sync", src)
    assert all("float" not in f.message for f in hits)


def test_suppression_own_line_covers_next_line():
    src = _src("""
        import jax.numpy as jnp

        def f(x):
            # repro-lint: disable=host-sync -- single cold readout
            return float(jnp.sum(x))
    """)
    assert check_source("host-sync", src) == []


def test_suppression_disable_file():
    src = ("# repro-lint: disable-file=host-sync -- timing harness module\n"
           + HOST_SYNC_BAD)
    assert check_source("host-sync", src) == []


def test_suppression_inside_string_literal_does_not_waive():
    src = _src("""
        import jax.numpy as jnp

        NOTE = "# repro-lint: disable-file=host-sync -- not a real comment"

        def f(x):
            return float(jnp.sum(x))
    """)
    assert check_source("host-sync", src), \
        "a suppression inside a string literal must not waive findings"


def test_parse_suppressions_reason_and_rules():
    src = "x = 1  # repro-lint: disable=host-sync,nondeterminism -- why not\n"
    (s,) = parse_suppressions(src, "src/repro/core/x.py")
    assert s.rules == ("host-sync", "nondeterminism")
    assert s.reason == "why not"
    assert s.scope == "line" and not s.own_line


# ---------------------------------------------------------------------------
# engine registry
# ---------------------------------------------------------------------------

def test_register_rule_rejects_bad_ids():
    with pytest.raises(ValueError, match="kebab-case"):
        register_rule("Bad_Id")(lambda ctx: [])
    with pytest.raises(ValueError, match="scope"):
        register_rule("fine-id", scope="galaxy")(lambda ctx: [])


def test_registered_rules_sorted_and_documented():
    rules = registered_rules()
    assert list(rules) == sorted(rules)
    expected = {"retrace-control", "dict-order-enumeration", "host-sync",
                "tracer-leak", "nondeterminism", "schema-manifest",
                "byte-terms-arity", "registry-docs", "import-orphans"}
    assert expected <= set(rules)
    for name in expected:
        assert rules[name].description and rules[name].rationale, name
    table = rule_table()
    for name in expected:
        assert f"docs/static-analysis.md#{name}" in table


# ---------------------------------------------------------------------------
# cross-module invariants against scratch repo copies
# ---------------------------------------------------------------------------

PERSIST_REL = "src/repro/engine/persist.py"
MANIFEST_REL = "src/repro/analysis/schema_manifest.json"


def _scratch_schema_repo(tmp_path):
    """Minimal repo copy: the live persist.py + pinned manifest."""
    for rel in (PERSIST_REL, MANIFEST_REL):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / rel, dst)
    return tmp_path


def test_schema_manifest_clean_on_live_copy(tmp_path):
    root = _scratch_schema_repo(tmp_path)
    res = run_analysis(root, rules=["schema-manifest"])
    assert res.ok, [f.render() for f in res.findings]


def test_schema_field_change_without_version_bump_fails(tmp_path):
    root = _scratch_schema_repo(tmp_path)
    p = root / PERSIST_REL
    src = p.read_text()
    assert "    rank: int\n" in src
    p.write_text(src.replace("    rank: int\n",
                             "    rank: int\n    layout: str\n", 1))
    res = run_analysis(root, rules=["schema-manifest"])
    assert not res.ok
    (f,) = res.findings
    assert f.rule == "schema-manifest" and f.path == PERSIST_REL
    assert "WorkloadKey" in f.message and "bump" in f.message


def test_schema_bump_plus_regen_is_clean(tmp_path):
    root = _scratch_schema_repo(tmp_path)
    p = root / PERSIST_REL
    src = p.read_text()
    src = src.replace("    rank: int\n", "    rank: int\n    layout: str\n", 1)
    src = src.replace("_SCHEMA_VERSION = 5", "_SCHEMA_VERSION = 6", 1)
    p.write_text(src)
    # bumped but manifest still pins v5 → finding points at the manifest
    res = run_analysis(root, rules=["schema-manifest"])
    assert not res.ok
    assert all(f.path == MANIFEST_REL for f in res.findings)
    assert any("regenerate" in f.message.lower() for f in res.findings)
    # the documented workflow: --regen-manifest → clean
    manifest = regen_manifest(root)
    assert manifest["schema_version"] == 6
    assert any(f.startswith("layout:") for f in manifest["classes"]["WorkloadKey"])
    res = run_analysis(root, rules=["schema-manifest"])
    assert res.ok, [f.render() for f in res.findings]


def test_extract_schema_static_fingerprint():
    schema = extract_schema((REPO / PERSIST_REL).read_text())
    pinned = json.loads((REPO / MANIFEST_REL).read_text())
    assert schema == pinned, \
        "live persist.py drifted from the pinned manifest — run --regen-manifest"
    assert set(schema["classes"]) == {"WorkloadKey", "StoredEntry", "Observation"}


def test_byte_terms_arity_drift_fails(tmp_path):
    for rel in ("src/repro/engine/costmodel.py", "src/repro/engine/calibrate.py"):
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / rel, dst)
    assert run_analysis(tmp_path, rules=["byte-terms-arity"]).ok
    cal = tmp_path / "src/repro/engine/calibrate.py"
    src = cal.read_text()
    assert "5 + len(" in src
    cal.write_text(src.replace("5 + len(", "6 + len(", 1))
    res = run_analysis(tmp_path, rules=["byte-terms-arity"])
    assert not res.ok
    assert any("6" in f.message and "5" in f.message for f in res.findings)


# ---------------------------------------------------------------------------
# strict-mode suppression hygiene
# ---------------------------------------------------------------------------

def _scratch_file_repo(tmp_path, source):
    dst = tmp_path / "src/repro/core/snippet.py"
    dst.parent.mkdir(parents=True, exist_ok=True)
    dst.write_text(source)
    return tmp_path


def test_strict_flags_unknown_suppression_id(tmp_path):
    root = _scratch_file_repo(
        tmp_path, "x = 1  # repro-lint: disable=host-snyc -- typo'd id\n")
    res = run_analysis(root, rules=["host-sync"], strict=True)
    assert {f.rule for f in res.findings} >= {"unknown-suppression"}
    # non-strict stays quiet: the hygiene checks are the CI gate's extra
    assert run_analysis(root, rules=["host-sync"], strict=False).ok


def test_strict_flags_missing_reason_and_unused(tmp_path):
    root = _scratch_file_repo(
        tmp_path,
        "import jax.numpy as jnp\n"
        "y = float(jnp.zeros(()))  # repro-lint: disable=host-sync\n"
        "z = 1  # repro-lint: disable=host-sync -- nothing to waive here\n")
    res = run_analysis(root, rules=["host-sync"], strict=True)
    rules = {f.rule for f in res.findings}
    assert "suppression-missing-reason" in rules
    assert "unused-suppression" in rules
    # the reasoned-but-unused one is also reported structurally
    assert len(res.unused_suppressions) == 1


# ---------------------------------------------------------------------------
# the live-tree gate + CLI
# ---------------------------------------------------------------------------

def test_live_tree_is_clean_strict():
    """The PR's acceptance gate: zero non-suppressed findings over the real
    tree, with every suppression carrying a reason and matching a finding."""
    res = run_analysis(REPO, strict=True)
    assert res.ok, "\n" + "\n".join(f.render() for f in res.findings)
    assert all(f.reason for f in res.suppressed), \
        "every live suppression must carry a reason string"


def test_cli_strict_json_exit_codes(capsys):
    rc = cli_main(["--root", str(REPO), "--strict", "--json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert report["counts"]["active"] == 0
    assert report["strict"] is True
    assert report["counts"]["suppressed"] == len(report["suppressed"])


def test_cli_rejects_unknown_rule_and_root(capsys, tmp_path):
    assert cli_main(["--root", str(REPO), "--rules", "no-such-rule"]) == 2
    assert cli_main(["--root", str(tmp_path)]) == 2


def test_cli_list_rules(capsys):
    assert cli_main(["--root", str(REPO), "--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in ("host-sync", "schema-manifest", "import-orphans"):
        assert name in out
