"""Optimizer, gradient compression, data-pipeline, and lock-free mask tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container — deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.lockfree import wave_collision_mask
from repro.data import SyntheticBatches, SyntheticTokens, host_shard_slice
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.cp_compress import compress_grad, cp_compress_state


def _toy_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(64, 1024)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(1024,)).astype(np.float32)),
    }


@pytest.mark.parametrize("use_8bit", [False, True])
def test_adamw_reduces_quadratic_loss(use_8bit):
    cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, grad_clip=1e9,
                      use_8bit=use_8bit)
    params = _toy_params()
    opt = adamw_init(params, cfg)
    def loss_fn(p):
        return sum(jnp.sum(a ** 2) for a in jax.tree.leaves(p))
    l0 = float(loss_fn(params))
    for _ in range(30):
        grads = jax.grad(loss_fn)(params)
        params, opt = adamw_update(grads, opt, params, cfg)
    assert float(loss_fn(params)) < 0.5 * l0


def test_8bit_states_really_int8():
    cfg = AdamWConfig(use_8bit=True)
    params = _toy_params()
    opt = adamw_init(params, cfg)
    grads = jax.tree.map(jnp.ones_like, params)
    params, opt = adamw_update(grads, opt, params, cfg)
    assert opt["m"]["w"]["q"].dtype == jnp.int8
    assert opt["v"]["w"]["q"].dtype == jnp.int8
    # q keeps the param's (padded) shape → sharding-aligned
    assert opt["m"]["w"]["q"].shape[0] == 64


def test_cp_compression_exact_on_lowrank_grad():
    """One ALS sweep recovers a gradient whose true rank ≤ compression rank
    (the CP-ALS ≡ PowerSGD equivalence), modulo error feedback warmup."""
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(128, 4)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    g = a @ b.T  # exactly rank 4
    state = {"err": jnp.zeros_like(g),
             "q": jax.random.normal(jax.random.key(0), (64, 8))}
    for _ in range(3):  # a couple of sweeps to align the subspace
        cg, state = compress_grad(g, state, axis_name=None)
    rel = float(jnp.linalg.norm(cg - g) / jnp.linalg.norm(g))
    assert rel < 1e-3, rel


def test_cp_compression_error_feedback_converges():
    """Compressed-gradient descent still reaches the optimum (error feedback
    re-injects what each rank-8 sweep missed)."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    w = jnp.zeros_like(target)
    state = {"err": jnp.zeros_like(w),
             "q": jax.random.normal(jax.random.key(0), (64, 8))}
    rels = []
    for _ in range(150):
        g = w - target
        cg, state = compress_grad(g, state, axis_name=None)
        w = w - 1.0 * cg
        rels.append(float(jnp.linalg.norm(w - target)
                          / jnp.linalg.norm(target)))
    assert rels[-1] < 0.10, rels[::30]
    assert rels[-1] < rels[10]


def test_cp_compression_ratio():
    g = jnp.ones((512, 256))
    state = cp_compress_state({"w": g}, rank=4)["w"]
    # wire cost would be rank*(512+256) vs 512*256
    assert 4 * (512 + 256) < g.size / 10


@settings(max_examples=20, deadline=None)
@given(p=st.integers(4, 60), t=st.integers(1, 5), g=st.sampled_from([4, 16]),
       seed=st.integers(0, 1000))
def test_lockfree_mask_properties(p, t, g, seed):
    rng = np.random.default_rng(seed)
    rows = jnp.asarray(rng.integers(0, 5, size=(t, p)).astype(np.int32))
    nnz = jnp.asarray(rng.integers(0, p + 1, size=(t,)).astype(np.int32))
    mask = np.asarray(wave_collision_mask(rows, nnz, n_tasklets=g))
    assert mask.shape == (t, p)
    # waves are strided: tasklet j owns the contiguous block [j·B, (j+1)·B),
    # B = padded_P/G; at time t the writers are entries {j·B + t}.  Among
    # valid same-row writers in a wave, exactly the last tasklet survives.
    pp = p + ((-p) % g)
    b = pp // g
    for ti in range(t):
        for w0 in range(b):
            idxs = [j * b + w0 for j in range(g)
                    if j * b + w0 < int(nnz[ti]) and j * b + w0 < p]
            seen = {}
            for i in idxs:
                seen.setdefault(int(rows[ti, i]), []).append(i)
            for _row, ii in seen.items():
                for i in ii[:-1]:
                    assert mask[ti, i] == 0.0
                assert mask[ti, ii[-1]] == 1.0


def test_data_pipeline_deterministic_and_shardable():
    ds = SyntheticTokens(vocab=100, seq_len=32, global_batch=8, seed=1)
    full = ds.batch(step=3)
    again = ds.batch(step=3)
    np.testing.assert_array_equal(full, again)
    # any host can recompute any shard
    parts = [ds.batch(step=3, shard=host_shard_slice(8, 4, h))
             for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
    other = ds.batch(step=4)
    assert not np.array_equal(other, full)


def test_arch_batches_match_model_inputs():
    from repro.configs import get_smoke_config
    for arch in ["whisper_medium", "internvl2_1b", "gemma3_4b"]:
        cfg = get_smoke_config(arch)
        b = SyntheticBatches(cfg, seq_len=32, global_batch=4).batch(0)
        if cfg.encoder_decoder:
            assert "frames" in b
            assert b["frames"].shape[0] == 4
        if cfg.n_image_tokens:
            assert b["image_embeds"].shape[1] == cfg.n_image_tokens
        assert b["tokens"].dtype == np.int32
