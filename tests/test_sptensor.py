"""Sparse-tensor container bugfix batch: `random_tensor` tops up the
post-dedup collision shortfall so the requested nnz is met exactly, and
`SparseTensor.permuted` rejects anything that is not a permutation of
`arange(nnz)` instead of silently dropping/duplicating nonzeros."""
import numpy as np
import pytest

from repro.core import random_tensor, table1_tensor
from repro.core.sptensor import TABLE1, SparseTensor


# ---------------------------------------------------------------------------
# random_tensor: exact nnz after dedup top-up
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(TABLE1))
def test_table1_tensor_has_exactly_requested_nnz(name):
    """Regression: powerlaw tensors used to lose up to ~10% of the requested
    nonzeros to duplicate-coordinate merging."""
    st = table1_tensor(name)
    assert st.nnz == TABLE1[name]["nnz"], (name, st.nnz)
    # coordinates stay canonical (unique) after the top-up
    assert np.unique(st.coords, axis=0).shape[0] == st.nnz


@pytest.mark.parametrize("dist", ["uniform", "powerlaw"])
def test_random_tensor_exact_nnz_small_dims(dist):
    # small dims force heavy collisions — the old behavior lost most of them
    st = random_tensor((8, 6, 10), 300, distribution=dist, seed=3)
    assert st.nnz == 300
    assert np.unique(st.coords, axis=0).shape[0] == 300


def test_random_tensor_nnz_caps_at_cell_count():
    st = random_tensor((3, 4), 1000, seed=0)
    assert st.nnz == 12            # the tensor is full, not overfull
    st0 = random_tensor((5, 5), 0, seed=0)
    assert st0.nnz == 0


def test_random_tensor_deterministic_per_seed():
    a = random_tensor((20, 16, 24), 500, seed=7, distribution="powerlaw")
    b = random_tensor((20, 16, 24), 500, seed=7, distribution="powerlaw")
    np.testing.assert_array_equal(a.coords, b.coords)
    np.testing.assert_array_equal(a.values, b.values)
    c = random_tensor((20, 16, 24), 500, seed=8, distribution="powerlaw")
    assert not np.array_equal(a.coords, c.coords)


def test_random_tensor_powerlaw_stays_imbalanced():
    """The top-up reuses the per-mode scatter permutations, so the hot rows
    of the first batch stay hot — the imbalanced character the partition
    decider is stress-tested with must survive."""
    st = random_tensor((2000, 1800, 2200), 30_000, distribution="powerlaw",
                       seed=1)
    assert st.nnz == 30_000
    counts = np.bincount(st.coords[:, 0], minlength=st.shape[0])
    top = np.sort(counts)[::-1][:20].sum()
    assert top > 0.2 * st.nnz      # a Zipf head, nothing like uniform


# ---------------------------------------------------------------------------
# SparseTensor.permuted: order validation
# ---------------------------------------------------------------------------

def _tensor():
    return random_tensor((10, 8, 12), 60, seed=5)


def test_permuted_accepts_real_permutation():
    st = _tensor()
    order = np.random.default_rng(0).permutation(st.nnz)
    pt = st.permuted(order)
    assert pt.nnz == st.nnz
    np.testing.assert_array_equal(pt.coords, st.coords[order])
    np.testing.assert_array_equal(pt.to_dense(), st.to_dense())


@pytest.mark.parametrize(("bad", "why"), [
    (np.arange(59), "wrong length (short)"),
    (np.arange(61), "wrong length (long)"),
    (np.zeros(60, dtype=np.int64), "repeated index"),
    (np.arange(60, dtype=np.float64), "float dtype"),
    (np.arange(1, 61), "out of range"),
    (np.concatenate([[-1], np.arange(1, 60)]), "negative index"),
    (np.ones(60, dtype=bool), "boolean mask"),
])
def test_permuted_rejects_non_permutations(bad, why):
    st = _tensor()
    assert st.nnz == 60
    with pytest.raises(ValueError, match="permutation"):
        st.permuted(bad)
