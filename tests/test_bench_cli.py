"""benchmarks.run CLI contract: an unknown --only suite name must abort
with a non-zero exit listing the valid names — never silently run the
recognizable subset and exit 0."""
import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(only: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--fast", "--only", only],
        capture_output=True, text=True, cwd=ROOT, env=env, timeout=600)


def test_unknown_suite_name_aborts_nonzero():
    out = _run("fig6,fig8")   # "fig8" is a typo for "fig8_9"
    assert out.returncode == 2, (out.returncode, out.stderr[-2000:])
    assert "fig8" in out.stderr
    assert "fig8_9" in out.stderr          # the valid names are listed
    assert "benchmarks.fig6" not in out.stdout   # nothing ran


def test_empty_token_aborts_nonzero():
    out = _run("fig6,")       # stray trailing comma
    assert out.returncode == 2, (out.returncode, out.stderr[-2000:])
    assert "valid names" in out.stderr
