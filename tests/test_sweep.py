"""Offline design-space sweep harness: config parsing + validation (incl.
the TOML-subset fallback parser), fingerprint-native resumability (zero
re-probes after a restart, kill-mid-grid equivalence), the Pareto report's
required axes, and the capacity-axis fingerprint distinctness the schema-v5
workload key exists for."""
import os
import threading
import time

import pytest

from repro.core import random_tensor
from repro.engine import TuningStore, WorkloadKey
from repro.engine import autotune as _autotune
from repro.sweep import (
    SweepConfig,
    SweepConfigError,
    TensorBand,
    cell_key,
    load_config,
    pareto_front,
    pareto_report,
    run_sweep,
)
from repro.sweep.config import _toml_subset_loads

ROOT = os.path.join(os.path.dirname(__file__), "..")
CI_GRID = os.path.join(ROOT, "benchmarks", "sweep_ci.toml")

#: Cheap lossless candidates — sweep tests exercise the harness, not the
#: backends.
CANDS = ("chunked", "ref")


def _band(**over):
    base = dict(name="u", shape=(12, 10, 8), nnz=(150, 200),
                distribution="uniform", seed=0)
    base.update(over)
    return TensorBand(**base)


def _config(**over):
    base = dict(name="t", tensors=(_band(),), ranks=(3,), candidates=CANDS,
                capacities=(None,), mem_bytes=64 * 1024, warmup=0, reps=1)
    base.update(over)
    return SweepConfig(**base)


def _fake_timer(monkeypatch, calls=None):
    """Deterministic per-(candidate, mode) probe timings through the
    `_time_backend` seam: restarted / re-ordered sweeps must reproduce the
    exact same stored numbers, which is what makes Pareto-set equality
    across interruption meaningful (and the tests fast)."""
    def fake(name, engine, factors, mode, *, warmup, reps):
        if calls is not None:
            calls.append((name, mode))
        return 1e-3 * (1 + sum(map(ord, name)) % 7) + 2e-4 * mode
    monkeypatch.setattr(_autotune, "_time_backend", fake)


# ---------------------------------------------------------------------------
# Config schema + TOML-subset parser
# ---------------------------------------------------------------------------

def test_config_validation_rejects_unusable_grids():
    with pytest.raises(SweepConfigError, match="no tensor bands"):
        _config(tensors=())
    with pytest.raises(SweepConfigError, match="ranks must be positive"):
        _config(ranks=(0,))
    with pytest.raises(SweepConfigError, match="bad candidate id"):
        _config(candidates=("no_such_backend",))
    with pytest.raises(SweepConfigError, match="accuracy_budget"):
        _config(candidates=("ref", "fixed:int7"))  # lossy without a budget
    with pytest.raises(SweepConfigError, match="capacity"):
        _config(capacities=(-3,))
    with pytest.raises(SweepConfigError, match="distribution"):
        _band(distribution="gaussian")
    with pytest.raises(SweepConfigError, match="nnz band must be positive"):
        _band(nnz=())


def test_from_dict_maps_sentinels_and_scalars():
    cfg = SweepConfig.from_dict({"sweep": {
        "name": "d",
        "ranks": [4],
        "capacities": [0, 32],         # TOML has no null: 0 → decider
        "candidates": ["ref"],
        "tensors": [{"name": "b", "shape": [8, 6, 4], "nnz": 50}],
    }})
    assert cfg.capacities == (None, 32)
    assert cfg.tensors[0].nnz == (50,)   # scalar nnz becomes a 1-band
    assert [c.label for c in cfg.cells()] == [
        "b/nnz=50/rank=4/cap=auto", "b/nnz=50/rank=4/cap=32"]


def test_toml_subset_parser_covers_the_schema():
    parsed = _toml_subset_loads(
        '# header comment\n'
        '[sweep]\n'
        'name = "g"  # trailing comment\n'
        'ranks = [4, 8]\n'
        'accuracy_budget = 0.2\n'
        'flag = true\n'
        'candidates = ["ref", "fixed:int7"]\n'
        '\n'
        '[[sweep.tensors]]\n'
        'name = "a"\n'
        'shape = [8, 6, 4]\n'
        'nnz = 50\n'
        '[[sweep.tensors]]\n'
        'name = "b # not a comment"\n'
        'shape = [10, 10, 10]\n'
        'nnz = [60, 70]\n')
    assert parsed["sweep"]["name"] == "g"
    assert parsed["sweep"]["ranks"] == [4, 8]
    assert parsed["sweep"]["accuracy_budget"] == 0.2
    assert parsed["sweep"]["flag"] is True
    assert parsed["sweep"]["candidates"] == ["ref", "fixed:int7"]
    assert [t["name"] for t in parsed["sweep"]["tensors"]] == [
        "a", "b # not a comment"]
    assert parsed["sweep"]["tensors"][1]["nnz"] == [60, 70]
    with pytest.raises(SweepConfigError, match="unsupported value"):
        _toml_subset_loads("x = 1979-05-27\n")
    with pytest.raises(SweepConfigError, match="key = value"):
        _toml_subset_loads("just words\n")


def test_shipped_ci_grid_loads_and_enumerates():
    """The pruned grid CI actually runs must stay parseable by the subset
    parser (not just tomllib) and declare a budget for its lossy row."""
    cfg = load_config(CI_GRID)
    assert cfg.name == "ci-pruned"
    assert len(cfg.cells()) == 6
    assert "fixed:int7" in cfg.candidates
    assert cfg.accuracy_budget == 0.2
    assert cfg.capacities == (None, 64)
    with open(CI_GRID, encoding="utf-8") as f:
        subset = SweepConfig.from_dict(_toml_subset_loads(f.read()))
    assert subset == cfg or subset.cells() == cfg.cells()


def test_toml_subset_agrees_with_tomllib_when_available():
    tomllib = pytest.importorskip("tomllib")
    with open(CI_GRID, "rb") as f:
        reference = tomllib.load(f)
    with open(CI_GRID, encoding="utf-8") as f:
        assert _toml_subset_loads(f.read()) == reference


# ---------------------------------------------------------------------------
# Fingerprint-native resumability
# ---------------------------------------------------------------------------

def test_cell_key_matches_live_autotune_fingerprint():
    """`cell_key` computes the workload fingerprint WITHOUT building the
    tensor; it must stay field-for-field identical to what the autotuner
    fingerprints after the build, or resume silently re-probes forever."""
    cfg = _config(capacities=(16,))
    cell = cfg.cells()[0]
    st = random_tensor(cell.band.shape, cell.nnz,
                       distribution=cell.band.distribution,
                       seed=cell.band.seed)
    live = WorkloadKey.from_tensor(st, cell.rank, cfg.candidates,
                                   capacity=cell.capacity)
    assert cell_key(cell, cfg) == live


def test_sweep_resumes_with_zero_probes(tmp_path, monkeypatch):
    """Acceptance: the same sweep twice against one store — the second run
    performs zero probes and reports every cell complete.  The nnz band
    (150 vs 200) sits outside no near-match window only because the sweep
    store runs nnz_tol=0."""
    calls = []
    _fake_timer(monkeypatch, calls)
    cfg = _config()
    store = TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)
    first = run_sweep(cfg, store)
    assert first.count("measured") == 2
    assert first.n_probes == len(calls)
    assert first.n_probes > 0

    calls.clear()
    second = run_sweep(cfg, store)
    assert calls == []
    assert second.n_probes == 0
    assert second.count("complete") == 2
    assert len(TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)) == 2
    # and the winners the resume path reports match what was measured
    assert ([o.winners for o in second.outcomes]
            == [o.winners for o in first.outcomes])


def test_adjacent_nnz_band_cells_stay_distinct(tmp_path, monkeypatch):
    """Cells 150 and 160 nnz apart sit inside the default ±10% near-match
    window; the sweep store's nnz_tol=0 must keep both as separate entries
    instead of letting them warm-serve / supersede each other."""
    _fake_timer(monkeypatch)
    cfg = _config(tensors=(_band(nnz=(150, 160)),))
    store = TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)
    result = run_sweep(cfg, store)
    assert result.count("measured") == 2
    assert len(store) == 2
    again = run_sweep(cfg, store)
    assert again.n_probes == 0
    assert again.count("complete") == 2


def test_sweep_rejects_near_match_store(tmp_path):
    with pytest.raises(ValueError, match="nnz_tol=0"):
        run_sweep(_config(), TuningStore(tmp_path / "s.json"))  # default 0.1


def test_interrupted_sweep_restart_skips_completed_cells_and_matches_pareto(
        tmp_path, monkeypatch):
    """Satellite acceptance: kill a sweep mid-grid, restart against the
    same store — zero re-probes of completed cells, and the final Pareto
    set is identical to an uninterrupted sweep's."""
    cfg = _config(ranks=(3, 4))   # 2 nnz × 2 ranks = 4 cells
    n_cells = len(cfg.cells())

    calls = []
    _fake_timer(monkeypatch, calls)
    oneshot_store = TuningStore(tmp_path / "oneshot.json", nnz_tol=0.0)
    oneshot = run_sweep(cfg, oneshot_store)
    assert oneshot.count("measured") == n_cells
    probes_full = len(calls)

    # "Kill" after 2 cells: max_cells defers the rest of the grid.
    calls.clear()
    store = TuningStore(tmp_path / "interrupted.json", nnz_tol=0.0)
    partial = run_sweep(cfg, store, max_cells=2)
    assert partial.count("measured") == 2
    assert partial.count("deferred") == n_cells - 2
    probes_before_kill = len(calls)

    # Restart: completed cells skip without a single probe.
    calls.clear()
    resumed = run_sweep(cfg, store)
    assert resumed.count("complete") == 2
    assert resumed.count("measured") == n_cells - 2
    assert all(c[1] is not None for c in calls)  # sanity: (name, mode) rows
    assert len(calls) == probes_full - probes_before_kill

    # Identical final Pareto set (deterministic timings make this exact).
    def front_view(s):
        return {(p["cell"], p["candidate"], p["time_s"], p["index_bytes"])
                for p in pareto_report(s)["front"]}
    assert front_view(store) == front_view(oneshot_store)


def test_no_resume_forgets_and_remeasures(tmp_path, monkeypatch):
    calls = []
    _fake_timer(monkeypatch, calls)
    cfg = _config(tensors=(_band(nnz=(150,)),))
    store = TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)
    run_sweep(cfg, store)
    calls.clear()
    redo = run_sweep(cfg, store, resume=False)
    assert redo.count("measured") == 1
    assert len(calls) > 0
    assert len(store) == 1        # overwrote, not duplicated


def test_capacity_axis_fingerprints_distinctly(tmp_path, monkeypatch):
    """Schema v5's reason to exist: an explicit-capacity cell and the
    decider-default cell are different workloads and must coexist in the
    store instead of warm-serving each other."""
    _fake_timer(monkeypatch)
    cfg = _config(tensors=(_band(nnz=(150,)),), capacities=(None, 16))
    store = TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)
    result = run_sweep(cfg, store)
    assert result.count("measured") == 2
    assert len(store) == 2
    caps = sorted((e.key.capacity for e in store.entries()),
                  key=lambda c: (c is not None, c))
    assert caps == [None, 16]
    # each cell resumes from its own entry
    again = run_sweep(cfg, store)
    assert again.n_probes == 0
    assert again.count("complete") == 2


# ---------------------------------------------------------------------------
# Pareto report
# ---------------------------------------------------------------------------

def test_report_points_carry_all_required_axes(tmp_path, monkeypatch):
    """Acceptance: every report point carries (time, rel-error, index
    bytes, peak-fraction)."""
    _fake_timer(monkeypatch)
    cfg = _config()
    store = TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)
    run_sweep(cfg, store)
    rep = pareto_report(store)
    assert rep["n_entries"] == 2
    assert rep["n_points"] == 2 * len(CANDS)
    assert rep["n_pareto"] >= 2          # at least one efficient point/cell
    for p in rep["points"]:
        assert p["time_s"] > 0
        assert p["rel_error"] == 0.0     # lossless candidates only
        assert p["index_bytes"] > 0
        assert 0 < p["peak_fraction"]
        assert p["roofline_dominant"] in ("compute_s", "memory_s",
                                          "collective_s")
        assert isinstance(p["pareto"], bool)
    assert {p["cell"] for p in rep["front"]} == {p["cell"]
                                                 for p in rep["points"]}


def test_pareto_front_marks_dominance_per_cell():
    mk = {"rel_error": 0.0, "index_bytes": 100.0}
    points = [
        {"cell": "a", "candidate": "x", "time_s": 1.0, **mk},
        {"cell": "a", "candidate": "y", "time_s": 2.0, **mk},   # dominated
        {"cell": "a", "candidate": "z", "time_s": 2.0,
         "rel_error": 0.0, "index_bytes": 50.0},                # trades off
        # same timings in another cell must not cross-dominate
        {"cell": "b", "candidate": "y", "time_s": 2.0, **mk},
    ]
    front = pareto_front(points)
    assert {(p["cell"], p["candidate"]) for p in front} == {
        ("a", "x"), ("a", "z"), ("b", "y")}
    assert [p["pareto"] for p in points] == [True, False, True, True]


# ---------------------------------------------------------------------------
# Concurrent sweep workers share one store
# ---------------------------------------------------------------------------

def test_parallel_sweep_workers_drop_no_cells(tmp_path, monkeypatch):
    """Two workers splitting one grid into one shared store: every cell's
    entry must survive (save() serializes read-merge-write under the
    advisory lock; see test_autotune_persist for the raw two-writer
    race)."""
    _fake_timer(monkeypatch)
    cfg_a = _config(tensors=(_band(nnz=(150,)),))
    cfg_b = _config(tensors=(_band(nnz=(200,)),))
    path = tmp_path / "shared.json"
    results = {}

    def worker(tag, cfg):
        results[tag] = run_sweep(cfg, TuningStore(path, nnz_tol=0.0))

    threads = [threading.Thread(target=worker, args=(t, c))
               for t, c in (("a", cfg_a), ("b", cfg_b))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert results["a"].count("failed") == 0
    assert results["b"].count("failed") == 0
    merged = TuningStore(path, nnz_tol=0.0)
    assert len(merged) == 2
    # a third run over the union grid is fully warm
    union = _config(tensors=(_band(nnz=(150, 200)),))
    again = run_sweep(union, merged)
    assert again.n_probes == 0
    assert again.count("complete") == 2


def test_failed_cell_does_not_take_down_the_grid(tmp_path, monkeypatch):
    def exploding(name, engine, factors, mode, *, warmup, reps):
        raise RuntimeError("probe rig on fire")
    monkeypatch.setattr(_autotune, "_time_backend", exploding)
    cfg = _config(tensors=(_band(nnz=(150,)),))
    store = TuningStore(tmp_path / "sweep.json", nnz_tol=0.0)
    result = run_sweep(cfg, store)
    assert result.count("failed") == 1
    assert result.outcomes[0].error is not None
    assert len(store) == 0
