"""repro.serve: coalescing, result correctness under concurrency, warm
zero-probe dispatch through the service, and failure isolation."""
import threading

import numpy as np
import pytest

from repro.core import SparseTensor
from repro.engine import TunePolicy
from repro.serve import DecomposeService

RANK = 4


def small(shape, nnz, seed=0):
    rng = np.random.default_rng(seed)
    coords = np.stack([rng.integers(0, d, size=nnz) for d in shape],
                      axis=1).astype(np.int32)
    values = rng.uniform(-1, 1, size=nnz).astype(np.float32)
    return SparseTensor(coords, values, tuple(shape))


def test_submit_returns_correct_shapes_and_order():
    tensors = [small((10, 9, 8), 40 + i, seed=i) for i in range(6)]
    with DecomposeService(RANK, n_iters=2, max_batch=4,
                          max_wait_ms=20.0) as svc:
        futs = [svc.submit(t) for t in tensors]
        results = [f.result(timeout=300) for f in futs]
    for t, r in zip(tensors, results, strict=True):
        assert [f.shape for f in r.factors] == [(d, RANK) for d in t.shape]
        assert len(r.fit_history) == 2


def test_coalescing_batches_requests():
    tensors = [small((8, 8, 8), 40, seed=i) for i in range(8)]
    with DecomposeService(RANK, n_iters=1, max_batch=8,
                          max_wait_ms=200.0) as svc:
        futs = [svc.submit(t) for t in tensors]
        [f.result(timeout=300) for f in futs]
        stats = svc.stats()
    # 200ms linger with instant submissions: far fewer batches than requests
    assert stats.n_requests == 8
    assert stats.n_batches < 8
    assert stats.max_batch_seen > 1
    assert stats.n_completed == 8


def test_warm_store_means_zero_probes_across_services(tmp_path):
    store = str(tmp_path / "serve-store.json")
    tensors = [small((10, 9, 8), 40, seed=i) for i in range(3)]
    with DecomposeService(RANK, n_iters=1, tune=TunePolicy(store=store),
                          max_batch=4, max_wait_ms=50.0) as svc:
        [svc.decompose(t, timeout=300) for t in tensors]
        assert svc.stats().n_probes > 0  # cold: the bucket probed once
    with DecomposeService(RANK, n_iters=1, tune=TunePolicy(store=store),
                          max_batch=4, max_wait_ms=50.0) as svc2:
        [svc2.decompose(t, timeout=300) for t in tensors]
        stats = svc2.stats()
    assert stats.n_probes == 0
    assert stats.n_bucket_decisions.get("persisted", 0) >= 1


def test_concurrent_clients_all_complete():
    tensors = [small((10, 9, 8), 40 + i, seed=i) for i in range(12)]
    results = [None] * len(tensors)
    with DecomposeService(RANK, n_iters=1, max_batch=6,
                          max_wait_ms=20.0) as svc:
        def client(idxs):
            for i in idxs:
                results[i] = svc.decompose(tensors[i], timeout=300)
        threads = [threading.Thread(target=client, args=(range(c, 12, 3),))
                   for c in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for t, r in zip(tensors, results, strict=True):
        assert r is not None
        assert [f.shape[0] for f in r.factors] == list(t.shape)


def test_batch_failure_fails_every_future_in_it():
    # A float64 member makes its whole coalesced batch invalid (mixed
    # dtypes): both futures must carry the TypeError, and the service must
    # keep serving afterwards.
    good = small((8, 8), 20, seed=1)
    rng = np.random.default_rng(2)
    coords = np.stack([rng.integers(0, 8, size=20) for _ in range(2)],
                      axis=1).astype(np.int32)
    bad = SparseTensor(coords, rng.uniform(-1, 1, 20), (8, 8))  # f64 values
    with DecomposeService(RANK, n_iters=1, max_batch=2,
                          max_wait_ms=500.0) as svc:
        f1, f2 = svc.submit(good), svc.submit(bad)
        with pytest.raises(TypeError, match="mixed value dtypes"):
            f1.result(timeout=300)
        with pytest.raises(TypeError, match="mixed value dtypes"):
            f2.result(timeout=300)
        assert svc.stats().n_failed == 2
        # service still alive
        res = svc.decompose(small((8, 8), 20, seed=3), timeout=300)
        assert res.factors[0].shape == (8, RANK)


def test_closed_service_rejects_and_non_tensor_rejected():
    svc = DecomposeService(RANK, n_iters=1, max_wait_ms=1.0)
    with pytest.raises(TypeError, match="SparseTensor"):
        svc.submit("nope")
    svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit(small((4, 4), 5))
    svc.close()  # idempotent


def test_constructor_validation():
    with pytest.raises(ValueError, match="max_batch"):
        DecomposeService(RANK, max_batch=0)
    with pytest.raises(ValueError, match="max_wait_ms"):
        DecomposeService(RANK, max_wait_ms=-1.0)


def test_stats_reports_latency_percentiles():
    tensors = [small((6, 5, 4), 20, seed=i) for i in range(5)]
    with DecomposeService(RANK, n_iters=1, max_batch=4,
                          max_wait_ms=10.0) as svc:
        assert svc.stats().request_ms == {}  # empty before any dispatch
        futs = [svc.submit(t) for t in tensors]
        [f.result(timeout=300) for f in futs]
        stats = svc.stats()
    for field in (stats.queue_wait_ms, stats.dispatch_ms, stats.request_ms):
        assert set(field) == {"p50", "p99"}
        assert 0 <= field["p50"] <= field["p99"]
    # Queue wait is part of the request, so p99 request dominates p50 wait,
    # and the service-side histograms agree with the raw counters.
    assert stats.request_ms["p99"] >= stats.queue_wait_ms["p50"]
    snap = svc.metrics.snapshot()
    assert snap["serve.request_seconds"]["count"] == len(tensors)
    assert snap["serve.dispatch_seconds"]["count"] == stats.n_batches


def test_stats_snapshot_does_not_alias_service_state():
    tensors = [small((6, 5, 4), 20, seed=i) for i in range(3)]
    with DecomposeService(RANK, n_iters=1, max_batch=4,
                          max_wait_ms=10.0) as svc:
        futs = [svc.submit(t) for t in tensors]
        [f.result(timeout=300) for f in futs]
        before = svc.stats()
        assert before.n_bucket_decisions  # at least one decision recorded
        # Mutating every container on the snapshot must not leak back.
        before.n_bucket_decisions["measured"] = 10_000
        before.n_bucket_decisions["bogus"] = 1
        before.queue_wait_ms["p50"] = -1.0
        after = svc.stats()
    assert "bogus" not in after.n_bucket_decisions
    assert after.n_bucket_decisions.get("measured", 0) != 10_000
    assert after.queue_wait_ms["p50"] >= 0
    # Two snapshots never share containers either.
    assert after.n_bucket_decisions is not before.n_bucket_decisions
    assert after.queue_wait_ms is not before.queue_wait_ms
