"""Core spMTTKRP correctness: chunked == COO reference for every mode, every
engine, sweeping tensor shapes/orders; fixed point bit-exact vs Algorithm-2
oracle; baselines agree."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Q17_15, Q9_7, random_tensor, value_qformat
from repro.core.baselines import alto_order, mttkrp_alto, mttkrp_plain_coo
from repro.core.chunking import chunk_tensor
from repro.core.hetero import densify_tasks, mttkrp_hetero, split_tasks
from repro.core.mttkrp import (dequantize_output, mttkrp_chunked,
                               mttkrp_chunked_fixed, mttkrp_coo,
                               mttkrp_coo_fixed)

CASES = [
    ((40, 30, 50), 500, (16, 8, 16), 32),
    ((17, 23, 9), 300, (8, 8, 4), 16),          # non-divisible dims
    ((64, 64, 64, 16), 800, (16, 16, 16, 8), 64),  # mode-4
    ((12, 10, 8, 6, 14), 400, (4, 4, 4, 4, 8), 32),  # mode-5
]


def _factors(shape, rank, seed=2):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
                 for d in shape)


@pytest.mark.parametrize(("shape", "nnz", "cs", "cap"), CASES)
def test_chunked_matches_coo_all_modes(shape, nnz, cs, cap):
    st = random_tensor(shape, nnz, seed=1)
    rank = 8
    factors = _factors(shape, rank)
    ct = chunk_tensor(st, cs, capacity=cap)
    assert ct.nnz == st.nnz
    for mode in range(len(shape)):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords), jnp.asarray(st.values),
                         mode=mode, out_dim=shape[mode])
        out = mttkrp_chunked(factors, jnp.asarray(ct.task_chunk),
                             jnp.asarray(ct.coords_rel), jnp.asarray(ct.values),
                             mode=mode, chunk_shape=ct.chunk_shape,
                             out_dim=shape[mode])
        np.testing.assert_allclose(ref, out, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(("qf", "prec_shift"), [(Q9_7, 0), (Q17_15, 3)])
@pytest.mark.parametrize(("shape", "nnz", "cs", "cap"), CASES[:3])
def test_fixed_chunked_bit_exact(shape, nnz, cs, cap, qf, prec_shift):
    st = random_tensor(shape, nnz, seed=3)
    rank = 6
    factors = _factors(shape, rank, seed=4)
    vq = value_qformat(st.values)
    qfs = tuple(qf.quantize(f) for f in factors)
    ct = chunk_tensor(st, cs, capacity=cap)
    qvals = jnp.asarray(vq.quantize_np(ct.values))
    qcoo = jnp.asarray(vq.quantize_np(st.values))
    for mode in range(len(shape)):
        ref = mttkrp_coo_fixed(qfs, jnp.asarray(st.coords), qcoo, mode=mode,
                               out_dim=shape[mode], matrix_frac=qf.frac_bits,
                               value_frac=vq.frac_bits, prec_shift=prec_shift)
        out = mttkrp_chunked_fixed(qfs, jnp.asarray(ct.task_chunk),
                                   jnp.asarray(ct.coords_rel), qvals,
                                   mode=mode, chunk_shape=ct.chunk_shape,
                                   out_dim=shape[mode],
                                   matrix_frac=qf.frac_bits,
                                   value_frac=vq.frac_bits,
                                   prec_shift=prec_shift)
        assert bool(jnp.all(ref == out)), f"mode {mode} not bit-exact"


def test_fixed_approximates_float():
    st = random_tensor((40, 30, 50), 600, seed=5)
    factors = _factors(st.shape, 8, seed=6)
    vq = value_qformat(st.values)
    qfs = tuple(Q9_7.quantize(f) for f in factors)
    qcoo = jnp.asarray(vq.quantize_np(st.values))
    ref = mttkrp_coo(factors, jnp.asarray(st.coords), jnp.asarray(st.values),
                     mode=0, out_dim=40)
    qout = mttkrp_coo_fixed(qfs, jnp.asarray(st.coords), qcoo, mode=0,
                            out_dim=40, matrix_frac=7, value_frac=vq.frac_bits)
    out = dequantize_output(qout, 7, 0)
    # Q9.7 quantization noise per partial ~2^-7; sums stay close.
    err = np.max(np.abs(np.asarray(out) - np.asarray(ref)))
    assert err < 0.5, err


def test_baselines_match():
    st = random_tensor((30, 40, 20), 700, seed=7)
    factors = _factors(st.shape, 5, seed=8)
    order = alto_order(st.coords, st.shape)
    for mode in range(3):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=st.shape[mode])
        alto = mttkrp_alto(factors, jnp.asarray(st.coords[order]),
                           jnp.asarray(st.values[order]), mode=mode,
                           out_dim=st.shape[mode])
        plain = mttkrp_plain_coo(factors, jnp.asarray(st.coords),
                                 jnp.asarray(st.values), mode=mode,
                                 out_dim=st.shape[mode])
        np.testing.assert_allclose(ref, alto, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(ref, plain, rtol=1e-5, atol=1e-5)


def test_hetero_split_paths_match():
    st = random_tensor((24, 16, 24), 2500, seed=9)
    rank = 5
    factors = _factors(st.shape, rank, seed=10)
    ct = chunk_tensor(st, (8, 8, 8), capacity=512)
    for frac in (0.0, 0.5, 1.0):
        split = split_tasks(ct, rank, dense_fraction=frac)
        db = jnp.asarray(densify_tasks(ct, split.dense_idx))
        for mode in range(3):
            ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                             jnp.asarray(st.values), mode=mode,
                             out_dim=st.shape[mode])
            out = mttkrp_hetero(factors, ct, split, db, mode=mode,
                                out_dim=st.shape[mode])
            np.testing.assert_allclose(ref, out, rtol=1e-4, atol=1e-4)


def test_hetero_cost_model_split_is_valid():
    st = random_tensor((24, 16, 24), 2500, seed=11)
    ct = chunk_tensor(st, (8, 8, 8), capacity=64)
    split = split_tasks(ct, 8)
    all_idx = np.sort(np.concatenate([split.dense_idx, split.sparse_idx]))
    np.testing.assert_array_equal(all_idx, np.arange(ct.num_tasks))
