"""Per-architecture smoke tests: reduced same-family config, one forward loss
+ one decode step on CPU, asserting output shapes and no NaNs (the FULL
configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import LM, MeshCtx


@pytest.fixture(scope="module")
def ctx(trivial_mesh):
    return MeshCtx(mesh=trivial_mesh, dp=("data",), tp="model",
                   seq_sharded=False)


def _batch(cfg, b=2, s=32):
    if cfg.encoder_decoder:
        return {"frames": jnp.ones((b, s, cfg.d_model), jnp.float32) * 0.02,
                "tokens": jnp.ones((b, max(s // cfg.dec_ratio, 8)), jnp.int32)}
    if cfg.n_image_tokens:
        return {"tokens": jnp.ones((b, s - cfg.n_image_tokens), jnp.int32),
                "image_embeds": jnp.ones((b, cfg.n_image_tokens, cfg.d_model),
                                         jnp.float32) * 0.02}
    return {"tokens": jnp.ones((b, s), jnp.int32)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_loss(arch, ctx):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params, specs = lm.init(jax.random.key(0))
    loss = lm.loss(params, ctx, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch, ctx):
    from repro.launch.steps import make_train_step
    from repro.optim import AdamWConfig, adamw_init
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.key(0))
    opt_cfg = AdamWConfig(lr=5e-3)
    opt = adamw_init(params, opt_cfg)
    step = jax.jit(make_train_step(lm, ctx, opt_cfg))
    batch = _batch(cfg)
    losses = []
    for _ in range(4):
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0], f"{arch}: {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, ctx):
    cfg = get_smoke_config(arch)
    lm = LM(cfg)
    params, _ = lm.init(jax.random.key(0))
    b = 2
    cache = lm.init_cache(b, max_len=64,
                          enc_len=32 if cfg.encoder_decoder else 0)
    logits, cache = lm.decode_step(params, ctx, jnp.ones((b, 1), jnp.int32),
                                   cache, jnp.int32(3))
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch
    logits2, _ = lm.decode_step(params, ctx, jnp.ones((b, 1), jnp.int32),
                                cache, jnp.int32(4))
    assert bool(jnp.all(jnp.isfinite(logits2))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The exact published hyperparameters from the assignment block."""
    spec = {
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen3_14b": (40, 5120, 40, 8, 17408, 151936),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "command_r_35b": (40, 8192, 64, 8, 22528, 256000),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "xlstm_350m": (24, 1024, 4, 4, 0, 50304),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51865),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab)
    assert got == spec, (arch, got, spec)
    # MoE extras
    moe = {"llama4_scout_17b_a16e": (16, 1), "qwen3_moe_30b_a3b": (128, 8),
           "jamba_1_5_large_398b": (16, 2)}
    if arch in moe:
        assert (cfg.n_experts, cfg.top_k) == moe[arch]
