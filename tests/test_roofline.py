"""Roofline machinery unit tests: HLO collective parsing (the §Roofline
collective term's foundation) and the three-term model."""
import numpy as np

from repro.roofline import collective_bytes, parse_collectives, roofline_terms
from repro.roofline.model import V5E, model_flops

HLO = """
ENTRY %main {
  %ag = f32[128,256]{1,0} all-gather(%p0), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = bf16[64,64]{1,0} all-reduce(%p1), channel_id=2, replica_groups=[8,16]<=[128], to_apply=%sum
  %rs = f32[32]{0} reduce-scatter(%p2), channel_id=3, replica_groups={{0,1}}, dimensions={0}
  %a2a = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(%p3, %p4), channel_id=4, replica_groups={{0,1,2,3}}
  %cp = u8[1024]{0} collective-permute(%p5), channel_id=5, source_target_pairs={{0,1}}
  %ags = f32[8,8]{1,0} all-gather-start(%p6), channel_id=6, replica_groups={{0,1}}
  %agd = f32[8,8]{1,0} all-gather-done(%ags)
  ROOT %t = tuple()
}
"""


def test_parse_collectives_ops_and_groups():
    recs = parse_collectives(HLO)
    ops = [r["op"] for r in recs]
    assert ops.count("all-gather") == 2  # incl. -start; -done skipped
    assert "all-reduce" in ops
    assert "reduce-scatter" in ops
    assert "all-to-all" in ops
    assert "collective-permute" in ops
    by_op = {}
    for r in recs:  # keep FIRST record per op (the -start dup comes later)
        by_op.setdefault(r["op"], r)
    # group sizes from both replica_groups encodings
    assert by_op["all-gather"]["group"] == 4
    assert by_op["all-reduce"]["group"] == 16  # iota [8,16]<=[128]
    # wire formulas
    ag = by_op["all-gather"]
    assert np.isclose(ag["wire_bytes"], 128 * 256 * 4 * 3 / 4)
    ar = by_op["all-reduce"]
    assert np.isclose(ar["wire_bytes"], 2 * 64 * 64 * 2 * 15 / 16)
    rs = by_op["reduce-scatter"]
    assert np.isclose(rs["wire_bytes"], 32 * 4 * 1)  # result × (g-1)
    a2a = by_op["all-to-all"]
    assert np.isclose(a2a["bytes"], 2 * 16 * 16 * 4)  # tuple type summed
    cp = by_op["collective-permute"]
    assert cp["wire_bytes"] == 1024


def test_collective_bytes_totals():
    agg = collective_bytes(HLO)
    assert agg["count"] == 6
    assert agg["total_wire_bytes"] == sum(
        r["wire_bytes"] for r in parse_collectives(HLO))
    assert set(agg["by_op"]) <= {"all-gather", "all-reduce", "reduce-scatter",
                                 "all-to-all", "collective-permute"}


def test_roofline_terms_dominance():
    # compute-bound case
    ro = roofline_terms(197e12, 1e9, 1e6)
    assert ro["dominant"] == "compute_s"
    assert np.isclose(ro["compute_s"], 1.0)
    assert np.isclose(ro["roofline_fraction"], 1.0)
    # collective-bound case
    ro = roofline_terms(1e12, 1e9, 500e9)
    assert ro["dominant"] == "collective_s"
    assert ro["roofline_fraction"] < 0.001 or ro["roofline_fraction"] > 0
    assert ro["step_time_lower_bound_s"] == ro["collective_s"]


def test_model_flops_conventions():
    assert model_flops(1e9, 1e6, "train") == 6e15
    assert model_flops(1e9, 1e6, "serve") == 2e15
