"""Minimal deterministic stand-in for `hypothesis` so the property-test
modules still collect and run in environments without it (the offline
container).  `pip install -e .[dev]` installs the real hypothesis, which
takes precedence via the try/except import in each test module.

Supports exactly the surface this repo's tests use:

  given(**kwargs_of_strategies), settings(max_examples=, deadline=),
  strategies.integers / floats / sampled_from / tuples

Sampling is deterministic (fixed seed per test) — these are smoke-strength
replays of the property tests, not a shrinking fuzzer.
"""
from __future__ import annotations

import numpy as np

DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:  # lowercase name mirrors the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def tuples(*strats: _Strategy) -> _Strategy:
        return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))


def settings(max_examples: int = DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**named_strategies):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples", DEFAULT_EXAMPLES))
            rng = np.random.default_rng(0)
            for _ in range(n):
                drawn = {k: s.sample(rng) for k, s in named_strategies.items()}
                fn(**drawn)
        # no functools.wraps: pytest must see a zero-arg signature, not the
        # original one (whose params would be mistaken for fixtures)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
