"""Dataflow analysis tier: the shape/dtype lattices (property tests), the
abstract interpreter on fixture snippets, the kernel contract rules against
scratch repo copies (seeded shape mutations must fail), the width rules,
and the chunking int32-boundary regression the width analysis demanded."""
import json
import shutil
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.analysis import dataflow as df
from repro.analysis import engine as _engine
from repro.analysis import shape_rules as sr
from repro.analysis import width_rules as wr
from repro.analysis.__main__ import main as cli_main
from repro.analysis.engine import ProjectContext
from repro.core.chunking import chunk_tensor
from repro.core.qformat import FIXED_PRESETS, accumulator_safe_nnz
from repro.core.sptensor import SparseTensor

REPO = _engine.default_root()


def _src(snippet: str) -> str:
    return textwrap.dedent(snippet).lstrip("\n")


# ---------------------------------------------------------------------------
# Dim algebra
# ---------------------------------------------------------------------------

def test_dim_ceil_pad_idiom_normalizes():
    # rows + (-rows) % chunk  ==  least multiple of chunk >= rows
    rows, chunk = df.Dim.sym("I0"), df.Dim.sym("S0")
    padded = rows + ((-rows) % chunk)
    assert padded == df.Dim.atom(df.CeilMul(rows, chunk))
    assert padded.divisible_by(chunk)
    assert not rows.divisible_by(chunk)


def test_dim_negfloordiv_ceil_idiom():
    # -(-out // c) * c  ==  ceil-pad of out to c
    out, c = df.Dim.sym("I1"), df.Dim.sym("S1")
    padded = (-((-out) // c)) * c
    assert padded == df.Dim.atom(df.CeilMul(out, c))
    assert padded.divisible_by(c)


def test_dim_const_arithmetic_and_exact_div():
    d = df.Dim.const_(12) * df.Dim.sym("R")
    assert d.divisible_by(df.Dim.const_(4))
    assert d.divisible_by(df.Dim.sym("R"))
    padded = df.Dim.atom(df.CeilMul(df.Dim.sym("R"), df.Dim.const_(128)))
    assert padded.divisible_by(df.Dim.const_(128))


def test_join_dims_absorbs_padding():
    # if rpad or cpad: f = pad(f)  — the two branches join to the padded dim
    base = df.Dim.sym("I0")
    padded = df.Dim.atom(df.CeilMul(base, df.Dim.sym("S0")))
    assert df.join_dims(base, padded) == padded
    assert df.join_dims(padded, base) == padded
    assert df.join_dims(base, base) == base


def test_join_dims_unequal_has_no_refinement():
    # unrelated symbols have no common refinement; the interpreter then
    # falls back to a fresh opaque dim (never to either branch's value)
    assert df.join_dims(df.Dim.sym("A"), df.Dim.sym("B")) is None


_DIMS = st.sampled_from(["nnz", "T", "P", "R", "I0", "S0"])


@settings(max_examples=50, deadline=None)
@given(a=_DIMS, b=_DIMS, ca=st.integers(min_value=0, max_value=7),
       cb=st.integers(min_value=0, max_value=7))
def test_join_dims_commutative_idempotent(a, b, ca, cb):
    da = df.Dim.sym(a) + ca
    dbv = df.Dim.sym(b) + cb
    assert df.join_dims(da, da) == da
    j1, j2 = df.join_dims(da, dbv), df.join_dims(dbv, da)
    # commutative: both directions refine to the same dim, or neither does
    assert j1 == j2


# ---------------------------------------------------------------------------
# DType lattice
# ---------------------------------------------------------------------------

_STRONG = ["bool", "int8", "int16", "int32", "uint8", "uint16", "uint32",
           "float16", "float32"]


@settings(max_examples=60, deadline=None)
@given(a=st.sampled_from(_STRONG), b=st.sampled_from(_STRONG))
def test_promote_matches_jnp_x64_off(a, b):
    got = df.promote(df.parse_dtype(a), df.parse_dtype(b))
    want = (jnp.zeros((), a) + jnp.zeros((), b)).dtype
    assert str(got) == str(want), (a, b, str(got), str(want))


@settings(max_examples=40, deadline=None)
@given(a=st.sampled_from(_STRONG), b=st.sampled_from(_STRONG))
def test_promote_commutative_idempotent(a, b):
    da, dbv = df.parse_dtype(a), df.parse_dtype(b)
    assert df.promote(da, da) == df.canonicalize(da)
    assert df.promote(da, dbv) == df.promote(dbv, da)


def test_weak_scalar_promotion():
    # python float scalar + int32 array stays... float32 (weak float adopts
    # the array's category-promoted width), python int + int16 stays int16
    i16 = df.parse_dtype("int16")
    weak_int = df.DType("int", 32, weak=True)
    weak_float = df.DType("float", 32, weak=True)
    assert df.promote(weak_int, i16) == i16
    assert str(df.promote(weak_float, i16)) == str(
        (jnp.zeros((), "int16") + 1.0).dtype)


def test_canonicalize_x64_off():
    assert df.canonicalize(df.parse_dtype("int64")).bits == 32
    assert df.canonicalize(df.parse_dtype("float64")).bits == 32


# ---------------------------------------------------------------------------
# Interpreter fixtures
# ---------------------------------------------------------------------------

def _interpret(source, fname, args, kwargs=None):
    program = df.Program({"src/repro/core/snippet.py": _src(source)})
    module = program.module("src/repro/core/snippet.py")
    interp = df.Interpreter(program)
    result = interp.call_function(module.functions[fname], module,
                                  list(args), dict(kwargs or {}))
    return result, interp


DOT_MISMATCH = """
    import jax.numpy as jnp

    def f(a, b):
        return jnp.dot(a, b)
"""


def test_interpreter_flags_dot_contraction_mismatch():
    a = df.AArray((df.Dim.sym("P"), df.Dim.sym("S0")), df.parse_dtype("float32"))
    b = df.AArray((df.Dim.sym("S1"), df.Dim.sym("R")), df.parse_dtype("float32"))
    _, interp = _interpret(DOT_MISMATCH, "f", [a, b])
    assert any("contract" in p.message or "dot" in p.message
               for p in interp.problems), interp.problems


def test_interpreter_quiet_on_matching_dot():
    a = df.AArray((df.Dim.sym("P"), df.Dim.sym("S0")), df.parse_dtype("float32"))
    b = df.AArray((df.Dim.sym("S0"), df.Dim.sym("R")), df.parse_dtype("float32"))
    out, interp = _interpret(DOT_MISMATCH, "f", [a, b])
    assert not interp.problems
    assert isinstance(out, df.AArray)
    assert out.shape == (df.Dim.sym("P"), df.Dim.sym("R"))


def test_interpreter_flags_broadcast_mismatch_in_binop():
    src = """
        def f(a, b):
            return a * b
    """
    a = df.AArray((df.Dim.sym("T"), df.Dim.sym("P")), df.parse_dtype("float32"))
    b = df.AArray((df.Dim.sym("T"), df.Dim.sym("R")), df.parse_dtype("float32"))
    _, interp = _interpret(src, "f", [a, b])
    assert any("broadcast" in p.message for p in interp.problems)


def test_interpreter_quiet_on_unknowns():
    src = """
        def f(a):
            b = some_unknown_library_call(a)
            return b * a
    """
    a = df.AArray((df.Dim.sym("T"),), df.parse_dtype("float32"))
    _, interp = _interpret(src, "f", [a])
    assert not interp.problems


def test_interpreter_segment_sum_record():
    src = """
        import jax

        def f(part, seg, n):
            return jax.ops.segment_sum(part, seg, num_segments=n,
                                       indices_are_sorted=True)
    """
    part = df.AArray((df.Dim.sym("nnz"), df.Dim.sym("R")),
                     df.parse_dtype("float32"))
    seg = df.AArray((df.Dim.sym("nnz"),), df.parse_dtype("int32"))
    out, interp = _interpret(src, "f", [part, seg, df.AInt(df.Dim.sym("F"))])
    assert len(interp.segment_sums) == 1
    rec = interp.segment_sums[0]
    assert rec.num_segments == df.Dim.sym("F")
    assert rec.indices_are_sorted is True
    assert isinstance(out, df.AArray)
    assert out.shape == (df.Dim.sym("F"), df.Dim.sym("R"))


# ---------------------------------------------------------------------------
# Kernel contracts on the live tree and on mutated scratch copies
# ---------------------------------------------------------------------------

def _scratch_repo(tmp_path, mutate=None):
    """Copy src/repro (sources + contracts) to tmp; `mutate` is a
    (rel, old, new) source replacement applied on the way."""
    live = ProjectContext(REPO)
    for fc in live.walk("src/repro"):
        dst = tmp_path / fc.rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        src = fc.source
        if mutate and fc.rel == mutate[0]:
            assert mutate[1] in src, f"mutation anchor gone from {fc.rel}"
            src = src.replace(mutate[1], mutate[2])
        dst.write_text(src)
    shutil.copy(REPO / sr._CONTRACTS, tmp_path / sr._CONTRACTS)
    return ProjectContext(tmp_path)


def test_live_tree_contracts_clean():
    ctx = ProjectContext(REPO)
    report = sr.contract_report(ctx)
    assert report["shape"] == set(), sorted(report["shape"])
    assert report["pallas"] == set(), sorted(report["pallas"])
    assert list(sr.check_kernel_contract_drift(ctx)) == []


def test_mutation_num_segments_swap_is_caught(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/mttkrp.py",
        "num_segments=n_fibers,", "num_segments=out_dim,"))
    report = sr.contract_report(ctx)
    assert any("num_segments" in msg for _, _, msg in report["shape"])


def test_mutation_sorted_flag_drop_is_caught(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/mttkrp.py",
        "num_segments=out_dim, indices_are_sorted=True)",
        "num_segments=out_dim)"))
    report = sr.contract_report(ctx)
    assert any("indices_are_sorted" in msg for _, _, msg in report["shape"])


def test_mutation_blockspec_mode_rotation_is_caught(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/kernels/mttkrp_kernel.py",
        "(chunk_shape[m], rank)", "(chunk_shape[mode], rank)"))
    report = sr.contract_report(ctx)
    assert any("divide" in msg for _, _, msg in report["pallas"])


def test_mutation_return_shape_is_caught(tmp_path):
    # transposing the output of the COO reference must break the
    # (dims[mode], rank) contract
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/mttkrp.py",
        "return out.at[coords[:, mode]].add(part, mode=\"drop\")",
        "return out.at[coords[:, mode]].add(part, mode=\"drop\").T"))
    report = sr.contract_report(ctx)
    assert report["shape"], "transposed return escaped the contract"


def test_signature_drift_is_caught(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/mttkrp.py",
        "def mttkrp_coo(factors, coords, values, *, mode: int, out_dim: int):",
        "def mttkrp_coo(factors, coords, values, *, mode: int, n_rows: int):"))
    findings = list(sr.check_kernel_contract_drift(ctx))
    assert any("drifted" in f.message for f in findings)


def test_contract_json_drift_is_caught(tmp_path):
    ctx = _scratch_repo(tmp_path)
    contracts = json.loads((tmp_path / sr._CONTRACTS).read_text())
    key = "src/repro/core/mttkrp.py::mttkrp_coo"
    contracts["functions"][key]["signature"]["static_argnames"] = ["mode"]
    (tmp_path / sr._CONTRACTS).write_text(json.dumps(contracts))
    findings = list(sr.check_kernel_contract_drift(ctx))
    assert any("mttkrp_coo" in f.message and "drifted" in f.message
               for f in findings)


def test_missing_contract_file_is_one_clear_finding(tmp_path):
    ctx = _scratch_repo(tmp_path)
    (tmp_path / sr._CONTRACTS).unlink()
    findings = list(sr.check_kernel_contract_drift(ctx))
    assert len(findings) == 1
    assert "--regen-contracts" in findings[0].message


def test_regen_contracts_roundtrip_is_noop(tmp_path):
    _scratch_repo(tmp_path)
    before = (tmp_path / sr._CONTRACTS).read_text()
    sr.regen_contracts(tmp_path)
    assert (tmp_path / sr._CONTRACTS).read_text() == before


def test_regen_preserves_hand_contracts_drops_vanished(tmp_path):
    _scratch_repo(tmp_path)
    contracts = json.loads((tmp_path / sr._CONTRACTS).read_text())
    contracts["functions"]["src/repro/kernels/ref.py::vanished_fn"] = {
        "signature": None, "params": None, "returns": None,
        "segment_sums": None}
    (tmp_path / sr._CONTRACTS).write_text(json.dumps(contracts))
    out = sr.regen_contracts(tmp_path)
    assert "src/repro/kernels/ref.py::vanished_fn" not in out["functions"]
    kept = out["functions"]["src/repro/core/mttkrp.py::mttkrp_csf"]
    assert kept["segment_sums"] == [
        {"num_segments": "F", "sorted": True},
        {"num_segments": "dim[mode]", "sorted": True}]


# ---------------------------------------------------------------------------
# Width rules
# ---------------------------------------------------------------------------

INT32_NARROW_BAD = """
    import numpy as np

    def pack(coords, chunk_shape):
        cs = np.asarray(chunk_shape, dtype=np.int64)
        return coords // cs.astype(np.int32)
"""

INT32_NARROW_GOOD_GUARDED = """
    import numpy as np

    def pack(coords, chunk_shape):
        cs = np.asarray(chunk_shape, dtype=np.int64)
        if int(cs.max()) > np.iinfo(np.int32).max:
            raise ValueError("chunk extent exceeds int32")
        return coords // cs.astype(np.int32)
"""

INT32_NARROW_GOOD_NOT_WIDE = """
    import numpy as np

    def pack(coords):
        uniq = np.unique(coords, axis=0)
        return uniq.astype(np.int32)
"""


def _file_findings(rule_fn, source, rel="src/repro/core/snippet.py"):
    fc = _engine.FileContext.from_source(_src(source), rel)
    return list(rule_fn(fc))


def test_int32_index_width_fires_on_unguarded_narrow():
    findings = _file_findings(wr.check_int32_index_width, INT32_NARROW_BAD)
    assert len(findings) == 1
    assert "cs" in findings[0].message


def test_int32_index_width_quiet_when_guarded():
    assert _file_findings(wr.check_int32_index_width,
                          INT32_NARROW_GOOD_GUARDED) == []


def test_int32_index_width_quiet_on_untracked_values():
    assert _file_findings(wr.check_int32_index_width,
                          INT32_NARROW_GOOD_NOT_WIDE) == []


def test_int32_index_width_tracks_argsort():
    src = """
        import numpy as np

        def order(key):
            perm = np.argsort(key, kind="stable")
            return perm.astype(np.int32)
    """
    findings = _file_findings(wr.check_int32_index_width, src)
    assert len(findings) == 1 and "perm" in findings[0].message


def test_width_rules_clean_on_live_tree():
    ctx = ProjectContext(REPO)
    assert list(wr.check_alto_key_width(ctx)) == []
    assert list(wr.check_qformat_accumulator(ctx)) == []


def test_alto_key_width_catches_word_geometry_drift(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/mttkrp.py",
        "key_words[:, p // 32]", "key_words[:, p // 64]"))
    findings = list(wr.check_alto_key_width(ctx))
    assert any("_alto_decode" in f.message and "64" in f.message
               for f in findings)


def test_alto_key_width_catches_byte_model_drift(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/formats/alto.py",
        "return 4 * nnz * n_words", "return 8 * nnz * n_words"))
    findings = list(wr.check_alto_key_width(ctx))
    assert any("alto_index_bytes" in f.message for f in findings)


def test_qformat_accumulator_catches_overwide_preset(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/qformat.py",
        "Q17_15 = QFormat(17, 15)", "Q17_15 = QFormat(17, 18)"))
    findings = list(wr.check_qformat_accumulator(ctx))
    assert any("int32" in f.message or "32" in f.message for f in findings)
    # the pinned safe_nnz no longer matches the re-derivation either
    assert any("safe_nnz" in f.message for f in findings)


def test_qformat_accumulator_catches_dropped_shift(tmp_path):
    ctx = _scratch_repo(tmp_path, (
        "src/repro/core/mttkrp.py",
        "part = jnp.right_shift(part, matrix_frac)", "pass"))
    findings = list(wr.check_qformat_accumulator(ctx))
    assert any("matrix_frac" in f.message for f in findings)


def test_accumulator_safe_nnz_pinned_values():
    assert accumulator_safe_nnz("int3") == 1048575
    assert accumulator_safe_nnz("int7") == 65535
    assert accumulator_safe_nnz("int15-12") == 2047
    for preset, (qf, shift) in FIXED_PRESETS.items():
        bound = accumulator_safe_nnz(preset)
        step = 1 << (qf.frac_bits + 15 - 7 - shift)
        assert bound * step <= 2**31 - 1 < (bound + 1) * step


# ---------------------------------------------------------------------------
# chunking int32 boundary regression (the fixed true positive)
# ---------------------------------------------------------------------------

def _tensor_with_shape(shape):
    coords = np.zeros((1, len(shape)), dtype=np.int32)
    return SparseTensor(coords, np.ones(1, dtype=np.float32), tuple(shape))


def test_chunk_tensor_rejects_past_int32_extent():
    # padded extent 2^31 + 8: max row index no longer fits int32
    st_big = _tensor_with_shape((2**31 + 1, 4))
    with pytest.raises(ValueError, match="int32"):
        chunk_tensor(st_big, (8, 4))


def test_chunk_tensor_accepts_near_boundary_extent():
    # padded extent == ceil(dim/chunk)*chunk == 2^31 - 8 < int32 max
    dim = 2**31 - 8
    ct = chunk_tensor(_tensor_with_shape((dim, 4)), (8, 4))
    assert ct.task_chunk.dtype == np.int32
    assert ct.coords_rel.dtype == np.int32


def test_chunk_tensor_small_unchanged():
    st_small = _tensor_with_shape((16, 8))
    ct = chunk_tensor(st_small, (4, 4))
    assert ct.task_chunk.shape[0] >= 1


# ---------------------------------------------------------------------------
# CLI: tiers, sarif, baseline
# ---------------------------------------------------------------------------

def test_cli_tier_split(capsys):
    assert cli_main(["--root", str(REPO), "--tier", "syntactic",
                     "--strict"]) == 0
    capsys.readouterr()
    assert cli_main(["--root", str(REPO), "--tier", "dataflow",
                     "--strict"]) == 0
    capsys.readouterr()


def test_cli_sarif_is_valid(capsys):
    assert cli_main(["--root", str(REPO), "--format", "sarif"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["version"] == "2.1.0"
    run = report["runs"][0]
    assert run["tool"]["driver"]["name"] == "repro.analysis"
    ids = {r["id"] for r in run["tool"]["driver"]["rules"]}
    assert {"kernel-shape-contract", "pallas-blockspec",
            "int32-index-width"} <= ids
    for r in run["tool"]["driver"]["rules"]:
        assert r["helpUri"].startswith("docs/static-analysis.md#")


def test_cli_baseline_masks_known_failures_only(tmp_path, capsys):
    # a scratch repo with one deliberate finding: baseline it, rerun clean,
    # then introduce a second finding and expect only that one to fail
    bad = _src("""
        import numpy as np

        def pack(x):
            k = np.asarray(x, dtype=np.int64)
            return k.astype(np.int32)
    """)
    repo = tmp_path / "repo"
    dst = repo / "src/repro/core/snippet.py"
    dst.parent.mkdir(parents=True, exist_ok=True)
    dst.write_text(bad)
    base = tmp_path / "baseline.json"
    args = ["--root", str(repo), "--rules", "int32-index-width"]
    assert cli_main(args) == 1
    capsys.readouterr()
    assert cli_main([*args, "--write-baseline", str(base)]) == 0
    capsys.readouterr()
    assert cli_main([*args, "--baseline", str(base)]) == 0
    capsys.readouterr()
    dst.write_text(bad + _src("""
        def pack2(x):
            k2 = np.asarray(x, dtype=np.int64)
            return k2.astype(np.int32)
    """))
    assert cli_main([*args, "--baseline", str(base), "--format",
                     "json"]) == 1
    report = json.loads(capsys.readouterr().out)
    assert report["counts"]["new"] == 1
    assert report["counts"]["active"] == 2
    assert "pack2" in report["new_findings"][0]["message"]


def test_cli_regen_contracts_noop_on_clean_tree(capsys):
    before = (REPO / sr._CONTRACTS).read_text()
    assert cli_main(["--root", str(REPO), "--regen-contracts"]) == 0
    capsys.readouterr()
    assert (REPO / sr._CONTRACTS).read_text() == before


def test_suppression_for_unselected_tier_not_flagged_unused():
    # hetero.py carries an int32-index-width suppression (dataflow tier);
    # a strict syntactic-only run must not call it unused
    result = _engine.run_analysis(REPO, tier="syntactic", strict=True)
    assert result.ok, [f.render() for f in result.findings]
