"""Engine subsystem: every registered backend matches the COO oracle; the
autotuner picks a measured winner and shares one chunking through the plan
cache; the distributed backend is reachable through the registry on a real
multi-device (host-platform) mesh."""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_engine, random_tensor
from repro.core.mttkrp import mttkrp_coo
from repro.engine import (
    Engine,
    EngineContext,
    PlanCache,
    build_engine,
    eligible_backends,
    get_backend,
    register_backend,
    registered_backends,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CASES = [
    ((30, 24, 36), 700, (8, 8, 8), 64),       # 3-mode
    ((17, 23, 9), 300, (8, 8, 4), 32),        # 3-mode, non-divisible dims
    ((24, 18, 20, 10), 500, (8, 8, 8, 4), 64),  # 4-mode
]

# fixed point is lossy by design (Q arithmetic); everything else must match
# the float oracle to reduction-order noise.
TOL = {"fixed": dict(rtol=5e-2, atol=5e-2), None: dict(rtol=1e-3, atol=1e-3)}


def _factors(shape, rank, seed=2):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
                 for d in shape)


@pytest.mark.parametrize("name", sorted(registered_backends()))
@pytest.mark.parametrize(("shape", "nnz", "cs", "cap"), CASES)
def test_backend_matches_coo_oracle(name, shape, nnz, cs, cap):
    st = random_tensor(shape, nnz, seed=1)
    rank = 6
    factors = _factors(shape, rank)
    # distributed runs on whatever this process has (a 1-device mesh in the
    # main pytest process; the real multi-device run is the subprocess test)
    eng = build_engine(st, name, rank, chunk_shape=cs, capacity=cap,
                       fixed_preset="int15-12", plans=PlanCache())
    tol = TOL.get(name, TOL[None])
    for mode in range(len(shape)):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=shape[mode])
        out = eng(factors, mode)
        assert out.shape == (shape[mode], rank), (name, mode, out.shape)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out), **tol)


def test_auto_picks_backend_and_caches_plan():
    st = random_tensor((30, 24, 36), 800, seed=2)
    plans = PlanCache()
    eng = build_engine(st, "auto", 5, chunk_shape=(8, 8, 8), capacity=64,
                       plans=plans)
    assert isinstance(eng, Engine)
    assert eng.name.startswith("auto:")
    report = eng.report
    assert sorted(report.winners) == [0, 1, 2]
    assert set(report.winners.values()) <= set(registered_backends())
    # every lossless eligible backend was either timed or recorded skipped
    assert set(report.timings) | set(report.skipped) == set(report.candidates)
    # chunking happened exactly once, shared by all chunk-based candidates
    assert plans.stats.chunk_misses == 1
    assert plans.stats.chunk_hits >= 2
    # the returned engine works and matches the oracle
    factors = _factors(st.shape, 5)
    ref = mttkrp_coo(factors, jnp.asarray(st.coords), jnp.asarray(st.values),
                     mode=0, out_dim=st.shape[0])
    np.testing.assert_allclose(np.asarray(ref), np.asarray(eng(factors, 0)),
                               rtol=1e-3, atol=1e-3)
    # a second build against the same tensor re-uses the cached chunking
    build_engine(st, "chunked", 5, chunk_shape=(8, 8, 8), capacity=64,
                 plans=plans)
    assert plans.stats.chunk_misses == 1


def test_auto_excludes_lossy_backends_by_default():
    st = random_tensor((20, 16, 24), 400, seed=3)
    eng = build_engine(st, "auto", 4, chunk_shape=(8, 8, 8), capacity=32,
                       plans=PlanCache())
    assert "fixed" not in eng.report.candidates
    # ...but explicit candidates may include it
    eng2 = build_engine(st, "auto", 4, chunk_shape=(8, 8, 8), capacity=32,
                        plans=PlanCache(), candidates=["chunked", "fixed"])
    assert set(eng2.report.candidates) == {"chunked", "fixed"}


def test_registry_capabilities_and_errors():
    specs = registered_backends()
    assert {"ref", "alto", "csf", "chunked", "fixed", "hetero", "pallas",
            "distributed"} <= set(specs)
    # the format backends are lossless, chunk-free, single-device-eligible
    for fmt in ("csf", "alto"):
        assert specs[fmt].lossless
        assert not specs[fmt].needs_chunking
    assert specs["fixed"].supports_fixed_point
    assert not specs["fixed"].lossless
    assert specs["distributed"].min_devices == 2
    assert specs["chunked"].needs_chunking
    assert not specs["ref"].needs_chunking
    with pytest.raises(ValueError, match="unknown engine"):
        get_backend("nonexistent")
    # single-device process: distributed must not be autotune-eligible
    assert "distributed" not in eligible_backends(n_devices=1)
    assert "distributed" in eligible_backends(n_devices=8)


def test_register_backend_decorator_roundtrip():
    @register_backend("_test_double_ref", description="test-only")
    def _build(ctx: EngineContext):
        base = get_backend("ref").build(ctx)
        return lambda factors, mode: 2.0 * base(factors, mode)
    try:
        st = random_tensor((12, 10, 8), 100, seed=4)
        factors = _factors(st.shape, 3)
        eng = build_engine(st, "_test_double_ref", 3)
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=1, out_dim=10)
        np.testing.assert_allclose(2.0 * np.asarray(ref),
                                   np.asarray(eng(factors, 1)),
                                   rtol=1e-5, atol=1e-5)
    finally:
        from repro.engine import registry as _reg
        _reg._REGISTRY.pop("_test_double_ref", None)


def test_make_engine_is_deprecated_shim():
    st = random_tensor((14, 12, 10), 150, seed=5)
    with pytest.warns(DeprecationWarning, match="build_engine"):
        eng = make_engine(st, "ref", 4)
    factors = _factors(st.shape, 4)
    ref = mttkrp_coo(factors, jnp.asarray(st.coords), jnp.asarray(st.values),
                     mode=0, out_dim=14)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(eng(factors, 0)),
                               rtol=1e-5, atol=1e-5)


def test_cp_als_accepts_auto_and_reports_winner():
    from repro.core import cp_als
    st = random_tensor((20, 16, 24), 400, seed=6)
    res = cp_als(st, 4, n_iters=2, engine="auto", chunk_shape=(8, 8, 8),
                 capacity=32, plans=PlanCache())
    assert res.engine.startswith("auto:")
    ref = cp_als(st, 4, n_iters=2, engine="ref", seed=0)
    np.testing.assert_allclose(res.fit_history, ref.fit_history,
                               rtol=1e-3, atol=1e-4)


def test_distributed_backend_via_registry_multi_device():
    """Acceptance: the distributed mesh backend is invocable through the
    registry on ≥2 host-platform devices (8 here) and matches the oracle."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, json
        from repro.core import random_tensor
        from repro.core.mttkrp import mttkrp_coo
        from repro.engine import build_engine, eligible_backends
        assert len(jax.devices()) == 8
        assert "distributed" in eligible_backends()
        st = random_tensor((40, 32, 48), 2000, seed=1)
        rank = 8
        rng = np.random.default_rng(2)
        factors = [jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
                   for d in st.shape]
        eng = build_engine(st, "distributed", rank,
                           chunk_shape=(8, 8, 8), capacity=32)
        errs = []
        for mode in range(3):
            ref = mttkrp_coo(tuple(factors), jnp.asarray(st.coords),
                             jnp.asarray(st.values), mode=mode,
                             out_dim=st.shape[mode])
            out = np.asarray(eng(factors, mode))
            assert out.shape == (st.shape[mode], rank)
            errs.append(float(np.max(np.abs(out - np.asarray(ref)))))
        print(json.dumps(errs))
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    errs = json.loads(out.stdout.strip().splitlines()[-1])
    assert max(errs) < 1e-3, errs
