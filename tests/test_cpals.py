"""CP-ALS system behaviour: convergence, engine equivalence, fixed-point and
lock-free accuracy (paper Fig. 6 claims), qformat properties."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container — deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import (Q17_15, Q5_3, Q9_7, cp_als, fit_value, random_tensor,
                        value_qformat)
from repro.core.qformat import QFormat


def _lowrank_tensor(shape, rank, nnz=None, seed=0):
    """Fully-observed exactly-rank-R tensor in COO form (sparse CP-ALS treats
    unobserved coords as zeros, so a partially-sampled low-rank tensor is NOT
    low rank — all entries must be present for a high fit to be reachable)."""
    rng = np.random.default_rng(seed)
    factors = [rng.uniform(-1, 1, (d, rank)).astype(np.float32) for d in shape]
    grids = np.meshgrid(*[np.arange(d) for d in shape], indexing="ij")
    coords = np.stack([g.reshape(-1) for g in grids], 1).astype(np.int32)
    prod = np.ones((coords.shape[0], rank), np.float32)
    for m, f in enumerate(factors):
        prod *= f[coords[:, m]]
    vals = prod.sum(1).astype(np.float32)
    from repro.core.sptensor import SparseTensor
    return SparseTensor(coords, vals, shape)


def test_cpals_converges_on_lowrank():
    st_ = _lowrank_tensor((14, 10, 12), 3, seed=0)
    res = cp_als(st_, 6, n_iters=15, engine="ref", seed=1)
    assert res.fit_history[-1] > 0.8, res.fit_history
    assert res.fit_history[-1] >= res.fit_history[0]


def test_engines_agree_float():
    st_ = random_tensor((30, 24, 36), 800, seed=2)
    kw = dict(chunk_shape=(8, 8, 8), capacity=64)
    r_ref = cp_als(st_, 5, n_iters=3, engine="ref", seed=3)
    r_chu = cp_als(st_, 5, n_iters=3, engine="chunked", seed=3, **kw)
    r_het = cp_als(st_, 5, n_iters=3, engine="hetero", seed=3,
                   dense_fraction=0.5, **kw)
    np.testing.assert_allclose(r_ref.fit_history, r_chu.fit_history,
                               rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(r_ref.fit_history, r_het.fit_history,
                               rtol=1e-3, atol=1e-4)


def test_fixed_point_tracks_float_convergence():
    """Paper Fig. 6 structure: Int15-12 ≈ Float; Int7 worst but convergent.

    On an exactly-low-rank tensor float converges toward 0, so Int7's
    quantization noise floor is visible (the paper's real tensors have a
    large model-error floor that hides it; see the fig6 benchmark for the
    paper-style relative comparison on Table-I-like tensors)."""
    st_ = _lowrank_tensor((12, 10, 12), 3, seed=4)
    kw = dict(chunk_shape=(8, 8, 8), capacity=512)
    r_f = cp_als(st_, 5, n_iters=5, engine="chunked", seed=5, **kw)
    r_q7 = cp_als(st_, 5, n_iters=5, engine="fixed", fixed_preset="int7",
                  seed=5, **kw)
    r_q15 = cp_als(st_, 5, n_iters=5, engine="fixed", fixed_preset="int15-12",
                   seed=5, **kw)
    # Int15-12 tracks float tightly (paper: preferred for tight precision)
    rel15 = abs(r_q15.diff_history[-1] - r_f.diff_history[-1]) / max(
        r_f.diff_history[-1], 1e-9)
    assert rel15 < 0.05, (r_q15.diff_history, r_f.diff_history)
    assert abs(r_q15.fit_history[-1] - r_f.fit_history[-1]) < 0.01
    # Int7 is the least accurate format (paper Fig. 6: highest avg-abs-diff
    # in all cases) but remains bounded at its quantization noise floor
    assert r_q7.diff_history[-1] >= r_q15.diff_history[-1]
    assert r_q7.diff_history[-1] < 3 * r_q7.diff_history[0]  # bounded, no blowup


def test_lockfree_emulation_minor_impact():
    """Paper §V-A: removing locks does not significantly hurt convergence —
    PREMISE: the tensor is sparse, so simultaneous same-row tasklet writes
    are rare.  (On a dense tensor collisions are systematic and the claim
    does not hold — which the paper's own argument predicts.)"""
    st_ = random_tensor((30, 24, 36), 900, seed=6)
    kw = dict(chunk_shape=(8, 8, 8), capacity=64)
    locked = cp_als(st_, 5, n_iters=5, engine="chunked", seed=7, **kw)
    lockfree = cp_als(st_, 5, n_iters=5, engine="chunked", seed=7,
                      lockfree_mode=True, **kw)
    rel = abs(lockfree.diff_history[-1] - locked.diff_history[-1]) / max(
        locked.diff_history[-1], 1e-9)
    # paper: "does not significantly decrease convergence, having some cases
    # where it can even increase it" — we observe the latter (~7% better)
    assert rel < 0.15, (lockfree.diff_history, locked.diff_history)


def test_pallas_engine_matches_chunked():
    st_ = random_tensor((24, 16, 24), 400, seed=8)
    kw = dict(chunk_shape=(8, 8, 8), capacity=32)
    r_c = cp_als(st_, 4, n_iters=2, engine="chunked", seed=9, **kw)
    r_p = cp_als(st_, 4, n_iters=2, engine="pallas", seed=9, **kw)
    np.testing.assert_allclose(r_c.fit_history, r_p.fit_history,
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# QFormat properties
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(
    bits=st.sampled_from([(5, 3), (9, 7), (17, 15)]),
    seed=st.integers(0, 10_000),
)
def test_qformat_roundtrip_error_bound(bits, seed):
    qf = QFormat(*bits)
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, 256).astype(np.float32)
    q = qf.quantize_np(x)
    back = q.astype(np.float64) / qf.scale
    assert np.max(np.abs(back - x)) <= 1.0 / qf.scale  # ≤ 1 ulp (round)
    assert q.dtype == qf.np_dtype


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), vmax=st.floats(0.01, 1000.0))
def test_value_qformat_covers_range(seed, vmax):
    rng = np.random.default_rng(seed)
    vals = (rng.uniform(-1, 1, 100) * vmax).astype(np.float32)
    vq = value_qformat(vals)
    q = vq.quantize_np(vals)
    # no saturation beyond 1 ulp: dequantized max within one step of true max
    back = q.astype(np.float64) / vq.scale
    assert np.max(np.abs(back - vals)) <= 2.0 / vq.scale + 1e-6


def test_fit_value_is_one_for_exact():
    st_ = _lowrank_tensor((10, 12, 8), 2, seed=10)
    res = cp_als(st_, 8, n_iters=25, engine="ref", seed=11)
    assert res.fit_history[-1] > 0.9
