"""Model-component behaviour tests: attention masks/decode parity, MoE
correctness, mamba/mlstm/slstm decode-vs-parallel consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as am
from repro.models import xlstm as xl
from repro.models.moe import MoEConfig, moe_apply, moe_init
from repro.models.ssm import (MambaConfig, init_mamba_cache, mamba_apply,
                              mamba_decode, mamba_init)


def _cfg(**kw):
    base = dict(d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                q_block=8, kv_block=16)
    base.update(kw)
    return am.AttnConfig(**base)


def _naive_attn(cfg, q, k, v, q_pos, kv_pos):
    g = cfg.n_heads // cfg.n_kv_heads
    b, s, h, d = q.shape
    qg = q.reshape(b, s, cfg.n_kv_heads, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(d)
    mask = jnp.ones((s, k.shape[1]), bool)
    if cfg.causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if cfg.window is not None:
        mask &= q_pos[:, None] - kv_pos[None, :] < cfg.window
    if cfg.chunk is not None:
        mask &= q_pos[:, None] // cfg.chunk == kv_pos[None, :] // cfg.chunk
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v).reshape(b, s, h, d)


@pytest.mark.parametrize("variant", ["full", "window", "chunk", "bidir"])
def test_flash_blocked_matches_naive(variant):
    cfg = _cfg(causal=variant != "bidir",
               window=7 if variant == "window" else None,
               chunk=8 if variant == "chunk" else None)
    b, s = 2, 37
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, s, 4, 8))
    k = jax.random.normal(jax.random.key(1), (b, s, 2, 8))
    v = jax.random.normal(jax.random.key(2), (b, s, 2, 8))
    pos = jnp.arange(s)
    got = (am._chunked_attn(cfg, q, k, v, pos, pos) if variant == "chunk"
           else am._flash(cfg, q, k, v, pos, pos))
    want = _naive_attn(cfg, q, k, v, pos, pos)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("variant", ["full", "window", "chunk"])
def test_attn_decode_matches_prefill(variant):
    """Decoding token-by-token == full parallel attention (same params)."""
    cfg = _cfg(causal=True,
               window=6 if variant == "window" else None,
               chunk=8 if variant == "chunk" else None,
               qk_norm=True)
    p, _ = am.attn_init(jax.random.key(3), cfg)
    b, s = 2, 17
    x = jax.random.normal(jax.random.key(4), (b, s, 32)) * 0.5
    full, _ = am.attention(p, cfg, x)
    cache = am.init_kv_cache(cfg, b, max_len=32, dtype=jnp.float32)
    outs = []
    for t in range(s):
        o, cache = am.attn_decode(p, cfg, x[:, t:t + 1], cache, jnp.int32(t))
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(full, seq, rtol=2e-3, atol=2e-3)


def test_moe_matches_dense_loop(trivial_mesh):
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2)
    p, _ = moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16)) * 0.5
    out = moe_apply(p, cfg, x, mesh=trivial_mesh, dp_axes=("data",),
                    seq_sharded=False)
    xt = x.reshape(-1, 16)
    logits = xt @ p["router"]
    gv, eids = jax.lax.top_k(logits, 2)
    gates = jax.nn.softmax(gv, axis=-1)
    ref = np.zeros((16, 16), np.float32)
    for tok in range(16):
        for j in range(2):
            e = int(eids[tok, j])
            h = jax.nn.silu(xt[tok] @ p["wg"][e]) * (xt[tok] @ p["wu"][e])
            ref[tok] += float(gates[tok, j]) * np.asarray(h @ p["wd"][e])
    np.testing.assert_allclose(out.reshape(-1, 16), ref, rtol=1e-4, atol=1e-5)


def test_mamba_decode_matches_apply():
    cfg = MambaConfig(d_model=16, d_state=4, scan_chunk=8)
    p, _ = mamba_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 21, 16)) * 0.5
    full = mamba_apply(p, cfg, x)
    cache = init_mamba_cache(cfg, 2, jnp.float32)
    outs = []
    for t in range(21):
        o, cache = mamba_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    np.testing.assert_allclose(full, jnp.concatenate(outs, 1),
                               rtol=1e-4, atol=1e-5)


def test_mlstm_chunkwise_matches_stepscan():
    cfg = xl.MLSTMConfig(d_model=32, n_heads=2)
    p, _ = xl.mlstm_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 37, 32)) * 0.5
    out_c, st_c = xl.mlstm_apply(p, cfg, x, return_state=True)
    # token-by-token decode must agree with the chunkwise-parallel form
    cache = xl.init_mlstm_cache(cfg, 2)
    outs = []
    for t in range(37):
        o, cache = xl.mlstm_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    np.testing.assert_allclose(out_c, jnp.concatenate(outs, 1),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(st_c["c"], cache["c"], rtol=1e-3, atol=1e-3)


def test_slstm_decode_matches_apply():
    cfg = xl.SLSTMConfig(d_model=16, n_heads=2)
    p, _ = xl.slstm_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 13, 16)) * 0.5
    full = xl.slstm_apply(p, cfg, x)
    cache = xl.init_slstm_cache(cfg, 2)
    outs = []
    for t in range(13):
        o, cache = xl.slstm_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    np.testing.assert_allclose(full, jnp.concatenate(outs, 1),
                               rtol=1e-4, atol=1e-5)
