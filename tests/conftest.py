import numpy as np
import pytest


@pytest.fixture(scope="session")
def trivial_mesh():
    import jax
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
