import numpy as np
import pytest


@pytest.fixture(scope="session")
def trivial_mesh():
    from repro.launch.mesh import make_mesh_compat
    return make_mesh_compat((1, 1), ("data", "model"))
