"""repro.obs: tracer contract (zero emissions + bounded overhead when
disabled, thread-aware nesting when enabled), histogram percentile
accuracy against the log-bucket error bound, trace JSONL schema
round-trip, Chrome export validity, and the summarize CLI exit codes."""
import json
import threading
import time

import pytest

from repro.obs import (
    MetricsRegistry,
    SpanRecord,
    capture,
    default_histogram_bounds,
    disable_tracing,
    enable_tracing,
    get_tracer,
    read_jsonl,
    record_span,
    span,
    span_kind_summary,
    to_chrome_trace,
    traced,
    tracing_enabled,
    tune_decision_summary,
    validate_spans,
    write_chrome_trace,
    write_jsonl,
)
from repro.obs.__main__ import main as obs_cli


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with the global tracer off and empty."""
    disable_tracing()
    get_tracer().clear()
    yield
    disable_tracing()
    get_tracer().clear()


# ---------------------------------------------------------------------------
# tracer: disabled fast path
# ---------------------------------------------------------------------------

def test_disabled_tracer_emits_zero_spans():
    assert not tracing_enabled()
    with span("work.outer", a=1):
        with span("work.inner"):
            pass
    record_span("work.record", 0.01)
    assert len(get_tracer()) == 0


def test_disabled_span_is_shared_noop_singleton():
    s1 = span("a")
    s2 = span("b", attr=1)
    assert s1 is s2  # no per-call allocation when disabled
    assert s1.set(x=1) is s1
    assert s1.duration is None


def test_disabled_overhead_budget():
    """The disabled path must stay within a generous constant factor of an
    uninstrumented loop — it is one attribute check, but CI machines are
    noisy, so the gate is deliberately loose (and the zero-span assertion
    above is the real contract)."""
    n = 20_000

    def plain():
        acc = 0
        for i in range(n):
            acc += i
        return acc

    def instrumented():
        acc = 0
        for i in range(n):
            with span("hot.iter"):
                acc += i
        return acc

    plain()
    instrumented()  # warm both paths before timing
    t0 = time.perf_counter()
    plain()
    t_plain = time.perf_counter() - t0
    t0 = time.perf_counter()
    instrumented()
    t_inst = time.perf_counter() - t0
    assert len(get_tracer()) == 0
    # Context-manager entry alone costs a few x of a bare add; 50x of the
    # plain loop is far above anything but a broken (allocating/locking)
    # disabled path.
    assert t_inst < max(50 * t_plain, 0.25), \
        f"disabled tracing overhead too high: {t_inst:.4f}s vs {t_plain:.4f}s"


def test_traced_decorator_disabled_passthrough():
    calls = []

    @traced("unit.fn", static=True)
    def fn(x):
        calls.append(x)
        return x + 1

    assert fn(1) == 2
    assert len(get_tracer()) == 0
    enable_tracing()
    assert fn(2) == 3
    spans = get_tracer().spans()
    assert [s.name for s in spans] == ["unit.fn"]
    assert spans[0].attrs["static"] is True
    assert calls == [1, 2]


# ---------------------------------------------------------------------------
# tracer: enabled semantics
# ---------------------------------------------------------------------------

def test_span_nesting_and_attrs():
    enable_tracing()
    with span("outer", kind="o") as so:
        with span("inner") as si:
            si.set(found=3)
    spans = get_tracer().spans()
    assert [s.name for s in spans] == ["inner", "outer"]  # close order
    inner, outer = spans
    assert inner.parent_id == outer.span_id
    assert outer.parent_id == 0
    assert inner.attrs == {"found": 3}
    assert outer.attrs == {"kind": "o"}
    assert so.duration >= inner.duration >= 0


def test_span_records_exception_and_reraises():
    enable_tracing()
    with pytest.raises(ValueError), span("boom"):
        raise ValueError("x")
    (rec,) = get_tracer().spans()
    assert rec.attrs["error"] == "ValueError"


def test_record_span_explicit_start_and_parent():
    enable_tracing()
    t0 = time.perf_counter()
    rid = record_span("req", 0.5, t_start=t0, parent_id=0, index=1)
    record_span("req.child", 0.2, t_start=t0, parent_id=rid)
    parent, child = get_tracer().spans()
    assert rid == parent.span_id and child.parent_id == rid
    assert child.t_start == pytest.approx(parent.t_start)
    assert parent.duration == 0.5


def test_threads_nest_independently():
    enable_tracing()
    ready = threading.Barrier(2)

    def work(tag):
        ready.wait()
        with span(f"{tag}.outer"):
            with span(f"{tag}.inner"):
                pass

    threads = [threading.Thread(target=work, args=(t,), name=t)
               for t in ("a", "b")]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    spans = {s.name: s for s in get_tracer().spans()}
    assert len(spans) == 4
    for tag in ("a", "b"):
        assert spans[f"{tag}.inner"].parent_id == spans[f"{tag}.outer"].span_id
        assert spans[f"{tag}.inner"].thread_name == tag
    # Cross-thread spans never parent each other implicitly.
    assert spans["a.outer"].parent_id == spans["b.outer"].parent_id == 0


def test_capture_scope_restores_disabled_state():
    assert not tracing_enabled()
    with capture() as spans:
        assert tracing_enabled()
        with span("scoped"):
            pass
    assert not tracing_enabled()
    assert [s.name for s in spans] == ["scoped"]


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    c = reg.counter("reqs")
    c.inc()
    c.inc(4)
    g = reg.gauge("depth")
    g.set_value(3)
    g.add(-1)
    snap = reg.snapshot()
    assert snap["reqs"] == {"type": "counter", "value": 5}
    assert snap["depth"]["value"] == 2.0
    with pytest.raises(TypeError):
        reg.gauge("reqs")  # kind mismatch on an existing name


def test_histogram_percentile_accuracy():
    """Log-bucketed percentiles must land within one bucket width — a
    factor of 10^(1/8) for the default 8-per-decade geometry — of the
    exact sample percentile."""
    import numpy as np
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-5.0, sigma=1.5, size=5000)
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    for v in samples:
        h.observe(float(v))
    width = 10 ** (1 / 8)
    for q in (50, 95, 99):
        exact = float(np.percentile(samples, q))
        est = h.percentile(q)
        assert exact / width <= est <= exact * width, \
            f"p{q}: est {est:.3g} vs exact {exact:.3g}"
    assert h.count == len(samples)
    assert h.total == pytest.approx(float(samples.sum()))


def test_histogram_percentile_clamps_to_observed_range():
    reg = MetricsRegistry()
    h = reg.histogram("one")
    h.observe(0.0123)
    for q in (0, 50, 100):
        assert h.percentile(q) == pytest.approx(0.0123)
    assert reg.histogram("empty").percentile(99) == 0.0


def test_histogram_overflow_bucket_returns_max():
    reg = MetricsRegistry()
    h = reg.histogram("big", bounds=(1.0, 10.0))
    h.observe(5000.0)
    assert h.percentile(99) == 5000.0


def test_default_bounds_geometry():
    b = default_histogram_bounds()
    assert b[0] == pytest.approx(1e-6) and b[-1] == pytest.approx(1e3)
    ratios = [b[i + 1] / b[i] for i in range(len(b) - 1)]
    assert all(r == pytest.approx(10 ** (1 / 8)) for r in ratios)


def test_metrics_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("v")

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(0.001)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert c.value == 4000
    assert h.count == 4000


# ---------------------------------------------------------------------------
# export: JSONL round-trip, Chrome trace, summaries
# ---------------------------------------------------------------------------

def _sample_spans():
    enable_tracing()
    with span("cp_als.iter", iter=0):
        with span("cp_als.mode", mode=1):
            pass
    record_span("autotune.probe", 0.002, candidate="ref", mode=0,
                seconds=0.001, provenance="measured")
    record_span("autotune.probe", 0.0, candidate="ref", mode=1,
                provenance="elided")
    record_span("autotune.decision", 0.0, source="measured", probes=1)
    return get_tracer().spans()


def test_jsonl_round_trip(tmp_path):
    spans = _sample_spans()
    path = write_jsonl(spans, tmp_path / "t.jsonl")
    meta, back = read_jsonl(path)
    assert meta["version"] == 1 and meta["pid"] > 0
    assert back == spans  # SpanRecord is frozen+eq: exact round-trip
    validate_spans(back)
    # Every line is JSON with an explicit type tag.
    lines = (tmp_path / "t.jsonl").read_text().splitlines()
    assert json.loads(lines[0])["type"] == "meta"
    assert all(json.loads(ln)["type"] == "span" for ln in lines[1:])


def test_read_jsonl_rejects_bad_traces(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text('{"type": "span", "name": "x"}\n')
    with pytest.raises(ValueError, match="no meta|missing"):
        read_jsonl(p)
    p.write_text('{"type": "meta", "version": 999}\n')
    with pytest.raises(ValueError, match="version"):
        read_jsonl(p)
    p.write_text("not json\n")
    with pytest.raises(ValueError, match="not JSON"):
        read_jsonl(p)


def test_validate_spans_catches_violations():
    rec = SpanRecord(name="a", t_start=0.0, duration=0.1, span_id=1,
                     parent_id=0, thread_id=1, thread_name="t", attrs={})
    import dataclasses
    dup = dataclasses.replace(rec)
    with pytest.raises(ValueError, match="duplicate"):
        validate_spans([rec, dup])
    orphan = dataclasses.replace(rec, span_id=2, parent_id=99)
    with pytest.raises(ValueError, match="unknown parent"):
        validate_spans([rec, orphan])
    neg = dataclasses.replace(rec, span_id=3, duration=-1.0)
    with pytest.raises(ValueError, match="negative"):
        validate_spans([rec, neg])


def test_chrome_trace_export(tmp_path):
    spans = _sample_spans()
    doc = to_chrome_trace(spans)
    events = doc["traceEvents"]
    meta_ev = [e for e in events if e["ph"] == "M"]
    x_ev = [e for e in events if e["ph"] == "X"]
    assert meta_ev and meta_ev[0]["name"] == "thread_name"
    assert len(x_ev) == len(spans)
    by_name = {e["name"]: e for e in x_ev}
    assert by_name["cp_als.iter"]["cat"] == "cp_als"
    assert by_name["cp_als.mode"]["args"]["mode"] == 1
    # Durations are microseconds: the probe's 2ms becomes 2000.
    assert by_name["autotune.probe"]["dur"] in (2000.0, 0.0)
    path = write_chrome_trace(spans, tmp_path / "t.json")
    json.loads(open(path).read())  # valid JSON document


def test_summaries():
    spans = _sample_spans()
    rows = {r["span"]: r for r in span_kind_summary(spans)}
    assert rows["cp_als.iter"]["count"] == 1
    assert rows["autotune.probe"]["count"] == 2
    tune = tune_decision_summary(spans)
    assert tune["decisions"] == {"measured": 1}
    assert tune["probes"] == {"measured": 1, "elided": 1}
    assert tune["probe_seconds"] == pytest.approx(0.002)


def test_summarize_cli(tmp_path, capsys):
    spans = _sample_spans()
    trace = str(tmp_path / "t.jsonl")
    write_jsonl(spans, trace)
    assert obs_cli(["summarize", trace]) == 0
    out = capsys.readouterr().out
    assert "cp_als.iter" in out and "probes:" in out
    # export subcommand produces a Perfetto-loadable JSON
    out_json = str(tmp_path / "t.json")
    assert obs_cli(["export", trace, "-o", out_json]) == 0
    assert json.loads(open(out_json).read())["traceEvents"]
    # invalid trace → exit 1
    (tmp_path / "bad.jsonl").write_text("nope\n")
    assert obs_cli(["summarize", str(tmp_path / "bad.jsonl")]) == 1
    assert obs_cli(["summarize", str(tmp_path / "missing.jsonl")]) == 1


# ---------------------------------------------------------------------------
# stack integration: the wired spans actually appear
# ---------------------------------------------------------------------------

def test_cp_als_iter_times_match_trace():
    from repro.core import cp_als, random_tensor
    st = random_tensor((5, 4, 3), 20, seed=0)
    with capture() as spans:
        res = cp_als(st, rank=2, n_iters=2)
    iters = [s for s in spans if s.name == "cp_als.iter"]
    assert [s.attrs["seconds"] for s in iters] == res.iter_times
    modes = [s for s in spans if s.name == "cp_als.mode"]
    assert len(modes) == 2 * st.ndim
    iter_ids = {s.span_id for s in iters}
    assert all(m.parent_id in iter_ids for m in modes)
    root = [s for s in spans if s.name == "cp_als.decompose"]
    assert len(root) == 1 and root[0].attrs["nnz"] == 20


def test_autotune_emits_probe_and_decision_spans(tmp_path):
    from repro.core import random_tensor
    from repro.engine import autotune_engine, TunePolicy
    from repro.engine.registry import EngineContext
    st = random_tensor((6, 5, 4), 30, seed=1)
    policy = TunePolicy(candidates=("ref", "chunked"), warmup=0, reps=1,
                        store=str(tmp_path / "store.json"))
    with capture() as spans:
        _eng, rep = autotune_engine(EngineContext(st=st, rank=2), tune=policy)
    probes = [s for s in spans if s.name == "autotune.probe"]
    assert len(probes) == rep.n_probes + rep.n_elided
    assert all(s.attrs["provenance"] == "measured" for s in probes
               if s.attrs.get("seconds") is not None)
    (decision,) = [s for s in spans if s.name == "autotune.decision"]
    assert decision.attrs["source"] == "measured"
    # Warm second call: zero probes, a persisted decision record.
    with capture() as spans2:
        _eng2, rep2 = autotune_engine(EngineContext(st=st, rank=2),
                                      tune=policy)
    assert rep2.source == "persisted"
    assert [s.name for s in spans2] == ["autotune.decision"]
    assert spans2[0].attrs["source"] == "persisted"


def test_report_to_dict_and_breakdown(tmp_path):
    from repro.core import random_tensor
    from repro.engine import autotune_engine, TunePolicy
    from repro.engine.registry import EngineContext
    st = random_tensor((5, 4, 3), 25, seed=2)
    policy = TunePolicy(candidates=("ref", "chunked"), warmup=0, reps=1,
                        store=str(tmp_path / "s.json"))
    _eng, rep = autotune_engine(EngineContext(st=st, rank=2), tune=policy)
    d = rep.to_dict()
    json.dumps(d)  # JSON-safe end to end
    assert d["source"] == "measured"
    assert d["probes"] == {"measured": rep.n_probes, "elided": rep.n_elided,
                           "persisted": 0}
    assert set(d["winners"]) == set(range(st.ndim))
    assert "probes: measured=" in rep.summary()
    _eng2, rep2 = autotune_engine(EngineContext(st=st, rank=2), tune=policy)
    assert rep2.to_dict()["probes"]["persisted"] == st.ndim
    assert "persisted=3" in rep2.summary()


def test_sweep_cell_spans_carry_fingerprint(tmp_path):
    from repro.sweep import run_sweep
    from repro.sweep.config import SweepConfig, TensorBand
    from repro.sweep.runner import cell_key
    cfg = SweepConfig(
        name="obs-smoke",
        tensors=(TensorBand(name="b0", shape=(5, 4, 3), nnz=(16,),
                            distribution="uniform", seed=0),),
        ranks=(2,), candidates=("ref",), warmup=0, reps=1)
    with capture() as spans:
        result = run_sweep(cfg, str(tmp_path / "store.json"))
    cells = [s for s in spans if s.name == "sweep.cell"]
    assert len(cells) == 1
    keys = {cell_key(c, cfg).fingerprint() for c in cfg.cells()}
    assert cells[0].attrs["fingerprint"] in keys
    # Probe/decision spans nest under the cell span.
    children = [s for s in spans if s.parent_id == cells[0].span_id]
    assert any(s.name == "autotune.decision" for s in children) or \
        any(s.name == "autotune.probe" for s in spans)
    assert result.count("measured") == 1
