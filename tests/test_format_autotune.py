"""Accuracy-budgeted format autotuning: the candidate space widens to
(backend × fixed-point preset) behind `accuracy_budget=`, every lossy
candidate is policed by its measured MTTKRP error, over-budget candidates
are rejected before ranking, and the budget + errors persist with the
tuning store so warm hits only apply when the budget still covers them."""
import numpy as np
import pytest

from repro.core import cp_als, fit_value, random_tensor
from repro.core.qformat import CROSS_MODE_SLACK, FIXED_PRESETS
from repro.engine import (
    CostModelPrior,
    PlanCache,
    TuningStore,
    WorkloadKey,
    backend_table,
    budget_covers,
    build_engine,
    byte_terms,
    candidate_lossless,
    parse_candidate,
    preset_candidates,
)
from repro.engine import autotune as _autotune

KW = dict(chunk_shape=(8, 8, 8), capacity=64)
FMT_CANDS = ["chunked", "fixed:int3", "fixed:int7", "fixed:int15-12"]


def _probe_counter(monkeypatch):
    calls = []
    real = _autotune._time_call

    def counting(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(_autotune, "_time_call", counting)
    return calls


def _rig_clock(monkeypatch, seconds_of):
    """Deterministic probe clock: `seconds_of(candidate, mode) -> seconds`."""
    def fake(name, engine, factors, mode, *, warmup, reps):
        return seconds_of(name, mode)
    monkeypatch.setattr(_autotune, "_time_backend", fake)


# ---------------------------------------------------------------------------
# Candidate ids
# ---------------------------------------------------------------------------

def test_parse_candidate_and_preset_enumeration():
    assert parse_candidate("chunked") == ("chunked", None)
    assert parse_candidate("fixed") == ("fixed", None)
    assert parse_candidate("fixed:int7") == ("fixed", "int7")
    with pytest.raises(ValueError, match="no preset 'int9'"):
        parse_candidate("fixed:int9")
    with pytest.raises(ValueError, match="no preset"):
        parse_candidate("chunked:int7")  # lossless backends have no presets
    with pytest.raises(ValueError, match="unknown engine"):
        parse_candidate("bogus:int7")
    assert set(preset_candidates()) == {
        f"fixed:{p}" for p in FIXED_PRESETS}
    assert candidate_lossless("chunked")
    assert not candidate_lossless("fixed")
    assert not candidate_lossless("fixed:int7")
    assert not candidate_lossless("never_registered")


def test_explicit_preset_candidate_builds_that_preset():
    st = random_tensor((20, 16, 24), 400, seed=1)
    rank = 4
    pinned = build_engine(st, "fixed:int15-12", rank, plans=PlanCache(), **KW)
    assert pinned.context.fixed_preset == "int15-12"
    assert pinned.name == "fixed:int15-12"
    via_option = build_engine(st, "fixed", rank, plans=PlanCache(),
                              fixed_preset="int15-12", **KW)
    rng = np.random.default_rng(2)
    factors = tuple(np.asarray(rng.uniform(-1, 1, (d, rank)), np.float32)
                    for d in st.shape)
    for mode in range(st.ndim):
        np.testing.assert_array_equal(np.asarray(pinned(factors, mode)),
                                      np.asarray(via_option(factors, mode)))


def test_backend_table_lists_presets():
    table = backend_table()
    assert "presets" in table.splitlines()[0]
    assert "`int7`" in table
    assert "`int15-12`" in table


# ---------------------------------------------------------------------------
# Budgeted candidate space + rejection before ranking
# ---------------------------------------------------------------------------

def test_budget_widens_default_candidates_and_none_keeps_lossless():
    st = random_tensor((20, 16, 24), 400, seed=2)
    plain = build_engine(st, "auto", 4, plans=PlanCache(), **KW)
    assert all(candidate_lossless(c) for c in plain.report.candidates)
    assert plain.report.accuracy_budget is None

    budgeted = build_engine(st, "auto", 4, plans=PlanCache(),
                            accuracy_budget=0.5, **KW)
    rep = budgeted.report
    assert set(preset_candidates()) <= set(rep.candidates)
    assert rep.accuracy_budget == 0.5
    # every surviving lossy candidate has a measured error per probed mode
    for cand, per_mode in rep.timings.items():
        if not candidate_lossless(cand):
            assert set(rep.errors[cand]) >= set(per_mode)
            assert all(e <= 0.5 for e in rep.errors[cand].values())


def test_over_budget_candidate_rejected_before_ranking():
    st = random_tensor((20, 16, 24), 400, seed=3)
    eng = build_engine(st, "auto", 4, plans=PlanCache(),
                       accuracy_budget=1e-9, candidates=FMT_CANDS, **KW)
    rep = eng.report
    # every lossy candidate measured over the (absurd) budget and none won
    assert set(rep.winners.values()) == {"chunked"}
    for cand in FMT_CANDS[1:]:
        assert "over accuracy budget" in rep.skipped[cand], rep.skipped
        assert cand not in rep.timings
    # the rejected candidates' real measurements are still reported
    assert any(rep.errors.get(c) for c in FMT_CANDS[1:])


def test_budget_validation():
    st = random_tensor((20, 16, 24), 300, seed=4)
    with pytest.raises(ValueError, match="accuracy_budget.*> 0"):
        build_engine(st, "auto", 4, plans=PlanCache(), accuracy_budget=0.0,
                     **KW)
    with pytest.raises(ValueError, match="accuracy_budget.*> 0"):
        build_engine(st, "auto", 4, plans=PlanCache(), accuracy_budget=-0.1,
                     **KW)
    with pytest.raises(ValueError, match="only applies to engine='auto'"):
        build_engine(st, "chunked", 4, plans=PlanCache(),
                     accuracy_budget=0.1, **KW)
    with pytest.raises(ValueError, match="only applies to engine='auto'"):
        cp_als(st, 4, n_iters=1, engine=lambda f, m: None,
               accuracy_budget=0.1)


def test_rigged_clock_selects_fixed_point_winner(monkeypatch):
    """When a fixed-point preset is genuinely fastest and within budget, the
    tuner must select it — and cp_als must report its measured quantization
    error while keeping the exact (slow-path) fit."""
    _rig_clock(monkeypatch, lambda n, m: 1e-4 if n == "fixed:int7" else 1e-2)
    st = random_tensor((18, 14, 16), 500, seed=12)
    res = cp_als(st, 4, n_iters=2, engine="auto", accuracy_budget=0.9,
                 candidates=FMT_CANDS, plans=PlanCache(), seed=13,
                 track_diff=False, **KW)
    rep = res.tune_report
    assert set(rep.winners.values()) == {"fixed:int7"}
    assert res.engine == "auto:fixed:int7"
    # measured quantization error surfaces on the result
    assert res.quant_error is not None
    assert res.quant_error == max(rep.errors["fixed:int7"].values())
    # lossy winner keeps the factors-only fit slow path
    slow = fit_value(st, res.factors, res.lam)
    assert abs(res.fit_history[-1] - slow) < 1e-6


def test_quant_error_measured_on_lossy_mode_without_budget(monkeypatch):
    """Legacy path (explicit lossy candidate, no budget, so no recorded
    errors): CPResult.quant_error must be measured on a mode the lossy
    winner actually serves — the dispatcher may route the last mode to a
    lossless backend, whose float noise is not a quantization error."""
    # fixed:int7 wins mode 0 only; chunked wins every other mode
    _rig_clock(monkeypatch,
               lambda n, m: 1e-4 if (n == "fixed:int7") == (m == 0) else 1e-2)
    st = random_tensor((18, 14, 16), 500, seed=14)
    res = cp_als(st, 4, n_iters=1, engine="auto",
                 candidates=["chunked", "fixed:int7"], plans=PlanCache(),
                 seed=15, track_diff=False, **KW)
    rep = res.tune_report
    assert rep.winners[0] == "fixed:int7"
    assert rep.winners[st.ndim - 1] == "chunked"
    assert rep.errors == {}                      # no budget, none recorded
    # int7 quantization noise is ~1e-2; float reduction noise is ~1e-7
    assert res.quant_error is not None
    assert res.quant_error > 1e-4


def test_conflicting_preset_spellings_rejected():
    st = random_tensor((20, 16, 24), 300, seed=9)
    with pytest.raises(ValueError, match="conflicting presets"):
        build_engine(st, "fixed:int7", 4, plans=PlanCache(),
                     fixed_preset="int15-12", **KW)
    # agreeing spellings are fine
    eng = build_engine(st, "fixed:int7", 4, plans=PlanCache(),
                       fixed_preset="int7", **KW)
    assert eng.context.fixed_preset == "int7"


def test_cross_mode_bound_rejects_under_elision(monkeypatch):
    """Under elision the un-probed modes lean on the quantization model: a
    budget between the measured anchor error and slack × anchor admits the
    candidate on a full sweep but must reject it when the other modes were
    never measured.  The clock is rigged to keep the lossy candidate out of
    the re-probe boundary, so its non-anchor modes deterministically stay
    un-measured."""
    st = random_tensor((20, 16, 24), 400, seed=5)
    cands = ["chunked", "ref", "fixed:int7"]
    # fixed:int7 is clearly slowest: it never wins a mode and (under
    # elision with a tight margin) is never re-probed off the anchor
    _rig_clock(monkeypatch, lambda n, m: 1e-2 if n == "fixed:int7" else 1e-4)

    full = build_engine(st, "auto", 4, plans=PlanCache(), candidates=cands,
                        accuracy_budget=0.9, elide=False, **KW)
    errs = full.report.errors["fixed:int7"]
    assert set(errs) == set(range(st.ndim))
    anchor_err, worst = errs[0], max(errs.values())

    # budget strictly between the worst measured error and slack × anchor:
    # full probing admits, elision (bounded, not measured) must not
    budget = min(worst * 1.2, CROSS_MODE_SLACK * anchor_err * 0.9)
    if budget <= worst:  # guard: errors too uniform to separate the regimes
        budget = worst * 1.05
        assert budget < CROSS_MODE_SLACK * anchor_err
    admitted = build_engine(st, "auto", 4, plans=PlanCache(),
                            candidates=cands, accuracy_budget=budget,
                            elide=False, **KW)
    assert "fixed:int7" in admitted.report.timings

    elided = build_engine(st, "auto", 4, plans=PlanCache(), candidates=cands,
                          accuracy_budget=budget, elide=True,
                          elide_margin=1.0, **KW)
    rep = elided.report
    assert "fixed:int7" not in rep.timings
    assert "un-probed" in rep.skipped["fixed:int7"]
    assert all(candidate_lossless(w) for w in rep.winners.values())


# ---------------------------------------------------------------------------
# Store: budget + errors persist, warm hits gated by budget_covers
# ---------------------------------------------------------------------------

def test_budget_covers_semantics():
    assert budget_covers(None, None)
    assert budget_covers(0.1, 0.1)
    assert budget_covers(0.1, 0.5)      # looser request: winners still valid
    assert not budget_covers(0.1, 0.01)  # stricter: must re-validate
    assert not budget_covers(0.1, None)  # lossless-only request
    assert not budget_covers(None, 0.1)  # entry never measured errors


def test_store_roundtrips_budget_and_errors(tmp_path):
    st = random_tensor((20, 16, 24), 400, seed=6)
    path = tmp_path / "t.json"
    key = WorkloadKey.from_tensor(st, 4, FMT_CANDS)
    errors = {"fixed:int7": {0: 0.01, 1: 0.02, 2: 0.015}}
    TuningStore(path).record(key, {0: "fixed:int7", 1: "chunked", 2: "chunked"},
                             {"chunked": {0: 2e-3, 1: 1e-3, 2: 1e-3},
                              "fixed:int7": {0: 1e-3, 1: 2e-3, 2: 2e-3}},
                             budget=0.05, errors=errors)
    entry = TuningStore(path).lookup(key)
    assert entry.budget == 0.05
    assert entry.errors == errors
    assert all(isinstance(m, int)
               for per in entry.errors.values() for m in per)
    # budget-aware lookup
    assert TuningStore(path).lookup(key, budget=0.05) is not None
    assert TuningStore(path).lookup(key, budget=0.5) is not None
    assert TuningStore(path).lookup(key, budget=0.01) is None
    assert TuningStore(path).lookup(key, budget=None) is None


def test_warm_hits_gated_by_budget(tmp_path, monkeypatch):
    st = random_tensor((30, 24, 36), 700, seed=7)
    path = tmp_path / "t.json"
    cold = build_engine(st, "auto", 4, plans=PlanCache(),
                        store=TuningStore(path), accuracy_budget=0.5,
                        candidates=FMT_CANDS, **KW)
    assert cold.report.source == "measured"

    calls = _probe_counter(monkeypatch)
    same = build_engine(st, "auto", 4, plans=PlanCache(),
                        store=TuningStore(path), accuracy_budget=0.5,
                        candidates=FMT_CANDS, **KW)
    assert calls == []
    assert same.report.source == "persisted"
    assert same.report.winners == cold.report.winners
    assert same.report.errors == cold.report.errors

    looser = build_engine(st, "auto", 4, plans=PlanCache(),
                          store=TuningStore(path), accuracy_budget=0.9,
                          candidates=FMT_CANDS, **KW)
    assert calls == []
    assert looser.report.source == "persisted"

    stricter = build_engine(st, "auto", 4, plans=PlanCache(),
                            store=TuningStore(path), accuracy_budget=1e-9,
                            candidates=FMT_CANDS, **KW)
    assert stricter.report.source == "measured"   # re-probed
    assert len(calls) > 0
    assert all(candidate_lossless(w)
               for w in stricter.report.winners.values())

    calls.clear()
    none_req = build_engine(st, "auto", 4, plans=PlanCache(),
                            store=TuningStore(path),
                            candidates=FMT_CANDS, **KW)
    assert none_req.report.source == "measured"   # budgeted entry can't serve
    assert len(calls) > 0


# ---------------------------------------------------------------------------
# Cost model: width-aware byte terms rank presets on cold start
# ---------------------------------------------------------------------------

def test_byte_terms_scale_with_preset_width():
    st = random_tensor((40, 32, 24), 2000, seed=8)
    narrow = {p: byte_terms(f"fixed:{p}", st, 8, 0)[3] for p in FIXED_PRESETS}
    assert narrow["int3"] < narrow["int7"] < narrow["int15-12"]
    # lossless backends move no narrow bytes
    for name in ("ref", "alto", "chunked", "hetero"):
        assert byte_terms(name, st, 8, 0)[3] == 0.0
    # bare "fixed" prices the int16 default preset
    assert byte_terms("fixed", st, 8, 0) == byte_terms("fixed:int7", st, 8, 0)


def test_prior_ranks_narrower_presets_cheaper():
    st = random_tensor((120, 100, 80), 200_000, seed=9)
    prior = CostModelPrior()
    order = prior.order(st, 16, [f"fixed:{p}" for p in FIXED_PRESETS])
    assert order == ["fixed:int3", "fixed:int7", "fixed:int15-12"]
    # a slower narrow path re-ranks against the float backends
    slow_narrow = CostModelPrior(narrow_bandwidth=1e8)
    assert (slow_narrow.seconds("fixed:int7", st, 16, 0)
            > prior.seconds("fixed:int7", st, 16, 0))
    # preset variants share their family's dispatch overhead
    tuned = CostModelPrior(dispatch_overheads={"fixed": 0.123})
    assert tuned.dispatch("fixed:int3") == 0.123


def test_calibration_recovers_narrow_bandwidth(tmp_path):
    """With lossy observations in the store the NNLS learns the narrow-int
    throughput term; without them the coefficient falls back silently."""
    from repro.engine import CalibratedPrior, WorkloadStats, device_fingerprint

    gt = CostModelPrior(bandwidth=5e9, narrow_bandwidth=1.2e9,
                        chunk_padding=1.6, hetero_overhead=1.4,
                        dispatch_s=2e-4)
    cands = ["alto", "chunked", "hetero", "ref", "fixed:int3", "fixed:int7",
             "fixed:int15-12"]
    store = TuningStore(tmp_path / "synth.json")
    for shape, nnz in [((200, 160, 240), 50_000), ((400, 320, 120), 200_000),
                       ((160, 480, 200, 40), 500_000),
                       ((800, 100, 300), 1_000_000)]:
        key = WorkloadKey(
            shape=shape, nnz=nnz, density=nnz / np.prod(shape),
            ndim=len(shape), rank=4, candidates=tuple(sorted(cands)),
            device=tuple(sorted(device_fingerprint().items())))
        stats = WorkloadStats.from_key(key)
        timings = {c: {m: gt.seconds(c, stats, 4, m)
                       for m in range(len(shape))} for c in cands}
        winners = {m: min(cands, key=lambda c, m=m, t=timings: t[c][m])
                   for m in range(len(shape))}
        store.record(key, winners, timings)
    prior = CalibratedPrior.from_store(store)
    assert prior.used_fit
    assert prior.bandwidth == pytest.approx(gt.bandwidth, rel=0.15)
    assert prior.narrow_bandwidth == pytest.approx(gt.narrow_bandwidth,
                                                   rel=0.15)
    assert "narrow_bandwidth" in prior.calibration.fitted
