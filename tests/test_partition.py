"""Partitioner invariants (hypothesis property tests) — paper §IV-A/B."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container — deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import decide_partition, random_tensor
from repro.core.chunking import chunk_tensor, replication_stats


@settings(max_examples=25, deadline=None)
@given(
    ndim=st.integers(3, 5),
    nnz=st.integers(50, 2000),
    seed=st.integers(0, 1000),
    dist=st.sampled_from(["uniform", "powerlaw"]),
)
def test_chunking_preserves_every_nonzero(ndim, nnz, seed, dist):
    dims = tuple(np.random.default_rng(seed).integers(8, 60, ndim))
    st_ = random_tensor(dims, nnz, seed=seed, distribution=dist)
    cs = tuple(max(d // 3, 1) for d in dims)
    ct = chunk_tensor(st_, cs, capacity=16)
    # every nonzero appears exactly once, with correct global coordinates
    assert ct.nnz == st_.nnz
    got = []
    for t in range(ct.num_tasks):
        c = int(ct.nnz_per_task[t])
        coords = ct.coords_rel[t, :c] + ct.task_chunk[t] * np.asarray(cs)
        for i in range(c):
            got.append((tuple(coords[i]), float(ct.values[t, i])))
    want = sorted((tuple(c), float(v))
                  for c, v in zip(st_.coords, st_.values, strict=True))
    assert sorted(got) == want


@settings(max_examples=25, deadline=None)
@given(
    ndim=st.integers(3, 5),
    nnz=st.integers(100, 3000),
    cap=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 1000),
)
def test_capacity_respected_and_coords_in_range(ndim, nnz, cap, seed):
    dims = tuple(np.random.default_rng(seed + 1).integers(6, 40, ndim))
    st_ = random_tensor(dims, nnz, seed=seed, distribution="powerlaw")
    cs = tuple(max(d // 2, 1) for d in dims)
    ct = chunk_tensor(st_, cs, capacity=cap)
    assert int(ct.nnz_per_task.max()) <= cap  # nonzero partitioning applied
    for m in range(ndim):
        assert ct.coords_rel[..., m].max() < cs[m]
        assert ct.coords_rel.min() >= 0


@settings(max_examples=20, deadline=None)
@given(
    nnz=st.integers(100, 20_000),
    rank=st.integers(2, 64),
    mem_kb=st.sampled_from([4, 64, 1024]),
    seed=st.integers(0, 100),
)
def test_decider_memory_budget_holds(nnz, rank, mem_kb, seed):
    dims = tuple(np.random.default_rng(seed).integers(16, 300, 3))
    st_ = random_tensor(dims, nnz, seed=seed)
    plan = decide_partition(st_, rank, mem_bytes=mem_kb * 1024,
                            n_devices=256, rank_axis=4)
    # the plan's own accounting must respect the budget (Fig. 5 invariant)
    assert plan.mem_bytes_per_device <= mem_kb * 1024 or plan.capacity == 1
    assert plan.capacity >= 1
    assert all(c >= 1 for c in plan.chunk_shape)
    # decider drives device density to at least tensor density (balanced case)
    if plan.capacity > 1 and all(c > 1 for c in plan.chunk_shape):
        assert plan.device_density >= plan.tensor_density * 0.99


def test_decider_prefers_fewer_chunks_when_memory_allows():
    st_ = random_tensor((64, 64, 64), 1000, seed=0)
    big = decide_partition(st_, 10, mem_bytes=64 << 20, rank_axis=1)
    small = decide_partition(st_, 10, mem_bytes=16 << 10, rank_axis=1)
    assert big.est_chunks <= small.est_chunks


def test_replication_grows_with_finer_chunks():
    st_ = random_tensor((60, 60, 60), 5000, seed=1)
    coarse = chunk_tensor(st_, (30, 30, 30), capacity=4096)
    fine = chunk_tensor(st_, (10, 10, 10), capacity=4096)
    rc = replication_stats(coarse, 10, mode=0)
    rf = replication_stats(fine, 10, mode=0)
    assert rf["replication_factor"] >= rc["replication_factor"]
