"""Doc-anchor round-trips: every link the generated capability tables emit
must resolve to an `<a id=...>` anchor in the committed docs, and the
anchor parser itself must handle the idioms those docs use.  This is the
unit-test twin of the `registry-docs` analysis rule — it pins the parser's
behavior so the rule's zero-findings gate means what it says."""
import pytest

from repro.analysis import engine as _engine
from repro.analysis.docanchors import extract_anchor_refs, extract_anchors
from repro.engine.registry import backend_table, registered_backends
from repro.formats import format_table, registered_formats

REPO = _engine.default_root()
CANDIDATES = "docs/candidates.md"
ANALYSIS_DOC = "docs/static-analysis.md"


def test_extract_anchors_ids_and_lines():
    md = '# T\n<a id="alpha"></a>\ntext\n<a id="beta-2"></a> after\n'
    anchors = extract_anchors(md)
    assert anchors == {"alpha": 2, "beta-2": 4}


def test_extract_anchor_refs_targets_and_fragments():
    md = ("see [`csf`](docs/candidates.md#csf) and\n"
          "[same-doc](#preset-int7) plus [plain](docs/store-schema.md)\n")
    refs = extract_anchor_refs(md)
    assert ("docs/candidates.md", "csf", 1) in refs
    assert ("", "preset-int7", 2) in refs
    # links without a fragment are not anchor refs
    assert all(frag for _t, frag, _l in refs)


def _anchors(rel):
    path = REPO / rel
    assert path.is_file(), f"{rel} missing"
    return extract_anchors(path.read_text(encoding="utf-8"))


@pytest.mark.parametrize("table_fn", [backend_table, format_table],
                         ids=["backend_table", "format_table"])
def test_generated_table_refs_resolve(table_fn):
    anchors = _anchors(CANDIDATES)
    refs = [r for r in extract_anchor_refs(table_fn())
            if r[0] == CANDIDATES]
    assert refs, "generated table emitted no doc links"
    missing = sorted({frag for _t, frag, _l in refs} - set(anchors))
    assert not missing, f"unanchored fragments in {CANDIDATES}: {missing}"


def test_every_registered_id_is_anchored():
    anchors = _anchors(CANDIDATES)
    for name, spec in registered_backends().items():
        assert name in anchors, f"backend {name!r} has no anchor"
        for preset in spec.presets:
            assert f"preset-{preset}" in anchors, \
                f"preset {name}:{preset} has no anchor"
    for name in registered_formats():
        assert name in anchors, f"format {name!r} has no anchor"


def test_rule_table_refs_resolve_in_analysis_doc():
    from repro.analysis import rule_table

    anchors = _anchors(ANALYSIS_DOC)
    refs = [r for r in extract_anchor_refs(rule_table())
            if r[0] == ANALYSIS_DOC]
    assert refs
    missing = sorted({frag for _t, frag, _l in refs} - set(anchors))
    assert not missing, \
        f"rule ids without a docs section anchor in {ANALYSIS_DOC}: {missing}"


def test_plain_mode_tables_emit_no_links():
    for text in (backend_table(docs_base=None), format_table(docs_base=None)):
        assert not extract_anchor_refs(text)
