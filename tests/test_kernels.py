"""Pallas kernel validation (interpret mode): shape/dtype sweeps against the
pure-jnp oracles in kernels/ref.py, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container — deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import Q17_15, Q9_7, random_tensor, value_qformat
from repro.core.chunking import chunk_tensor
from repro.core.mttkrp import mttkrp_coo
from repro.kernels import mttkrp_fixed_pallas, mttkrp_pallas
from repro.kernels import ref as kref
from repro.kernels.mttkrp_fixed_kernel import mttkrp_fixed_pallas_local
from repro.kernels.mttkrp_kernel import mttkrp_pallas_local

SWEEP = [
    # shape, nnz, chunk_shape, capacity, rank
    ((32, 32, 32), 400, (8, 8, 8), 16, 4),
    ((40, 30, 50), 600, (16, 8, 16), 32, 8),
    ((17, 23, 9), 200, (8, 8, 4), 16, 3),
    ((20, 12, 20, 12), 300, (8, 4, 8, 4), 32, 5),
    ((8, 8, 8, 8, 8), 200, (4, 4, 4, 4, 4), 16, 2),
]


def _setup(shape, nnz, cs, cap, rank, seed=0):
    st_ = random_tensor(shape, nnz, seed=seed)
    rng = np.random.default_rng(seed + 1)
    factors = tuple(
        jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
        for d in shape)
    ct = chunk_tensor(st_, cs, capacity=cap)
    return st_, factors, ct


@pytest.mark.parametrize(("shape", "nnz", "cs", "cap", "rank"), SWEEP)
def test_float_kernel_local_vs_oracle(shape, nnz, cs, cap, rank):
    st_, factors, ct = _setup(shape, nnz, cs, cap, rank)
    from repro.kernels.ops import pad_factor
    padded = tuple(pad_factor(f, cs[m]) for m, f in enumerate(factors))
    tc = jnp.asarray(ct.task_chunk)
    cr = jnp.asarray(ct.coords_rel)
    vals = jnp.asarray(ct.values)
    for mode in range(len(shape)):
        got = mttkrp_pallas_local(padded, tc, cr, vals, mode=mode,
                                  chunk_shape=ct.chunk_shape, interpret=True)
        want = kref.mttkrp_local_ref(padded, tc, cr, vals, mode=mode,
                                     chunk_shape=ct.chunk_shape)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(("shape", "nnz", "cs", "cap", "rank"), SWEEP[:3])
@pytest.mark.parametrize(("qf", "prec_shift"), [(Q9_7, 0), (Q17_15, 3)])
def test_fixed_kernel_bit_exact_vs_oracle(shape, nnz, cs, cap, rank, qf,
                                          prec_shift):
    st_, factors, ct = _setup(shape, nnz, cs, cap, rank, seed=2)
    vq = value_qformat(st_.values)
    from repro.kernels.ops import pad_factor
    qfs = tuple(pad_factor(qf.quantize(f), cs[m])
                for m, f in enumerate(factors))
    tc = jnp.asarray(ct.task_chunk)
    cr = jnp.asarray(ct.coords_rel)
    qvals = jnp.asarray(vq.quantize_np(ct.values))
    for mode in range(len(shape)):
        got = mttkrp_fixed_pallas_local(
            qfs, tc, cr, qvals, mode=mode, chunk_shape=ct.chunk_shape,
            matrix_frac=qf.frac_bits, value_frac=vq.frac_bits,
            prec_shift=prec_shift, interpret=True)
        want = kref.mttkrp_fixed_local_ref(
            qfs, tc, cr, qvals, mode=mode, chunk_shape=ct.chunk_shape,
            matrix_frac=qf.frac_bits, value_frac=vq.frac_bits,
            prec_shift=prec_shift)
        assert bool(jnp.all(got == want)), f"mode {mode}"


@pytest.mark.parametrize(("shape", "nnz", "cs", "cap", "rank"), SWEEP[:2])
def test_full_pallas_op_vs_coo(shape, nnz, cs, cap, rank):
    st_, factors, ct = _setup(shape, nnz, cs, cap, rank, seed=3)
    for mode in range(len(shape)):
        ref = mttkrp_coo(factors, jnp.asarray(st_.coords),
                         jnp.asarray(st_.values), mode=mode,
                         out_dim=shape[mode])
        out = mttkrp_pallas(factors, jnp.asarray(ct.task_chunk),
                            jnp.asarray(ct.coords_rel), jnp.asarray(ct.values),
                            mode=mode, chunk_shape=ct.chunk_shape,
                            out_dim=shape[mode], interpret=True)
        np.testing.assert_allclose(ref, out, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    dims=st.tuples(*[st.integers(6, 24)] * 3),
    nnz=st.integers(20, 300),
    rank=st.integers(1, 9),
    chunk=st.sampled_from([4, 8, 16]),
    cap=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 10_000),
)
def test_property_pallas_float_any_shape(dims, nnz, rank, chunk, cap, seed):
    st_ = random_tensor(dims, nnz, seed=seed)
    rng = np.random.default_rng(seed)
    factors = tuple(
        jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
        for d in dims)
    cs = tuple(min(chunk, d) for d in dims)
    ct = chunk_tensor(st_, cs, capacity=cap)
    mode = seed % 3
    ref = mttkrp_coo(factors, jnp.asarray(st_.coords), jnp.asarray(st_.values),
                     mode=mode, out_dim=dims[mode])
    out = mttkrp_pallas(factors, jnp.asarray(ct.task_chunk),
                        jnp.asarray(ct.coords_rel), jnp.asarray(ct.values),
                        mode=mode, chunk_shape=ct.chunk_shape,
                        out_dim=dims[mode], interpret=True)
    np.testing.assert_allclose(ref, out, rtol=2e-4, atol=2e-4)
