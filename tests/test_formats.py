"""Pluggable sparse-format subsystem: COO↔CSF↔ALTO round-trips preserve the
(coords, values) multiset and `to_dense()` exactly (property tests + edge
cases); the `csf`/`alto` registry backends match the COO oracle on every
TABLE1 workload; the widened autotune candidate space persists format
candidate ids and serves them warm with zero probes; `FormatStats` feeds
width-aware byte terms the calibration can fit."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as hs
except ImportError:  # offline container — deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as hs

import jax.numpy as jnp

from repro.core import random_tensor, table1_tensor
from repro.core.mttkrp import mttkrp_alto, mttkrp_coo, mttkrp_csf
from repro.core.sptensor import TABLE1, SparseTensor
from repro.engine import (
    PlanCache,
    TuningStore,
    WorkloadKey,
    WorkloadStats,
    build_engine,
    byte_terms,
    registered_backends,
)
from repro.formats import (
    ALTOTensor,
    CSFModeTree,
    FormatCache,
    FormatStats,
    alto_key_bits,
    alto_positions,
    alto_to_coo,
    alto_to_csf,
    build_alto,
    build_csf_tree,
    coo_to_alto,
    coo_to_csf,
    csf_mode_order,
    csf_to_alto,
    csf_to_coo,
    fiber_count,
    format_table,
    get_format,
    register_format,
    registered_formats,
)


def _coord_set(st: SparseTensor) -> set:
    return {(*map(int, c), float(np.float32(v)))
            for c, v in zip(st.coords, st.values, strict=True)}


def _assert_same_tensor(a: SparseTensor, b: SparseTensor):
    """Conversion invariant: the (coords, values) multiset — and therefore
    the dense tensor — survives exactly (coords are unique post-_dedup, so
    set equality is multiset equality)."""
    assert a.shape == b.shape
    assert a.nnz == b.nnz
    assert _coord_set(a) == _coord_set(b)
    np.testing.assert_array_equal(a.to_dense(), b.to_dense())


def _factors(shape, rank, seed=2):
    rng = np.random.default_rng(seed)
    return tuple(jnp.asarray(rng.uniform(-1, 1, (d, rank)).astype(np.float32))
                 for d in shape)


# ---------------------------------------------------------------------------
# Format registry
# ---------------------------------------------------------------------------

def test_format_registry_capabilities_and_errors():
    specs = registered_formats()
    assert {"coo", "csf", "alto"} <= set(specs)
    assert specs["alto"].mode_agnostic
    assert specs["coo"].mode_agnostic
    assert not specs["csf"].mode_agnostic      # one tree per output mode
    assert specs["csf"].sorted_reduce
    with pytest.raises(ValueError, match="unknown format"):
        get_format("nonexistent")
    table = format_table()
    assert "`csf`" in table
    assert "`alto`" in table
    assert "`coo`" in table


def test_register_format_decorator_roundtrip():
    @register_format("_test_fmt", description="test-only")
    def _build(st, mode=0):
        return ("built", st.nnz, mode)
    try:
        st = random_tensor((8, 6, 4), 40, seed=1)
        assert get_format("_test_fmt").build(st, 1) == ("built", 40, 1)
    finally:
        import repro.formats as _formats
        _formats._REGISTRY.pop("_test_fmt", None)


def test_builders_reachable_through_registry():
    st = random_tensor((10, 8, 12), 120, seed=3)
    assert get_format("coo").build(st) is st
    assert isinstance(get_format("csf").build(st, 1), CSFModeTree)
    assert isinstance(get_format("alto").build(st), ALTOTensor)


# ---------------------------------------------------------------------------
# Round-trip property tests (hypothesis, with the offline fallback shim)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    dims=hs.tuples(hs.integers(1, 24), hs.integers(1, 24), hs.integers(1, 24)),
    nnz=hs.integers(0, 300),
    seed=hs.integers(0, 10_000),
    mode=hs.integers(0, 2),
    dist=hs.sampled_from(["uniform", "powerlaw"]),
)
def test_roundtrips_preserve_tensor(dims, nnz, seed, mode, dist):
    st = random_tensor(tuple(dims), nnz, seed=seed, distribution=dist)
    _assert_same_tensor(csf_to_coo(coo_to_csf(st, mode)), st)
    _assert_same_tensor(alto_to_coo(coo_to_alto(st)), st)
    # cross conversions compose through COO exactly
    _assert_same_tensor(alto_to_coo(csf_to_alto(coo_to_csf(st, mode))), st)
    _assert_same_tensor(csf_to_coo(alto_to_csf(coo_to_alto(st), mode)), st)


@settings(max_examples=20, deadline=None)
@given(
    dims=hs.tuples(hs.integers(2, 16), hs.integers(2, 16),
                   hs.integers(2, 16), hs.integers(2, 16)),
    nnz=hs.integers(1, 200),
    seed=hs.integers(0, 10_000),
)
def test_format_kernels_match_coo_oracle(dims, nnz, seed):
    st = random_tensor(tuple(dims), nnz, seed=seed)
    rank = 4
    factors = _factors(st.shape, rank, seed=seed + 1)
    at = build_alto(st)
    for mode in range(st.ndim):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=st.shape[mode])
        tree = build_csf_tree(st, mode)
        out = mttkrp_csf(
            factors, jnp.asarray(tree.inner_coord), jnp.asarray(tree.values),
            jnp.asarray(tree.fiber_ids), jnp.asarray(tree.fiber_coords),
            mode=mode, inner_mode=tree.inner_mode, mid_modes=tree.mid_modes,
            out_dim=st.shape[mode], n_fibers=tree.n_fibers)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-4, atol=1e-5)
        out2 = mttkrp_alto(factors, jnp.asarray(at.key_words),
                           jnp.asarray(at.values), mode=mode,
                           positions=at.positions, out_dim=st.shape[mode])
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out2),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize(("shape", "nnz"), [
    ((4, 5, 6), 0),        # empty tensor
    ((4, 5, 6), 1),        # single nonzero
    ((5, 1, 7), 20),       # a mode of size 1
    ((1, 1, 1), 1),        # all modes size 1
    ((9, 3), 12),          # 2-mode (no interior CSF levels)
])
def test_roundtrip_edge_cases(shape, nnz):
    st = random_tensor(shape, nnz, seed=9)
    for mode in range(st.ndim):
        _assert_same_tensor(csf_to_coo(coo_to_csf(st, mode)), st)
    _assert_same_tensor(alto_to_coo(coo_to_alto(st)), st)
    # kernels stay shape-correct (and exact-zero) on the empty tensor
    factors = _factors(shape, 3)
    at = build_alto(st)
    for mode in range(st.ndim):
        tree = build_csf_tree(st, mode)
        out = mttkrp_csf(
            factors, jnp.asarray(tree.inner_coord), jnp.asarray(tree.values),
            jnp.asarray(tree.fiber_ids), jnp.asarray(tree.fiber_coords),
            mode=mode, inner_mode=tree.inner_mode, mid_modes=tree.mid_modes,
            out_dim=shape[mode], n_fibers=tree.n_fibers)
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=shape[mode])
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out),
                                   rtol=1e-4, atol=1e-5)
        out2 = mttkrp_alto(factors, jnp.asarray(at.key_words),
                           jnp.asarray(at.values), mode=mode,
                           positions=at.positions, out_dim=shape[mode])
        np.testing.assert_allclose(np.asarray(ref), np.asarray(out2),
                                   rtol=1e-4, atol=1e-5)


def test_csf_tree_structure_sorted_for_segment_sum():
    st = random_tensor((12, 30, 8), 400, seed=4, distribution="powerlaw")
    for mode in range(3):
        tree = build_csf_tree(st, mode)
        root, mids, inner = csf_mode_order(st.shape, mode)
        assert (tree.mode, tree.mid_modes, tree.inner_mode) == (root, mids, inner)
        # the largest remaining dim sits innermost (mode 1 has size 30)
        assert inner == (1 if mode != 1 else 0)
        # both reduction levels run with indices_are_sorted=True
        assert (np.diff(tree.fiber_ids) >= 0).all()
        assert (np.diff(tree.fiber_coords[:, mode]) >= 0).all()
        assert tree.n_fibers == fiber_count(st, mode)
        assert tree.index_bytes > 0


def test_alto_positions_adaptive_and_exclusive():
    shape = (533, 17300, 2500, 140)     # delicious-like: 10+15+12+8 bits
    pos = alto_positions(shape)
    flat = [p for per in pos for p in per]
    assert len(flat) == len(set(flat)) == alto_key_bits(shape) == 45
    assert max(flat) == 44              # densely packed
    # short modes drop out of the rotation early (adaptive interleave)
    assert len(pos[3]) == 8
    assert len(pos[1]) == 15


def test_alto_key_width_guard():
    huge = SparseTensor(np.zeros((1, 3), np.int32), np.ones(1, np.float32),
                        (1 << 30, 1 << 30, 1 << 30))
    with pytest.raises(ValueError, match="key needs"):
        build_alto(huge)
    # the registry backend degrades to the ALTO-ordered COO baseline
    # instead of failing the build (the engine itself would need huge
    # factors, so only the build is exercised here)
    eng = build_engine(huge, "alto", 3, plans=PlanCache(),
                       formats=FormatCache())
    assert eng is not None


# ---------------------------------------------------------------------------
# FormatCache
# ---------------------------------------------------------------------------

def test_format_cache_builds_each_layout_once():
    st = random_tensor((20, 16, 24), 300, seed=5)
    fc = FormatCache()
    t0 = fc.csf(st, 0)
    assert fc.csf(st, 0) is t0
    assert fc.csf(st, 1) is not t0          # per-mode trees are distinct
    a0 = fc.alto(st)
    assert fc.alto(st) is a0
    d0 = fc.device_csf(st, 0)
    assert fc.device_csf(st, 0) is d0
    assert fc.device_alto(st) is fc.device_alto(st)
    assert fc.stats.csf_misses == 2
    assert fc.stats.csf_hits >= 2
    assert fc.stats.alto_misses == 1
    s = fc.format_stats(st)
    assert fc.format_stats(st) is s
    fc.clear()
    assert fc.csf(st, 0) is not t0


# ---------------------------------------------------------------------------
# Engine integration: acceptance criteria
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["csf", "alto"])
@pytest.mark.parametrize("tname", sorted(TABLE1))
def test_backend_matches_coo_on_every_table1_tensor(tname, backend):
    """Acceptance: `build_engine(st, "csf"/"alto")` within 1e-5 relative
    error of `mttkrp_coo` for every mode of every TABLE1 tensor (CI runs the
    same gate at full nnz in the format-parity job; the reduced nnz here
    keeps tier-1 fast without changing the property)."""
    st = table1_tensor(tname, nnz=4000)
    rank = 6
    factors = _factors(st.shape, rank)
    eng = build_engine(st, backend, rank, plans=PlanCache(),
                       formats=FormatCache())
    for mode in range(st.ndim):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=st.shape[mode])
        out = eng(factors, mode)
        assert out.shape == (st.shape[mode], rank)
        rel = (np.linalg.norm(np.asarray(out) - np.asarray(ref))
               / max(np.linalg.norm(np.asarray(ref)), 1e-30))
        assert rel <= 1e-5, (tname, backend, mode, rel)


def test_autotune_widened_space_persists_and_serves_warm(tmp_path):
    """Acceptance: the default candidate space includes the format backends,
    the tuner returns a valid pick, and the persisted entry (with its
    format candidate ids and FormatStats) is served warm — zero probes —
    on the second run."""
    st = random_tensor((30, 24, 36), 800, seed=6)
    path = tmp_path / "t.json"
    fc = FormatCache()
    cold = build_engine(st, "auto", 5, plans=PlanCache(), formats=fc,
                        store=TuningStore(path))
    rep = cold.report
    assert {"csf", "alto"} <= set(rep.candidates)
    assert rep.source == "measured"
    assert rep.n_probes > 0
    assert set(rep.winners) == {0, 1, 2}
    assert set(rep.winners.values()) <= set(registered_backends())

    entry = TuningStore(path).lookup(
        WorkloadKey.from_tensor(st, 5, rep.candidates))
    assert entry is not None
    assert {"csf", "alto"} <= set(entry.key.candidates)
    assert entry.format_stats is not None
    stats = FormatStats.from_json(entry.format_stats)
    assert stats.measured
    assert len(stats.fiber_counts) == st.ndim

    warm = build_engine(st, "auto", 5, plans=PlanCache(), formats=fc,
                        store=TuningStore(path))
    assert warm.report.source == "persisted"
    assert warm.report.n_probes == 0
    assert warm.report.winners == rep.winners
    # the warm engine still matches the oracle
    factors = _factors(st.shape, 5)
    ref = mttkrp_coo(factors, jnp.asarray(st.coords), jnp.asarray(st.values),
                     mode=1, out_dim=st.shape[1])
    np.testing.assert_allclose(np.asarray(ref), np.asarray(warm(factors, 1)),
                               rtol=1e-3, atol=1e-3)


def test_explicit_format_backend_winner_rebuilds_warm(tmp_path):
    """A persisted format-backend winner must rebuild through the registry
    on a warm start (candidate-id round-trip, not just name storage)."""
    from repro.engine import WorkloadKey
    st = random_tensor((20, 16, 24), 400, seed=7)
    path = tmp_path / "t.json"
    cands = ["csf", "alto", "ref"]
    key = WorkloadKey.from_tensor(st, 4, cands)
    TuningStore(path).record(
        key, {0: "csf", 1: "alto", 2: "csf"},
        {"csf": {0: 1e-4, 1: 3e-4, 2: 1e-4}, "alto": {0: 2e-4, 1: 1e-4, 2: 2e-4},
         "ref": {0: 5e-4, 1: 5e-4, 2: 5e-4}},
        format_stats=FormatStats.from_tensor(st).to_json())
    eng = build_engine(st, "auto", 4, plans=PlanCache(), formats=FormatCache(),
                       store=TuningStore(path), candidates=cands)
    assert eng.report.source == "persisted"
    assert eng.name == "auto:alto+csf"
    factors = _factors(st.shape, 4)
    for mode in range(3):
        ref = mttkrp_coo(factors, jnp.asarray(st.coords),
                         jnp.asarray(st.values), mode=mode,
                         out_dim=st.shape[mode])
        np.testing.assert_allclose(np.asarray(ref),
                                   np.asarray(eng(factors, mode)),
                                   rtol=1e-4, atol=1e-5)


def test_cp_als_runs_on_format_backends():
    from repro.core import cp_als
    st = random_tensor((20, 16, 24), 400, seed=8)
    ref = cp_als(st, 4, n_iters=2, engine="ref", seed=0)
    for backend in ("csf", "alto"):
        res = cp_als(st, 4, n_iters=2, engine=backend, seed=0,
                     formats=FormatCache())
        assert res.engine == backend
        np.testing.assert_allclose(res.fit_history, ref.fit_history,
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# FormatStats → cost model
# ---------------------------------------------------------------------------

def test_format_stats_measured_vs_estimate():
    st = table1_tensor("nell2", nnz=4000)
    measured = FormatStats.from_tensor(st)
    est = FormatStats.estimate(st.shape, st.nnz)
    assert measured.measured
    assert not est.measured
    assert measured.key_bits == est.key_bits
    assert all(0 < f <= st.nnz for f in measured.fiber_counts)
    # uniform draws: the balls-in-bins estimate lands near the real count
    for m, e in zip(measured.fiber_counts, est.fiber_counts, strict=True):
        assert abs(m - e) / m < 0.15, (measured.fiber_counts, est.fiber_counts)
    # round-trips through JSON (what the tuning store persists)
    assert FormatStats.from_json(measured.to_json()) == measured


def test_format_stats_estimate_edges():
    est = FormatStats.estimate((5, 4, 3), 0)
    assert est.fiber_counts == (0, 0, 0)
    assert est.nnz == 0
    one = FormatStats.estimate((1, 1, 1), 1)
    assert one.fiber_counts == (1, 1, 1)
    big = FormatStats.estimate((10**6, 10**6, 10**6), 1000)
    assert all(f == 1000 for f in big.fiber_counts)  # no collisions expected


def test_byte_terms_have_indexed_component_for_formats():
    st = random_tensor((40, 32, 24), 2000, seed=9)
    for name in ("csf", "alto"):
        terms = byte_terms(name, st, 8, 0)
        assert len(terms) == 5, (name, terms)
        assert terms[4] > 0.0, (name, terms)
    for name in ("ref", "chunked", "hetero", "fixed", "fixed:int3"):
        assert byte_terms(name, st, 8, 0)[4] == 0.0
    # measured stats flow through a WorkloadStats wrapper
    ws = WorkloadStats(shape=st.shape, nnz=st.nnz,
                       format_stats=FormatStats.from_tensor(st))
    assert byte_terms("csf", ws, 8, 0)[4] > 0.0
    # ALTO's one packed key stream is smaller than COO's coordinate columns
    fs = FormatStats.from_tensor(st)
    assert fs.alto_index_bytes() < fs.coo_index_bytes()


def test_csf_prior_prefers_long_fibers():
    """The cost model must rank csf ahead of ref when fibers are long (few
    fibers, lots of reuse) and not when every nonzero is its own fiber."""
    from repro.engine import CostModelPrior
    prior = CostModelPrior()
    long_f = WorkloadStats(
        shape=(100, 100, 100_000), nnz=1_000_000,
        format_stats=FormatStats(shape=(100, 100, 100_000), nnz=1_000_000,
                                 fiber_counts=(10_000, 10_000, 1_000_000),
                                 key_bits=31, key_words=1))
    assert (prior.seconds("csf", long_f, 16, 0)
            < prior.seconds("ref", long_f, 16, 0))
    # degenerate fibers (one nonzero each) kill the reuse advantage
    frag = WorkloadStats(
        shape=(100, 100, 100_000), nnz=1_000_000,
        format_stats=FormatStats(shape=(100, 100, 100_000), nnz=1_000_000,
                                 fiber_counts=(1_000_000,) * 3,
                                 key_bits=31, key_words=1))
    assert (prior.seconds("csf", frag, 16, 0)
            > prior.seconds("csf", long_f, 16, 0))


def test_calibration_learns_indexed_bandwidth(tmp_path):
    """With format-backend observations in the store the NNLS learns the
    indexed-traffic throughput; the persisted FormatStats feed the design
    columns."""
    from repro.engine import (
        CalibratedPrior,
        CostModelPrior,
        WorkloadKey,
        device_fingerprint,
    )
    gt = CostModelPrior(bandwidth=5e9, indexed_bandwidth=1.1e9,
                        chunk_padding=1.6, dispatch_s=2e-4)
    cands = ["ref", "chunked", "csf", "alto"]
    store = TuningStore(tmp_path / "synth.json")
    for shape, nnz in [((200, 160, 240), 50_000), ((400, 320, 120), 200_000),
                       ((160, 480, 200, 40), 500_000),
                       ((800, 100, 300), 1_000_000)]:
        key = WorkloadKey(
            shape=shape, nnz=nnz, density=nnz / float(np.prod(shape)),
            ndim=len(shape), rank=4, candidates=tuple(sorted(cands)),
            device=tuple(sorted(device_fingerprint().items())))
        fstats = FormatStats.estimate(shape, nnz)
        stats = WorkloadStats.from_key(key, format_stats=fstats)
        timings = {c: {m: gt.seconds(c, stats, 4, m)
                       for m in range(len(shape))} for c in cands}
        winners = {m: min(cands, key=lambda c, m=m, t=timings: t[c][m])
                   for m in range(len(shape))}
        store.record(key, winners, timings, format_stats=fstats.to_json())
    prior = CalibratedPrior.from_store(store)
    assert prior.used_fit
    assert prior.bandwidth == pytest.approx(gt.bandwidth, rel=0.15)
    assert prior.indexed_bandwidth == pytest.approx(gt.indexed_bandwidth,
                                                    rel=0.15)
    assert "indexed_bandwidth" in prior.calibration.fitted
