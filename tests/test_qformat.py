"""Qm.n fixed-point round-trip bounds (paper §IV-C) — the per-element
guarantees the autotuner's accuracy-budget check builds on: quantization is
off by at most half a step on in-range values, and `value_qformat` always
picks a precision whose range covers the sampled tensor values."""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container — deterministic replay shim
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.qformat import (
    CROSS_MODE_SLACK,
    FIXED_PRESETS,
    QFormat,
    cross_mode_error_bound,
    preset_error_bound,
    value_qformat,
)


@settings(max_examples=30, deadline=None)
@given(
    preset=st.sampled_from(sorted(FIXED_PRESETS)),
    seed=st.integers(0, 10_000),
    n=st.integers(1, 512),
)
def test_roundtrip_error_within_half_step_on_linf_normalized(preset, seed, n):
    """quantize→dequantize error ≤ 1/(2·scale) for every preset, on inputs
    in the L∞-normalized [-1, 1] range CP-ALS feeds the fixed engines."""
    qf, _shift = FIXED_PRESETS[preset]
    x = np.random.default_rng(seed).uniform(-1.0, 1.0, n).astype(np.float32)
    # numpy path (build-time value quantization)
    back_np = qf.quantize_np(x).astype(np.float64) / qf.scale
    assert np.max(np.abs(back_np - x)) <= qf.max_abs_error + 1e-9
    # jnp path (per-call factor quantization) — float32 rounding of x/scale
    # itself can add at most a few ulps on top of the half-step bound
    back_j = np.asarray(qf.dequantize(qf.quantize(x)))
    assert np.max(np.abs(back_j - x)) <= qf.max_abs_error * (1 + 1e-5) + 1e-6
    assert qf.max_abs_error == 1.0 / (2 * qf.scale)


@settings(max_examples=30, deadline=None)
@given(
    # up to ~2^14: beyond that a 16-bit storage cannot cover the range at
    # all (int_bits saturates at 15), so "covers the sample" stops being a
    # property the chooser can honor
    vmax=st.floats(1e-3, 1.6e4),
    seed=st.integers(0, 10_000),
    n=st.integers(1, 256),
)
def test_value_qformat_range_covers_sampled_values(vmax, seed, n):
    """The runtime-chosen value format must represent max|value| without
    saturating: every sampled value round-trips within half a step."""
    rng = np.random.default_rng(seed)
    values = (rng.uniform(-1.0, 1.0, n) * vmax).astype(np.float64)
    vq = value_qformat(values)
    assert vq.storage_bits == 16
    # the format's representable range covers the sample
    assert vq.max_int / vq.scale >= np.max(np.abs(values)) * (1 - 1e-6)
    back = vq.quantize_np(values).astype(np.float64) / vq.scale
    assert np.max(np.abs(back - values)) <= vq.max_abs_error + 1e-12


def test_value_qformat_empty_and_degenerate():
    vq = value_qformat(np.asarray([]))
    assert vq.storage_bits == 16
    # all-zero values: any precision works, the chosen one must be valid
    vq0 = value_qformat(np.zeros(5))
    assert vq0.int_bits + vq0.frac_bits == 16


@pytest.mark.parametrize("ndim", [3, 4, 5])
def test_preset_error_estimates_order_the_presets(ndim):
    """Coarser formats must carry larger first-order error estimates — the
    ordering (not the absolute value) is what cold-start reasoning uses."""
    b = {p: preset_error_bound(p, ndim) for p in FIXED_PRESETS}
    assert b["int3"] > b["int7"] > 0
    # int15-12 trades prec_shift truncation against a much finer scale and
    # still lands well under int3
    assert b["int15-12"] < b["int3"]
    # more modes, more quantized gathers, more error
    for p in FIXED_PRESETS:
        assert preset_error_bound(p, ndim + 1) > preset_error_bound(p, ndim)


def test_cross_mode_bound_prefers_measurement_over_model():
    """With measurements the bound is slack × worst-measured; without, the
    analytic estimate (with the same headroom) stands in."""
    measured = {0: 0.01, 1: 0.03}
    got = cross_mode_error_bound(measured, "int7", 3)
    assert got == pytest.approx(CROSS_MODE_SLACK * 0.03)
    # no measurement: analytic estimate with headroom
    cold = cross_mode_error_bound({}, "int7", 3)
    assert cold == pytest.approx(
        CROSS_MODE_SLACK * preset_error_bound("int7", 3))
    # the slack covers mode-to-mode rearrangement, so it must exceed 1
    assert CROSS_MODE_SLACK > 1.0


def test_qformat_storage_dtypes_follow_bit_width():
    assert QFormat(5, 3).storage_bits == 8
    assert QFormat(9, 7).storage_bits == 16
    assert QFormat(17, 15).storage_bits == 32
    assert QFormat(5, 3).np_dtype == np.int8
    assert QFormat(9, 7).np_dtype == np.int16
    assert QFormat(17, 15).np_dtype == np.int32
    for qf, _ in FIXED_PRESETS.values():
        assert qf.min_int == -(1 << (qf.storage_bits - 1))
        assert qf.max_int == (1 << (qf.storage_bits - 1)) - 1
        assert math.log2(qf.scale) == qf.frac_bits
