"""Manual Megatron-SP MLP (§Perf H11a): numerical equivalence with the
GSPMD-implicit baseline, forward and backward, on a real multi-device mesh
(subprocess, 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, n: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_manual_sp_matches_baseline_fwd_bwd():
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, json, dataclasses as dc
        from repro.configs import get_smoke_config
        from repro.models import LM
        from repro.launch.steps import make_ctx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dc.replace(get_smoke_config("qwen3_14b"), d_ff=128)
        ctx = make_ctx(mesh, seq_sharded=True)
        toks = jax.random.randint(jax.random.key(7), (4, 32), 0, cfg.vocab)
        batch = {"tokens": toks}
        lm0 = LM(dc.replace(cfg, manual_sp=False))
        lm1 = LM(dc.replace(cfg, manual_sp=True))
        p, _ = lm0.init(jax.random.key(0))
        l0, l1 = lm0.loss(p, ctx, batch), lm1.loss(p, ctx, batch)
        g0 = jax.grad(lambda q: lm0.loss(q, ctx, batch))(p)
        g1 = jax.grad(lambda q: lm1.loss(q, ctx, batch))(p)
        # global relative error: bf16 reduction-order noise scales with the
        # overall gradient magnitude, so compare against the global norm
        num = sum(float(jnp.sum(jnp.square((a - b).astype(jnp.float32))))
                  for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1), strict=True))
        den = sum(float(jnp.sum(jnp.square(a.astype(jnp.float32))))
                  for a in jax.tree.leaves(g0))
        print(json.dumps([float(l0), float(l1), (num / den) ** 0.5]))
    """))
    l0, l1, rel = json.loads(out.strip().splitlines()[-1])
    assert abs(l0 - l1) < 2e-4 * max(abs(l0), 1), (l0, l1)  # fwd equivalent
    assert rel < 0.02, rel                         # bf16 reduction-order noise


def test_manual_sp_falls_back_when_not_applicable():
    # non-divisible d_ff / decode path must silently use the baseline MLP
    out = _run(textwrap.dedent("""
        import jax, jax.numpy as jnp, json, dataclasses as dc
        from repro.configs import get_smoke_config
        from repro.models import LM
        from repro.launch.steps import make_ctx
        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 4), ("data", "model"))
        cfg = dc.replace(get_smoke_config("qwen3_14b"), d_ff=130,
                         manual_sp=True)  # 130 % 4 != 0 → fallback
        lm = LM(cfg)
        p, _ = lm.init(jax.random.key(0))
        ctx = make_ctx(mesh, seq_sharded=True)
        l = lm.loss(p, ctx, {"tokens": jnp.ones((4, 32), jnp.int32)})
        cache = lm.init_cache(4, max_len=16)
        ctx_d = make_ctx(mesh, seq_sharded=False)
        lg, _ = lm.decode_step(p, ctx_d, jnp.ones((4, 1), jnp.int32), cache,
                               jnp.int32(0))
        print(json.dumps([float(l), bool(jnp.all(jnp.isfinite(lg)))]))
    """))
    l, ok = json.loads(out.strip().splitlines()[-1])
    assert np.isfinite(l)
    assert ok
