"""repro.batch: bucketing edge cases, batched-vs-sequential parity, and the
one-decision-per-bucket tuning contract (zero probes for the 2nd..Nth
members and for a fresh process against a warm store)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.batch import (
    BucketPlanCache,
    bucket_tensors,
    cp_als_batched,
    nnz_band,
    pad_bucket,
    shape_class,
)
from repro.core import SparseTensor, cp_als, random_tensor
from repro.engine import TunePolicy

RANK = 4
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def small(shape, nnz, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    coords = np.stack([rng.integers(0, d, size=nnz) for d in shape],
                      axis=1).astype(np.int32)
    values = rng.uniform(-1, 1, size=nnz).astype(dtype)
    return SparseTensor(coords, values, tuple(shape))


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------

def test_shape_class_rounds_to_pow2():
    assert shape_class((12, 10, 8)) == (16, 16, 8)
    assert shape_class((1, 2, 3)) == (1, 2, 4)


def test_nnz_band_boundary_is_exact():
    # 2^k is band k; 2^k - 1 is band k-1 — the boundary itself never
    # wobbles (integer bit_length, no float log).
    for k in (3, 5, 10, 20):
        assert nnz_band(2 ** k) == k
        assert nnz_band(2 ** k - 1) == k - 1
        assert nnz_band(2 ** k + 1) == k
    assert nnz_band(1) == 0
    assert nnz_band(0) == -1
    with pytest.raises(ValueError, match="nnz must be >= 0"):
        nnz_band(-1)


def test_empty_input_is_empty():
    assert bucket_tensors([]) == {}
    assert cp_als_batched([], RANK) == []


def test_single_tensor_bucket_round_trips():
    t = small((9, 7, 5), 33, seed=1)
    buckets = bucket_tensors([t])
    assert len(buckets) == 1
    ((dims, band), bucket), = buckets.items()
    assert dims == (16, 8, 8) and band == 5 and bucket.size == 1
    res = cp_als_batched([t], RANK, n_iters=2)
    assert len(res) == 1
    assert [f.shape for f in res[0].factors] == [(9, RANK), (7, RANK),
                                                 (5, RANK)]


def test_band_boundary_splits_buckets():
    lo = small((8, 8, 8), 63, seed=2)   # band 5
    hi = small((8, 8, 8), 64, seed=3)   # band 6 — exactly on the boundary
    buckets = bucket_tensors([lo, hi])
    assert len(buckets) == 2
    assert sorted(b for (_, b) in buckets) == [5, 6]


def test_mixed_value_dtypes_rejected():
    a = small((8, 8), 10, seed=4, dtype=np.float32)
    b = small((8, 8), 10, seed=5, dtype=np.float64)
    with pytest.raises(TypeError, match="mixed value dtypes"):
        cp_als_batched([a, b], RANK)


def test_non_tensor_input_rejected():
    with pytest.raises(TypeError, match="input 1"):
        bucket_tensors([small((4, 4), 5), "nope"])


def test_padding_is_zero_and_masked():
    # nnz 17 and 30 are both band 4 → one bucket, padded to 30
    a, b = small((6, 6), 17, seed=6), small((6, 6), 30, seed=7)
    bucket, = bucket_tensors([a, b]).values()
    pb = pad_bucket(bucket)
    assert pb.pad_nnz == 30
    assert pb.values.shape == (2, 30)
    assert np.all(pb.values[0, 17:] == 0.0)
    assert np.all(pb.coords[0, 17:] == 0)
    assert pb.mask[0].sum() == 17 and pb.mask[1].sum() == 30


# ---------------------------------------------------------------------------
# batched ALS correctness
# ---------------------------------------------------------------------------

def test_batched_matches_sequential_ref_bitexact():
    tensors = [small((12, 10, 8), 40 + i, seed=10 + i) for i in range(4)]
    res = cp_als_batched(tensors, RANK, n_iters=3,
                         tune=TunePolicy(candidates=("ref",)))
    for t, rb in zip(tensors, res, strict=True):
        rs = cp_als(t, RANK, n_iters=3, engine="ref", track_diff=False)
        for fb, fs in zip(rb.factors, rs.factors, strict=True):
            np.testing.assert_array_equal(fb, np.asarray(fs))
        np.testing.assert_array_equal(rb.lam, np.asarray(rs.lam))
        assert rb.fit_history[-1] == pytest.approx(rs.fit_history[-1],
                                                   abs=1e-5)


def test_batched_alto_matches_sequential_alto():
    tensors = [small((12, 10, 8), 40 + i, seed=20 + i) for i in range(3)]
    res = cp_als_batched(tensors, RANK, n_iters=2,
                         tune=TunePolicy(candidates=("alto",)))
    for t, rb in zip(tensors, res, strict=True):
        rs = cp_als(t, RANK, n_iters=2, engine="alto", track_diff=False)
        for fb, fs in zip(rb.factors, rs.factors, strict=True):
            np.testing.assert_allclose(fb, np.asarray(fs), atol=1e-6)


def test_mixed_buckets_preserve_input_order():
    tensors = [small((12, 10, 8), 40, seed=30), small((24, 24), 50, seed=31),
               small((12, 10, 8), 45, seed=32)]
    res = cp_als_batched(tensors, RANK, n_iters=1)
    for t, r in zip(tensors, res, strict=True):
        assert [f.shape[0] for f in r.factors] == list(t.shape)


def test_random_tensor_inputs_work_end_to_end():
    tensors = [random_tensor((10, 9, 8), nnz=70, seed=s) for s in range(3)]
    res = cp_als_batched(tensors, RANK, n_iters=2, track_diff=True)
    for r in res:
        assert len(r.fit_history) == 2
        assert len(r.diff_history) == 2
        assert r.engine.startswith("batched:")


# ---------------------------------------------------------------------------
# one autotune decision per bucket
# ---------------------------------------------------------------------------

def test_second_member_and_second_call_are_probe_free(tmp_path):
    store = str(tmp_path / "bucket-store.json")
    tensors = [small((12, 10, 8), 40 + i, seed=40 + i) for i in range(4)]
    plans = BucketPlanCache()
    pol = TunePolicy(store=store)
    res = cp_als_batched(tensors, RANK, n_iters=1, tune=pol, plans=plans)
    # one bucket => every member shares literally the same report object
    reports = {id(r.tune_report) for r in res}
    assert len(reports) == 1
    assert res[0].tune_report.source == "measured"
    assert res[0].tune_report.n_probes > 0

    # same process, warm plan cache: zero probes, no store read
    res2 = cp_als_batched(tensors, RANK, n_iters=1, tune=pol, plans=plans)
    assert res2[0].tune_report.n_probes == 0
    assert res2[0].tune_report.source == "cached"

    # no plan cache, warm store: still zero probes
    res3 = cp_als_batched(tensors, RANK, n_iters=1, tune=pol)
    assert res3[0].tune_report.n_probes == 0
    assert res3[0].tune_report.source == "persisted"


def test_fresh_process_reports_zero_probes(tmp_path):
    store = str(tmp_path / "bucket-store.json")
    code = textwrap.dedent(f"""
        import numpy as np
        from repro.batch import cp_als_batched
        from repro.core import SparseTensor
        from repro.engine import TunePolicy
        rng = np.random.default_rng(0)
        ts = []
        for s in range(3):
            coords = np.stack([rng.integers(0, d, size=40)
                               for d in (12, 10, 8)], axis=1).astype(np.int32)
            vals = rng.uniform(-1, 1, size=40).astype(np.float32)
            ts.append(SparseTensor(coords, vals, (12, 10, 8)))
        res = cp_als_batched(ts, {RANK}, n_iters=1,
                             tune=TunePolicy(store={store!r}))
        print("PROBES", res[0].tune_report.n_probes,
              res[0].tune_report.source)
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out1 = subprocess.run([sys.executable, "-c", code], check=True, env=env,
                          capture_output=True, text=True, timeout=600).stdout
    assert "PROBES" in out1 and "measured" in out1
    out2 = subprocess.run([sys.executable, "-c", code], check=True, env=env,
                          capture_output=True, text=True, timeout=600).stdout
    assert "PROBES 0 persisted" in out2


def test_accuracy_budget_rejected_on_batched_path():
    t = small((8, 8), 20, seed=50)
    with pytest.raises(ValueError, match="accuracy_budget does not apply"):
        cp_als_batched([t], RANK, tune=TunePolicy(accuracy_budget=0.1))
