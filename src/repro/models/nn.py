"""Minimal functional NN substrate: params are nested dicts of jnp arrays,
every layer is (init, apply) pure functions.  No framework dependency.

Sharding is expressed as a parallel tree of logical-axis tuples produced by
each init alongside the params ("spec tree"); `launch/shardings.py` maps
logical axes → mesh axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Params", "dense_init", "embed_init", "rmsnorm_init",
    "linear", "rmsnorm", "layernorm", "apply_rope", "softcap",
    "param_count", "tree_cast",
]

Params = dict  # nested dict of arrays


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               scale: float | None = None, axes=("in", "out"),
               dtype=jnp.float32):
    """Returns (params, specs). Logical axes name the sharding intent."""
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype) * scale
    p, s = {"w": w}, {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = (axes[1],)
    return p, s


def embed_init(key, vocab: int, d: int, *, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"w": w}, {"w": ("vocab", "embed")}


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"g": jnp.ones((d,), dtype)}, {"g": (None,)}


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["g"].astype(jnp.float32))).astype(dtype)


def layernorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * p["g"].astype(jnp.float32)).astype(dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, *,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, H, D) rotary over last dim; positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap)


def param_count(params: Params) -> int:
    return sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))


def tree_cast(params: Params, dtype) -> Params:
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        params)
