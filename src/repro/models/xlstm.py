"""xLSTM blocks: mLSTM (matrix memory, exponential gating) and sLSTM (scalar
memory with recurrent gate preactivations) — arXiv:2405.04517.

Both use the stabilized exponential-gating recurrences from the paper.  The
parallel projections (q/k/v/gates) are computed for the whole sequence up
front; the state recurrence runs as a `lax.scan` over time.  A chunkwise-
parallel mLSTM formulation is the §Perf hillclimb opportunity for this arch
(see EXPERIMENTS.md).  Decode is a single O(1)-state update — this is what
makes long_500k decoding trivially cheap for this family.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import nn

__all__ = [
    "MLSTMConfig", "mlstm_init", "mlstm_apply", "mlstm_decode", "init_mlstm_cache",
    "SLSTMConfig", "slstm_init", "slstm_apply", "slstm_decode", "init_slstm_cache",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLSTMConfig:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def d_head(self) -> int:
        return self.d_inner // self.n_heads


def mlstm_init(key, cfg: MLSTMConfig):
    ks = jax.random.split(key, 8)
    d, di = cfg.d_model, cfg.d_inner
    p = {
        "up": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * d**-0.5,
        "wq": jax.random.normal(ks[1], (di, di), jnp.float32) * di**-0.5,
        "wk": jax.random.normal(ks[2], (di, di), jnp.float32) * di**-0.5,
        "wv": jax.random.normal(ks[3], (di, di), jnp.float32) * di**-0.5,
        "wi": jax.random.normal(ks[4], (di, cfg.n_heads), jnp.float32) * di**-0.5,
        "wf": jax.random.normal(ks[5], (di, cfg.n_heads), jnp.float32) * di**-0.5,
        "fb": jnp.full((cfg.n_heads,), 3.0, jnp.float32),  # open forget gates
        "gn": jnp.ones((di,), jnp.float32),
        "down": jax.random.normal(ks[6], (di, d), jnp.float32) * di**-0.5,
    }
    s = {
        "up": ("embed", "inner"), "wq": ("inner", "inner"),
        "wk": ("inner", "inner"), "wv": ("inner", "inner"),
        "wi": ("inner", None), "wf": ("inner", None), "fb": (None,),
        "gn": ("inner",), "down": ("inner", "embed"),
    }
    return p, s


def _mlstm_qkvif(p, cfg: MLSTMConfig, x):
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    up = x @ p["up"].astype(x.dtype)
    u, z = jnp.split(up, 2, axis=-1)
    q = (u @ p["wq"].astype(x.dtype)).reshape(b, s, h, dh) * dh**-0.5
    k = (u @ p["wk"].astype(x.dtype)).reshape(b, s, h, dh) * dh**-0.5
    v = (u @ p["wv"].astype(x.dtype)).reshape(b, s, h, dh)
    log_i = (u @ p["wi"].astype(x.dtype)).astype(jnp.float32)  # (B,S,H)
    log_f = jax.nn.log_sigmoid(
        (u @ p["wf"].astype(x.dtype)).astype(jnp.float32) + p["fb"])
    return q, k, v, log_i, log_f, z


def _mlstm_step(carry, xs):
    c, n, m = carry  # (B,H,dk,dv), (B,H,dk), (B,H)
    q, k, v, log_i, log_f = xs  # (B,H,dh) ×3, (B,H) ×2
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)[..., None]
    f_s = jnp.exp(log_f + m - m_new)[..., None]
    kf = k.astype(jnp.float32)
    c = f_s[..., None] * c + i_s[..., None] * (kf[..., :, None] * v.astype(jnp.float32)[..., None, :])
    n = f_s * n + i_s * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), 1.0)
    return (c, n, m_new), num / den[..., None]


def _mlstm_chunk(carry, xs):
    """Chunkwise-parallel mLSTM (stabilized): O(L²) intra-chunk on the MXU +
    O(1) carried (C, n, m̂) state — memory per chunk boundary only, which is
    what makes 32k-prefill/4k-train backward fit (step-scan stores the full
    (B,H,dk,dv) carry per token: ~TBs)."""
    c_st, n_st, m_st = carry          # (B,H,dk,dv), (B,H,dk), (B,H)
    q, k, v, log_i, log_f = xs        # (B,L,H,dh) ×3, (B,L,H) ×2
    b, l, h, dh = q.shape
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,H,L,dh)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    li = log_i.transpose(0, 2, 1)                      # (B,H,L)
    g = jnp.cumsum(log_f.transpose(0, 2, 1), axis=-1)  # (B,H,L) cumulative
    g_total = g[..., -1:]

    # Stabilizers: intra max over s≤t of (g_t - g_s + i_s); inter g_t + m̂.
    a = li - g                                          # (B,H,L) source terms
    a_run = jax.lax.cummax(a, axis=2)
    m_intra = g + a_run
    m_t = jnp.maximum(m_intra, g + m_st[..., None])     # (B,H,L)

    # Intra-chunk decay matrix D[t,s] = exp(g_t - g_s + i_s - m_t), s ≤ t.
    dmat = g[..., :, None] - g[..., None, :] + li[..., None, :] \
        - m_t[..., :, None]
    mask = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(mask, dmat, -jnp.inf)
    dexp = jnp.exp(dmat)                                # (B,H,L,L)
    scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * dexp
    h_intra = jnp.einsum("bhts,bhsv->bhtv", scores, vf)
    n_intra = jnp.einsum("bhts,bhsd->bhtd", dexp, kf)

    # Inter-chunk (carried state) contribution.
    w = jnp.exp(g + m_st[..., None] - m_t)              # (B,H,L)
    h_inter = jnp.einsum("bhtd,bhdv->bhtv", qf, c_st) * w[..., None]
    n_inter = n_st[:, :, None, :] * w[..., None]

    num = h_intra + h_inter                             # (B,H,L,dv)
    n_t = n_intra + n_inter                             # (B,H,L,dk)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhtd,bhtd->bht", qf, n_t)), 1.0)
    y = (num / den[..., None]).transpose(0, 2, 1, 3)    # (B,L,H,dv)

    # Carry update to the chunk end (stabilized by the new running max).
    m_new = jnp.maximum(g_total[..., 0] + m_st,
                        jnp.max(g_total - g + li, axis=-1))
    scat = jnp.exp(g_total - g + li - m_new[..., None])  # (B,H,L)
    c_new = jnp.exp(g_total[..., 0] + m_st - m_new)[..., None, None] * c_st \
        + jnp.einsum("bhs,bhsd,bhsv->bhdv", scat, kf, vf)
    n_new = jnp.exp(g_total[..., 0] + m_st - m_new)[..., None] * n_st \
        + jnp.einsum("bhs,bhsd->bhd", scat, kf)
    return (c_new, n_new, m_new), y


def mlstm_apply(p, cfg: MLSTMConfig, x, *, cache=None, return_state=False):
    """x (B,S,D) → (B,S,D).  Chunkwise-parallel scan (see _mlstm_chunk)."""
    b, s, _ = x.shape
    q, k, v, log_i, log_f, z = _mlstm_qkvif(p, cfg, x)
    if cache is None:
        cache = init_mlstm_cache(cfg, b)
    carry = (cache["c"], cache["n"], cache["m"])
    l = min(cfg.chunk, s)
    pad = (-s) % l
    if pad:
        padf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        # keep padded forget gates at 0 decay / -inf input gate: no effect
        q, k, v = padf(q), padf(k), padf(v)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    nch = (s + pad) // l
    chunked = lambda a: a.reshape(b, nch, l, *a.shape[2:]).swapaxes(0, 1)
    (c, n, m), ys = jax.lax.scan(
        _mlstm_chunk, carry,
        (chunked(q), chunked(k), chunked(v), chunked(log_i), chunked(log_f)))
    ys = ys.swapaxes(0, 1).reshape(b, nch * l, cfg.n_heads, cfg.d_head)
    ys = ys[:, :s].reshape(b, s, cfg.d_inner).astype(x.dtype)
    ys = nn.rmsnorm({"g": p["gn"] - 1.0}, ys)  # group-norm stand-in
    out = (ys * jax.nn.silu(z)) @ p["down"].astype(x.dtype)
    if return_state:
        return out, {"c": c, "n": n, "m": m}
    return out


def init_mlstm_cache(cfg: MLSTMConfig, batch: int, dtype=jnp.float32):
    h, dh = cfg.n_heads, cfg.d_head
    return {
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(p, cfg: MLSTMConfig, x, cache):
    out, state = mlstm_apply(p, cfg, x, cache=cache, return_state=True)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SLSTMConfig:
    d_model: int
    n_heads: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


def slstm_init(key, cfg: SLSTMConfig):
    ks = jax.random.split(key, 3)
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.d_head
    p = {
        "wx": jax.random.normal(ks[0], (d, 4 * d), jnp.float32) * d**-0.5,
        # block-diagonal (per-head) recurrent weights
        "r": jax.random.normal(ks[1], (4, h, dh, dh), jnp.float32) * dh**-0.5,
        "b": jnp.concatenate([jnp.zeros((2 * d,)), jnp.full((d,), 3.0),
                              jnp.zeros((d,))]).astype(jnp.float32),
        "gn": jnp.ones((d,), jnp.float32),
        "out": jax.random.normal(ks[2], (d, d), jnp.float32) * d**-0.5,
    }
    s = {"wx": ("embed", None), "r": (None, None, None, None), "b": (None,),
         "gn": (None,), "out": ("embed", "embed")}
    return p, s


def _slstm_step(p, cfg, carry, x_pre):
    """x_pre (B, 4D) precomputed input contribution to gate preactivations."""
    c, n, m, h = carry  # (B,H,dh) ×2, (B,H) wait: c,n (B,H,dh); m (B,H,dh); h (B,H,dh)
    b = x_pre.shape[0]
    hh = h.reshape(b, cfg.n_heads, cfg.d_head)
    rec = jnp.einsum("ghij,bhi->gbhj", p["r"], hh)  # (4,B,H,dh)
    pre = x_pre.reshape(b, 4, cfg.n_heads, cfg.d_head).transpose(1, 0, 2, 3) + rec
    z_p, i_p, f_p, o_p = pre[0], pre[1], pre[2], pre[3]
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    log_i = i_p
    log_f = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c = f_s * c + i_s * z
    n = f_s * n + i_s
    h_new = (o * c / jnp.maximum(n, 1.0)).reshape(b, -1)
    return (c, n, m_new, h_new), h_new


def slstm_apply(p, cfg: SLSTMConfig, x, *, cache=None, return_state=False):
    b, s, d = x.shape
    x_pre = (x @ p["wx"].astype(x.dtype)).astype(jnp.float32) + p["b"]
    # layout (B,S,4D) with gate-major grouping z|i|f|o
    x_pre = x_pre.reshape(b, s, 4, d).swapaxes(0, 1).reshape(s, b, 4 * d)
    if cache is None:
        cache = init_slstm_cache(cfg, b)
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    step = lambda carry, xp: _slstm_step(p, cfg, carry, xp)
    (c, n, m, h), hs = jax.lax.scan(step, carry, x_pre)
    hs = hs.swapaxes(0, 1).astype(x.dtype)  # (B,S,D)
    hs = nn.rmsnorm({"g": p["gn"] - 1.0}, hs)
    out = hs @ p["out"].astype(x.dtype)
    if return_state:
        return out, {"c": c, "n": n, "m": m, "h": h}
    return out


def init_slstm_cache(cfg: SLSTMConfig, batch: int, dtype=jnp.float32):
    h, dh = cfg.n_heads, cfg.d_head
    return {
        "c": jnp.zeros((batch, h, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h, dh), -1e30, jnp.float32),
        "h": jnp.zeros((batch, h * dh), jnp.float32),
    }


def slstm_decode(p, cfg: SLSTMConfig, x, cache):
    out, state = slstm_apply(p, cfg, x, cache=cache, return_state=True)
    return out, state
