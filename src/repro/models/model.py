"""Model wrapper: config dataclass + LM (decoder-only / enc-dec / VLM).

All ten assigned architectures instantiate this one composable definition
(configs/<arch>.py provides the exact hyperparameters).  Modality frontends
are stubs per the brief: `[audio]` inputs are precomputed frame embeddings,
`[vlm]` inputs are precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import nn
from .moe import MoEConfig
from .transformer import (LayerSpec, MeshCtx, init_stack_cache, segment_layout,
                          stack_apply, stack_decode, stack_init)

__all__ = ["ModelConfig", "LM", "LayerSpec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int
    # attention variants
    window: int | None = None
    chunk_attn: int | None = None
    qk_norm: bool = False
    rope: bool = True
    nope_global: bool = False      # llama4 iRoPE: global layers have no RoPE
    rope_theta: float = 10000.0
    attn_softcap: float | None = None
    final_softcap: float | None = None
    bias: bool = False
    q_block: int = 512
    kv_block: int = 1024
    # layer pattern (repeats to cover n_layers; remainder truncates pattern)
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    # mlp / moe
    mlp_act: str = "silu"
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # ssm
    d_state: int = 16
    # embeddings
    tie_embeddings: bool = False
    scale_embed: bool = False
    norm_eps: float = 1e-6
    remat: bool = True
    # enc-dec (whisper)
    encoder_decoder: bool = False
    n_enc_layers: int = 0
    dec_ratio: int = 8             # decoder len = seq // dec_ratio
    # vlm
    n_image_tokens: int = 0
    # frontend stubs
    audio_frontend: bool = False
    # dtypes / memory policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_8bit: bool = False
    # long-context capability (sub-quadratic path exists)
    supports_long_context: bool = False
    # inner-loop chunking (single-chunk + unroll_stack = exact HLO cost
    # accounting for the dry-run calibration; see launch/dryrun.py)
    mamba_scan_chunk: int = 512
    mlstm_chunk: int = 256
    unroll_stack: bool = False
    # §Perf experiment: pin one consistent layout inside blocked attention.
    # REFUTED as the dominant collective cost (−2 GB/layer wire but 2×
    # bytes from model-axis replication) — see EXPERIMENTS.md §Perf; kept
    # as a flag for the record.
    attn_pin_layout: bool = False
    # §Perf H11a: explicit Megatron-SP MLP collectives via shard_map
    # (bf16 all-gather(seq) → TP matmuls → psum_scatter(seq); FSDP weight
    # gathers in bf16).  False = paper-faithful GSPMD-implicit baseline.
    manual_sp: bool = False

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(self.d_model, self.d_ff, self.n_experts, self.top_k,
                         self.n_shared_experts)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


class LM:
    """Pure-function model: params passed explicitly everywhere."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.segs = segment_layout(cfg.n_layers, cfg.pattern)
        if cfg.encoder_decoder:
            enc_spec = LayerSpec(mixer="attn", attn_kind="global",
                                 mlp="dense", causal=False)
            self.enc_segs = segment_layout(cfg.n_enc_layers, (enc_spec,))
            dec_pattern = tuple(
                dataclasses.replace(s, cross_attn=True) for s in cfg.pattern)
            self.segs = segment_layout(cfg.n_layers, dec_pattern)

    # -- init ---------------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p, s = {}, {}
        p["embed"], s["embed"] = nn.embed_init(ks[0], cfg.vocab, cfg.d_model)
        p["final_norm"], s["final_norm"] = nn.rmsnorm_init(cfg.d_model)
        pattern = self.segs[0][0]
        p["layers"], s["layers"], _ = stack_init(ks[1], cfg, pattern,
                                                 cfg.n_layers)
        if not cfg.tie_embeddings:
            p["unembed"], s["unembed"] = nn.dense_init(
                ks[2], cfg.d_model, cfg.vocab, axes=("embed", "vocab"),
                scale=cfg.d_model ** -0.5)
        if cfg.encoder_decoder:
            enc_pattern = self.enc_segs[0][0]
            p["enc_layers"], s["enc_layers"], _ = stack_init(
                ks[3], cfg, enc_pattern, cfg.n_enc_layers)
            p["enc_norm"], s["enc_norm"] = nn.rmsnorm_init(cfg.d_model)
        if cfg.n_image_tokens:
            p["img_proj"], s["img_proj"] = nn.dense_init(
                ks[4], cfg.d_model, cfg.d_model, axes=("embed", None))
        p = jax.tree.map(lambda a: a.astype(cfg.pdtype), p)
        return p, s

    # -- shared pieces --------------------------------------------------------

    def _embed(self, p, tokens):
        cfg = self.cfg
        x = p["embed"]["w"][tokens].astype(cfg.cdtype)
        if cfg.scale_embed:
            x = x * math.sqrt(cfg.d_model)
        return x

    def _logits(self, p, x):
        cfg = self.cfg
        w = (p["embed"]["w"].T if cfg.tie_embeddings
             else p["unembed"]["w"]).astype(x.dtype)
        logits = x @ w
        if cfg.final_softcap:
            logits = nn.softcap(logits.astype(jnp.float32), cfg.final_softcap)
        return logits

    def _encode(self, p, ctx, frames):
        """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
        x = frames.astype(self.cfg.cdtype)
        pos = jnp.arange(x.shape[1])
        x = stack_apply(p["enc_layers"], self.cfg, self.enc_segs, ctx, x,
                        positions=pos)
        return nn.rmsnorm(p["enc_norm"], x, self.cfg.norm_eps)

    def _backbone_inputs(self, p, ctx, batch):
        """Returns (x, positions, enc_out, label_mask_offset)."""
        cfg = self.cfg
        if cfg.encoder_decoder:
            enc_out = self._encode(p, ctx, batch["frames"])
            x = self._embed(p, batch["tokens"])
            return x, jnp.arange(x.shape[1]), enc_out
        if cfg.n_image_tokens:
            img = nn.linear(p["img_proj"],
                            batch["image_embeds"].astype(cfg.cdtype))
            tok = self._embed(p, batch["tokens"])
            x = jnp.concatenate([img, tok], axis=1)
            return x, jnp.arange(x.shape[1]), None
        if cfg.audio_frontend and not cfg.encoder_decoder:
            return batch["frames"].astype(cfg.cdtype), \
                jnp.arange(batch["frames"].shape[1]), None
        x = self._embed(p, batch["tokens"])
        return x, jnp.arange(x.shape[1]), None

    # -- train --------------------------------------------------------------

    def loss(self, p, ctx: MeshCtx, batch):
        cfg = self.cfg
        x, positions, enc_out = self._backbone_inputs(p, ctx, batch)
        x = ctx.resid(x)
        x = stack_apply(p["layers"], cfg, self.segs, ctx, x,
                        positions=positions, enc_out=enc_out)
        x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
        x = ctx.shard(x, ctx.dp, None, None)
        if cfg.n_image_tokens:  # loss only over the text tail
            x = x[:, cfg.n_image_tokens:]
        logits = self._logits(p, x)
        logits = ctx.shard(logits, ctx.dp, None, ctx.tp)
        tokens = batch["tokens"]
        targets = tokens[:, 1:]
        logits = logits[:, :-1].astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        true = jnp.take_along_axis(logits, targets[..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(lse - true)

    # -- serve --------------------------------------------------------------

    def prefill(self, p, ctx: MeshCtx, batch):
        """Returns last-token logits.  (Cache seeding for decode is exercised
        through decode_step whose cache is an explicit input.)"""
        cfg = self.cfg
        x, positions, enc_out = self._backbone_inputs(p, ctx, batch)
        x = ctx.resid(x)
        x = stack_apply(p["layers"], cfg, self.segs, ctx, x,
                        positions=positions, enc_out=enc_out)
        x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
        return self._logits(p, x[:, -1:])

    def init_cache(self, batch: int, max_len: int, enc_len: int = 0,
                   dtype=jnp.bfloat16):
        return init_stack_cache(self.cfg, self.segs, batch, max_len, enc_len,
                                dtype)

    def decode_step(self, p, ctx: MeshCtx, token, cache, pos):
        """token (B,1) int32; pos scalar int32.  Returns (logits (B,V), cache)."""
        cfg = self.cfg
        x = self._embed(p, token)
        x, new_cache = stack_decode(p["layers"], cfg, self.segs, ctx, x,
                                    cache, pos)
        x = nn.rmsnorm(p["final_norm"], x, cfg.norm_eps)
        logits = self._logits(p, x)[:, 0]
        return logits, new_cache
