"""Mixture-of-Experts layer with explicit expert parallelism.

Design (DESIGN.md §5): expert placement follows the paper's replicate-nothing-
first partitioning discipline — the expert axis is the "rank axis" analogue
(different experts need disjoint weights → shard it first, over `model`), and
FSDP shards the expert hidden dim over `data` with just-in-time all-gather.

Implementation: dropless token-choice top-k.  Inside a fully-manual shard_map:
  1. all-gather the (sequence-sharded) tokens over `model`;
  2. route; keep assignments owned by this shard's local experts
     (non-local assignments fall into a zero-weight dummy group);
  3. sort assignments by local expert, run two `lax.ragged_dot`s (grouped
     GEMM — the MegaBlocks pattern, TPU-native via XLA ragged ops);
  4. scatter-add weighted outputs back to token order;
  5. psum_scatter over `model` (each shard contributed its experts' part).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..launch.mesh import shard_map
from . import nn

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int               # per-expert hidden dim
    n_experts: int
    top_k: int
    n_shared_experts: int = 0
    act: str = "silu"       # swiglu-style gating inside each expert


def moe_init(key, cfg: MoEConfig):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * scale,
        "wg": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale,
        "wu": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale,
        "wd": jax.random.normal(ks[3], (e, f, d), jnp.float32) * (f ** -0.5),
    }
    s = {
        "router": (None, None),
        # expert axis ≡ the paper's rank axis (replicate-nothing, shard first:
        # → model); hidden dim FSDP-sharded over data, gathered JIT in-body.
        "wg": ("expert", None, "expert_ffn"),
        "wu": ("expert", None, "expert_ffn"),
        "wd": ("expert", "expert_ffn", None),
    }
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        p["shared_wg"] = jax.random.normal(ks[4], (d, fs), jnp.float32) * scale
        p["shared_wu"] = jax.random.normal(ks[4], (d, fs), jnp.float32) * scale
        p["shared_wd"] = jax.random.normal(ks[4], (fs, d), jnp.float32) * (fs ** -0.5)
        s["shared_wg"] = ("embed", "ffn")
        s["shared_wu"] = ("embed", "ffn")
        s["shared_wd"] = ("ffn", "embed")
    return p, s


def _act(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _moe_body(cfg: MoEConfig, e_loc: int, model_axis, data_axes, seq_sharded,
              x, router, wg, wu, wd):
    """shard_map body. x: (B_loc, S_loc, D). Expert weights: (E_loc, D, F_loc)
    / (E_loc, F_loc, D). Returns (B_loc, S_loc, D)."""
    x_full = (jax.lax.all_gather(x, model_axis, axis=1, tiled=True)
              if seq_sharded else x)
    b, s, d = x_full.shape
    t = b * s
    xt = x_full.reshape(t, d)

    # FSDP: gather the hidden dim of this shard's experts just-in-time.
    wg = jax.lax.all_gather(wg, data_axes, axis=2, tiled=True)
    wu = jax.lax.all_gather(wu, data_axes, axis=2, tiled=True)
    wd = jax.lax.all_gather(wd, data_axes, axis=1, tiled=True)

    logits = (xt @ router).astype(jnp.float32)  # (T, E)
    gate_vals, eids = jax.lax.top_k(logits, cfg.top_k)  # (T, k)
    gates = jax.nn.softmax(gate_vals, axis=-1).astype(x.dtype)

    flat_e = eids.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), cfg.top_k)
    flat_gate = gates.reshape(-1)

    e0 = jax.lax.axis_index(model_axis) * e_loc
    local = (flat_e >= e0) & (flat_e < e0 + e_loc)
    le = jnp.where(local, flat_e - e0, e_loc)  # dummy group = e_loc
    a = t * cfg.top_k
    # Expert capacity: this shard only computes its expected share of
    # assignments (×2 headroom).  Sorting by local expert puts local
    # assignments in a contiguous PREFIX → a static slice, so per-shard
    # compute is A·E_loc/E·2 instead of A (16× less for jamba).  Overflow
    # assignments drop (GShard-style capacity dropping).
    cap = min(a, max(_round_up(int(a * e_loc / cfg.n_experts * 2.0), 128), 128))
    order = jnp.argsort(le)[:cap]
    xs = xt[flat_tok[order]]                   # (cap, D)
    gs = jnp.where(local, flat_gate, 0.0)[order]
    counts = jnp.bincount(le, length=e_loc + 1)[:e_loc]
    capped = jnp.minimum(jnp.cumsum(counts), cap)
    sizes = jnp.diff(capped, prepend=0)
    group_sizes = jnp.concatenate(
        [sizes, cap - capped[-1:]]).astype(jnp.int32)  # + dummy remainder

    zpad = lambda w: jnp.concatenate([w, jnp.zeros((1,) + w.shape[1:], w.dtype)])
    h = _act(cfg.act)(jax.lax.ragged_dot(xs, zpad(wg).astype(xs.dtype), group_sizes))
    h = h * jax.lax.ragged_dot(xs, zpad(wu).astype(xs.dtype), group_sizes)
    ys = jax.lax.ragged_dot(h, zpad(wd).astype(xs.dtype), group_sizes)  # (cap, D)
    ys = ys * gs[:, None]

    out = jnp.zeros((t, d), x.dtype).at[flat_tok[order]].add(ys)
    out = out.reshape(b, s, d)
    if seq_sharded:
        return jax.lax.psum_scatter(out, model_axis, scatter_dimension=1, tiled=True)
    return jax.lax.psum(out, model_axis)


def moe_apply(p, cfg: MoEConfig, x, *, mesh, dp_axes=("data",),
              model_axis="model", seq_sharded=True):
    """x: (B, S, D) — batch sharded over dp_axes, S over model when
    seq_sharded (Megatron-SP residual layout).  Returns same layout."""
    axes = dict(mesh.shape)
    e_loc = cfg.n_experts // axes.get(model_axis, 1)
    assert e_loc * axes.get(model_axis, 1) == cfg.n_experts, \
        f"n_experts {cfg.n_experts} must divide over model axis"
    dp = tuple(a for a in dp_axes if a in axes)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    if x.shape[0] % max(dp_size, 1) != 0:
        dp = ()  # batch too small to shard (e.g. batch-1 long-context decode)

    body = partial(_moe_body, cfg, e_loc, model_axis, dp, seq_sharded)
    seq_spec = model_axis if seq_sharded else None
    out = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(dp, seq_spec, None),
            P(None, None),
            P(model_axis, None, dp),
            P(model_axis, None, dp),
            P(model_axis, dp, None),
        ),
        out_specs=P(dp, seq_spec, None),
    )(x, p["router"], p["wg"], p["wu"], p["wd"])

    if cfg.n_shared_experts:
        h = _act(cfg.act)(x @ p["shared_wg"].astype(x.dtype))
        h = h * (x @ p["shared_wu"].astype(x.dtype))
        out = out + h @ p["shared_wd"].astype(x.dtype)
    return out
