"""LM substrate: functional nn lib, attention/MoE/SSM/xLSTM mixers,
pattern-scanned stacks, and the composable LM wrapper."""
from .model import LM, LayerSpec, ModelConfig
from .transformer import MeshCtx
