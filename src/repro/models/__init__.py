"""LM substrate: functional nn lib, attention/MoE/SSM/xLSTM mixers,
pattern-scanned stacks, and the composable LM wrapper."""
from .model import LM, ModelConfig, LayerSpec
from .transformer import MeshCtx
