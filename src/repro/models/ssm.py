"""Mamba (selective SSM) block — for the Jamba hybrid architecture.

Training/prefill uses a *chunkwise* selective scan: within-chunk parallel
(associative scan) + cross-chunk recurrent carry, so peak memory is
O(B · chunk · d_inner · d_state) instead of O(B · S · d_inner · d_state) —
this is what makes long_500k runnable (DESIGN.md §5).  Decode is a single
O(1)-state update.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import nn

__all__ = ["MambaConfig", "mamba_init", "mamba_apply", "mamba_decode",
           "init_mamba_cache"]


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int | None = None
    scan_chunk: int = 512

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)


def mamba_init(key, cfg: MambaConfig):
    ks = jax.random.split(key, 6)
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank_
    p = {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), jnp.float32) * d**-0.5,
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": jax.random.normal(ks[2], (di, r + 2 * n), jnp.float32) * di**-0.5,
        "dt_proj": jax.random.normal(ks[3], (r, di), jnp.float32) * r**-0.5,
        "dt_bias": jnp.log(jnp.expm1(  # init dt in [1e-3, 1e-1] (Mamba paper)
            jnp.exp(jax.random.uniform(ks[4], (di,), jnp.float32)
                    * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3)))),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), jnp.float32) * di**-0.5,
    }
    s = {
        "in_proj": ("embed", "inner"), "conv_w": (None, "inner"),
        "conv_b": ("inner",), "x_proj": ("inner", None),
        "dt_proj": (None, "inner"), "dt_bias": ("inner",),
        "a_log": ("inner", None), "d_skip": ("inner",),
        "out_proj": ("inner", "embed"),
    }
    return p, s


def _ssm_inputs(p, cfg: MambaConfig, u):
    """u (B,S,di) post-conv. Returns dA (B,S,di,N), dBu (B,S,di,N), C (B,S,N)."""
    r, n = cfg.dt_rank_, cfg.d_state
    proj = u @ p["x_proj"].astype(u.dtype)
    dt, b_ssm, c = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt @ p["dt_proj"].astype(u.dtype) + p["dt_bias"].astype(u.dtype))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)
    dt32 = dt.astype(jnp.float32)
    da = jnp.exp(dt32[..., None] * a)  # (B,S,di,N)
    dbu = (dt32 * u.astype(jnp.float32))[..., None] * \
        b_ssm.astype(jnp.float32)[..., None, :]
    return da, dbu, c


def _conv(p, cfg: MambaConfig, x, conv_state=None):
    """Causal depthwise conv over time. x (B,S,di)."""
    k = cfg.d_conv
    xp = (jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0))) if conv_state is None
          else jnp.concatenate([conv_state.astype(x.dtype), x], axis=1))
    w = p["conv_w"].astype(x.dtype)  # (K, di)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(k))
    return out + p["conv_b"].astype(x.dtype), xp[:, -(k - 1):]


def mamba_apply(p, cfg: MambaConfig, x, *, h0=None, conv_state=None,
                return_state=False, constrain=None):
    """x (B,S,D) → (B,S,D).  Chunked selective scan.  `constrain(arr, dims)`
    pins activation shardings (dims ∈ {"dp","tp",None} per axis) — without it
    GSPMD falls into involuntary full rematerialization on the state einsum."""
    if constrain is None:
        constrain = lambda a, dims: a
    b, s, _ = x.shape
    xz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_out = _conv(p, cfg, u, conv_state)
    u = jax.nn.silu(u)
    u = constrain(u, ("dp", None, "tp"))

    cc = min(cfg.scan_chunk, s)
    pad = (-s) % cc
    u_p = jnp.pad(u, ((0, 0), (0, pad), (0, 0))) if pad else u
    nchunks = (s + pad) // cc
    u_c = u_p.reshape(b, nchunks, cc, cfg.d_inner).swapaxes(0, 1)

    def chunk_step(h, u_k):
        # Discretize INSIDE the chunk: the (B,cc,di,N) dA/dBu tensors exist
        # only per chunk, never for the full sequence (S/cc × less memory).
        da_k, dbu_k, c_k = _ssm_inputs(p, cfg, u_k)
        da_k = constrain(da_k, ("dp", None, "tp", None))
        dbu_k = constrain(dbu_k, ("dp", None, "tp", None))
        def combine(l, r):
            return (l[0] * r[0], l[1] * r[0] + r[1])
        a_acc, b_acc = jax.lax.associative_scan(combine, (da_k, dbu_k), axis=1)
        hs = constrain(a_acc * h[:, None] + b_acc, ("dp", None, "tp", None))
        y_k = jnp.einsum("bsdn,bsn->bsd", hs, c_k.astype(jnp.float32))
        return hs[:, -1], constrain(y_k, ("dp", None, "tp"))

    h0 = jnp.zeros((b, cfg.d_inner, cfg.d_state), jnp.float32) if h0 is None else h0
    h_last, y = jax.lax.scan(jax.checkpoint(chunk_step), h0, u_c)
    y = y.swapaxes(0, 1).reshape(b, nchunks * cc, cfg.d_inner)[:, :s]

    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        return out, (h_last, conv_out)
    return out


def init_mamba_cache(cfg: MambaConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_inner), dtype),
    }


def mamba_decode(p, cfg: MambaConfig, x, cache):
    """Single-token step. x (B,1,D) → (B,1,D), new cache."""
    xz = x @ p["in_proj"].astype(x.dtype)
    u, z = jnp.split(xz, 2, axis=-1)
    u, conv_state = _conv(p, cfg, u, cache["conv"])
    u = jax.nn.silu(u)
    da, dbu, c = _ssm_inputs(p, cfg, u)  # S=1
    h = cache["h"] * da[:, 0] + dbu[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0].astype(jnp.float32))[:, None]
    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"h": h, "conv": conv_state.astype(cache["conv"].dtype)}
