"""GQA attention: blocked (flash-style) training/prefill paths, cache-based
decode, sliding-window and chunked-local variants, optional qk-norm, RoPE and
logit softcap.  Pure jnp — memory-efficient by construction so 32k prefill
never materializes an S×S score matrix.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import nn

__all__ = ["AttnConfig", "attn_init", "attention", "attn_decode", "init_kv_cache"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    causal: bool = True
    window: int | None = None      # sliding-window (local) attention
    chunk: int | None = None       # llama4-style chunked attention
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    softcap: float | None = None
    bias: bool = False
    q_block: int = 512
    kv_block: int = 1024


def attn_init(key, cfg: AttnConfig, *, cross: bool = False):
    ks = jax.random.split(key, 6)
    qd, kvd = cfg.n_heads * cfg.head_dim, cfg.n_kv_heads * cfg.head_dim
    p, s = {}, {}
    p["wq"], s["wq"] = nn.dense_init(ks[0], cfg.d_model, qd, bias=cfg.bias,
                                     axes=("embed", "heads"))
    p["wk"], s["wk"] = nn.dense_init(ks[1], cfg.d_model, kvd, bias=cfg.bias,
                                     axes=("embed", "heads"))
    p["wv"], s["wv"] = nn.dense_init(ks[2], cfg.d_model, kvd, bias=cfg.bias,
                                     axes=("embed", "heads"))
    p["wo"], s["wo"] = nn.dense_init(ks[3], qd, cfg.d_model, bias=cfg.bias,
                                     axes=("heads", "embed"))
    if cfg.qk_norm:
        p["qn"], s["qn"] = nn.rmsnorm_init(cfg.head_dim)
        p["kn"], s["kn"] = nn.rmsnorm_init(cfg.head_dim)
    return p, s


def _project_qkv(p, cfg: AttnConfig, x, kv_x, q_pos, kv_pos):
    b, sq, _ = x.shape
    skv = kv_x.shape[1]
    q = nn.linear(p["wq"], x).reshape(b, sq, cfg.n_heads, cfg.head_dim)
    k = nn.linear(p["wk"], kv_x).reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    v = nn.linear(p["wv"], kv_x).reshape(b, skv, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["qn"], q)
        k = nn.rmsnorm(p["kn"], k)
    if cfg.rope:
        q = nn.apply_rope(q, q_pos, theta=cfg.rope_theta)
        k = nn.apply_rope(k, kv_pos, theta=cfg.rope_theta)
    return q, k, v


def _sdpa(cfg: AttnConfig, q, k, v, mask):
    """Dense attention on a (already block-sliced) window.
    q (B,Sq,H,D), k/v (B,Sk,KH,D), mask (Sq,Sk) or None → (B,Sq,H,D)."""
    g = cfg.n_heads // cfg.n_kv_heads
    b, sq, h, d = q.shape
    qg = q.reshape(b, sq, cfg.n_kv_heads, g, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(d)
    if cfg.softcap:
        logits = nn.softcap(logits, cfg.softcap)
    if mask is not None:
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(b, sq, h, d)


def _flash(cfg: AttnConfig, q, k, v, q_pos, kv_pos, constrain=None):
    """Blocked attention: map over q blocks, online-softmax scan over kv
    blocks.  Peak memory O(B·H·q_block·kv_block).

    `constrain` pins ONE layout (batch over dp, replicated over model) on
    every block tensor and on the scan carry — without it GSPMD solves
    layouts per-op inside the loop bodies and flip-flops between head- and
    row-sharded forms with "involuntary full rematerialization" copies
    (measured: 39 GB/device/layer of f32 reshard traffic on qwen3-14b)."""
    if constrain is None:
        constrain = lambda a, dims: a
    b, sq, h, d = q.shape
    skv = k.shape[1]
    g = cfg.n_heads // cfg.n_kv_heads
    qb = min(cfg.q_block, sq)
    kb = min(cfg.kv_block, skv)
    nq, nk = -(-sq // qb), -(-skv // kb)
    pad_q, pad_k = nq * qb - sq, nk * kb - skv
    q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    kv_pos = jnp.pad(kv_pos, (0, pad_k), constant_values=2**30)

    qs = q.reshape(b, nq, qb, cfg.n_kv_heads, g, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nk, kb, cfg.n_kv_heads, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kb, cfg.n_kv_heads, d).transpose(1, 0, 3, 2, 4)
    blk6 = (None, "dp", None, None, None, None)
    blk5 = (None, "dp", None, None, None)
    qs = constrain(qs, blk6)
    ks = constrain(ks, blk5)
    vs = constrain(vs, blk5)
    qp = q_pos.reshape(nq, qb)
    kp = kv_pos.reshape(nk, kb)
    scale = 1.0 / math.sqrt(d)

    def one_q_block(args):
        qblk, qpos = args  # (B,KH,G,qb,D), (qb,)

        def kv_step(carry, xs):
            m, l, acc = carry
            kblk, vblk, kpos = xs
            logits = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk)
            logits = logits.astype(jnp.float32) * scale
            if cfg.softcap:
                logits = nn.softcap(logits, cfg.softcap)
            mask = jnp.ones((qb, kb), bool)
            if cfg.causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if cfg.window is not None:
                mask &= qpos[:, None] - kpos[None, :] < cfg.window
            mask &= (qpos >= 0)[:, None] & (kpos < 2**30)[None, :]
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m, logits.max(axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(qblk.dtype), vblk).astype(jnp.float32)
            m_new = constrain(m_new, ("dp", None, None, None))
            l_new = constrain(l_new, ("dp", None, None, None))
            acc_new = constrain(acc_new, ("dp", None, None, None, None))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, cfg.n_kv_heads, g, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, cfg.n_kv_heads, g, qb), jnp.float32)
        a0 = jnp.zeros((b, cfg.n_kv_heads, g, qb, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, kp))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(one_q_block, (qs, qp))  # (nq,B,KH,G,qb,D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qb, h, d)
    return out[:, :sq].astype(q.dtype)


def _chunked_attn(cfg: AttnConfig, q, k, v, q_pos, kv_pos):
    """Chunked-local attention (llama4 iRoPE style): causal within aligned
    chunks of size cfg.chunk; no cross-chunk attention."""
    b, s, h, d = q.shape
    c = cfg.chunk
    pad = (-s) % c
    q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (s + pad) // c
    qc = q.reshape(b * n, c, h, d) if False else q.reshape(b, n, c, h, d)
    kc = k.reshape(b, n, c, cfg.n_kv_heads, d)
    vc = v.reshape(b, n, c, cfg.n_kv_heads, d)
    mask = jnp.tril(jnp.ones((c, c), bool)) if cfg.causal else None
    out = jax.vmap(lambda qq, kk, vv: _sdpa(cfg, qq, kk, vv, mask),
                   in_axes=(1, 1, 1), out_axes=1)(qc, kc, vc)
    return out.reshape(b, n * c, h, d)[:, :s]


def attention(p, cfg: AttnConfig, x, *, positions=None, kv_x=None,
              kv_positions=None, constrain=None):
    """Training / prefill attention.  x (B,S,D); kv_x for cross-attention.
    Returns (out (B,S,D), (k, v) for cache seeding)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _project_qkv(p, cfg, x, kv_x, positions, kv_positions)
    if constrain is not None:
        q = constrain(q, ("dp", None, None, None))
        k = constrain(k, ("dp", None, None, None))
        v = constrain(v, ("dp", None, None, None))
    out = (_chunked_attn(cfg, q, k, v, positions, kv_positions)
           if cfg.chunk is not None
           else _flash(cfg, q, k, v, positions, kv_positions,
                       constrain=constrain))
    if constrain is not None:
        out = constrain(out, ("dp", None, None, None))
    return nn.linear(p["wo"], out.reshape(b, s, -1)), (k, v)


# ---------------------------------------------------------------------------
# Decode path (single new token against a cache)
# ---------------------------------------------------------------------------

def cache_len(cfg: AttnConfig, max_len: int) -> int:
    """Local layers only keep a ring buffer of their receptive field."""
    if cfg.window is not None:
        return min(cfg.window, max_len)
    if cfg.chunk is not None:
        return min(cfg.chunk, max_len)
    return max_len


def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    s = cache_len(cfg, max_len)
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros(
            (batch, s), jnp.int32) - 1,  # absolute position per slot, -1 = empty
    }


def attn_decode(p, cfg: AttnConfig, x, cache, pos):
    """x (B,1,D), pos scalar int32 (same position for the whole batch).
    Returns (out (B,1,D), new_cache).  Ring-buffer update for local layers."""
    b = x.shape[0]
    q, k, v = _project_qkv(
        p, cfg, x, x, jnp.full((1,), pos), jnp.full((1,), pos))
    slot = pos % cache["k"].shape[1]
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((b, 1), pos, jnp.int32), slot, 1)

    kp = cpos[0]  # (S,) absolute positions in slots
    valid = kp >= 0
    if cfg.causal:
        valid &= kp <= pos
    if cfg.window is not None:
        valid &= pos - kp < cfg.window
    if cfg.chunk is not None:
        valid &= kp // cfg.chunk == pos // cfg.chunk

    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.head_dim)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck.astype(q.dtype))
    logits = logits.astype(jnp.float32) / math.sqrt(cfg.head_dim)
    if cfg.softcap:
        logits = nn.softcap(logits, cfg.softcap)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, cv.astype(q.dtype))
    out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    return nn.linear(p["wo"], out), {"k": ck, "v": cv, "pos": cpos}


def attn_cross_decode(p, cfg: AttnConfig, x, enc_k, enc_v, pos):
    """Cross-attention decode: static encoder KV, no cache update."""
    b = x.shape[0]
    q = nn.linear(p["wq"], x).reshape(b, 1, cfg.n_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.rmsnorm(p["qn"], q)
    out = _sdpa(dataclasses.replace(cfg, causal=False, rope=False),
                q, enc_k, enc_v, None)
    return nn.linear(p["wo"], out.reshape(b, 1, -1))
