"""Block composition + layer stacking.

A model is a list of *segments*; each segment is a repeating pattern of
heterogeneous blocks (e.g. gemma3 = [(local×5, global×1) ×5, (local×4) ×1];
jamba = [(mamba×4, attn, mamba×3 with alternating MoE) ×9]).  Each segment
lowers to ONE `lax.scan` whose body unrolls the pattern — HLO stays small
(one pattern body per segment) regardless of depth, which keeps 72-layer
compiles fast on the CPU dry-run host and on real TPU.

Blocks are pre-norm residual: x + Mixer(LN(x)); x + MLP(LN(x)).
The residual stream is Megatron-SP sharded (sequence over `model`) between
blocks during train/prefill; mixers reshard internally as needed.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import shard_map
from . import attention as attn_mod
from . import moe as moe_mod
from . import nn, ssm, xlstm

__all__ = ["LayerSpec", "MeshCtx", "block_init", "block_apply", "block_decode",
           "stack_init", "stack_apply", "stack_decode", "init_stack_cache"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # attn | mamba | mlstm | slstm
    attn_kind: str = "global"    # global | local | chunked
    mlp: str = "dense"           # dense | moe | none
    cross_attn: bool = False     # enc-dec decoder blocks
    causal: bool = True


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Any
    dp: tuple[str, ...] = ("data",)
    tp: str = "model"
    seq_sharded: bool = True

    def shard(self, x, *spec):
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*spec)))

    def resid(self, x):
        sp = self.tp if self.seq_sharded else None
        return self.shard(x, self.dp, sp, None)


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------

def _attn_cfg(cfg, spec: LayerSpec) -> attn_mod.AttnConfig:
    return attn_mod.AttnConfig(
        d_model=cfg.d_model, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim, causal=spec.causal,
        window=cfg.window if spec.attn_kind == "local" else None,
        chunk=cfg.chunk_attn if spec.attn_kind == "chunked" else None,
        qk_norm=cfg.qk_norm,
        rope=cfg.rope and not (spec.attn_kind == "global" and cfg.nope_global),
        rope_theta=cfg.rope_theta, softcap=cfg.attn_softcap, bias=cfg.bias,
        q_block=cfg.q_block, kv_block=cfg.kv_block,
    )


def _cross_cfg(cfg) -> attn_mod.AttnConfig:
    return dataclasses.replace(
        _attn_cfg(cfg, LayerSpec(causal=False)), causal=False, rope=False)


def _mamba_cfg(cfg) -> ssm.MambaConfig:
    return ssm.MambaConfig(d_model=cfg.d_model, d_state=cfg.d_state,
                           scan_chunk=cfg.mamba_scan_chunk)


def _mlp_init(key, cfg):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_act in ("gelu2", "relu2"):  # plain 2-matrix MLP (whisper/minitron)
        p = {"w1": jax.random.normal(ks[0], (d, f), jnp.float32) * d**-0.5,
             "w2": jax.random.normal(ks[1], (f, d), jnp.float32) * f**-0.5}
        s = {"w1": ("embed", "ffn"), "w2": ("ffn", "embed")}
    else:  # gated: swiglu / geglu
        p = {"wg": jax.random.normal(ks[0], (d, f), jnp.float32) * d**-0.5,
             "wu": jax.random.normal(ks[1], (d, f), jnp.float32) * d**-0.5,
             "wd": jax.random.normal(ks[2], (f, d), jnp.float32) * f**-0.5}
        s = {"wg": ("embed", "ffn"), "wu": ("embed", "ffn"),
             "wd": ("ffn", "embed")}
    return p, s


def _acts(cfg):
    acts = {"gelu": jax.nn.gelu, "gelu2": jax.nn.gelu, "geglu": jax.nn.gelu,
            "relu2": lambda v: jnp.square(jax.nn.relu(v))}
    return acts.get(cfg.mlp_act, jax.nn.silu)


def _mlp_apply(p, cfg, ctx: MeshCtx, x):
    act = _acts(cfg)
    if cfg.mlp_act in ("gelu2", "relu2"):  # non-gated 2-matrix MLP
        h = act(x @ p["w1"].astype(x.dtype))
        h = ctx.shard(h, ctx.dp, None, ctx.tp)
        return h @ p["w2"].astype(x.dtype)
    h = act(x @ p["wg"].astype(x.dtype)) * (x @ p["wu"].astype(x.dtype))
    h = ctx.shard(h, ctx.dp, None, ctx.tp)
    return h @ p["wd"].astype(x.dtype)


def _mlp_manual_sp(p, cfg, ctx: MeshCtx, h):
    """§Perf H11a — explicit Megatron-SP MLP collectives via shard_map.

    GSPMD's implicit resharding around the TP MLP emits full all-reduces of
    (B,S,D) activations fwd AND bwd (~2/3 of the measured 29 GB/layer wire
    on qwen3-14b).  The manual schedule is the textbook pairing:
      fwd:  bf16 all-gather(seq) → column-parallel → row-parallel →
            psum_scatter(seq)
      bwd:  the exact transposes (psum_scatter ↔ all-gather), for free via
            JAX AD through shard_map.
    FSDP weight gathers happen in-body AFTER casting to bf16 (half wire vs
    gathering fp32 masters).  h: (B, S, D) at P(dp, tp, None)."""
    mesh = ctx.mesh
    dp, tp = ctx.dp, ctx.tp
    act = _acts(cfg)
    gated = cfg.mlp_act not in ("gelu2", "relu2")
    data = "data" if "data" in dict(mesh.shape) else None

    def gather_w(w, axis):
        if data is None:
            return w
        return jax.lax.all_gather(w, data, axis=axis, tiled=True)

    if gated:
        def body(h_loc, wg, wu, wd):
            hf = jax.lax.all_gather(h_loc, tp, axis=1, tiled=True)
            wg = gather_w(wg.astype(hf.dtype), 0)
            wu = gather_w(wu.astype(hf.dtype), 0)
            wd = gather_w(wd.astype(hf.dtype), 1)
            inter = act(hf @ wg) * (hf @ wu)       # (B/dp, S, F/tp)
            out = inter @ wd                        # (B/dp, S, D) partial
            return jax.lax.psum_scatter(out, tp, scatter_dimension=1,
                                        tiled=True)
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(dp, tp, None), P(data, tp), P(data, tp),
                      P(tp, data)),
            out_specs=P(dp, tp, None),
        )(h, p["wg"], p["wu"], p["wd"])

    def body(h_loc, w1, w2):
        hf = jax.lax.all_gather(h_loc, tp, axis=1, tiled=True)
        w1 = gather_w(w1.astype(hf.dtype), 0)
        w2 = gather_w(w2.astype(hf.dtype), 1)
        out = act(hf @ w1) @ w2
        return jax.lax.psum_scatter(out, tp, scatter_dimension=1, tiled=True)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, tp, None), P(data, tp), P(tp, data)),
        out_specs=P(dp, tp, None),
    )(h, p["w1"], p["w2"])


def _mlp_manual_ok(cfg, ctx: MeshCtx, x) -> bool:
    """Manual SP needs seq-sharded residuals and divisible dims."""
    if not (cfg.manual_sp and ctx.seq_sharded and ctx.mesh is not None):
        return False
    axes = dict(ctx.mesh.shape)
    tp, d_sz = axes.get(ctx.tp, 1), axes.get("data", 1)
    b, s, d = x.shape
    dp_sz = 1
    for a in ctx.dp:
        dp_sz *= axes.get(a, 1)
    return (s % tp == 0 and b % dp_sz == 0 and cfg.d_ff % tp == 0
            and d % d_sz == 0 and cfg.d_ff % d_sz == 0 and d % tp == 0)


def block_init(key, cfg, spec: LayerSpec):
    ks = jax.random.split(key, 5)
    p, s = {}, {}
    p["ln1"], s["ln1"] = nn.rmsnorm_init(cfg.d_model)
    if spec.mixer == "attn":
        p["attn"], s["attn"] = attn_mod.attn_init(ks[0], _attn_cfg(cfg, spec))
    elif spec.mixer == "mamba":
        p["mamba"], s["mamba"] = ssm.mamba_init(ks[0], _mamba_cfg(cfg))
    elif spec.mixer == "mlstm":
        p["mlstm"], s["mlstm"] = xlstm.mlstm_init(
            ks[0], xlstm.MLSTMConfig(cfg.d_model, cfg.n_heads))
    elif spec.mixer == "slstm":
        p["slstm"], s["slstm"] = xlstm.slstm_init(
            ks[0], xlstm.SLSTMConfig(cfg.d_model, cfg.n_heads))
    else:
        raise ValueError(spec.mixer)
    if spec.cross_attn:
        p["lnx"], s["lnx"] = nn.rmsnorm_init(cfg.d_model)
        p["xattn"], s["xattn"] = attn_mod.attn_init(ks[1], _cross_cfg(cfg))
    if spec.mlp != "none":
        p["ln2"], s["ln2"] = nn.rmsnorm_init(cfg.d_model)
        if spec.mlp == "moe":
            p["moe"], s["moe"] = moe_mod.moe_init(ks[2], cfg.moe_cfg())
        else:
            p["mlp"], s["mlp"] = _mlp_init(ks[2], cfg)
    return p, s


# ---------------------------------------------------------------------------
# Block apply (train / prefill)
# ---------------------------------------------------------------------------

def block_apply(p, cfg, spec: LayerSpec, ctx: MeshCtx, x, *, positions,
                enc_out=None, return_cache=False):
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    cache_seed = None

    def constrain(arr, dims):
        spec_ = tuple(ctx.dp if d == "dp" else (ctx.tp if d == "tp" else None)
                      for d in dims)
        return ctx.shard(arr, *spec_)

    if spec.mixer == "attn":
        h = ctx.shard(h, ctx.dp, None, None)  # gather seq for attention
        out, kv = attn_mod.attention(
            p["attn"], _attn_cfg(cfg, spec), h, positions=positions,
            constrain=constrain if cfg.attn_pin_layout else None)
        cache_seed = kv
        # land the mixer output in the residual's seq-sharded layout BEFORE
        # the add, so GSPMD turns the wo psum into a reduce-scatter (§Perf)
        out = ctx.resid(out)
    elif spec.mixer == "mamba":
        h = ctx.shard(h, ctx.dp, None, None)
        out = ssm.mamba_apply(p["mamba"], _mamba_cfg(cfg), h,
                              constrain=constrain)
    elif spec.mixer == "mlstm":
        out = xlstm.mlstm_apply(
            p["mlstm"],
            xlstm.MLSTMConfig(cfg.d_model, cfg.n_heads, chunk=cfg.mlstm_chunk),
            h)
    else:
        out = xlstm.slstm_apply(
            p["slstm"], xlstm.SLSTMConfig(cfg.d_model, cfg.n_heads), h)
    x = ctx.resid(x + out)

    if spec.cross_attn:
        h = nn.rmsnorm(p["lnx"], x, cfg.norm_eps)
        h = ctx.shard(h, ctx.dp, None, None)
        out, _ = attn_mod.attention(
            p["xattn"], _cross_cfg(cfg), h, kv_x=enc_out,
            positions=positions,
            kv_positions=jnp.arange(enc_out.shape[1]))
        x = ctx.resid(x + out)

    if spec.mlp != "none":
        h = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if spec.mlp == "moe":
            out = moe_mod.moe_apply(
                p["moe"], cfg.moe_cfg(), h, mesh=ctx.mesh, dp_axes=ctx.dp,
                model_axis=ctx.tp, seq_sharded=ctx.seq_sharded)
        elif _mlp_manual_ok(cfg, ctx, h):
            h = ctx.resid(h)  # ensure the manual schedule's input layout
            out = _mlp_manual_sp(p["mlp"], cfg, ctx, h)
        else:
            out = _mlp_apply(p["mlp"], cfg, ctx, h)
        x = ctx.resid(x + out)
    return (x, cache_seed) if return_cache else x


# ---------------------------------------------------------------------------
# Block decode (single token against cache)
# ---------------------------------------------------------------------------

def init_block_cache(cfg, spec: LayerSpec, batch: int, max_len: int,
                     enc_len: int = 0, dtype=jnp.bfloat16):
    c = {}
    if spec.mixer == "attn":
        c["kv"] = attn_mod.init_kv_cache(
            _attn_cfg(cfg, spec), batch, max_len, dtype)
    elif spec.mixer == "mamba":
        c["mamba"] = ssm.init_mamba_cache(_mamba_cfg(cfg), batch, dtype)
    elif spec.mixer == "mlstm":
        c["mlstm"] = xlstm.init_mlstm_cache(
            xlstm.MLSTMConfig(cfg.d_model, cfg.n_heads), batch)
    else:
        c["slstm"] = xlstm.init_slstm_cache(
            xlstm.SLSTMConfig(cfg.d_model, cfg.n_heads), batch)
    if spec.cross_attn:
        kvd = cfg.n_kv_heads * cfg.head_dim
        c["xk"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
        c["xv"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype)
    return c


def block_decode(p, cfg, spec: LayerSpec, ctx: MeshCtx, x, cache, pos):
    h = nn.rmsnorm(p["ln1"], x, cfg.norm_eps)
    new = dict(cache)
    if spec.mixer == "attn":
        out, new["kv"] = attn_mod.attn_decode(
            p["attn"], _attn_cfg(cfg, spec), h, cache["kv"], pos)
    elif spec.mixer == "mamba":
        out, new["mamba"] = ssm.mamba_decode(
            p["mamba"], _mamba_cfg(cfg), h, cache["mamba"])
    elif spec.mixer == "mlstm":
        out, new["mlstm"] = xlstm.mlstm_decode(
            p["mlstm"], xlstm.MLSTMConfig(cfg.d_model, cfg.n_heads), h,
            cache["mlstm"])
    else:
        out, new["slstm"] = xlstm.slstm_decode(
            p["slstm"], xlstm.SLSTMConfig(cfg.d_model, cfg.n_heads), h,
            cache["slstm"])
    x = x + out
    if spec.cross_attn:
        h = nn.rmsnorm(p["lnx"], x, cfg.norm_eps)
        out = attn_mod.attn_cross_decode(
            p["xattn"], _cross_cfg(cfg), h,
            cache["xk"].astype(x.dtype), cache["xv"].astype(x.dtype), pos)
        x = x + out
    if spec.mlp != "none":
        h = nn.rmsnorm(p["ln2"], x, cfg.norm_eps)
        out = (moe_mod.moe_apply(
                   p["moe"], cfg.moe_cfg(), h, mesh=ctx.mesh, dp_axes=ctx.dp,
                   model_axis=ctx.tp, seq_sharded=False)
               if spec.mlp == "moe" else _mlp_apply(p["mlp"], cfg, ctx, h))
        x = x + out
    return x, new


# ---------------------------------------------------------------------------
# Stacking: segments of repeated patterns, one lax.scan per segment
# ---------------------------------------------------------------------------

def segment_layout(n_layers: int, pattern: tuple[LayerSpec, ...]):
    """[(pattern, n_repeats), (remainder_pattern, 1)] covering n_layers."""
    plen = len(pattern)
    reps, rem = divmod(n_layers, plen)
    segs = []
    if reps:
        segs.append((tuple(pattern), reps))
    if rem:
        segs.append((tuple(pattern[:rem]), 1))
    return segs


def stack_init(key, cfg, pattern, n_layers: int):
    """Per segment: pytree stacked over repeats: {"b0": stacked, "b1": ...}."""
    segs = segment_layout(n_layers, pattern)
    params, specs = [], []
    keys = jax.random.split(key, sum(r for _, r in segs) * len(pattern) + 1)
    ki = 0
    for pat, reps in segs:
        seg_p, seg_s = {}, {}
        for j, spec in enumerate(pat):
            per_rep = []
            for _ in range(reps):
                p, s = block_init(keys[ki], cfg, spec)
                ki += 1
                per_rep.append(p)
            seg_p[f"b{j}"] = jax.tree.map(lambda *a: jnp.stack(a), *per_rep)
            seg_s[f"b{j}"] = jax.tree.map(
                lambda ax: (None,) + tuple(ax), s,
                is_leaf=lambda x: isinstance(x, tuple))
        params.append(seg_p)
        specs.append(seg_s)
    return params, specs, segs


def stack_apply(params, cfg, segs, ctx: MeshCtx, x, *, positions,
                enc_out=None):
    for seg_p, (pat, reps) in zip(params, segs, strict=True):
        def body(x, layer_p, pat=pat):
            for j, spec in enumerate(pat):
                x = block_apply(layer_p[f"b{j}"], cfg, spec, ctx, x,
                                positions=positions, enc_out=enc_out)
            return x, None
        body = jax.checkpoint(body) if cfg.remat else body
        if cfg.unroll_stack:
            # exact-cost mode: XLA counts a while body once, so the dry-run
            # calibration unrolls the layer loop into straight-line HLO
            for r in range(reps):
                layer_p = jax.tree.map(lambda a, r=r: a[r], seg_p)
                x, _ = body(x, layer_p)
        else:
            x, _ = jax.lax.scan(body, x, seg_p)
    return x


def init_stack_cache(cfg, segs, batch: int, max_len: int, enc_len: int = 0,
                     dtype=jnp.bfloat16):
    caches = []
    for pat, reps in segs:
        seg_c = {}
        for j, spec in enumerate(pat):
            one = init_block_cache(cfg, spec, batch, max_len, enc_len, dtype)
            seg_c[f"b{j}"] = jax.tree.map(
                lambda a, reps=reps: jnp.broadcast_to(a, (reps,) + a.shape),
                one)
        caches.append(seg_c)
    return caches


def stack_decode(params, cfg, segs, ctx: MeshCtx, x, caches, pos):
    new_caches = []
    for seg_p, seg_c, (pat, reps) in zip(params, caches, segs, strict=True):
        def body(x, pc, pat=pat):
            layer_p, layer_c = pc
            new_c = dict(layer_c)
            for j, spec in enumerate(pat):
                x, new_c[f"b{j}"] = block_decode(
                    layer_p[f"b{j}"], cfg, spec, ctx, x, layer_c[f"b{j}"], pos)
            return x, new_c
        if cfg.unroll_stack:  # exact-cost mode (see stack_apply)
            outs = []
            for r in range(reps):
                pc = jax.tree.map(lambda a, r=r: a[r], (seg_p, seg_c))
                x, nc_r = body(x, pc)
                outs.append(nc_r)
            nc = jax.tree.map(lambda *a: jnp.stack(a), *outs)
        else:
            x, nc = jax.lax.scan(body, x, (seg_p, seg_c))
        new_caches.append(nc)
    return x, new_caches
