"""Persistent autotuner store: measured winners survive the process.

The paper's finding (Fig. 7) is that the best spMTTKRP strategy is
workload-dependent; the autotuner measures that — but measurement is only
worth its cost if a familiar workload doesn't re-pay it every process.  The
store persists each `AutotuneReport` keyed by a *workload fingerprint*
(tensor shape, nnz, density, mode count, rank, candidate set) plus a
*device fingerprint* (jax backend/platform, device count, device kind, jax
version), so a repeat decomposition of the same — or a near-identical —
tensor skips the probe phase entirely and dispatches straight to the
persisted per-mode winners.

Matching is exact-or-near: everything in the fingerprint must match
exactly except nnz/density, which tolerate a relative drift (default 10%)
— re-decomposing this week's crawl of last week's tensor should still hit.
A device-fingerprint change (different backend, device count, or jax
version) always invalidates: timings measured on other silicon are noise.

Default store path: `~/.cache/repro/autotune.json`, overridable with the
`REPRO_AUTOTUNE_CACHE` environment variable or the `path` argument.  Writes
are atomic (temp file + rename) and the read-merge-write cycle in `save()`
runs under an advisory file lock (`<path>.lock`, flock), so concurrent
processes — sweep workers filling one store in parallel — never drop each
other's fresh entries; last writer wins per fingerprint.  On hosts without
POSIX locks the writer falls back to verify-and-re-merge retries after the
atomic rename.

Entries can expire: pass `ttl_s=` (or set `REPRO_AUTOTUNE_TTL` seconds) and
`lookup` ignores entries older than the TTL, so a stale workload re-probes —
the device fingerprint can't see silent environment drift (thermal state,
background load, a driver update under the same version string), but a TTL
bounds how long a drifted measurement keeps steering dispatch.  Expired
entries are also excluded from `observations()`, the training-data iterator
the cost-model calibration (calibrate.py) fits against.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import NamedTuple

import jax

try:
    import fcntl
except ImportError:  # pragma: no cover — non-POSIX host
    fcntl = None

__all__ = [
    "DEFAULT_STORE_ENV",
    "DEFAULT_TTL_ENV",
    "Observation",
    "StoredEntry",
    "TuningStore",
    "WorkloadKey",
    "budget_covers",
    "device_fingerprint",
    "device_fingerprint_id",
]

DEFAULT_STORE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_TTL_ENV = "REPRO_AUTOTUNE_TTL"
# v2 adds nothing to the entry layout (per-entry `created` timestamps were
# already written by v1) but marks stores whose entries are TTL-aware and
# near-match-deduplicated; v1 files load unchanged.  v3 adds the optional
# `budget` / `errors` fields (accuracy-budgeted format autotuning); v1/v2
# files load unchanged with budget=None and no recorded errors.  v4 adds the
# optional `format_stats` field — the measured layout statistics
# (repro.formats.FormatStats: per-mode fiber counts, interleave key bits) of
# the tuned tensor, so format candidate ids ("csf"/"alto") round-trip with
# the numbers their byte models need at calibration time; v1-v3 files load
# unchanged with format_stats=None (calibration falls back to the
# balls-in-bins estimate).  v5 adds the optional `capacity` field to the
# workload KEY — the explicit chunk capacity the workload was tuned under
# (None: the partition decider's choice) — so the offline sweep's capacity
# axis fingerprints distinctly instead of colliding with the default-
# capacity entry; v1-v4 files load unchanged with capacity=None, which is
# exactly what every pre-v5 writer ran with.  See docs/store-schema.md.
_SCHEMA_VERSION = 5
_READABLE_VERSIONS = (1, 2, 3, 4, 5)
#: Bounded verify-and-re-merge retries for the no-flock save() fallback.
_SAVE_RETRIES = 5


def default_store_path() -> str:
    env = os.environ.get(DEFAULT_STORE_ENV)
    if env:
        return env
    return os.path.join(
        os.path.expanduser("~"), ".cache", "repro", "autotune.json")


def default_ttl_s() -> float | None:
    env = os.environ.get(DEFAULT_TTL_ENV)
    if not env:
        return None
    try:
        ttl = float(env)
    except ValueError:
        return None
    return ttl if ttl > 0 else None


def device_fingerprint() -> dict[str, str]:
    """What the timings were measured on.  Any change invalidates entries:
    a winner measured on other silicon (or another XLA) is not a prior worth
    trusting over re-measurement."""
    devices = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_count": str(len(devices)),
        "device_kind": devices[0].device_kind,
        "jax": jax.__version__,
    }


def device_fingerprint_id(fp: dict[str, str] | None = None) -> str:
    """Short stable hex id of a device fingerprint — the key CI uses to name
    a shipped warm-store artifact, so a downstream job only loads stores
    measured on matching silicon (benchmarks/sweep.py `--fingerprint`)."""
    fp = device_fingerprint() if fp is None else fp
    blob = json.dumps(dict(fp), sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


@dataclasses.dataclass(frozen=True)
class WorkloadKey:
    """Fingerprint of one (tensor, rank, candidate set, device) workload.

    `capacity` (schema v5) is the *explicit* chunk capacity the workload was
    tuned under, None when the partition decider chose (the default path —
    and the only value pre-v5 stores could have run with, so old entries
    load compatibly).  An explicitly-pinned capacity changes every chunked
    backend's padding, so timings measured under one must not serve
    another — the offline sweep enumerates capacity as a grid axis and
    relies on the distinct fingerprints.
    """

    shape: tuple[int, ...]
    nnz: int
    density: float
    ndim: int
    rank: int
    candidates: tuple[str, ...]
    device: tuple[tuple[str, str], ...]
    capacity: int | None = None

    @classmethod
    def from_tensor(cls, st, rank: int, candidates, *,
                    capacity: int | None = None) -> WorkloadKey:
        return cls(
            shape=tuple(int(d) for d in st.shape),
            nnz=int(st.nnz),
            density=float(st.density),
            ndim=int(st.ndim),
            rank=int(rank),
            candidates=tuple(sorted(candidates)),
            device=tuple(sorted(device_fingerprint().items())),
            capacity=int(capacity) if capacity is not None else None,
        )

    def to_json(self) -> dict:
        return {
            "shape": list(self.shape),
            "nnz": self.nnz,
            "density": self.density,
            "ndim": self.ndim,
            "rank": self.rank,
            "candidates": list(self.candidates),
            "device": {k: v for k, v in self.device},
            "capacity": self.capacity,
        }

    @classmethod
    def from_json(cls, d: dict) -> WorkloadKey:
        cap = d.get("capacity")
        return cls(
            shape=tuple(int(x) for x in d["shape"]),
            nnz=int(d["nnz"]),
            density=float(d["density"]),
            ndim=int(d["ndim"]),
            rank=int(d["rank"]),
            # Sort exactly as `from_tensor` does: a hand-edited or foreign-
            # order entry must still exact-match (and dedup) against the key
            # built from the live candidate list.
            candidates=tuple(sorted(str(c) for c in d["candidates"])),
            device=tuple(sorted((str(k), str(v))
                                for k, v in d["device"].items())),
            capacity=int(cap) if cap is not None else None,
        )

    def fingerprint(self) -> str:
        """Short stable hex id of the whole key (the workload analogue of
        `device_fingerprint_id`): the sweep runner tags each cell's trace
        spans with it, so a trace row is joinable back to the store entry
        it produced."""
        blob = json.dumps(self.to_json(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    def matches(self, other: WorkloadKey, *, nnz_tol: float = 0.1) -> bool:
        """Exact-or-near: everything exact except nnz/density within a
        relative tolerance (the same tensor re-ingested rarely has the
        byte-identical nonzero count).  `nnz_tol=0` degrades to exact-stat
        matching — what the sweep runner uses so adjacent nnz-band cells
        stay distinct."""
        if (self.shape, self.ndim, self.rank, self.candidates, self.device,
                self.capacity) != (
                other.shape, other.ndim, other.rank, other.candidates,
                other.device, other.capacity):
            return False
        if other.nnz == 0 or self.nnz == 0:
            return self.nnz == other.nnz
        if abs(self.nnz - other.nnz) / other.nnz > nnz_tol:
            return False
        return abs(self.density - other.density) / max(other.density, 1e-30) <= nnz_tol


@dataclasses.dataclass
class StoredEntry:
    """One persisted autotune outcome.

    `budget` is the accuracy budget the entry was tuned under (None: the
    lossless-only default), and `errors` the measured per-mode MTTKRP
    relative errors of the lossy candidates that were probed — together they
    let a later lookup decide whether the persisted winners are *valid* for
    its own budget (see `budget_covers`) instead of trusting blindly.

    `format_stats` (schema v4) is the tuned tensor's measured layout
    statistics as a `repro.formats.FormatStats` JSON dict — fiber counts per
    mode, interleave key width — recorded whenever the candidate space held
    a format backend, so the calibration's csf/alto design columns train on
    the same numbers the live prediction used.
    """

    key: WorkloadKey
    winners: dict[int, str]                # mode -> candidate id
    timings: dict[str, dict[int, float]]   # candidate -> mode -> best seconds
    overall: str | None = None             # fallback for untimed modes
    warmup: int = 1
    reps: int = 2
    created: float = 0.0
    budget: float | None = None            # accuracy budget tuned under
    errors: dict[str, dict[int, float]] = dataclasses.field(
        default_factory=dict)              # candidate -> mode -> rel error
    format_stats: dict | None = None       # FormatStats.to_json() payload

    def to_json(self) -> dict:
        return {
            "key": self.key.to_json(),
            "winners": {str(m): n for m, n in self.winners.items()},
            "timings": {n: {str(m): t for m, t in per.items()}
                        for n, per in self.timings.items()},
            "overall": self.overall,
            "warmup": self.warmup,
            "reps": self.reps,
            "created": self.created,
            "budget": self.budget,
            "errors": {n: {str(m): e for m, e in per.items()}
                       for n, per in self.errors.items()},
            "format_stats": self.format_stats,
        }

    @classmethod
    def from_json(cls, d: dict) -> StoredEntry:
        budget = d.get("budget")
        fstats = d.get("format_stats")
        return cls(
            key=WorkloadKey.from_json(d["key"]),
            winners={int(m): str(n) for m, n in d["winners"].items()},
            timings={n: {int(m): float(t) for m, t in per.items()}
                     for n, per in d.get("timings", {}).items()},
            overall=d.get("overall"),
            warmup=int(d.get("warmup", 1)),
            reps=int(d.get("reps", 2)),
            created=float(d.get("created", 0.0)),
            budget=float(budget) if budget is not None else None,
            errors={n: {int(m): float(e) for m, e in per.items()}
                    for n, per in d.get("errors", {}).items()},
            format_stats=dict(fstats) if isinstance(fstats, dict) else None,
        )


#: Sentinel: "don't filter on budget" (distinct from None, which is the
#: real lossless-only budget value).
_ANY_BUDGET = object()


def budget_covers(stored: float | None, requested: float | None) -> bool:
    """Whether winners tuned under `stored` remain valid for `requested`.

    Matching or looser requests reuse the entry: every admitted candidate's
    measured error was <= the stored budget, so it is also <= any looser
    one.  Everything else re-probes — a *stricter* request could be handed
    an over-budget winner, a `None` (lossless-only) request must never
    dispatch to a lossy winner tuned under some budget, and a budgeted
    request can't trust an entry that never measured errors at all.
    """
    if stored is None:
        return requested is None
    if requested is None:
        return False
    return requested >= stored


def _drop_shadowed(entries: list[StoredEntry], *,
                   nnz_tol: float = 0.1) -> list[StoredEntry]:
    """Keep only the newest of any near-matching cluster: an entry recorded
    later supersedes older entries its key near-matches (they would only
    shadow each other in `lookup`).  Exact-duplicate keys are expected to be
    merged by the caller already.  `nnz_tol=0` keeps every distinct
    fingerprint — the sweep-store policy, where adjacent nnz-band cells are
    deliberate grid points, not drift."""
    kept: list[StoredEntry] = []
    for e in sorted(entries, key=lambda e: e.created):
        kept = [k for k in kept if not e.key.matches(k.key, nnz_tol=nnz_tol)]
        kept.append(e)
    return kept


class Observation(NamedTuple):
    """One measured (workload, backend, mode) → seconds data point — the
    training rows the cost-model calibration fits against.  `format_stats`
    carries the entry's persisted layout statistics (schema v4) when
    present, so the csf/alto design columns train on measured fiber
    counts."""

    key: WorkloadKey
    backend: str
    mode: int
    seconds: float
    created: float
    format_stats: dict | None = None


class TuningStore:
    """JSON-file store of autotune outcomes.

    Lookup is linear over entries (stores hold tens of workloads, not
    millions); exact fingerprint matches win over near matches, and among
    near matches the closest nnz wins.

    `ttl_s` (default: the `REPRO_AUTOTUNE_TTL` env var, else no expiry)
    bounds how long an entry steers dispatch: entries older than the TTL are
    invisible to `lookup` and `observations`, so the workload re-probes and
    the fresh measurement replaces the stale one.  A TTL of 0 or less means
    "no expiry" here exactly as it does in the env var, so `ttl_s=0` is the
    explicit opt-out when the environment sets a TTL.  Entries with no
    recorded timestamp (`created == 0`, from pre-v2 stores) count as stale
    whenever a TTL is in force — unknown age is not trusted age.

    `nnz_tol` is the store's near-match policy (default 0.1): the relative
    nnz/density drift `lookup` tolerates AND the radius within which
    `record`/`save` treat entries as superseding each other.  The offline
    sweep (repro.sweep) opens its store with `nnz_tol=0`: grid cells a few
    percent apart in nnz are deliberate design points that must neither
    serve each other warm nor dedup each other away.
    """

    def __init__(self, path: str | os.PathLike | None = None, *,
                 ttl_s: float | None = None, nnz_tol: float = 0.1):
        self.path = os.fspath(path) if path is not None else default_store_path()
        self.ttl_s = ((ttl_s if ttl_s > 0 else None)
                      if ttl_s is not None else default_ttl_s())
        if nnz_tol < 0:
            raise ValueError(f"nnz_tol is a relative drift tolerance and "
                             f"must be >= 0 (got {nnz_tol})")
        self.nnz_tol = float(nnz_tol)
        self._entries: list[StoredEntry] | None = None  # lazy-loaded
        #: Keys `forget()` removed but save() hasn't published yet: the
        #: read-merge-write in save() would otherwise resurrect them from
        #: the on-disk copy (merging can only add/update, never delete).
        self._forgotten: set[WorkloadKey] = set()

    def expired(self, entry: StoredEntry, *, now: float | None = None) -> bool:
        if self.ttl_s is None:
            return False
        now = time.time() if now is None else now  # repro-lint: disable=nondeterminism -- TTL expiry and created-ordering compare against epoch wall-clock by design (docs/store-schema.md)
        return (now - entry.created) > self.ttl_s

    # -- I/O ---------------------------------------------------------------
    def _read_disk(self) -> list[StoredEntry]:
        try:
            with open(self.path) as f:
                raw = json.load(f)
            if isinstance(raw, dict) and raw.get("version") in _READABLE_VERSIONS:
                return [StoredEntry.from_json(e) for e in raw.get("entries", [])]
        except FileNotFoundError:
            pass
        except (json.JSONDecodeError, KeyError, TypeError, ValueError, OSError):
            # A corrupt or foreign-schema store must never take the
            # decomposition down — fall back to cold-start behaviour.
            pass
        return []

    def _load(self) -> list[StoredEntry]:
        if self._entries is None:
            self._entries = self._read_disk()
        return self._entries

    @contextlib.contextmanager
    def _save_lock(self):
        """Advisory inter-process lock (`<path>.lock`, flock) serializing
        the read-merge-write cycle in `save`.  Yields whether the lock was
        actually taken — False on hosts without POSIX locks, where `save`
        falls back to verify-and-re-merge retries."""
        if fcntl is None:  # pragma: no cover — non-POSIX host
            yield False
            return
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        with open(self.path + ".lock", "a") as lf:
            fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
            try:
                yield True
            finally:
                fcntl.flock(lf.fileno(), fcntl.LOCK_UN)

    def _merge_and_write(self) -> None:
        # Merge with what's on disk right now, not with our lazily-cached
        # snapshot: concurrent processes sharing a store must lose at most
        # a racing write to the *same* fingerprint, never other workloads'
        # entries.  (The rename below is atomic; this read-merge-write makes
        # "last writer wins" hold per fingerprint rather than per file.)
        by_key = {e.key: e for e in self._read_disk()
                  if e.key not in self._forgotten}
        by_key.update({e.key: e for e in self._load()})
        self._entries = _drop_shadowed(list(by_key.values()),
                                       nnz_tol=self.nnz_tol)
        payload = {
            "version": _SCHEMA_VERSION,
            "entries": [e.to_json() for e in self._entries],
        }
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".autotune-", suffix=".json", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1)
            os.replace(tmp, self.path)  # atomic: concurrent readers see old/new
            self._forgotten.clear()     # the deletions are published now
        except BaseException:
            with contextlib.suppress(OSError):
                os.unlink(tmp)
            raise

    def save(self) -> None:
        """Write the store to disk, merged with concurrent writers' entries.

        The read-merge-write cycle runs under an advisory flock on
        `<path>.lock`: without it, two writers that both read before either
        renamed would each publish a payload missing the other's fresh
        fingerprints — the second rename wins and silently drops the
        first's work (exactly the concurrent-sweep-worker case).  Where
        flock is unavailable the writer re-reads after its rename and
        re-merges until its own entries are all present (bounded retries).
        """
        with self._save_lock() as locked:
            self._merge_and_write()
        if locked:
            return
        for _ in range(_SAVE_RETRIES):  # pragma: no cover — non-POSIX host
            ours = {e.key for e in self._load()}
            if ours <= {e.key for e in self._read_disk()}:
                return
            self._merge_and_write()

    # -- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._load())

    def entries(self) -> list[StoredEntry]:
        return list(self._load())

    def lookup(self, key: WorkloadKey, *, nnz_tol: float | None = None,
               budget: float | None | object = _ANY_BUDGET,
               ) -> StoredEntry | None:
        """Exact-or-near fingerprint match (see `WorkloadKey.matches`),
        ignoring entries past the store's TTL — stale winners re-probe.
        `nnz_tol` defaults to the store's policy (`self.nnz_tol`).

        `budget` (when given) additionally requires the entry's tuning
        budget to cover the requested one (`budget_covers`): an entry tuned
        under a stricter-or-equal budget serves a looser request, anything
        else is invisible and the workload re-probes."""
        nnz_tol = self.nnz_tol if nnz_tol is None else nnz_tol
        now = time.time()  # repro-lint: disable=nondeterminism -- TTL expiry compares stored epoch timestamps against wall-clock now
        best: StoredEntry | None = None
        best_dist = float("inf")
        for e in self._load():
            if self.expired(e, now=now):
                continue
            if budget is not _ANY_BUDGET and not budget_covers(e.budget, budget):
                continue
            if e.key == key:
                return e
            if key.matches(e.key, nnz_tol=nnz_tol):
                dist = abs(e.key.nnz - key.nnz) / max(key.nnz, 1)
                if dist < best_dist:
                    best, best_dist = e, dist
        return best

    def observations(self, *, device: dict[str, str] | None = None,
                     include_expired: bool = False) -> list[Observation]:
        """Flatten every persisted timing into (key, backend, mode, seconds)
        training rows.  `device` filters to entries measured on one device
        fingerprint (pass `device_fingerprint()` for this host); expired
        entries are excluded unless `include_expired` — stale timings are no
        better as training data than as dispatch decisions."""
        want = tuple(sorted(device.items())) if device is not None else None
        now = time.time()  # repro-lint: disable=nondeterminism -- TTL expiry compares stored epoch timestamps against wall-clock now
        rows: list[Observation] = []
        for e in self._load():
            if not include_expired and self.expired(e, now=now):
                continue
            if want is not None and e.key.device != want:
                continue
            for backend, per_mode in e.timings.items():
                for mode, t in per_mode.items():
                    rows.append(Observation(e.key, backend, int(mode),
                                            float(t), e.created,
                                            e.format_stats))
        return rows

    def record(self, key: WorkloadKey, winners: dict[int, str],
               timings: dict[str, dict[int, float]], *,
               overall: str | None = None, warmup: int = 1, reps: int = 2,
               budget: float | None = None,
               errors: dict[str, dict[int, float]] | None = None,
               format_stats: dict | None = None,
               save: bool = True) -> StoredEntry:
        """Insert the entry for `key`, replacing the exact fingerprint AND
        any near-match it supersedes (within the store's `nnz_tol` policy):
        without the latter, repeated decompositions of a slowly drifting
        tensor (nnz creeping within the ±10% near-match window) accumulate
        entries that shadow each other in `lookup`, growing the store
        without bound.  A `nnz_tol=0` store keeps every distinct
        fingerprint — sweep grid cells never supersede their neighbours."""
        entry = StoredEntry(key=key, winners=dict(winners),
                            timings={n: dict(p) for n, p in timings.items()},
                            overall=overall, warmup=warmup, reps=reps,
                            created=time.time(), budget=budget,  # repro-lint: disable=nondeterminism -- entry creation timestamp is an epoch wall-clock field of the persisted schema
                            errors={n: dict(p)
                                    for n, p in (errors or {}).items()},
                            format_stats=format_stats)
        entries = self._load()
        self._entries = [*(e for e in entries
                           if e.key != key
                           and not key.matches(e.key, nnz_tol=self.nnz_tol)),
                         entry]
        if save:
            self.save()
        return entry

    def forget(self, key: WorkloadKey, *, save: bool = True) -> bool:
        """Drop the exact-fingerprint entry for `key`, if present.  The
        sweep runner's re-measure path (`resume=False`) forgets each cell
        before probing so the fresh measurement is recorded as a cold start
        instead of being served warm from the stale entry.

        The removal is remembered until the next successful `save()`:
        save's read-merge-write would otherwise resurrect the entry from
        the on-disk copy (merging can only add/update)."""
        entries = self._load()
        kept = [e for e in entries if e.key != key]
        if len(kept) == len(entries):
            return False
        self._entries = kept
        self._forgotten.add(key)
        if save:
            self.save()
        return True

    def clear(self) -> None:
        """Drop all entries and delete the backing file (and its lock)."""
        self._entries = []
        with contextlib.suppress(FileNotFoundError):
            os.unlink(self.path)
        with contextlib.suppress(FileNotFoundError):
            os.unlink(self.path + ".lock")

    def __repr__(self) -> str:
        return f"TuningStore({self.path!r}, entries={len(self)})"


def resolve_store(store) -> TuningStore | None:
    """Normalize the `store=` argument accepted by the autotuner:
    None/False → no persistence; True → default path (env-overridable);
    str/PathLike → that path; TuningStore → itself."""
    if store is None or store is False:
        return None
    if store is True:
        return TuningStore()
    if isinstance(store, TuningStore):
        return store
    return TuningStore(store)
