"""Store-calibrated cost model: fit the prior's coefficients to measured
timings.

The analytic prior (costmodel.py) ranks backends with hard-coded guesses for
bandwidth, chunk padding and dispatch overheads — good enough to spend a
probe budget wisely, but every tuned workload leaves behind exactly the
ground truth those guesses stand in for: the tuning store's
``(workload, backend, mode) → seconds`` observations.  This module closes
the loop, the way the paper's placement decision closes it with an analytic
memory-bound model: cold-start ranking improves with every workload tuned.

The per-backend byte models are linear in the reparametrized coefficients

    seconds ≈ a0·fixed + a1·padded + a2·densified + a3·narrow + a4·indexed
              + dispatch[backend]

with ``a0 = 1/bandwidth``, ``a1 = chunk_padding/bandwidth``,
``a2 = chunk_padding·hetero_overhead/bandwidth``,
``a3 = 1/narrow_bandwidth`` — the per-width bandwidth term: `narrow` counts
bytes moved through quantized int paths, already scaled by each candidate's
preset storage width, so one learned throughput coefficient prices every
Qm.n width (see `costmodel.byte_terms`) — and ``a4 = 1/indexed_bandwidth``,
the throughput of format-index traffic (CSF fiber-tree levels, ALTO key
words), whose design column uses the `FormatStats` persisted with each
entry when present (schema v4) and the balls-in-bins estimate otherwise,
exactly as prediction does.  The fit is one weighted least squares solve —
rows are weighted by ``1/seconds`` to minimize *relative* error, since a
giant tensor must not drown out the small ones the ranking also serves.
Recovered coefficients are sanitized (positivity, physical clamps) and any
unfittable coefficient falls back to the analytic default; a model-selection
guard additionally keeps the analytic coefficients outright when the fit's
in-sample top-1 agreement with the measured winners is worse than the
default's (thin, collinear stores can fit seconds yet mis-rank).  The
residual report says how far to trust the result, and feeds the autotuner's
cross-mode elision margin (a well-fit prior elides aggressively, a sloppy
one re-probes near the decision boundary).

``pallas`` observations are excluded from the fit: in interpret mode its
timing is dominated by a multiplicative simulation penalty, which is not
linear in the coefficients above.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .costmodel import (
    CostModelPrior,
    WorkloadStats,
    default_prior,
    device_byte_terms,
)
from .persist import Observation, TuningStore, device_fingerprint

__all__ = [
    "CalibratedPrior",
    "CalibrationError",
    "CalibrationReport",
    "MIN_OBSERVATIONS",
    "ranking_accuracy",
]

#: Fewest observations worth fitting: the model has 5 byte coefficients plus
#: one dispatch term per backend, so one full sweep of a 3-D tensor over 4
#: candidates (12 rows) is the floor for a non-degenerate solve (the narrow
#: column is all-zero without lossy candidates, the indexed column without
#: format-backend rows — either drops out of the fit).
MIN_OBSERVATIONS = 12

_BANDWIDTH_RANGE = (1e8, 1e13)   # B/s — below DDR3 single-channel / above HBM3e
_PADDING_RANGE = (1.0, 4.0)      # padding can only add traffic, and not 4x
_HETERO_RANGE = (1.0, 4.0)
_DISPATCH_RANGE = (0.0, 1.0)     # a per-call overhead beyond 1s is not dispatch
_DISPATCH_MIN = 1e-9             # below a nanosecond it's numerical dust


class CalibrationError(ValueError):
    """The store cannot support a fit (missing, empty, or too few rows)."""


#: Memoized fits keyed by store state (path, TTL, device, entry count,
#: newest timestamp): every cold-start autotune against a fat store resolves
#: a prior, and refitting identical data per build is pure waste.  A record()
#: or TTL change alters the token, so staleness is bounded by store writes.
_FIT_CACHE: dict[tuple, CalibratedPrior] = {}
_FIT_CACHE_MAX = 8


def _n_devices(key) -> int:
    return max(1, int(dict(key.device).get("device_count", "1")))


def _design_terms(backend: str, stats: WorkloadStats, rank: int, mode: int,
                  n_devices: int) -> tuple[float, float, float, float, float]:
    """The five byte columns of one observation's design row — the same
    decomposition `CostModelPrior.seconds` predicts with, by construction."""
    return device_byte_terms(backend, stats, rank, mode, n_devices=n_devices)


def _obs_stats(o: Observation) -> WorkloadStats:
    """Training stats for one observation: the entry's persisted
    `FormatStats` when the store recorded them (schema v4), else the
    estimate `WorkloadStats.from_key` falls back to — matching what the
    prior will use at prediction time for a store-only workload."""
    return WorkloadStats.from_key(o.key, format_stats=o.format_stats)


def _base_backend(candidate: str) -> str:
    """Preset candidate ids ("fixed:int7") share their backend's dispatch
    column and exclusion rules — the preset only changes byte widths."""
    return candidate.partition(":")[0]


def _clamp(x: float, lo: float, hi: float) -> float:
    return min(max(x, lo), hi)


def _nnls(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Nonnegative least squares by column elimination: every coefficient is
    a bandwidth reciprocal, a padding factor or a dispatch overhead — all
    physically nonnegative — and an unconstrained solve on collinear,
    dispatch-dominated data happily returns negative values whose clamped
    remains rank *worse* than the analytic defaults.  Solve, drop the most
    negative column, repeat; eliminated columns report 0 (= unfittable, the
    caller falls back to the analytic default for that coefficient)."""
    active = list(range(a.shape[1]))
    sol = np.zeros(0)
    while active:
        sol, *_ = np.linalg.lstsq(a[:, active], b, rcond=None)
        sol = np.nan_to_num(sol, nan=-np.inf)
        if (sol >= 0).all():
            break
        del active[int(np.argmin(sol))]
    theta = np.zeros(a.shape[1])
    if active:
        theta[active] = np.clip(sol, 0.0, None)
    return theta


@dataclasses.dataclass(frozen=True)
class CalibrationReport:
    """What the fit consumed and how well the result explains it."""

    n_observations: int
    n_workloads: int
    backends: tuple[str, ...]
    fitted: dict[str, float]              # coefficient name -> fitted value
    fallbacks: tuple[str, ...]            # coefficients kept at their default
    mean_rel_err: float                   # mean |pred - t| / t over the fit set
    max_rel_err: float
    rmse_s: float
    per_backend_rel_err: dict[str, float]

    def summary(self) -> str:
        head = (f"calibration: {self.n_observations} observations / "
                f"{self.n_workloads} workloads / {len(self.backends)} backends; "
                f"rel err mean={self.mean_rel_err:.1%} max={self.max_rel_err:.1%}")
        coeffs = " ".join(f"{k}={v:.3g}" for k, v in sorted(self.fitted.items()))
        lines = [head, f"  fitted: {coeffs}"]
        if self.fallbacks:
            lines.append("  defaults kept: " + " ".join(self.fallbacks))
        return "\n".join(lines)


@dataclasses.dataclass
class CalibratedPrior(CostModelPrior):
    """A `CostModelPrior` whose coefficients were fitted to a `TuningStore`.

    Build with `CalibratedPrior.from_store(store)`; ranking/`seconds` behave
    exactly like the analytic prior, only with measured coefficients.  The
    attached `calibration` report carries the residuals, and
    `suggested_margin` converts them into the autotuner's cross-mode elision
    margin: candidates predicted within this factor of the per-mode winner
    are re-probed, the rest are elided.
    """

    calibration: CalibrationReport | None = None
    #: False when the model-selection guard rejected the fit and the
    #: analytic default coefficients were kept: the prior then carries real
    #: residuals for *this* store but nothing learned — consumers (the
    #: autotuner's elide=None policy, report labels) must not treat it as a
    #: trusted fit.
    used_fit: bool = True

    @property
    def suggested_margin(self) -> float:
        """1 + k·(mean relative residual), clamped to [1.15, 2.0]."""
        if self.calibration is None:
            return 2.0
        return 1.0 + _clamp(3.0 * self.calibration.mean_rel_err, 0.15, 1.0)

    @classmethod
    def from_store(
        cls,
        store: TuningStore | None,
        *,
        device: dict[str, str] | None = None,
        min_observations: int = MIN_OBSERVATIONS,
        use_cache: bool = True,
    ) -> CalibratedPrior:
        """Fit the coefficients to `store`'s observations for one device
        fingerprint (default: this host's).  Raises `CalibrationError` when
        the store is missing or holds fewer than `min_observations` usable
        rows — callers fall back to the analytic default prior.

        Successful fits are memoized on the store's state (entry count +
        newest timestamp), so repeated cold starts against an unchanged
        store pay the solve once; the returned instance is shared — treat
        it as read-only.
        """
        if store is None:
            raise CalibrationError("no tuning store to calibrate against")
        if device is None:
            device = device_fingerprint()
        token = None
        if use_cache:
            entries = store.entries()
            token = (store.path, store.ttl_s, min_observations,
                     tuple(sorted(device.items())), len(entries),
                     max((e.created for e in entries), default=0.0))
            cached = _FIT_CACHE.get(token)
            if cached is not None:
                return cached
        # "batched" rows are bucket-level timings from repro.batch (a whole
        # vmap'd batch per probe) — not single-tensor training data for
        # these per-tensor design terms, so they are excluded like pallas.
        obs = [o for o in store.observations(device=device)
               if _base_backend(o.backend) not in ("pallas", "batched")
               and o.seconds > 0.0 and math.isfinite(o.seconds)]
        if len(obs) < min_observations:
            raise CalibrationError(
                f"{len(obs)} usable observations in {store.path!r} "
                f"(need >= {min_observations})")

        # Dispatch columns are per *backend*, not per candidate id: every
        # preset variant shares its family's launch path, so their rows
        # pool into one dispatch coefficient instead of fragmenting.
        backends = tuple(sorted({_base_backend(o.backend) for o in obs}))
        col_of = {b: 5 + i for i, b in enumerate(backends)}
        a = np.zeros((len(obs), 5 + len(backends)))
        t = np.empty(len(obs))
        for i, o in enumerate(obs):
            a[i, :5] = _design_terms(o.backend, _obs_stats(o), o.key.rank,
                                     o.mode, _n_devices(o.key))
            a[i, col_of[_base_backend(o.backend)]] = 1.0
            t[i] = o.seconds
        # Weight by 1/t: minimize relative residuals, not absolute seconds.
        w = 1.0 / t
        theta = _nnls(a * w[:, None], t * w)

        prior = cls._sanitize(theta, backends,
                              has_narrow=bool(a[:, 3].any()),
                              has_indexed=bool(a[:, 4].any()))
        prior.calibration = prior._residual_report(obs, backends)
        # Model-selection guard: a fit on thin, collinear data (a handful of
        # same-scale dispatch-dominated workloads) can explain the *seconds*
        # tolerably yet rank the *winners* worse than the analytic guesses —
        # the one job the prior has.  Deploy the fit only if its in-sample
        # top-1 agreement is no worse than the default's; otherwise keep the
        # analytic coefficients, with the residual report (and therefore a
        # conservative elision margin) still measured against this store.
        fit_hits, total = ranking_accuracy(store, prior, device=device)
        default_hits, _ = ranking_accuracy(store, default_prior, device=device)
        if total and fit_hits < default_hits:
            d = default_prior
            prior = cls(bandwidth=d.bandwidth, chunk_padding=d.chunk_padding,
                        hetero_overhead=d.hetero_overhead,
                        narrow_bandwidth=d.narrow_bandwidth,
                        indexed_bandwidth=d.indexed_bandwidth,
                        interpret_penalty=d.interpret_penalty,
                        dispatch_s=d.dispatch_s,
                        distributed_dispatch_s=d.distributed_dispatch_s,
                        used_fit=False)
            prior._fallbacks = (
                f"all coefficients: fit ranked worse than analytic defaults "
                f"in-sample ({fit_hits}/{total} vs {default_hits}/{total})",)
            prior.calibration = prior._residual_report(obs, backends)
        if token is not None:
            while len(_FIT_CACHE) >= _FIT_CACHE_MAX:
                _FIT_CACHE.pop(next(iter(_FIT_CACHE)))
            _FIT_CACHE[token] = prior
        return prior

    @classmethod
    def _sanitize(cls, theta: np.ndarray, backends: tuple[str, ...], *,
                  has_narrow: bool = False,
                  has_indexed: bool = False) -> CalibratedPrior:
        """Map the raw least-squares solution back to physical coefficients,
        keeping the analytic default for anything unfittable (non-positive,
        non-finite, or outside its physical clamp)."""
        d = default_prior
        a0, a1, a2, a3, a4 = (float(x) for x in theta[:5])
        fallbacks: list[str] = []

        if math.isfinite(a0) and a0 > 0:
            bandwidth = _clamp(1.0 / a0, *_BANDWIDTH_RANGE)
        else:
            bandwidth = d.bandwidth
            fallbacks.append("bandwidth")
        if math.isfinite(a1) and a1 > 0 and a0 > 0:
            chunk_padding = _clamp(a1 / a0, *_PADDING_RANGE)
        else:
            chunk_padding = d.chunk_padding
            fallbacks.append("chunk_padding")
        if math.isfinite(a2) and a2 > 0 and a1 > 0:
            hetero_overhead = _clamp(a2 / a1, *_HETERO_RANGE)
        else:
            hetero_overhead = d.hetero_overhead
            fallbacks.append("hetero_overhead")
        if has_narrow and math.isfinite(a3) and a3 > 0:
            narrow_bandwidth = _clamp(1.0 / a3, *_BANDWIDTH_RANGE)
        else:
            # Without lossy observations the narrow column is all-zero and
            # never enters the solve: price narrow bytes at the *fitted*
            # stream bandwidth (the best-informed guess for this host), and
            # only report a fallback when there was data and the fit failed.
            narrow_bandwidth = bandwidth
            if has_narrow:
                fallbacks.append("narrow_bandwidth")
        if has_indexed and math.isfinite(a4) and a4 > 0:
            indexed_bandwidth = _clamp(1.0 / a4, *_BANDWIDTH_RANGE)
        else:
            # Same policy as `narrow`: no format-backend observations means
            # the indexed column never entered the solve.
            indexed_bandwidth = bandwidth
            if has_indexed:
                fallbacks.append("indexed_bandwidth")

        dispatch: dict[str, float] = {}
        for i, b in enumerate(backends):
            v = float(theta[5 + i])
            if math.isfinite(v) and v > _DISPATCH_MIN:
                dispatch[b] = _clamp(v, *_DISPATCH_RANGE)
            else:
                # 0 means the NNLS eliminated the column (see `_nnls`):
                # charging a backend no dispatch at all would under-rank it
                # on every out-of-sample workload — keep the analytic value.
                fallbacks.append(f"dispatch[{b}]")

        prior = cls(bandwidth=bandwidth, chunk_padding=chunk_padding,
                    hetero_overhead=hetero_overhead,
                    narrow_bandwidth=narrow_bandwidth,
                    indexed_bandwidth=indexed_bandwidth,
                    interpret_penalty=d.interpret_penalty,
                    dispatch_s=d.dispatch_s,
                    distributed_dispatch_s=d.distributed_dispatch_s,
                    dispatch_overheads=dispatch)
        prior._fallbacks = tuple(fallbacks)  # consumed by _residual_report
        return prior

    def _residual_report(self, obs: list[Observation],
                         backends: tuple[str, ...]) -> CalibrationReport:
        rel_errs: list[float] = []
        sq_errs: list[float] = []
        per_backend: dict[str, list[float]] = {}
        for o in obs:
            pred = self.seconds(o.backend, _obs_stats(o), o.key.rank, o.mode,
                                n_devices=_n_devices(o.key))
            rel = abs(pred - o.seconds) / o.seconds
            rel_errs.append(rel)
            sq_errs.append((pred - o.seconds) ** 2)
            # Keyed by candidate id, so "fixed:int3" and "fixed:int7" report
            # separately even though they share one dispatch coefficient.
            per_backend.setdefault(o.backend, []).append(rel)
        fitted = {
            "bandwidth": self.bandwidth,
            "chunk_padding": self.chunk_padding,
            "hetero_overhead": self.hetero_overhead,
            "narrow_bandwidth": self.narrow_bandwidth,
            "indexed_bandwidth": self.indexed_bandwidth,
        }
        fitted.update({f"dispatch[{b}]": v
                       for b, v in sorted(self.dispatch_overheads.items())})
        return CalibrationReport(
            n_observations=len(obs),
            n_workloads=len({o.key for o in obs}),
            backends=backends,
            fitted=fitted,
            fallbacks=getattr(self, "_fallbacks", ()),
            mean_rel_err=float(np.mean(rel_errs)),
            max_rel_err=float(np.max(rel_errs)),
            rmse_s=float(np.sqrt(np.mean(sq_errs))),
            per_backend_rel_err={b: float(np.mean(v))
                                 for b, v in per_backend.items() if v},
        )


def ranking_accuracy(store: TuningStore, prior: CostModelPrior, *,
                     device: dict[str, str] | None = None,
                     ) -> tuple[int, int]:
    """How often `prior`'s top-1 agrees with the store's measured winner.

    For every persisted (workload, mode) with at least two measured
    backends, compare the prior's cheapest prediction *among those measured
    backends* against the measured argmin.  Returns ``(hits, decisions)`` —
    the CI gate asserts the calibrated prior's rate is no worse than the
    analytic default's.
    """
    if device is None:
        device = device_fingerprint()
    want = tuple(sorted(device.items()))
    hits = total = 0
    for e in store.entries():
        if store.expired(e) or e.key.device != want:
            continue
        stats = WorkloadStats.from_key(e.key, format_stats=e.format_stats)
        nd = _n_devices(e.key)
        for mode in range(e.key.ndim):
            measured = {b: per[mode] for b, per in e.timings.items()
                        if mode in per}
            if len(measured) < 2:
                continue
            winner = min(measured, key=lambda b, t=measured: (t[b], b))
            predicted = min(
                measured,
                key=lambda b, s=stats, r=e.key.rank, m=mode, nd=nd: (
                    prior.seconds(b, s, r, m, n_devices=nd), b))
            hits += predicted == winner
            total += 1
    return hits, total
