"""Analytic memory-bound cost prior for cold-start backend ranking.

spMTTKRP is memory-bound (the paper's roofline argument: a handful of FLOPs
per nonzero against coordinate reads, factor-row gathers and output
scatters), so candidate backends can be *ranked* — not timed — by the bytes
they move per MTTKRP call.  The prior exists for one job: when the
autotuner starts cold on a workload it has never measured, decide which
candidates are worth spending probe budget on (`max_probes`).  It is a
prior, not a predictor — measured timings always override it, and the
persisted store (persist.py) means a workload pays the probe phase once.

The per-backend models mirror how each execution strategy touches memory:

  ref          COO scatter-add: every nonzero read-modify-writes its output
               row (2x traffic on the accumulator).
  alto         ALTO ordering turns the scatter into a near-sequential
               segment sum (1x accumulator traffic) and improves factor
               gather locality.
  chunked      PRISM chunked format: padded tasks (capacity padding moves
               dead bytes) but chunk-local accumulation.
  hetero       chunked plus densified blocks for the MXU — extra traffic
               for the dense side, in exchange for (hardware) MXU peak.
  pallas       chunked bytes; in interpret mode a large constant penalty
               reflects per-element Python dispatch.
  distributed  chunked bytes split across devices plus an output
               all-reduce and a per-call dispatch overhead.
  fixed        chunked with 16-bit values/factors (half the gather and
               value bytes).  Lossy — normally excluded upstream.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.sptensor import SparseTensor

__all__ = ["CostModelPrior", "default_prior", "prior_order"]

_IDX = 4   # int32 coordinate bytes
_VAL = 4   # float32 value bytes


@dataclasses.dataclass
class CostModelPrior:
    """Ranks backend candidates by estimated seconds per MTTKRP call.

    `bandwidth` is a sustained-stream guess (B/s) used only to convert bytes
    into comparable seconds so per-call dispatch overheads can be folded in;
    absolute values are meaningless, only the ordering matters.
    """

    bandwidth: float = 2.0e10        # sustained memory bandwidth guess, B/s
    chunk_padding: float = 1.25      # padded-task overhead guess for chunked
    hetero_overhead: float = 1.2     # densified-block traffic multiplier
    interpret_penalty: float = 200.0 # pallas interpret-mode slowdown factor
    dispatch_s: float = 1e-4         # per-call jit dispatch overhead
    distributed_dispatch_s: float = 2e-3  # shard_map per-call overhead

    def bytes_moved(self, name: str, st: SparseTensor, rank: int,
                    mode: int) -> float:
        """Estimated bytes moved by one mode-`mode` MTTKRP for `name`."""
        n, d, r = st.nnz, st.ndim, rank
        out = st.shape[mode] * r * _VAL
        coords = n * d * _IDX
        values = n * _VAL
        gathers = n * (d - 1) * r * _VAL
        base = coords + values + gathers
        if name == "ref":
            return base + 2 * n * r * _VAL + out
        if name == "alto":
            return coords + values + 0.75 * gathers + n * r * _VAL + out
        if name in ("chunked", "pallas"):
            return self.chunk_padding * (base + n * r * _VAL) + out
        if name == "hetero":
            return (self.hetero_overhead
                    * (self.chunk_padding * (base + n * r * _VAL)) + out)
        if name == "distributed":
            return self.chunk_padding * (base + n * r * _VAL) + out
        if name == "fixed":
            return coords + 0.5 * (values + gathers) + n * r * _VAL + out
        # Unknown (user-registered) backend: assume COO-like traffic so it
        # ranks mid-field and still gets probed under a generous budget.
        return base + 2 * n * r * _VAL + out

    def seconds(self, name: str, st: SparseTensor, rank: int, mode: int, *,
                interpret: bool = True, n_devices: int = 1) -> float:
        t = self.bytes_moved(name, st, rank, mode) / self.bandwidth
        if name == "distributed":
            t = t / max(2, n_devices) + self.distributed_dispatch_s
            t += 2 * st.shape[mode] * rank * _VAL / self.bandwidth  # all-reduce
        else:
            t += self.dispatch_s
        if name == "pallas" and interpret:
            t *= self.interpret_penalty
        return t

    def order(self, st: SparseTensor, rank: int, candidates: list[str],
              modes: list[int] | None = None, *, interpret: bool = True,
              n_devices: int = 1) -> list[str]:
        """Candidates sorted cheapest-first by estimated total seconds over
        `modes` (ties broken by name, so the ordering is deterministic)."""
        if modes is None:
            modes = list(range(st.ndim))
        def total(name: str) -> float:
            return math.fsum(
                self.seconds(name, st, rank, m, interpret=interpret,
                             n_devices=n_devices) for m in modes)
        return sorted(candidates, key=lambda name: (total(name), name))


#: Shared default instance (the prior is stateless apart from coefficients).
default_prior = CostModelPrior()


def prior_order(st: SparseTensor, rank: int, candidates: list[str],
                modes: list[int] | None = None, **kw) -> list[str]:
    """Module-level convenience over `default_prior.order`."""
    return default_prior.order(st, rank, candidates, modes, **kw)
