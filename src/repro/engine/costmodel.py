"""Analytic memory-bound cost prior for cold-start backend ranking.

spMTTKRP is memory-bound (the paper's roofline argument: a handful of FLOPs
per nonzero against coordinate reads, factor-row gathers and output
scatters), so candidate backends can be *ranked* — not timed — by the bytes
they move per MTTKRP call.  The prior exists for one job: when the
autotuner starts cold on a workload it has never measured, decide which
candidates are worth spending probe budget on (`max_probes`) and which
modes are worth probing at all (cross-mode elision).  It is a prior, not a
predictor — measured timings always override it, and the persisted store
(persist.py) means a workload pays the probe phase once.

The per-backend models mirror how each execution strategy touches memory:

  ref          COO scatter-add: every nonzero read-modify-writes its output
               row (2x traffic on the accumulator).
  alto         ALTO ordering turns the scatter into a near-sequential
               segment sum (1x accumulator traffic) and improves factor
               gather locality.
  chunked      PRISM chunked format: padded tasks (capacity padding moves
               dead bytes) but chunk-local accumulation.
  hetero       chunked plus densified blocks for the MXU — extra traffic
               for the dense side, in exchange for (hardware) MXU peak.
  pallas       chunked bytes; in interpret mode a large constant penalty
               reflects per-element Python dispatch.
  distributed  chunked bytes split across devices plus an output
               all-reduce and a per-call dispatch overhead.
  fixed        chunked with quantized values/factors.  Candidate ids carry
               the Qm.n preset ("fixed:int3" / "fixed:int7" /
               "fixed:int15-12"), and the gather/value traffic scales with
               that preset's storage width — the whole point of the paper's
               narrow-int path is fewer bytes against the memory roofline.
               Lossy — only admitted under an accuracy budget.
  csf          CSF fiber trees (repro.formats.csf): interior factor gathers
               scale with the *fiber* count, not nnz — the model consumes
               `FormatStats` fiber counts (measured when the autotuner has
               the live tensor, balls-in-bins-estimated from (shape, nnz)
               otherwise) so a long-fibered tensor ranks csf ahead of COO
               on a cold start.
  alto         ALTO linearized index: the per-mode coordinate columns are
               replaced by one packed key stream (FormatStats.key_words ·
               4 bytes/nnz), de-interleaved at kernel time.

Every model is decomposed into five byte components (`byte_terms`):

    seconds = (fixed + chunk_padding·padded + chunk_padding·hetero_overhead·densified)
              / bandwidth  +  narrow / narrow_bandwidth
              + indexed / indexed_bandwidth  +  dispatch(backend)

where `narrow` counts the bytes moved through quantized (int8/int16/int32)
paths — already scaled by the preset's storage width — and
`narrow_bandwidth` is the effective throughput of that traffic (quantize /
dequantize arithmetic rides on every narrow byte, so it need not equal the
float-stream bandwidth).  `indexed` counts the bytes of *format index
structure* (CSF fiber pointers/coords, ALTO key words) whose consumption
carries extra address arithmetic — bit de-interleaves, fiber-tree walks —
priced at its own `indexed_bandwidth`.  The model stays *linear* in the
reparametrized coefficients (1/bandwidth, chunk_padding/bandwidth,
chunk_padding·hetero_overhead/bandwidth, 1/narrow_bandwidth,
1/indexed_bandwidth, and the per-backend dispatch terms) — exactly what
`calibrate.py` needs to fit them by least squares against the tuning
store's measured timings.
"""
from __future__ import annotations

import dataclasses
import math

from ..core.qformat import FIXED_PRESETS
from ..formats import MAX_KEY_BITS, FormatStats

__all__ = [
    "CostModelPrior",
    "WorkloadStats",
    "byte_terms",
    "default_prior",
    "device_byte_terms",
    "prior_order",
]

_IDX = 4   # int32 coordinate bytes
_VAL = 4   # float32 value bytes
_QVAL = 2  # runtime 16-bit quantized tensor-value bytes (value_qformat)


def _split_candidate(name: str) -> tuple[str, str | None]:
    """Candidate ids are "backend" or "backend:preset"; the byte models (and
    dispatch lookups) key on the backend, widths on the preset.  Kept local —
    unknown names must degrade to the COO-like default, not raise, so the
    registry's strict parser is not used here."""
    base, _, preset = name.partition(":")
    return base, (preset or None)


def _preset_width(preset: str | None) -> float:
    """Factor storage bytes per element for a fixed-point preset (falls back
    to int16/Q9.7 — the paper's preferred mode-3 format — when the candidate
    doesn't pin one)."""
    if preset is not None and preset in FIXED_PRESETS:
        qf, _shift = FIXED_PRESETS[preset]
        return qf.storage_bits / 8.0
    return 2.0


@dataclasses.dataclass(frozen=True)
class WorkloadStats:
    """The tensor statistics the byte models consume — duck-compatible with
    `SparseTensor` (shape/nnz/ndim), constructible from a persisted
    `WorkloadKey` so calibration can evaluate the prior on workloads whose
    tensors are long gone.

    `format_stats` (a `repro.formats.FormatStats`) carries the layout
    statistics — per-mode fiber counts, interleave key width — the csf/alto
    byte models need; None falls back to the balls-in-bins estimate from
    (shape, nnz) inside `byte_terms`.  The autotuner attaches measured
    stats for live tensors and persists them with the entry (schema v4), so
    calibration trains on the same numbers prediction used."""

    shape: tuple[int, ...]
    nnz: int
    format_stats: FormatStats | None = None

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @classmethod
    def from_key(cls, key, format_stats: FormatStats | dict | None = None,
                 ) -> WorkloadStats:
        if isinstance(format_stats, dict):
            format_stats = FormatStats.from_json(format_stats)
        return cls(shape=tuple(key.shape), nnz=int(key.nnz),
                   format_stats=format_stats)


def _format_stats(st) -> FormatStats:
    """The `FormatStats` for anything byte_terms accepts: an attached
    (measured or persisted) instance when present, else the estimate —
    which is a pure function of (shape, nnz), so prediction and training
    agree whenever neither side has real counts."""
    fs = getattr(st, "format_stats", None)
    if fs is not None:
        return fs
    return FormatStats.estimate(tuple(st.shape), int(st.nnz))


def byte_terms(name: str, st, rank: int, mode: int,
               ) -> tuple[float, float, float, float, float]:
    """Decompose candidate `name`'s mode-`mode` MTTKRP traffic on `st` into
    ``(fixed, padded, densified, narrow, indexed)`` byte components:

    - *fixed* bytes move regardless of chunking (coordinates, values,
      gathers, the output);
    - *padded* bytes are scaled by the chunk-capacity padding factor
      (`CostModelPrior.chunk_padding`);
    - *densified* bytes are additionally scaled by the dense-block traffic
      multiplier (`CostModelPrior.hetero_overhead`);
    - *narrow* bytes move through quantized integer paths, already scaled by
      the candidate's preset storage width, and are charged at
      `CostModelPrior.narrow_bandwidth` — this is what lets the prior rank
      an int8 candidate above an int16 one on a cold start;
    - *indexed* bytes are format index structure (CSF fiber tree levels,
      ALTO key words) whose consumption pays address arithmetic on top of
      the load, charged at `CostModelPrior.indexed_bandwidth`.

    `name` accepts preset candidate ids ("fixed:int3"); `st` is anything
    with `.shape`, `.nnz`, `.ndim` (a `SparseTensor` or a `WorkloadStats` —
    the latter may carry measured `FormatStats`; without them the csf/alto
    models fall back to the balls-in-bins fiber estimate).
    """
    base_name, preset = _split_candidate(name)
    n, d, r = st.nnz, st.ndim, rank
    out = st.shape[mode] * r * _VAL
    coords = n * d * _IDX
    values = n * _VAL
    gathers = n * (d - 1) * r * _VAL
    base = coords + values + gathers
    if base_name == "ref":
        return base + 2 * n * r * _VAL + out, 0.0, 0.0, 0.0, 0.0
    if base_name == "alto":
        # One packed key stream replaces the coordinate columns (indexed
        # traffic: every key byte is de-interleaved); the ALTO order keeps
        # the 0.75 gather-locality credit, and the sorted segment reduction
        # writes the accumulator once (1x, vs ref's read-modify-write 2x).
        # Past the 64-bit key cap the backend falls back to ALTO-*ordered*
        # COO (see backends._build_alto): explicit coordinate columns move
        # as plain stream bytes and no key is ever decoded.
        fs = _format_stats(st)
        if fs.key_bits > MAX_KEY_BITS:
            return (coords + values + 0.75 * gathers + n * r * _VAL + out,
                    0.0, 0.0, 0.0, 0.0)
        return (values + 0.75 * gathers + n * r * _VAL + out,
                0.0, 0.0, 0.0, fs.alto_index_bytes())
    if base_name == "csf":
        # Fiber reuse: interior gathers + the first reduction level scale
        # with the fiber count, not nnz — only the innermost factor is
        # gathered per nonzero.  The tree's index arrays are indexed bytes.
        fs = _format_stats(st)
        fibers = fs.fiber_counts[mode]
        return (values + n * r * _VAL                    # leaf gathers
                + max(d - 2, 0) * fibers * r * _VAL      # interior gathers
                + 2 * fibers * r * _VAL + out,           # fiber accumulator
                0.0, 0.0, 0.0, fs.csf_index_bytes(mode))
    if base_name in ("chunked", "pallas", "distributed"):
        return out, base + n * r * _VAL, 0.0, 0.0, 0.0
    if base_name == "hetero":
        return out, 0.0, base + n * r * _VAL, 0.0, 0.0
    if base_name == "fixed":
        # Quantized traffic scales with the preset width: w-byte factor
        # gathers and accumulator, 16-bit tensor values.  Coordinates and
        # the dequantized f32 output stay full-width.
        w = _preset_width(preset)
        narrow = (w / _VAL) * gathers + n * _QVAL + (w / _VAL) * n * r * _VAL
        return coords + out, 0.0, 0.0, narrow, 0.0
    # Unknown (user-registered) backend: assume COO-like traffic so it
    # ranks mid-field and still gets probed under a generous budget.
    return base + 2 * n * r * _VAL + out, 0.0, 0.0, 0.0, 0.0


def device_byte_terms(name: str, st, rank: int, mode: int, *,
                      n_devices: int = 1,
                      ) -> tuple[float, float, float, float, float]:
    """`byte_terms` adjusted for the device count: the distributed backend
    splits its traffic across the real device count and adds an output
    all-reduce (to the fixed component — it is not sharded).  This is the
    single source of the per-observation decomposition: `CostModelPrior
    .seconds` consumes it for prediction and `calibrate._design_terms` for
    the training design matrix, so the two cannot drift apart."""
    fixed, padded, densified, narrow, indexed = byte_terms(name, st, rank, mode)
    if _split_candidate(name)[0] == "distributed":
        nd = max(1, n_devices)
        fixed = fixed / nd + 2 * st.shape[mode] * rank * _VAL
        padded /= nd
        densified /= nd
        narrow /= nd
        indexed /= nd
    return fixed, padded, densified, narrow, indexed


@dataclasses.dataclass
class CostModelPrior:
    """Ranks backend candidates by estimated seconds per MTTKRP call.

    `bandwidth` is a sustained-stream guess (B/s) used only to convert bytes
    into comparable seconds so per-call dispatch overheads can be folded in;
    absolute values are meaningless, only the ordering matters.  All
    coefficients here are the hard-coded defaults — `calibrate.CalibratedPrior`
    replaces them with values fitted to the tuning store's measurements.
    """

    bandwidth: float = 2.0e10        # sustained memory bandwidth guess, B/s
    chunk_padding: float = 1.25      # padded-task overhead guess for chunked
    hetero_overhead: float = 1.2     # densified-block traffic multiplier
    #: Effective throughput of quantized-int traffic (B/s).  Bytes are bytes
    #: on the bus, but every narrow byte also pays quantize/dequantize
    #: arithmetic, so calibration may learn a value below `bandwidth`.
    narrow_bandwidth: float = 2.0e10
    #: Effective throughput of format-index traffic (B/s): CSF fiber-tree
    #: levels and ALTO key words carry address arithmetic (tree walks, bit
    #: de-interleaves) on every byte, so calibration may learn a value
    #: below the plain stream bandwidth.
    indexed_bandwidth: float = 2.0e10
    interpret_penalty: float = 200.0 # pallas interpret-mode slowdown factor
    dispatch_s: float = 1e-4         # per-call jit dispatch overhead
    distributed_dispatch_s: float = 2e-3  # shard_map per-call overhead
    #: Per-backend dispatch overrides (seconds); missing backends fall back
    #: to `dispatch_s` / `distributed_dispatch_s`.  Populated by calibration.
    dispatch_overheads: dict[str, float] = dataclasses.field(default_factory=dict)

    def dispatch(self, name: str) -> float:
        """Per-call dispatch overhead for candidate `name`, in seconds.
        Preset variants share their backend's dispatch term ("fixed:int3"
        and "fixed:int7" run the same kernel launch path)."""
        base, _preset = _split_candidate(name)
        if base in self.dispatch_overheads:
            return self.dispatch_overheads[base]
        if base == "distributed":
            return self.distributed_dispatch_s
        return self.dispatch_s

    def bytes_moved(self, name: str, st, rank: int, mode: int) -> float:
        """Estimated bytes moved by one mode-`mode` MTTKRP for `name`
        (single-device traffic; `seconds` applies the device split)."""
        fixed, padded, densified, narrow, indexed = byte_terms(
            name, st, rank, mode)
        return (fixed + self.chunk_padding * padded
                + self.chunk_padding * self.hetero_overhead * densified
                + narrow + indexed)

    def seconds(self, name: str, st, rank: int, mode: int, *,
                interpret: bool = True, n_devices: int = 1) -> float:
        # device_byte_terms splits distributed traffic across the real
        # device count (a single-device host gets no speedup — the mesh
        # degenerates to one shard) and adds the output all-reduce.
        fixed, padded, densified, narrow, indexed = device_byte_terms(
            name, st, rank, mode, n_devices=n_devices)
        t = (fixed + self.chunk_padding * padded
             + self.chunk_padding * self.hetero_overhead * densified
             ) / self.bandwidth
        t += narrow / self.narrow_bandwidth
        t += indexed / self.indexed_bandwidth
        t += self.dispatch(name)
        if _split_candidate(name)[0] == "pallas" and interpret:
            t *= self.interpret_penalty
        return t

    def order(self, st, rank: int, candidates: list[str],
              modes: list[int] | None = None, *, interpret: bool = True,
              n_devices: int = 1) -> list[str]:
        """Candidates sorted cheapest-first by estimated total seconds over
        `modes` (ties broken by name, so the ordering is deterministic)."""
        if modes is None:
            modes = list(range(st.ndim))
        def total(name: str) -> float:
            return math.fsum(
                self.seconds(name, st, rank, m, interpret=interpret,
                             n_devices=n_devices) for m in modes)
        return sorted(candidates, key=lambda name: (total(name), name))


#: Shared default instance (the prior is stateless apart from coefficients).
default_prior = CostModelPrior()


def prior_order(st, rank: int, candidates: list[str],
                modes: list[int] | None = None, **kw) -> list[str]:
    """Module-level convenience over `default_prior.order`."""
    return default_prior.order(st, rank, candidates, modes, **kw)
