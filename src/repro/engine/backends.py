"""The built-in execution strategies, as registry backends.

Each maps one of the paper's execution arms onto this host:

  ref          plain COO scatter (paper Fig. 1; the "GPU/BLCO" role)
  alto         ALTO linearized format: one bit-interleaved index serving
               every mode, de-interleaved at kernel time (the "CPU" role)
  csf          CSF fiber trees (repro.formats.csf): per-mode mode trees
               with fiber-level factor reuse
  chunked      PRISM chunked format, float (the "PIM" role)
  fixed        PRISM chunked + Alg.-2 fixed point (paper §IV-C)
  hetero       dense(MXU)/sparse split (paper §IV-D collaboration)
  pallas       the Pallas TPU kernel (interpret mode on CPU hosts)
  distributed  shard_map over a (data, model) mesh (paper §IV-B on TPU)

All chunk-based builders pull their ChunkedTensor / device arrays from the
context's PlanCache, so building several backends against one tensor chunks
it exactly once; the format-based builders (`csf`, `alto`) likewise pull
their layouts from the context's FormatCache.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..core import baselines, hetero, lockfree, mttkrp
from ..core.distributed import DistributedMTTKRP
from ..core.qformat import FIXED_PRESETS, value_qformat
from ..launch.mesh import make_local_mesh
from .registry import EngineContext, register_backend

__all__ = []  # backends are reached through the registry, not by import


@register_backend(
    "ref",
    description="plain COO scatter-add reference (paper Fig. 1)")
def _build_ref(ctx: EngineContext):
    coords = jnp.asarray(ctx.st.coords)
    values = jnp.asarray(ctx.st.values)
    shape = ctx.st.shape

    def engine(factors, mode):
        return mttkrp.mttkrp_coo(tuple(factors), coords, values,
                                 mode=mode, out_dim=shape[mode])
    return engine


@register_backend(
    "alto",
    description="ALTO linearized index: one bit-interleaved copy serves all modes (CPU role)")
def _build_alto(ctx: EngineContext):
    from ..formats.alto import MAX_KEY_BITS, alto_key_bits
    shape = ctx.st.shape
    if alto_key_bits(shape) > MAX_KEY_BITS:
        # The packed linearization caps at 64 key bits (BLCO block splitting
        # is the ROADMAP lift); beyond it, degrade to the ALTO-*ordered* COO
        # baseline — same traversal order, explicit coordinates.
        order = baselines.alto_order(ctx.st.coords, shape)
        a_coords = jnp.asarray(ctx.st.coords[order])
        a_values = jnp.asarray(ctx.st.values[order])

        def engine(factors, mode):
            return baselines.mttkrp_alto(tuple(factors), a_coords, a_values,
                                         mode=mode, out_dim=shape[mode])
        return engine

    at = ctx.formats.alto(ctx.st)
    dev = ctx.formats.device_alto(ctx.st)
    positions = at.positions

    def engine(factors, mode):
        return mttkrp.mttkrp_alto(
            tuple(factors), dev["key_words"], dev["values"],
            mode=mode, positions=positions, out_dim=shape[mode])
    return engine


@register_backend(
    "csf",
    description="CSF fiber trees: interior factor rows fetched once per fiber")
def _build_csf(ctx: EngineContext):
    st, shape, formats = ctx.st, ctx.st.shape, ctx.formats

    def engine(factors, mode):
        # Trees build lazily per mode (the autotuner may only ever probe an
        # anchor mode) and come from the FormatCache, so CP-ALS and repeated
        # builds against one tensor construct each tree exactly once.
        tree = formats.csf(st, mode)
        dev = formats.device_csf(st, mode)
        return mttkrp.mttkrp_csf(
            tuple(factors), dev["inner_coord"], dev["values"],
            dev["fiber_ids"], dev["fiber_coords"],
            mode=mode, inner_mode=tree.inner_mode, mid_modes=tree.mid_modes,
            out_dim=shape[mode], n_fibers=tree.n_fibers)
    return engine


@register_backend(
    "chunked", needs_chunking=True,
    description="PRISM chunked format, float (PIM role)")
def _build_chunked(ctx: EngineContext):
    ct = ctx.chunked()
    dev = ctx.device_arrays()
    cs, shape = ct.chunk_shape, ctx.st.shape
    nnz_pt = jnp.asarray(ct.nnz_per_task) if ctx.lockfree_mode else None

    def engine(factors, mode):
        vals = dev["values"]
        if nnz_pt is not None:
            m = lockfree.wave_collision_mask(dev["coords_rel"][:, :, mode], nnz_pt)
            vals = vals * m
        return mttkrp.mttkrp_chunked(
            tuple(factors), dev["task_chunk"], dev["coords_rel"], vals,
            mode=mode, chunk_shape=cs, out_dim=shape[mode])
    return engine


@register_backend(
    "fixed", needs_chunking=True, supports_fixed_point=True, lossless=False,
    presets=tuple(FIXED_PRESETS),
    description="PRISM chunked + paper Alg. 2 fixed point (int7 / int15-12)")
def _build_fixed(ctx: EngineContext):
    ct = ctx.chunked()
    dev = ctx.device_arrays()
    cs, shape = ct.chunk_shape, ctx.st.shape
    qf, prec_shift = FIXED_PRESETS[ctx.fixed_preset]
    vq = value_qformat(ctx.st.values, storage_bits=16)
    qvalues = jnp.asarray(vq.quantize_np(ct.values))
    nnz_pt = jnp.asarray(ct.nnz_per_task) if ctx.lockfree_mode else None

    # One compiled program per mode: unlike the float backends (a single
    # pre-jitted kernel call), the fixed path wraps its kernel in factor
    # quantization and output dequantization — left eager, those ~4 ops per
    # factor of dispatch overhead swamp the narrow-int memory win this
    # backend exists for.  Fusing quantize → kernel → dequantize also lets
    # XLA keep the intermediates in int registers.
    @partial(jax.jit, static_argnums=1)
    def engine(factors, mode):
        qfactors = tuple(qf.quantize(f) for f in factors)
        qvals = qvalues
        if nnz_pt is not None:
            m = lockfree.wave_collision_mask(dev["coords_rel"][:, :, mode], nnz_pt)
            qvals = qvals * m.astype(qvals.dtype)
        qout = mttkrp.mttkrp_chunked_fixed(
            qfactors, dev["task_chunk"], dev["coords_rel"], qvals,
            mode=mode, chunk_shape=cs, out_dim=shape[mode],
            matrix_frac=qf.frac_bits, value_frac=vq.frac_bits,
            prec_shift=prec_shift)
        return mttkrp.dequantize_output(qout, qf.frac_bits, prec_shift)
    return engine


@register_backend(
    "hetero", needs_chunking=True,
    description="dense(MXU)/sparse split, cost-model scheduled (paper §IV-D)")
def _build_hetero(ctx: EngineContext):
    ct = ctx.chunked()
    split = hetero.split_tasks(ct, ctx.rank, dense_fraction=ctx.dense_fraction)
    dense_blocks = jnp.asarray(hetero.densify_tasks(ct, split.dense_idx))
    shape = ctx.st.shape

    def engine(factors, mode):
        return hetero.mttkrp_hetero(
            tuple(factors), ct, split, dense_blocks,
            mode=mode, out_dim=shape[mode])
    return engine


@register_backend(
    "pallas", needs_chunking=True,
    description="Pallas TPU kernel (interpret mode on CPU hosts)")
def _build_pallas(ctx: EngineContext):
    from ..kernels import ops as kops
    ct = ctx.chunked()
    dev = ctx.device_arrays()
    cs, shape = ct.chunk_shape, ctx.st.shape
    interpret = ctx.interpret

    def engine(factors, mode):
        return kops.mttkrp_pallas(
            tuple(factors), dev["task_chunk"], dev["coords_rel"],
            dev["values"], mode=mode, chunk_shape=cs,
            out_dim=shape[mode], interpret=interpret)
    return engine


@register_backend(
    "distributed", needs_chunking=True, min_devices=2,
    description="shard_map mesh: rank partitioning on `model`, tasks on `data`")
def _build_distributed(ctx: EngineContext):
    # Default to a real model axis when the host allows it, so rank
    # partitioning (the paper's favored, replication-free partitioning)
    # is actually exercised — not just the data/task axis.
    mesh = (ctx.mesh if ctx.mesh is not None
            else make_local_mesh(n_model=2 if len(jax.devices()) >= 2 else 1))
    dmt = DistributedMTTKRP(mesh, ctx.chunked(), ctx.rank, reduce=ctx.reduce)
    shape = ctx.st.shape

    def engine(factors, mode):
        # Materialize + trim the task-padding rows so the engine contract
        # (exact (I_mode, R)) holds regardless of the reduction strategy.
        return jnp.asarray(dmt(factors, mode))[: shape[mode]]
    return engine
