"""MTTKRP backend registry.

The paper's central finding is that the best spMTTKRP execution strategy is
workload-dependent — PIM wins on some tensors, CPU/heterogeneous
collaboration on others.  This registry is the seam where execution
strategies plug in: each backend registers itself with a capability
declaration, and selection (explicit name or the `auto` autotuner) goes
through one API instead of an if/elif ladder.

A backend is a *builder*: ``build(ctx: EngineContext) -> engine`` where
``engine(factors, mode) -> (I_mode, R) f32``.  Builders run once per
(tensor, rank, options); the returned closure serves every CP-ALS
iteration, with chunking shared through ``ctx.plans`` (see plan.py).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax

from ..core.sptensor import SparseTensor
from ..formats.convert import FormatCache, default_format_cache
from .plan import PlanCache, default_plan_cache

__all__ = [
    "BackendSpec",
    "Engine",
    "EngineContext",
    "backend_table",
    "build_candidate",
    "candidate_lossless",
    "eligible_backends",
    "get_backend",
    "parse_candidate",
    "preset_candidates",
    "register_backend",
    "registered_backends",
]


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Capability declaration for one registered execution strategy.

    needs_chunking       — consumes the PRISM chunked format (built once,
                           shared through the plan cache).
    supports_fixed_point — runs the paper's Alg.-2 Qm.n arithmetic.
    lossless             — bit-compatible with the float COO reference (up
                           to reduction order); lossy backends (fixed point)
                           are excluded from autotuning unless the caller
                           grants an explicit `accuracy_budget` — format
                           choice is an accuracy decision, and the tuner
                           only makes it against a declared error budget.
    presets              — the Qm.n fixed-point presets this backend can run
                           (`FIXED_PRESETS` names).  Each preset becomes its
                           own autotune candidate `"name:preset"` when an
                           accuracy budget admits lossy candidates.
    min_devices          — minimum jax device count to be eligible.
    """

    name: str
    build: Callable
    needs_chunking: bool = False
    supports_fixed_point: bool = False
    lossless: bool = True
    presets: tuple[str, ...] = ()
    min_devices: int = 1
    description: str = ""


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(
    name: str,
    *,
    needs_chunking: bool = False,
    supports_fixed_point: bool = False,
    lossless: bool = True,
    presets: tuple[str, ...] = (),
    min_devices: int = 1,
    description: str = "",
):
    """Decorator registering a builder under `name` (last wins, so tests
    and downstream code can override a backend)."""
    if ":" in name:
        raise ValueError(
            f"backend name {name!r} may not contain ':' — that separator is "
            "reserved for preset candidate ids (e.g. 'fixed:int7')")
    def deco(build: Callable) -> Callable:
        _REGISTRY[name] = BackendSpec(
            name=name,
            build=build,
            needs_chunking=needs_chunking,
            supports_fixed_point=supports_fixed_point,
            lossless=lossless,
            presets=tuple(presets),
            min_devices=min_devices,
            description=description,
        )
        return build
    return deco


def get_backend(name: str) -> BackendSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_backends() -> dict[str, BackendSpec]:
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Candidate ids: "backend" or "backend:preset"
#
# The autotuner's candidate space is (backend × fixed-point preset): a lossy
# backend contributes one candidate per Qm.n preset it declares, spelled
# "name:preset" ("fixed:int7").  These helpers are the single parser/builder
# for that spelling — the tuning store, cost model and autotuner all agree on
# it because they all come through here.
# ---------------------------------------------------------------------------

def parse_candidate(candidate: str) -> tuple[str, str | None]:
    """Split a candidate id into (backend name, preset or None), validating
    both halves against the registry."""
    name, _, preset = candidate.partition(":")
    spec = get_backend(name)
    if not preset:
        return name, None
    if preset not in spec.presets:
        raise ValueError(
            f"backend {name!r} has no preset {preset!r}; "
            f"registered presets: {list(spec.presets) or 'none'}")
    return name, preset


def candidate_lossless(candidate: str) -> bool:
    """Whether a candidate id names a lossless backend.  Unknown candidates
    count as lossy — nothing is known about their output, so accuracy-
    sensitive callers (the cp_als fit fast path) must not trust them."""
    try:
        name, _preset = parse_candidate(candidate)
    except ValueError:
        return False
    return _REGISTRY[name].lossless


def build_candidate(candidate: str, ctx: EngineContext):
    """Build a candidate id against `ctx`, overriding `ctx.fixed_preset`
    when the id pins one.  The preset-pinned context shares the plan cache
    (and therefore the chunking) with the original."""
    name, preset = parse_candidate(candidate)
    spec = _REGISTRY[name]
    if preset is not None and preset != ctx.fixed_preset:
        ctx = dataclasses.replace(ctx, fixed_preset=preset)
    return spec.build(ctx)


def preset_candidates(*, n_devices: int | None = None) -> list[str]:
    """Every lossy (backend, preset) candidate id this process could build:
    what an accuracy budget adds to the default candidate set.  Sorted by
    name so the enumeration (and everything keyed on it: probe order,
    store fingerprints, tie-breaks) is independent of registration order."""
    if n_devices is None:
        n_devices = len(jax.devices())
    return [
        f"{s.name}:{p}"
        for s in sorted(_REGISTRY.values(), key=lambda s: s.name)
        if not s.lossless and n_devices >= s.min_devices
        for p in s.presets
    ]


def eligible_backends(
    *,
    n_devices: int | None = None,
    lossless_only: bool = False,
) -> list[str]:
    """Backends whose device requirements this process satisfies, sorted by
    name — registration (import) order must not leak into probe order or
    autotune tie-breaks."""
    if n_devices is None:
        n_devices = len(jax.devices())
    return [
        s.name
        for s in sorted(_REGISTRY.values(), key=lambda s: s.name)
        if n_devices >= s.min_devices and (s.lossless or not lossless_only)
    ]


def backend_table(docs_base: str | None = "docs/candidates.md") -> str:
    """Markdown capability table (used by the README and `--help` text).

    Each backend row cites its section of the candidate-id documentation
    (`docs_base` anchors, e.g. ``docs/candidates.md#csf``), and each preset
    its entry under the preset grammar; pass ``docs_base=None`` for plain
    terminal output without link noise."""
    def _name(n: str) -> str:
        return f"[`{n}`]({docs_base}#{n})" if docs_base else f"`{n}`"

    def _preset(p: str) -> str:
        return (f"[`{p}`]({docs_base}#preset-{p})" if docs_base else f"`{p}`")

    rows = [
        "| backend | chunked | fixed-point | lossless | presets | min devices | description |",
        "|---------|---------|-------------|----------|---------|-------------|-------------|",
    ]
    for s in sorted(_REGISTRY.values(), key=lambda s: s.name):
        presets = " ".join(_preset(p) for p in s.presets) if s.presets else "—"
        rows.append(
            f"| {_name(s.name)} | {'✓' if s.needs_chunking else '—'} "
            f"| {'✓' if s.supports_fixed_point else '—'} "
            f"| {'✓' if s.lossless else '—'} "
            f"| {presets} "
            f"| {s.min_devices} | {s.description} |"
        )
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Build context + engine handle
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EngineContext:
    """Everything a builder may need, with chunking resolved lazily ONCE.

    `chunk_shape`/`capacity` default to the Fig.-5 partition decider's plan
    for (st, rank, mem_bytes); all chunk-based backends built from the same
    context therefore share one ChunkedTensor via `plans`.
    """

    st: SparseTensor
    rank: int
    mem_bytes: int | None = None
    chunk_shape: tuple[int, ...] | None = None
    capacity: int | None = None
    fixed_preset: str = "int7"
    lockfree_mode: bool = False
    dense_fraction: float | None = None
    mesh: object | None = None      # distributed backend; None → local mesh
    reduce: str = "psum"            # distributed reduction strategy
    interpret: bool = True          # pallas: interpret mode (CPU) vs real TPU
    plans: PlanCache = dataclasses.field(default_factory=lambda: default_plan_cache)
    #: Sparse-layout cache (repro.formats): CSF trees / ALTO linearization
    #: built once per tensor and shared across backends and autotune probes,
    #: exactly as `plans` shares the chunking.
    formats: FormatCache = dataclasses.field(
        default_factory=lambda: default_format_cache)

    def __post_init__(self):
        # Validate up front: `capacity or plan.capacity` downstream would
        # silently turn an explicit 0 into the plan's value.
        if self.capacity is not None and self.capacity < 1:
            raise ValueError(
                f"capacity must be >= 1 nonzero slot per chunk task (got "
                f"{self.capacity}); pass capacity=None to let the partition "
                "decider choose")

    def resolve_chunking(self) -> tuple[tuple[int, ...], int | None]:
        """Fill chunk_shape/capacity from the partition decider if unset."""
        if self.chunk_shape is None:
            plan = self.plans.plan(
                self.st, self.rank,
                mem_bytes=self.mem_bytes or 64 * 1024 * 1024)
            self.chunk_shape = plan.chunk_shape
            if self.capacity is None:
                self.capacity = plan.capacity
        return self.chunk_shape, self.capacity

    def chunked(self):
        cs, cap = self.resolve_chunking()
        return self.plans.chunked(self.st, cs, cap)

    def device_arrays(self) -> dict:
        cs, cap = self.resolve_chunking()
        return self.plans.device_arrays(self.st, cs, cap)


class Engine:
    """Callable engine handle: `engine(factors, mode) -> (I_mode, R)`.

    Carries the metadata CP-ALS and the benchmarks report on (`name`), plus
    the build context and — for autotuned engines — the timing report.
    """

    def __init__(self, name: str, fn: Callable, *, spec: BackendSpec | None = None,
                 context: EngineContext | None = None, report=None):
        self.name = name
        self._fn = fn
        self.spec = spec
        self.context = context
        self.report = report

    def __call__(self, factors, mode: int):
        return self._fn(factors, mode)

    def __repr__(self) -> str:
        return f"Engine({self.name!r})"
