"""Unified MTTKRP engine subsystem: backend registry + plan cache +
empirical autotuner with persistence and a cost-model prior.

Public entrypoint::

    from repro.engine import build_engine
    eng = build_engine(st, "auto", rank=10)     # measured selection
    eng = build_engine(st, "auto", rank=10,
                       store=True)              # persist winners across runs
    eng = build_engine(st, "chunked", rank=10)  # explicit backend
    out = eng(factors, mode)                    # (I_mode, R) f32

`cp_als(st, rank, engine="auto", store=...)` goes through the same path.
"""
from __future__ import annotations

from typing import Callable

from . import backends as _backends  # noqa: F401 — registers the built-ins
from .autotune import AutotuneReport, autotune_engine
from .costmodel import CostModelPrior, default_prior, prior_order
from .persist import (
    DEFAULT_STORE_ENV,
    StoredEntry,
    TuningStore,
    WorkloadKey,
    device_fingerprint,
)
from .plan import CacheStats, PlanCache, default_plan_cache
from .registry import (
    BackendSpec,
    Engine,
    EngineContext,
    backend_table,
    eligible_backends,
    get_backend,
    register_backend,
    registered_backends,
)

__all__ = [
    "AutotuneReport",
    "BackendSpec",
    "CacheStats",
    "CostModelPrior",
    "DEFAULT_STORE_ENV",
    "Engine",
    "EngineContext",
    "PlanCache",
    "StoredEntry",
    "TuningStore",
    "WorkloadKey",
    "autotune_engine",
    "backend_table",
    "build_engine",
    "default_plan_cache",
    "default_prior",
    "device_fingerprint",
    "eligible_backends",
    "get_backend",
    "prior_order",
    "register_backend",
    "registered_backends",
]


def build_engine(
    st,
    method: str | Callable = "auto",
    rank: int = 10,
    *,
    plans: PlanCache | None = None,
    candidates: list[str] | None = None,
    warmup: int = 1,
    reps: int = 2,
    autotune_modes: list[int] | None = None,
    store: TuningStore | str | bool | None = None,
    prior: CostModelPrior | None = None,
    max_probes: int | None = None,
    **options,
) -> Engine:
    """Build an MTTKRP engine through the registry.

    method     — a registered backend name, ``"auto"`` (empirical selection
                 over the eligible lossless backends), or a callable
                 ``f(factors, mode)`` which is wrapped unchanged.
    store      — autotuner persistence: ``True`` for the default store
                 (``~/.cache/repro/autotune.json``, env
                 ``REPRO_AUTOTUNE_CACHE`` overrides), a path, or a
                 ``TuningStore``.  A workload+device fingerprint hit skips
                 the probe phase and dispatches to the persisted winners.
    prior      — cost-model prior ranking candidates on a cold start
                 (default: the analytic memory-bound `default_prior`).
    max_probes — cold-start probe budget: only the prior's top-k candidates
                 are timed.
    options    — EngineContext fields: mem_bytes, chunk_shape, capacity,
                 fixed_preset, lockfree_mode, dense_fraction, mesh, reduce,
                 interpret.
    """
    if callable(method):
        return Engine(getattr(method, "__name__", "custom"), method)

    ctx = EngineContext(
        st=st, rank=rank,
        plans=plans if plans is not None else default_plan_cache,
        **options)

    if method == "auto":
        handle, _report = autotune_engine(
            ctx, candidates=candidates, warmup=warmup, reps=reps,
            modes=autotune_modes, store=store, prior=prior,
            max_probes=max_probes)
        return handle

    spec = get_backend(method)
    return Engine(spec.name, spec.build(ctx), spec=spec, context=ctx)
