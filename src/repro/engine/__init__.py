"""Unified MTTKRP engine subsystem: backend registry + plan cache +
empirical autotuner with persistence and a cost-model prior.

Public entrypoint::

    from repro.engine import TunePolicy, build_engine
    eng = build_engine(st, "auto", rank=10)     # measured selection
    eng = build_engine(st, "auto", rank=10,     # persist winners across runs
                       tune=TunePolicy(store=True))
    eng = build_engine(st, "chunked", rank=10)  # explicit backend
    out = eng(factors, mode)                    # (I_mode, R) f32

`cp_als(st, rank, engine="auto", tune=TunePolicy(...))` goes through the
same path.  `TunePolicy` is the one bundle of tuning knobs (candidates,
warmup/reps, store, prior, probe budget, elision, accuracy budget); the old
loose keyword arguments still work but are deprecated shims that fold into
a policy and warn.
"""
from __future__ import annotations

from collections.abc import Callable

from . import backends as _backends  # imported for side effect: registers the built-ins
from .autotune import AutotuneReport, autotune_engine
from .calibrate import (
    CalibratedPrior,
    CalibrationError,
    CalibrationReport,
    ranking_accuracy,
)
from .costmodel import (
    CostModelPrior,
    WorkloadStats,
    byte_terms,
    default_prior,
    prior_order,
)
from .persist import (
    DEFAULT_STORE_ENV,
    DEFAULT_TTL_ENV,
    Observation,
    StoredEntry,
    TuningStore,
    WorkloadKey,
    budget_covers,
    device_fingerprint,
    device_fingerprint_id,
)
from .plan import CacheStats, PlanCache, default_plan_cache
from .registry import (
    BackendSpec,
    Engine,
    EngineContext,
    backend_table,
    build_candidate,
    candidate_lossless,
    eligible_backends,
    get_backend,
    parse_candidate,
    preset_candidates,
    register_backend,
    registered_backends,
)
from .tunepolicy import TUNE_FIELDS, UNSET, TunePolicy, nearest_kwarg_error

__all__ = [
    "AutotuneReport",
    "BackendSpec",
    "CacheStats",
    "CalibratedPrior",
    "CalibrationError",
    "CalibrationReport",
    "CostModelPrior",
    "DEFAULT_STORE_ENV",
    "DEFAULT_TTL_ENV",
    "Engine",
    "EngineContext",
    "Observation",
    "PlanCache",
    "StoredEntry",
    "TUNE_FIELDS",
    "TunePolicy",
    "TuningStore",
    "WorkloadKey",
    "WorkloadStats",
    "autotune_engine",
    "backend_table",
    "budget_covers",
    "build_candidate",
    "build_engine",
    "byte_terms",
    "candidate_lossless",
    "default_plan_cache",
    "default_prior",
    "device_fingerprint",
    "device_fingerprint_id",
    "eligible_backends",
    "get_backend",
    "parse_candidate",
    "preset_candidates",
    "prior_order",
    "ranking_accuracy",
    "register_backend",
    "registered_backends",
    "validate_engine_kwargs",
]


def _context_option_names() -> set[str]:
    """EngineContext fields a caller may pass as options (everything the
    builder fills itself — tensor, rank, plan cache — excluded)."""
    import dataclasses as _dc
    return {f.name for f in _dc.fields(EngineContext)} - {"st", "rank", "plans"}


def validate_engine_kwargs(caller: str, options: dict,
                           *, extra: tuple[str, ...] = ()) -> None:
    """Reject unknown engine/tuning keywords with a nearest-match hint.

    The valid set is derived from the live signatures — `EngineContext`'s
    option fields plus the `TunePolicy` shim keywords plus `extra` — so it
    can never drift from what the builder actually accepts."""
    valid = _context_option_names() | set(TUNE_FIELDS) | set(extra)
    unknown = set(options) - valid
    if unknown:
        raise nearest_kwarg_error(caller, unknown, valid)


def build_engine(
    st,
    method: str | Callable = "auto",
    rank: int = 10,
    *,
    tune: TunePolicy | None = None,
    plans: PlanCache | None = None,
    autotune_modes: list[int] | None = None,
    candidates=UNSET,
    warmup=UNSET,
    reps=UNSET,
    store=UNSET,
    prior=UNSET,
    max_probes=UNSET,
    elide=UNSET,
    elide_margin=UNSET,
    accuracy_budget=UNSET,
    **options,
) -> Engine:
    """Build an MTTKRP engine through the registry.

    method       — a registered backend name, a preset candidate id
                   (``"fixed:int7"`` pins that Qm.n preset), ``"auto"``
                   (empirical selection over the eligible lossless backends
                   — plus, under `tune.accuracy_budget`, every lossy preset
                   variant), or a callable ``f(factors, mode)`` which is
                   wrapped unchanged.
    tune         — a `TunePolicy` bundling the autotuner's knobs
                   (candidates, warmup, reps, store, prior, max_probes,
                   elide, elide_margin, accuracy_budget — see
                   `repro.engine.tunepolicy` for the per-field semantics);
                   None means the policy defaults.  The individual keywords
                   survive as deprecated shims that fold into the policy
                   (`DeprecationWarning`, exactly one per call); mixing them
                   with `tune=` raises.
    options      — EngineContext fields: mem_bytes, chunk_shape, capacity,
                   fixed_preset, lockfree_mode, dense_fraction, mesh, reduce,
                   interpret, formats (a `repro.formats.FormatCache` — pass
                   one to isolate the csf/alto layout cache, as the plan
                   cache is isolated with `plans=`).  Unknown keywords raise
                   a `TypeError` naming the nearest valid spelling.
    """
    policy = TunePolicy.resolve(
        tune, caller="build_engine",
        candidates=candidates, warmup=warmup, reps=reps, store=store,
        prior=prior, max_probes=max_probes, elide=elide,
        elide_margin=elide_margin, accuracy_budget=accuracy_budget)
    validate_engine_kwargs("build_engine", options)

    if callable(method):
        return Engine(getattr(method, "__name__", "custom"), method)

    ctx = EngineContext(
        st=st, rank=rank,
        plans=plans if plans is not None else default_plan_cache,
        **options)

    if method == "auto":
        handle, _report = autotune_engine(ctx, tune=policy,
                                          modes=autotune_modes)
        return handle
    if policy.accuracy_budget is not None:
        raise ValueError(
            "accuracy_budget only applies to engine='auto' (an explicit "
            f"backend — here {method!r} — is already a format decision); "
            "drop the budget or switch to the autotuner")

    name, preset = parse_candidate(method)
    spec = get_backend(name)
    if preset is not None:
        explicit = options.get("fixed_preset")
        if explicit is not None and explicit != preset:
            raise ValueError(
                f"conflicting presets: method {method!r} pins "
                f"{preset!r} but fixed_preset={explicit!r} was also passed; "
                "drop one of the two spellings")
        ctx.fixed_preset = preset
    return Engine(method, spec.build(ctx), spec=spec, context=ctx)
