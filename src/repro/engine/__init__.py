"""Unified MTTKRP engine subsystem: backend registry + plan cache +
empirical autotuner with persistence and a cost-model prior.

Public entrypoint::

    from repro.engine import build_engine
    eng = build_engine(st, "auto", rank=10)     # measured selection
    eng = build_engine(st, "auto", rank=10,
                       store=True)              # persist winners across runs
    eng = build_engine(st, "chunked", rank=10)  # explicit backend
    out = eng(factors, mode)                    # (I_mode, R) f32

`cp_als(st, rank, engine="auto", store=...)` goes through the same path.
"""
from __future__ import annotations

from collections.abc import Callable

from . import backends as _backends  # imported for side effect: registers the built-ins
from .autotune import AutotuneReport, autotune_engine
from .calibrate import (
    CalibratedPrior,
    CalibrationError,
    CalibrationReport,
    ranking_accuracy,
)
from .costmodel import (
    CostModelPrior,
    WorkloadStats,
    byte_terms,
    default_prior,
    prior_order,
)
from .persist import (
    DEFAULT_STORE_ENV,
    DEFAULT_TTL_ENV,
    Observation,
    StoredEntry,
    TuningStore,
    WorkloadKey,
    budget_covers,
    device_fingerprint,
    device_fingerprint_id,
)
from .plan import CacheStats, PlanCache, default_plan_cache
from .registry import (
    BackendSpec,
    Engine,
    EngineContext,
    backend_table,
    build_candidate,
    candidate_lossless,
    eligible_backends,
    get_backend,
    parse_candidate,
    preset_candidates,
    register_backend,
    registered_backends,
)

__all__ = [
    "AutotuneReport",
    "BackendSpec",
    "CacheStats",
    "CalibratedPrior",
    "CalibrationError",
    "CalibrationReport",
    "CostModelPrior",
    "DEFAULT_STORE_ENV",
    "DEFAULT_TTL_ENV",
    "Engine",
    "EngineContext",
    "Observation",
    "PlanCache",
    "StoredEntry",
    "TuningStore",
    "WorkloadKey",
    "WorkloadStats",
    "autotune_engine",
    "backend_table",
    "budget_covers",
    "build_candidate",
    "build_engine",
    "byte_terms",
    "candidate_lossless",
    "default_plan_cache",
    "default_prior",
    "device_fingerprint",
    "device_fingerprint_id",
    "eligible_backends",
    "get_backend",
    "parse_candidate",
    "preset_candidates",
    "prior_order",
    "ranking_accuracy",
    "register_backend",
    "registered_backends",
]


def build_engine(
    st,
    method: str | Callable = "auto",
    rank: int = 10,
    *,
    plans: PlanCache | None = None,
    candidates: list[str] | None = None,
    warmup: int = 1,
    reps: int = 2,
    autotune_modes: list[int] | None = None,
    store: TuningStore | str | bool | None = None,
    prior: CostModelPrior | str | None = None,
    max_probes: int | None = None,
    elide: bool | None = None,
    elide_margin: float | None = None,
    accuracy_budget: float | None = None,
    **options,
) -> Engine:
    """Build an MTTKRP engine through the registry.

    method       — a registered backend name, a preset candidate id
                   (``"fixed:int7"`` pins that Qm.n preset), ``"auto"``
                   (empirical selection over the eligible lossless backends
                   — plus, under `accuracy_budget`, every lossy preset
                   variant), or a callable ``f(factors, mode)`` which is
                   wrapped unchanged.
    accuracy_budget — admit lossy (fixed-point) candidates to the ``"auto"``
                   tuner, each policed against this max per-mode MTTKRP
                   relative error (measured on a deterministic nnz sample
                   during probing); None keeps the lossless-only space.
                   Only meaningful with ``method="auto"``.
    store        — autotuner persistence: ``True`` for the default store
                   (``~/.cache/repro/autotune.json``, env
                   ``REPRO_AUTOTUNE_CACHE`` overrides), a path, or a
                   ``TuningStore``.  A workload+device fingerprint hit skips
                   the probe phase and dispatches to the persisted winners.
    prior        — cold-start ranking model: a `CostModelPrior`,
                   ``"default"`` (analytic coefficients), ``"calibrated"``
                   (least-squares fit to the store's measured timings), or
                   None — calibrate when the store holds enough
                   observations, else the analytic default.
    max_probes   — cold-start probe budget: only the prior's top-k
                   candidates are timed.
    elide        — cross-mode probe elision (see `autotune_engine`); default
                   None enables it exactly when the prior is calibrated.
    elide_margin — decision-boundary width for elision (default: the
                   calibrated prior's residual-derived margin).
    options      — EngineContext fields: mem_bytes, chunk_shape, capacity,
                   fixed_preset, lockfree_mode, dense_fraction, mesh, reduce,
                   interpret, formats (a `repro.formats.FormatCache` — pass
                   one to isolate the csf/alto layout cache, as the plan
                   cache is isolated with `plans=`).
    """
    if callable(method):
        return Engine(getattr(method, "__name__", "custom"), method)

    ctx = EngineContext(
        st=st, rank=rank,
        plans=plans if plans is not None else default_plan_cache,
        **options)

    if method == "auto":
        handle, _report = autotune_engine(
            ctx, candidates=candidates, warmup=warmup, reps=reps,
            modes=autotune_modes, store=store, prior=prior,
            max_probes=max_probes, elide=elide, elide_margin=elide_margin,
            accuracy_budget=accuracy_budget)
        return handle
    if accuracy_budget is not None:
        raise ValueError(
            "accuracy_budget only applies to engine='auto' (an explicit "
            f"backend — here {method!r} — is already a format decision); "
            "drop the budget or switch to the autotuner")

    name, preset = parse_candidate(method)
    spec = get_backend(name)
    if preset is not None:
        explicit = options.get("fixed_preset")
        if explicit is not None and explicit != preset:
            raise ValueError(
                f"conflicting presets: method {method!r} pins "
                f"{preset!r} but fixed_preset={explicit!r} was also passed; "
                "drop one of the two spellings")
        ctx.fixed_preset = preset
    return Engine(method, spec.build(ctx), spec=spec, context=ctx)
