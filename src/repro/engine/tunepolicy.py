"""`TunePolicy` — the autotuner's knobs as one frozen value object.

Historically `build_engine`, `autotune_engine` and `cp_als` each re-declared
the same nine tuning keywords (candidates, warmup, reps, store, prior,
max_probes, elide, elide_margin, accuracy_budget), so their defaults could —
and did — threaten to drift.  `TunePolicy` is the single home for those
defaults; every tuning-aware entrypoint (including the batched
`cp_als_batched` / `repro.serve` paths) accepts ``tune: TunePolicy | None``
and the old keywords survive only as deprecated shims that fold into a
policy through `TunePolicy.resolve`.

The field semantics are documented once, here, and referenced everywhere:

  candidates      — candidate ids to tune over ("ref", "fixed:int7", ...);
                    None → every eligible lossless backend (plus, under an
                    accuracy budget, every lossy preset variant).
  warmup / reps   — probe repetitions: `warmup` unmeasured calls drain
                    compilation, `reps` measured calls keep the best.
  store           — persistence: True for the default
                    `~/.cache/repro/autotune.json` (env
                    `REPRO_AUTOTUNE_CACHE` overrides), a path, or a
                    `TuningStore`; None/False → no persistence.
  prior           — cold-start ranking model: "default", "calibrated", a
                    `CostModelPrior` instance, or None (calibrate when the
                    store supports it, else analytic default).
  max_probes      — cold-start probe budget: only the prior's top-k
                    candidates are timed (None: no cap).
  elide           — cross-mode probe elision; None → on exactly when the
                    resolved prior carries a deployed calibration fit.
  elide_margin    — elision decision-boundary width, a slowdown factor
                    >= 1.0 (None: the calibrated prior's suggested margin).
  accuracy_budget — max tolerated per-mode MTTKRP relative error; admits
                    lossy (fixed-point) candidates, each policed against it
                    (None: lossless-only candidate space).
"""
from __future__ import annotations

import dataclasses
import difflib
import warnings

__all__ = ["TUNE_FIELDS", "TunePolicy", "nearest_kwarg_error", "split_tune_kwargs"]


class _Unset:
    """Sentinel distinguishing 'keyword not passed' from an explicit None
    (None is a meaningful value for most tuning fields)."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover — debug aid
        return "<unset>"


UNSET = _Unset()

#: The nine consolidated tuning keywords, in their historical signature
#: order — the deprecated-shim parameters of every entrypoint spell exactly
#: these names, and `split_tune_kwargs` peels them out of a `**kwargs` bag.
TUNE_FIELDS = (
    "candidates",
    "warmup",
    "reps",
    "store",
    "prior",
    "max_probes",
    "elide",
    "elide_margin",
    "accuracy_budget",
)


@dataclasses.dataclass(frozen=True)
class TunePolicy:
    """Frozen bundle of the autotuner's knobs (see the module docstring for
    per-field semantics).  Scalar fields are validated at construction so a
    bad policy fails where it was written, not probes-deep in the tuner."""

    candidates: tuple[str, ...] | None = None
    warmup: int = 1
    reps: int = 2
    store: object = None            # TuningStore | str | bool | None
    prior: object = None            # CostModelPrior | str | None
    max_probes: int | None = None
    elide: bool | None = None
    elide_margin: float | None = None
    accuracy_budget: float | None = None

    def __post_init__(self):
        if self.candidates is not None and not isinstance(self.candidates, tuple):
            object.__setattr__(self, "candidates", tuple(self.candidates))
        if self.warmup < 0:
            raise ValueError(f"warmup must be >= 0 (got {self.warmup})")
        if self.reps < 1:
            raise ValueError(f"reps must be >= 1 (got {self.reps})")
        if self.max_probes is not None and self.max_probes < 1:
            raise ValueError(f"max_probes must be >= 1 (got {self.max_probes})")
        if self.elide_margin is not None and self.elide_margin < 1.0:
            # A margin below 1 would exclude even the unmeasured predicted
            # leader from re-probing, silently deciding every non-anchor
            # mode with zero measurements — the opposite of a "tight margin".
            raise ValueError(
                f"elide_margin is a slowdown factor and must be >= 1.0 "
                f"(got {self.elide_margin}); 1.0 trusts the prior "
                f"completely, larger values re-probe more")
        if self.accuracy_budget is not None and not self.accuracy_budget > 0:
            raise ValueError(
                f"accuracy_budget is a max relative error and must be > 0 (got "
                f"{self.accuracy_budget}); pass None to keep the lossless-only "
                "candidate space")
        # The prior's *type* is a policy property; the cross-field
        # "calibrated needs a store" rule stays in autotune_engine, which
        # owns store resolution.
        from .costmodel import CostModelPrior
        if not (self.prior is None or isinstance(self.prior, CostModelPrior)
                or self.prior in ("default", "calibrated")):
            raise ValueError(
                f"prior must be 'default', 'calibrated', a CostModelPrior "
                f"instance or None (got {self.prior!r})")

    @classmethod
    def resolve(cls, tune: TunePolicy | None, *, caller: str,
                **legacy) -> TunePolicy:
        """Collapse (`tune=`, deprecated keywords) into one policy.

        `legacy` holds the nine shim keywords with `UNSET` marking "not
        passed".  Exactly one spelling may be used: mixing `tune=` with any
        legacy keyword raises (folding silently would hide which one wins),
        and using legacy keywords alone emits ONE `DeprecationWarning` per
        call naming everything that should fold into the policy.
        """
        unknown = sorted(set(legacy) - set(TUNE_FIELDS))
        if unknown:
            raise TypeError(
                f"{caller}: internal error — {unknown} are not tuning "
                f"keywords (expected a subset of {list(TUNE_FIELDS)})")
        passed = {k: v for k, v in legacy.items() if v is not UNSET}
        if tune is not None:
            if not isinstance(tune, TunePolicy):
                raise TypeError(
                    f"{caller}: tune= expects a TunePolicy "
                    f"(got {type(tune).__name__})")
            if passed:
                raise TypeError(
                    f"{caller}: got both tune= and the deprecated tuning "
                    f"keyword(s) {sorted(passed)}; fold the keyword(s) into "
                    "the TunePolicy and pass only tune=")
            return tune
        if not passed:
            return cls()
        warnings.warn(
            f"{caller}: the tuning keyword(s) {', '.join(sorted(passed))} "
            f"are deprecated; pass "
            f"tune=TunePolicy({', '.join(f'{k}=...' for k in sorted(passed))}) "
            "instead",
            DeprecationWarning, stacklevel=3)
        return cls(**passed)


def split_tune_kwargs(kwargs: dict) -> dict:
    """Destructively peel the nine tuning keywords out of a `**kwargs` bag
    (for entrypoints like `cp_als` that historically forwarded them
    blindly).  Returns the peeled {name: value} dict; `kwargs` keeps the
    rest."""
    return {k: kwargs.pop(k) for k in TUNE_FIELDS if k in kwargs}


def nearest_kwarg_error(caller: str, unknown, valid) -> TypeError:
    """A `TypeError` for unknown keyword(s) that names the nearest valid
    spelling — a typo'd `max_prob=` must fail at the call, with a hint, not
    surface as a confusing error deep in the builder."""
    valid = sorted(valid)
    parts = []
    for k in sorted(unknown):
        close = difflib.get_close_matches(k, valid, n=1)
        parts.append(f"{k!r} (did you mean {close[0]!r}?)" if close else repr(k))
    return TypeError(
        f"{caller}() got unexpected keyword argument(s) {', '.join(parts)}; "
        f"valid keywords: {', '.join(valid)}")
