"""Empirical backend autotuner (`engine="auto"`) with persistence + prior.

The software analogue of the paper's PIM-vs-CPU-vs-heterogeneous decision:
rather than predicting the winner from a model, measure it.  For each
eligible backend the tuner runs a few warm MTTKRP calls per (tensor, rank,
mode) — warm, because jit compilation and chunking are amortized across
CP-ALS iterations exactly as the paper amortizes tensor placement — and
selects the fastest backend *per mode* (the paper's finding is per-workload;
mode changes the gather/scatter balance enough to flip winners).

Measurement is only paid once per workload: pass `store=` (a `TuningStore`,
a path, or `True` for the default `~/.cache/repro/autotune.json`) and the
measured winners are persisted under a workload + device fingerprint; an
exact-or-near fingerprint hit on a later run skips the probe phase entirely.
On a cold start, `max_probes=` caps the probe budget to the top-k candidates
of the analytic memory-bound prior (costmodel.py), so a fat candidate set
doesn't mean a fat tuning bill.

Lossy backends (fixed point) are excluded by default: number format is an
accuracy choice (paper Fig. 6), execution strategy is a speed choice
(paper Fig. 7); the tuner only makes the latter.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..core.cpals import init_factors
from .costmodel import CostModelPrior, default_prior
from .persist import StoredEntry, TuningStore, WorkloadKey, resolve_store
from .registry import Engine, EngineContext, eligible_backends, get_backend

__all__ = ["AutotuneReport", "autotune_engine"]


@dataclasses.dataclass
class AutotuneReport:
    """What the tuner measured (or recalled) and decided."""

    winners: dict[int, str]               # mode -> backend name
    timings: dict[str, dict[int, float]]  # backend -> mode -> best seconds
    candidates: list[str]                 # what was considered
    skipped: dict[str, str]               # backend -> reason (error/prune text)
    warmup: int
    reps: int
    source: str = "measured"              # "measured" | "persisted"
    n_probes: int = 0                     # _time_call invocations this build
    prior_order: list[str] | None = None  # cost-model ranking, when consulted
    store_path: str | None = None         # persistence store, when used

    @property
    def chosen(self) -> str:
        """Single display name: the per-mode winners, deduplicated."""
        uniq = sorted(set(self.winners.values()))
        return uniq[0] if len(uniq) == 1 else "+".join(uniq)

    def summary(self) -> str:
        head = f"autotune: warmup={self.warmup} reps={self.reps}"
        if self.source != "measured":
            head += f" source={self.source} probes={self.n_probes}"
            if self.store_path:
                head += f" store={self.store_path}"
        lines = [head]
        for name, per_mode in sorted(self.timings.items()):
            t = " ".join(f"m{m}={s * 1e3:.2f}ms" for m, s in sorted(per_mode.items()))
            lines.append(f"  {name:12s} {t}")
        for name, why in sorted(self.skipped.items()):
            lines.append(f"  {name:12s} skipped: {why.splitlines()[0]}")
        lines.append("  winners: " + " ".join(
            f"m{m}={n}" for m, n in sorted(self.winners.items())))
        return "\n".join(lines)


def _time_call(engine, factors, mode: int, *, warmup: int, reps: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(engine(factors, mode))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(engine(factors, mode))
        best = min(best, time.perf_counter() - t0)
    return best


def _dispatcher(built: dict, winners: dict[int, str], overall: str | None,
                ndim: int):
    """Route each MTTKRP call to its per-mode winner; untimed modes fall
    back to `overall` when one was retained, else fail loudly — a stale
    mode index must not surface as a bare KeyError from the closure."""
    def engine(factors, mode):
        name = winners.get(mode, overall)
        if name is None:
            raise ValueError(
                f"autotuned engine has no backend for mode {mode}: tuned "
                f"modes are {sorted(winners)} on a {ndim}-mode tensor "
                f"(valid modes: 0..{ndim - 1})")
        return built[name](factors, mode)
    return engine


def _engine_from_entry(
    ctx: EngineContext,
    entry: StoredEntry,
    candidates: list[str],
    modes: list[int],
    store: TuningStore,
) -> tuple[Engine, AutotuneReport] | None:
    """Rebuild the persisted winners without probing.  Returns None — fall
    back to cold measurement — when the entry doesn't cover the requested
    modes or a persisted winner no longer builds on this host."""
    winners = dict(entry.winners)
    if not set(modes) <= set(winners):
        return None
    # Build every persisted winner — not just the requested modes' — so the
    # dispatcher can serve any mode the entry covers (a caller that probed
    # with restricted `modes` may still run CP-ALS over all of them).
    needed = sorted(set(winners.values())
                    | ({entry.overall} if entry.overall else set()))
    built: dict[str, object] = {}
    for name in needed:
        try:
            built[name] = get_backend(name).build(ctx)
        except Exception:  # noqa: BLE001 — stale winner → re-measure
            return None
    report = AutotuneReport(
        winners=winners, timings={n: dict(p) for n, p in entry.timings.items()},
        candidates=list(candidates), skipped={},
        warmup=entry.warmup, reps=entry.reps,
        source="persisted", n_probes=0, store_path=store.path)
    fn = _dispatcher(built, winners, entry.overall, ctx.st.ndim)
    return Engine(f"auto:{report.chosen}", fn, context=ctx, report=report), report


def autotune_engine(
    ctx: EngineContext,
    *,
    candidates: list[str] | None = None,
    warmup: int = 1,
    reps: int = 2,
    modes: list[int] | None = None,
    seed: int = 0,
    store: TuningStore | str | bool | None = None,
    prior: CostModelPrior | None = None,
    max_probes: int | None = None,
) -> tuple[Engine, AutotuneReport]:
    """Measure every candidate backend on `ctx.st` and return a dispatching
    engine that routes each MTTKRP mode to its measured winner.

    store      — persistence (see persist.py): `True` for the default
                 `~/.cache/repro/autotune.json` (env `REPRO_AUTOTUNE_CACHE`
                 overrides), a path, or a `TuningStore`.  A fingerprint hit
                 skips probing and reuses the persisted winners; a cold
                 start writes its measurements back.
    prior      — cost-model prior used to rank candidates on a cold start
                 (defaults to `costmodel.default_prior`).
    max_probes — probe only the prior's top-k candidates on a cold start;
                 the rest are recorded in `report.skipped` as pruned.

    A backend that raises during build or timing is recorded in
    `report.skipped` and excluded — one broken strategy must not take the
    decomposition down with it.
    """
    if candidates is None:
        candidates = [n for n in eligible_backends(lossless_only=True)
                      if n != "auto"]
        # Interpret-mode Pallas is a simulation/verification path — orders
        # of magnitude slower than any contender on a CPU host, so probing
        # it just burns the tuning budget.  On real TPU (interpret=False)
        # it competes like everyone else.  Explicit `candidates` overrides.
        if ctx.interpret and "pallas" in candidates:
            candidates.remove("pallas")
    if not candidates:
        raise ValueError("no eligible backends to autotune over")
    if max_probes is not None and max_probes < 1:
        raise ValueError(f"max_probes must be >= 1 (got {max_probes})")
    if modes is None:
        modes = list(range(ctx.st.ndim))

    tuning_store = resolve_store(store)
    key = None
    if tuning_store is not None:
        key = WorkloadKey.from_tensor(ctx.st, ctx.rank, candidates)
        entry = tuning_store.lookup(key)
        if entry is not None:
            warm = _engine_from_entry(ctx, entry, candidates, modes,
                                      tuning_store)
            if warm is not None:
                return warm

    # -- cold start: rank by the prior, probe (a budgeted subset), measure --
    skipped: dict[str, str] = {}
    probe_list = list(candidates)
    order: list[str] | None = None
    if max_probes is not None and max_probes < len(probe_list):
        ranking = prior if prior is not None else default_prior
        order = ranking.order(
            ctx.st, ctx.rank, probe_list, modes, interpret=ctx.interpret,
            n_devices=len(jax.devices()))
        probe_list = order[:max_probes]
        for name in order[max_probes:]:
            skipped[name] = (
                f"pruned by cost-model prior (max_probes={max_probes})")

    factors = [jnp.asarray(f) for f in init_factors(ctx.st.shape, ctx.rank, seed)]
    built: dict[str, object] = {}
    timings: dict[str, dict[int, float]] = {}
    n_probes = 0
    for name in probe_list:
        try:
            eng = get_backend(name).build(ctx)
            per_mode: dict[int, float] = {}
            for m in modes:
                per_mode[m] = _time_call(eng, factors, m, warmup=warmup,
                                         reps=reps)
                n_probes += 1
        except Exception as e:  # noqa: BLE001 — any failure disqualifies
            skipped[name] = f"{type(e).__name__}: {e}"
            continue
        built[name] = eng
        timings[name] = per_mode

    if not timings:
        raise RuntimeError(
            f"autotune: every candidate failed: {skipped}")

    winners = {m: min(timings, key=lambda n, m=m: timings[n][m]) for m in modes}

    # Untimed modes (when `modes` was restricted) fall back to the overall
    # fastest backend summed over the timed modes; with every mode timed the
    # fallback is unreachable and need not be retained.
    overall = None
    if set(winners) != set(range(ctx.st.ndim)):
        overall = min(timings, key=lambda n: sum(timings[n].values()))

    report = AutotuneReport(
        winners=winners, timings=timings, candidates=list(candidates),
        skipped=skipped, warmup=warmup, reps=reps,
        source="measured", n_probes=n_probes, prior_order=order,
        store_path=tuning_store.path if tuning_store is not None else None)

    if tuning_store is not None and key is not None:
        try:
            tuning_store.record(key, winners, timings, overall=overall,
                                warmup=warmup, reps=reps)
        except OSError:
            pass  # an unwritable store degrades to per-process tuning

    # Drop losing engines so their device-resident data (reordered copies,
    # densified blocks, ...) doesn't stay alive for the whole CP-ALS run.
    built = {n: e for n, e in built.items()
             if n == overall or n in winners.values()}

    fn = _dispatcher(built, winners, overall, ctx.st.ndim)
    handle = Engine(f"auto:{report.chosen}", fn, context=ctx, report=report)
    return handle, report
