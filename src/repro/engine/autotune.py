"""Empirical backend autotuner (`engine="auto"`).

The software analogue of the paper's PIM-vs-CPU-vs-heterogeneous decision:
rather than predicting the winner from a model, measure it.  For each
eligible backend the tuner runs a few warm MTTKRP calls per (tensor, rank,
mode) — warm, because jit compilation and chunking are amortized across
CP-ALS iterations exactly as the paper amortizes tensor placement — and
selects the fastest backend *per mode* (the paper's finding is per-workload;
mode changes the gather/scatter balance enough to flip winners).

Lossy backends (fixed point) are excluded by default: number format is an
accuracy choice (paper Fig. 6), execution strategy is a speed choice
(paper Fig. 7); the tuner only makes the latter.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..core.cpals import init_factors
from .registry import Engine, EngineContext, eligible_backends, get_backend

__all__ = ["AutotuneReport", "autotune_engine"]


@dataclasses.dataclass
class AutotuneReport:
    """What the tuner measured and decided."""

    winners: dict[int, str]               # mode -> backend name
    timings: dict[str, dict[int, float]]  # backend -> mode -> best seconds
    candidates: list[str]                 # what was considered
    skipped: dict[str, str]               # backend -> reason (error text)
    warmup: int
    reps: int

    @property
    def chosen(self) -> str:
        """Single display name: the per-mode winners, deduplicated."""
        uniq = sorted(set(self.winners.values()))
        return uniq[0] if len(uniq) == 1 else "+".join(uniq)

    def summary(self) -> str:
        lines = [f"autotune: warmup={self.warmup} reps={self.reps}"]
        for name, per_mode in sorted(self.timings.items()):
            t = " ".join(f"m{m}={s * 1e3:.2f}ms" for m, s in sorted(per_mode.items()))
            lines.append(f"  {name:12s} {t}")
        for name, why in sorted(self.skipped.items()):
            lines.append(f"  {name:12s} skipped: {why.splitlines()[0]}")
        lines.append("  winners: " + " ".join(
            f"m{m}={n}" for m, n in sorted(self.winners.items())))
        return "\n".join(lines)


def _time_call(engine, factors, mode: int, *, warmup: int, reps: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(engine(factors, mode))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(engine(factors, mode))
        best = min(best, time.perf_counter() - t0)
    return best


def autotune_engine(
    ctx: EngineContext,
    *,
    candidates: list[str] | None = None,
    warmup: int = 1,
    reps: int = 2,
    modes: list[int] | None = None,
    seed: int = 0,
) -> tuple[Engine, AutotuneReport]:
    """Measure every candidate backend on `ctx.st` and return a dispatching
    engine that routes each MTTKRP mode to its measured winner.

    A backend that raises during build or timing is recorded in
    `report.skipped` and excluded — one broken strategy must not take the
    decomposition down with it.
    """
    if candidates is None:
        candidates = [n for n in eligible_backends(lossless_only=True)
                      if n != "auto"]
        # Interpret-mode Pallas is a simulation/verification path — orders
        # of magnitude slower than any contender on a CPU host, so probing
        # it just burns the tuning budget.  On real TPU (interpret=False)
        # it competes like everyone else.  Explicit `candidates` overrides.
        if ctx.interpret and "pallas" in candidates:
            candidates.remove("pallas")
    if not candidates:
        raise ValueError("no eligible backends to autotune over")
    if modes is None:
        modes = list(range(ctx.st.ndim))

    factors = [jnp.asarray(f) for f in init_factors(ctx.st.shape, ctx.rank, seed)]
    built: dict[str, object] = {}
    timings: dict[str, dict[int, float]] = {}
    skipped: dict[str, str] = {}
    for name in candidates:
        try:
            eng = get_backend(name).build(ctx)
            per_mode = {
                m: _time_call(eng, factors, m, warmup=warmup, reps=reps)
                for m in modes
            }
        except Exception as e:  # noqa: BLE001 — any failure disqualifies
            skipped[name] = f"{type(e).__name__}: {e}"
            continue
        built[name] = eng
        timings[name] = per_mode

    if not timings:
        raise RuntimeError(
            f"autotune: every candidate failed: {skipped}")

    winners = {m: min(timings, key=lambda n: timings[n][m]) for m in modes}
    report = AutotuneReport(
        winners=winners, timings=timings, candidates=list(candidates),
        skipped=skipped, warmup=warmup, reps=reps)

    # Untimed modes (when `modes` was restricted) fall back to the overall
    # fastest backend summed over the timed modes; with every mode timed the
    # fallback is unreachable and need not be retained.
    overall = None
    if set(winners) != set(range(ctx.st.ndim)):
        overall = min(timings, key=lambda n: sum(timings[n].values()))
    # Drop losing engines so their device-resident data (reordered copies,
    # densified blocks, ...) doesn't stay alive for the whole CP-ALS run.
    built = {n: e for n, e in built.items()
             if n == overall or n in winners.values()}

    def engine(factors, mode):
        return built[winners.get(mode, overall)](factors, mode)

    handle = Engine(f"auto:{report.chosen}", engine, context=ctx, report=report)
    return handle, report
