"""Empirical backend autotuner (`engine="auto"`) with persistence + prior.

The software analogue of the paper's PIM-vs-CPU-vs-heterogeneous decision:
rather than predicting the winner from a model, measure it.  For each
eligible backend the tuner runs a few warm MTTKRP calls per (tensor, rank,
mode) — warm, because jit compilation and chunking are amortized across
CP-ALS iterations exactly as the paper amortizes tensor placement — and
selects the fastest backend *per mode* (the paper's finding is per-workload;
mode changes the gather/scatter balance enough to flip winners).

Measurement is only paid once per workload: pass `store=` (a `TuningStore`,
a path, or `True` for the default `~/.cache/repro/autotune.json`) and the
measured winners are persisted under a workload + device fingerprint; an
exact-or-near fingerprint hit on a later run skips the probe phase entirely.
On a cold start, `max_probes=` caps the probe budget to the top-k candidates
of the cost-model prior (costmodel.py), so a fat candidate set doesn't mean
a fat tuning bill.

The prior itself improves with use: once the store holds enough measured
timings, the tuner fits the prior's coefficients to them
(`calibrate.CalibratedPrior`) instead of trusting the analytic guesses —
and a calibrated prior unlocks *cross-mode probe elision*: every candidate
is probed on one representative mode, and the remaining modes are decided
from the prior's per-mode byte ratios anchored to that measurement,
re-probing only candidates whose prediction sits within a confidence margin
of the per-mode decision boundary.  A cold start's probe count drops from
`len(candidates) × ndim` toward `len(candidates)`, the same
measure-once-predict-the-rest structure the paper uses for tensor
placement.

Number format joins the candidate space behind an explicit accuracy budget
(paper Fig. 6): by default lossy backends are excluded — format is an
accuracy choice, and the tuner only makes speed choices for free — but
`accuracy_budget=` (max tolerated per-mode MTTKRP relative error) widens
the candidate space to (backend × fixed-point preset).  Each lossy
candidate's probe then measures error against the float COO reference on a
deterministic nnz sample alongside time; candidates over budget are
rejected before ranking, and under elision the modes never probed are
bounded by the quantization model (`qformat.cross_mode_error_bound`) —
measured on the anchor, modelled on the rest, exactly like the timings.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cpals import init_factors
from ..core.mttkrp import mttkrp_coo
from ..core.qformat import FIXED_PRESETS, cross_mode_error_bound, value_qformat
from ..formats import registered_formats
from ..obs.tracing import record_span, span, tracing_enabled
from .calibrate import CalibratedPrior, CalibrationError
from .costmodel import CostModelPrior, WorkloadStats, default_prior
from .persist import StoredEntry, TuningStore, WorkloadKey, resolve_store
from .registry import (
    Engine,
    EngineContext,
    build_candidate,
    candidate_lossless,
    eligible_backends,
    get_backend,
    parse_candidate,
    preset_candidates,
)
from .tunepolicy import UNSET, TunePolicy

__all__ = ["AutotuneReport", "autotune_engine"]

#: Upper bound on the deterministic nnz sample the error probes draw; the
#: sampled nonzeros' mode-coordinates select the output rows compared
#: against the float reference (small tensors are compared in full).
_ERROR_SAMPLE_NNZ = 2048


@dataclasses.dataclass
class AutotuneReport:
    """What the tuner measured (or recalled, or inferred) and decided."""

    winners: dict[int, str]               # mode -> backend name
    timings: dict[str, dict[int, float]]  # backend -> mode -> best MEASURED s
    candidates: list[str]                 # what was considered
    skipped: dict[str, str]               # backend -> reason (error/prune text)
    warmup: int
    reps: int
    source: str = "measured"              # "measured" | "persisted"
    n_probes: int = 0                     # timing probes charged this build
                                          # (candidates that raised are not)
    prior_order: list[str] | None = None  # cost-model ranking, when consulted
    prior_name: str | None = None         # "default" | "calibrated" | "custom"
    predicted: dict[str, dict[int, float]] = dataclasses.field(
        default_factory=dict)             # anchored predictions (elision path)
    n_elided: int = 0                     # (candidate, mode) probes skipped
    store_path: str | None = None         # persistence store, when used
    accuracy_budget: float | None = None  # max per-mode MTTKRP rel error
    errors: dict[str, dict[int, float]] = dataclasses.field(
        default_factory=dict)             # candidate -> mode -> MEASURED err

    @property
    def chosen(self) -> str:
        """Single display name: the per-mode winners, deduplicated."""
        uniq = sorted(set(self.winners.values()))
        return uniq[0] if len(uniq) == 1 else "+".join(uniq)

    def probe_breakdown(self) -> dict[str, int]:
        """Where the per-mode decisions came from: probes `measured` this
        build, (candidate, mode) pairs `elided` by the anchored prior, and
        modes decided from `persisted` store entries (a warm hit pays zero
        probes, so all its modes count as persisted)."""
        return {
            "measured": self.n_probes,
            "elided": self.n_elided,
            "persisted": (len(self.winners)
                          if self.source == "persisted" else 0),
        }

    def to_dict(self) -> dict:
        """JSON-safe view of the full report: winners, per-candidate
        timings/predictions/errors, skip reasons, and the probe-provenance
        breakdown.  `serve_bench` embeds this per bucket; mode keys stay
        ints (json.dumps stringifies them)."""
        return {
            "chosen": self.chosen,
            "winners": {int(m): n for m, n in self.winners.items()},
            "timings": {n: {int(m): float(s) for m, s in per.items()}
                        for n, per in self.timings.items()},
            "predicted": {n: {int(m): float(s) for m, s in per.items()}
                          for n, per in self.predicted.items()},
            "errors": {n: {int(m): float(e) for m, e in per.items()}
                       for n, per in self.errors.items()},
            "candidates": list(self.candidates),
            "skipped": dict(self.skipped),
            "warmup": self.warmup,
            "reps": self.reps,
            "source": self.source,
            "probes": self.probe_breakdown(),
            "prior_order": (list(self.prior_order)
                            if self.prior_order is not None else None),
            "prior_name": self.prior_name,
            "store_path": self.store_path,
            "accuracy_budget": self.accuracy_budget,
        }

    def summary(self) -> str:
        head = f"autotune: warmup={self.warmup} reps={self.reps}"
        if self.source != "measured":
            head += f" source={self.source}"
        head += f" probes={self.n_probes}"
        if self.n_elided:
            head += f" elided={self.n_elided}"
        if self.accuracy_budget is not None:
            head += f" budget={self.accuracy_budget:.3g}"
        if self.prior_name:
            head += f" prior={self.prior_name}"
        if self.store_path:
            head += f" store={self.store_path}"
        pb = self.probe_breakdown()
        lines = [head,
                 "  probes: " + " ".join(f"{k}={pb[k]}" for k in
                                         ("measured", "elided", "persisted"))]
        for name, per_mode in sorted(self.timings.items()):
            t = " ".join(f"m{m}={s * 1e3:.2f}ms" for m, s in sorted(per_mode.items()))
            pred = self.predicted.get(name, {})
            if pred:
                t += "  " + " ".join(f"m{m}~{s * 1e3:.2f}ms"
                                     for m, s in sorted(pred.items())
                                     if m not in per_mode)
            errs = self.errors.get(name, {})
            if errs:
                t += "  err " + " ".join(f"m{m}={e:.2e}"
                                         for m, e in sorted(errs.items()))
            lines.append(f"  {name:12s} {t}")
        for name, why in sorted(self.skipped.items()):
            lines.append(f"  {name:12s} skipped: {why.splitlines()[0]}")
        lines.append("  winners: " + " ".join(
            f"m{m}={n}" for m, n in sorted(self.winners.items())))
        return "\n".join(lines)


def _time_call(engine, factors, mode: int, *, warmup: int, reps: int) -> float:
    for _ in range(warmup):
        # repro-lint: disable=host-sync -- timing harness: warmup must drain compilation before the measured reps
        jax.block_until_ready(engine(factors, mode))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        # repro-lint: disable=host-sync -- timing harness: the barrier IS the measurement boundary
        jax.block_until_ready(engine(factors, mode))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_backend(name: str, engine, factors, mode: int, *,
                  warmup: int, reps: int) -> float:
    """Probe seam: identical to `_time_call` but carries the backend name so
    tests can substitute deterministic per-backend timings."""
    return _time_call(engine, factors, mode, warmup=warmup, reps=reps)


def _dispatcher(built: dict, winners: dict[int, str], overall: str | None,
                ndim: int):
    """Route each MTTKRP call to its per-mode winner; untimed modes fall
    back to `overall` when one was retained, else fail loudly — a stale
    mode index must not surface as a bare KeyError from the closure."""
    def engine(factors, mode):
        name = winners.get(mode, overall)
        if name is None:
            raise ValueError(
                f"autotuned engine has no backend for mode {mode}: tuned "
                f"modes are {sorted(winners)} on a {ndim}-mode tensor "
                f"(valid modes: 0..{ndim - 1})")
        return built[name](factors, mode)
    return engine


def _engine_from_entry(
    ctx: EngineContext,
    entry: StoredEntry,
    candidates: list[str],
    modes: list[int],
    store: TuningStore,
) -> tuple[Engine, AutotuneReport] | None:
    """Rebuild the persisted winners without probing.  Returns None — fall
    back to cold measurement — when the entry doesn't cover the requested
    modes or a persisted winner no longer builds on this host."""
    winners = dict(entry.winners)
    if not set(modes) <= set(winners):
        return None
    # Build every persisted winner — not just the requested modes' — so the
    # dispatcher can serve any mode the entry covers (a caller that probed
    # with restricted `modes` may still run CP-ALS over all of them).
    needed = sorted(set(winners.values())
                    | ({entry.overall} if entry.overall else set()))
    built: dict[str, object] = {}
    for name in needed:
        try:
            built[name] = build_candidate(name, ctx)
        except Exception:  # blind by design: a stale winner of any kind → re-measure
            return None
    report = AutotuneReport(
        winners=winners, timings={n: dict(p) for n, p in entry.timings.items()},
        candidates=list(candidates), skipped={},
        warmup=entry.warmup, reps=entry.reps,
        source="persisted", n_probes=0, store_path=store.path,
        accuracy_budget=entry.budget,
        errors={n: dict(p) for n, p in entry.errors.items()})
    fn = _dispatcher(built, winners, entry.overall, ctx.st.ndim)
    return Engine(f"auto:{report.chosen}", fn, context=ctx, report=report), report


def _prior_label(prior: CalibratedPrior) -> str:
    """A guard-rejected fit keeps the analytic coefficients — the label must
    not read as if something was learned."""
    return "calibrated" if prior.used_fit else "calibrated (analytic fallback)"


def _resolve_prior(
    prior: CostModelPrior | str | None,
    store: TuningStore | None,
) -> tuple[CostModelPrior, str]:
    """Resolve a *validated* `prior=` argument (see `autotune_engine`, the
    only caller) to a concrete prior instance + label.

    None        — calibrate from the store when it holds enough observations
                  for this device, else the analytic default.
    "calibrated"— fit to the store; fall back to the default (with a
                  labelled reason) only when the store is too thin yet.
    "default"   — the analytic default, even with a fat store.
    instance    — used as-is.
    """
    if isinstance(prior, CostModelPrior):
        return prior, (_prior_label(prior)
                       if isinstance(prior, CalibratedPrior) else "custom")
    if prior == "default":
        return default_prior, "default"
    # None or "calibrated": calibrate when the store supports it.
    if store is not None:
        try:
            fitted = CalibratedPrior.from_store(store)
            return fitted, _prior_label(fitted)
        except CalibrationError as e:
            if prior == "calibrated":
                return default_prior, f"default (calibration unavailable: {e})"
    return default_prior, "default"


def autotune_engine(
    ctx: EngineContext,
    *,
    tune: TunePolicy | None = None,
    modes: list[int] | None = None,
    seed: int = 0,
    candidates=UNSET,
    warmup=UNSET,
    reps=UNSET,
    store=UNSET,
    prior=UNSET,
    max_probes=UNSET,
    elide=UNSET,
    elide_margin=UNSET,
    accuracy_budget=UNSET,
) -> tuple[Engine, AutotuneReport]:
    """Measure candidate backends on `ctx.st` and return a dispatching
    engine that routes each MTTKRP mode to its measured (or, under elision,
    confidently predicted) winner.

    The tuning knobs arrive as one `tune: TunePolicy` (see
    `repro.engine.tunepolicy` for per-field semantics — candidates, warmup,
    reps, store, prior, max_probes, elide, elide_margin, accuracy_budget);
    the individual keywords survive as deprecated shims that fold into the
    policy with a single `DeprecationWarning` per call.  In brief:

    accuracy_budget — max tolerated per-mode MTTKRP relative error, or None
                   (default) to keep the lossless-only candidate space.
                   With a budget, the default candidates additionally
                   include every lossy (backend × preset) variant
                   ("fixed:int3" / "fixed:int7" / "fixed:int15-12"); each
                   probe of a lossy candidate also measures its error
                   against the float COO reference on a deterministic nnz
                   sample, candidates whose measured (or, for un-probed
                   modes, quantization-model-bounded) error exceeds the
                   budget are rejected before ranking, and the budget plus
                   measured errors ride along into the tuning store so a
                   warm hit only applies when its budget covers the request.
    store        — persistence (see persist.py): `True` for the default
                   `~/.cache/repro/autotune.json` (env `REPRO_AUTOTUNE_CACHE`
                   overrides), a path, or a `TuningStore`.  A fingerprint hit
                   skips probing and reuses the persisted winners; a cold
                   start writes its measurements back.
    prior        — cold-start ranking model: a `CostModelPrior` instance,
                   `"default"` (analytic coefficients), `"calibrated"` (fit
                   to the store's measurements), or None — which calibrates
                   whenever the store holds enough observations and falls
                   back to the analytic default otherwise.
    max_probes   — probe only the prior's top-k candidates on a cold start;
                   the rest are recorded in `report.skipped` as pruned.
    elide        — cross-mode probe elision: probe every candidate on one
                   representative mode, decide the remaining modes from the
                   prior's anchored per-mode predictions, and re-probe only
                   candidates within `elide_margin` of the per-mode decision
                   boundary.  Default (None): on exactly when the resolved
                   prior carries a deployed calibration fit — elision is
                   only as good as the prior's cross-mode byte ratios, and
                   a guard-rejected fit (`CalibratedPrior.used_fit=False`)
                   does not qualify.
    elide_margin — boundary width as a slowdown factor, >= 1.0 (default:
                   the calibrated prior's residual-derived
                   `suggested_margin`); 1.0 trusts the prior completely,
                   larger values re-probe more.

    A backend that raises during build or timing is recorded in
    `report.skipped` and excluded — one broken strategy must not take the
    decomposition down with it — and its probes are not charged to
    `report.n_probes`.
    """
    policy = TunePolicy.resolve(
        tune, caller="autotune_engine",
        candidates=candidates, warmup=warmup, reps=reps, store=store,
        prior=prior, max_probes=max_probes, elide=elide,
        elide_margin=elide_margin, accuracy_budget=accuracy_budget)
    candidates = (list(policy.candidates)
                  if policy.candidates is not None else None)
    warmup, reps = policy.warmup, policy.reps
    store, prior = policy.store, policy.prior
    max_probes, elide = policy.max_probes, policy.elide
    elide_margin = policy.elide_margin
    accuracy_budget = policy.accuracy_budget
    if candidates is None:
        candidates = [n for n in eligible_backends(lossless_only=True)
                      if n != "auto"]
        # Interpret-mode Pallas is a simulation/verification path — orders
        # of magnitude slower than any contender on a CPU host, so probing
        # it just burns the tuning budget.  On real TPU (interpret=False)
        # it competes like everyone else.  Explicit `candidates` overrides.
        if ctx.interpret and "pallas" in candidates:
            candidates.remove("pallas")
        # An accuracy budget widens the space to (backend × preset): every
        # lossy variant competes, each policed by its measured error.
        if accuracy_budget is not None:
            candidates.extend(preset_candidates())
    else:
        for cand in candidates:
            parse_candidate(cand)  # fail fast on a typo'd backend/preset
    if not candidates:
        raise ValueError("no eligible backends to autotune over")
    # Scalar-field validation (max_probes >= 1, elide_margin >= 1.0, the
    # prior's type, accuracy_budget > 0) lives in TunePolicy.__post_init__ —
    # one home for the rules, whether the caller passed a policy or the
    # deprecated keywords.
    if modes is None:
        modes = list(range(ctx.st.ndim))

    tuning_store = resolve_store(store)
    if prior == "calibrated" and tuning_store is None:
        raise ValueError(
            "prior='calibrated' needs a store= to fit against (pass a "
            "TuningStore/path, or a pre-built CalibratedPrior instance)")
    key = None
    if tuning_store is not None:
        # An explicitly-pinned chunk capacity is part of the fingerprint
        # (schema v5): it changes every chunked backend's padding, so
        # timings tuned under one capacity must not serve another.  The
        # default (capacity=None, partition decider chooses) matches every
        # pre-v5 entry, which could only have been tuned that way.
        key = WorkloadKey.from_tensor(ctx.st, ctx.rank, candidates,
                                      capacity=ctx.capacity)
        # The budget gates the hit: an entry tuned under a stricter-or-equal
        # budget serves (its winners' measured errors satisfy this request
        # too); anything else is invisible and the workload re-probes.
        entry = tuning_store.lookup(key, budget=accuracy_budget)
        if entry is not None:
            warm = _engine_from_entry(ctx, entry, candidates, modes,
                                      tuning_store)
            if warm is not None:
                record_span("autotune.decision", 0.0, source="persisted",
                            chosen=warm[1].chosen, probes=0,
                            store=tuning_store.path)
                return warm

    # -- cold start: rank by the prior, probe a budgeted subset ------------
    prior_obj, prior_name = _resolve_prior(prior, tuning_store)
    n_devices = len(jax.devices())
    # When the candidate space holds a format backend (csf/alto — the
    # backend name doubles as its layout's registry name), measure the
    # tensor's layout statistics once and hand the prior a stats-carrying
    # view: the csf/alto byte models then rank on *measured* fiber counts,
    # and the same numbers are persisted with the entry (schema v4) so
    # calibration trains on what prediction used.
    fmt_stats = None
    fmt_names = set(registered_formats()) - {"coo"}
    if any(parse_candidate(c)[0] in fmt_names for c in candidates):
        fmt_stats = ctx.formats.format_stats(ctx.st)
    stats_view = (WorkloadStats(shape=ctx.st.shape, nnz=ctx.st.nnz,
                                format_stats=fmt_stats)
                  if fmt_stats is not None else ctx.st)
    order = prior_obj.order(stats_view, ctx.rank, list(candidates), modes,
                            interpret=ctx.interpret, n_devices=n_devices)
    skipped: dict[str, str] = {}
    probe_list = list(order)
    if max_probes is not None and max_probes < len(probe_list):
        probe_list = order[:max_probes]
        for name in order[max_probes:]:
            skipped[name] = (
                f"pruned by cost-model prior (max_probes={max_probes})")

    # Elision is only as trustworthy as the prior's cross-mode ratios: the
    # default policy requires a fit that was actually deployed (a guard-
    # rejected fit keeps analytic coefficients with evidence they mis-rank
    # this store — worse grounds for elision than no store at all).
    do_elide = (elide if elide is not None
                else isinstance(prior_obj, CalibratedPrior)
                and prior_obj.used_fit)
    margin = (elide_margin if elide_margin is not None
              else getattr(prior_obj, "suggested_margin", 2.0))

    factors = [jnp.asarray(f) for f in init_factors(ctx.st.shape, ctx.rank, seed)]
    built: dict[str, object] = {}
    timings: dict[str, dict[int, float]] = {}
    predicted: dict[str, dict[int, float]] = {}
    probe_counts: dict[str, int] = {}
    errors: dict[str, dict[int, float]] = {}

    # -- accuracy probes (lossy candidates under a budget) -----------------
    # The float COO reference and the deterministic nnz sample are shared by
    # every lossy candidate: one reference MTTKRP per probed mode, compared
    # on the output rows that the sampled nonzeros touch.
    lossy = {c for c in candidates if not candidate_lossless(c)}
    value_frac = (value_qformat(ctx.st.values).frac_bits
                  if accuracy_budget is not None and lossy else 7)
    _refs: dict[int, jnp.ndarray] = {}
    _rows: dict[int, np.ndarray] = {}
    _ref_norms: dict[int, float] = {}
    _sample = None

    def _ref_rows(m: int) -> tuple[jnp.ndarray, np.ndarray]:
        nonlocal _sample
        if m not in _refs:
            coords = np.asarray(ctx.st.coords)
            if _sample is None:
                rng = np.random.default_rng(seed)
                n = min(int(ctx.st.nnz), _ERROR_SAMPLE_NNZ)
                _sample = rng.choice(int(ctx.st.nnz), size=n, replace=False)
            rows = np.unique(coords[_sample, m])
            # Output row i of mode m only receives contributions from the
            # nonzeros with coords[:, m] == i, so the reference is computed
            # EXACTLY on that subset — the sample bounds the reference cost,
            # not just the norm comparison.
            touch = np.isin(coords[:, m], rows)
            ref = mttkrp_coo(
                tuple(factors), jnp.asarray(coords[touch]),
                jnp.asarray(np.asarray(ctx.st.values)[touch]),
                mode=m, out_dim=ctx.st.shape[m])
            # Keep only the compared rows, and read the reference norm back
            # ONCE per mode — it is candidate-invariant, so syncing it inside
            # _measure_error would pay a device round-trip per lossy probe.
            _refs[m] = ref[rows]
            _ref_norms[m] = float(jnp.linalg.norm(_refs[m]))  # repro-lint: disable=host-sync -- candidate-invariant norm, read back once per mode (hoisted out of the per-candidate probe loop)
            _rows[m] = rows
        return _refs[m], _rows[m]

    def _measure_error(name: str, m: int) -> float:
        ref, rows = _ref_rows(m)
        out = built[name](factors, m)
        diff = jnp.linalg.norm(jnp.asarray(out)[rows] - ref)
        # Budget gating is host control flow: one scalar readout per lossy
        # probe is the measurement itself (the reference norm is cached).
        return float(diff) / (_ref_norms[m] + 1e-30)

    def _cand_preset(name: str) -> str | None:
        """Preset whose quantization model bounds this candidate's un-probed
        modes; None for a lossy backend outside the Qm.n preset family (a
        user-registered approximate backend has no model to lean on)."""
        base, preset = parse_candidate(name)
        if preset is None and get_backend(base).supports_fixed_point:
            preset = ctx.fixed_preset
        return preset if preset in FIXED_PRESETS else None

    def _cross_bound(name: str, m: int) -> float:
        """Error estimate for an un-probed (candidate, mode): the worst
        measured mode with the quantization model's headroom/cap, or
        infinity for a lossy candidate with no model and no measurement."""
        measured = errors.get(name, {})
        preset = _cand_preset(name)
        if preset is not None:
            return cross_mode_error_bound(measured, preset, ctx.st.ndim,
                                          value_frac=value_frac)
        return max(measured.values(), default=float("inf")) * 2.0

    def _probe(name: str, m: int) -> bool:
        """Measure (name, mode); False + full disqualification on failure —
        a candidate that raised anywhere contributes no timings, no winners
        and no charged probes.  Under an accuracy budget a lossy candidate's
        probe also measures its error; over budget disqualifies the same
        way (the probes already spent are likewise not charged)."""
        probe_sp = span("autotune.probe", candidate=name, mode=m,
                        provenance="measured")
        try:
            # The span covers build + warmup + reps + the error probe;
            # `seconds` is the best single measured rep.
            with probe_sp:
                if name not in built:
                    built[name] = build_candidate(name, ctx)
                t = _time_backend(name, built[name], factors, m,
                                  warmup=warmup, reps=reps)
                err = None
                if accuracy_budget is not None and name in lossy:
                    err = _measure_error(name, m)
                probe_sp.set(seconds=t)
                if err is not None:
                    probe_sp.set(rel_error=err)
        except Exception as e:  # blind by design: any failure disqualifies
            skipped[name] = f"{type(e).__name__}: {e}"
            for book in (built, timings, predicted, probe_counts, errors):
                book.pop(name, None)
            return False
        if err is not None:
            errors.setdefault(name, {})[m] = err
            if err > accuracy_budget:
                skipped[name] = (
                    f"over accuracy budget: mode {m} rel err {err:.3g} > "
                    f"{accuracy_budget:.3g}")
                # Keep `errors` — a real measurement of a rejected candidate
                # is still worth reporting (and persisting).
                for book in (built, timings, predicted, probe_counts):
                    book.pop(name, None)
                return False
        timings.setdefault(name, {})[m] = t
        probe_counts[name] = probe_counts.get(name, 0) + 1
        return True

    if not do_elide or len(modes) < 2 or len(probe_list) < 2:
        for name in probe_list:
            for m in modes:
                if not _probe(name, m):
                    break
    else:
        # Anchor phase: one representative mode for every candidate.  The
        # anchor's job is to absorb each backend's absolute scale (the prior
        # only has to get the *cross-mode byte ratios* right), so any mode
        # works; the first requested one keeps the choice deterministic.
        anchor = modes[0]
        alive = [n for n in probe_list if _probe(n, anchor)]
        for n in alive:
            base = prior_obj.seconds(n, stats_view, ctx.rank, anchor,
                                     interpret=ctx.interpret,
                                     n_devices=n_devices)
            predicted[n] = {
                m: timings[n][anchor]
                * prior_obj.seconds(n, stats_view, ctx.rank, m,
                                    interpret=ctx.interpret,
                                    n_devices=n_devices) / base
                for m in modes if m != anchor}
        # Per-mode elision: re-probe only candidates whose prediction sits
        # within `margin` of the current best estimate; a lone leader means
        # the mode is decided entirely by the prior.
        for m in modes[1:]:
            while True:
                alive_now = [n for n in alive if n in timings]
                if len(alive_now) <= 1:
                    break
                est = {n: timings[n].get(m, predicted[n][m])
                       for n in alive_now}
                best = min(est.values())
                need = [n for n in alive_now
                        if est[n] <= margin * best and m not in timings[n]]
                if not need:
                    break
                for n in need:
                    _probe(n, m)

    if accuracy_budget is not None:
        # Rejection happens BEFORE ranking: a lossy candidate must sit under
        # budget on every requested mode — measured where it was probed,
        # bounded by the quantization model (`cross_mode_error_bound`)
        # where elision skipped the probe.
        for name in [n for n in timings if n in lossy]:
            unmeasured = {m: _cross_bound(name, m) for m in modes
                          if m not in errors.get(name, {})}
            bad = {m: e for m, e in unmeasured.items()
                   if e > accuracy_budget}
            if bad:
                m, e = min(bad.items())
                skipped[name] = (
                    f"over accuracy budget: mode {m} error bound {e:.3g} > "
                    f"{accuracy_budget:.3g} (un-probed mode; quantization-"
                    "model bound)")
                for book in (built, timings, predicted, probe_counts):
                    book.pop(name, None)

    if not timings:
        raise RuntimeError(
            f"autotune: every candidate failed: {skipped}")

    survivors = sorted(timings)
    winners: dict[int, str] = {}
    for m in modes:
        measured = [n for n in survivors if m in timings[n]]
        # A mode nobody measured was fully elided: the prior's anchored
        # prediction decides it.
        winners[m] = (
            min(measured, key=lambda n, m=m: (timings[n][m], n))
            if measured
            else min(survivors,
                     key=lambda n, m=m: (predicted[n].get(m, float("inf")), n)))

    # Untimed modes (when `modes` was restricted) fall back to the overall
    # fastest backend over the requested modes — measured where available,
    # anchored prediction where elided; with every mode covered by `winners`
    # the fallback is unreachable and need not be retained.
    overall = None
    if set(winners) != set(range(ctx.st.ndim)):
        def total(n: str) -> float:
            return sum(
                timings[n].get(m, predicted.get(n, {}).get(m, float("inf")))
                for m in modes)
        overall = min(survivors, key=lambda n: (total(n), n))

    n_probes = sum(probe_counts.get(n, 0) for n in survivors)
    n_elided = sum(1 for n in survivors for m in modes if m not in timings[n])
    report = AutotuneReport(
        winners=winners, timings=timings, candidates=list(candidates),
        skipped=skipped, warmup=warmup, reps=reps,
        source="measured", n_probes=n_probes, prior_order=order,
        prior_name=prior_name, predicted=predicted, n_elided=n_elided,
        store_path=tuning_store.path if tuning_store is not None else None,
        accuracy_budget=accuracy_budget, errors=errors)

    if tracing_enabled():
        # Elided (candidate, mode) probes appear in the trace as
        # zero-duration probe records so the tune-decision breakdown sees
        # them; measured probes were recorded live inside `_probe`.
        for n in survivors:
            for m in modes:
                if m not in timings[n]:
                    record_span("autotune.probe", 0.0, candidate=n, mode=m,
                                provenance="elided",
                                predicted=predicted.get(n, {}).get(m))
        record_span("autotune.decision", 0.0, source="measured",
                    chosen=report.chosen, probes=n_probes, elided=n_elided)

    if tuning_store is not None and key is not None:
        # An unwritable store degrades to per-process tuning.
        with contextlib.suppress(OSError):
            tuning_store.record(key, winners, timings, overall=overall,
                                warmup=warmup, reps=reps,
                                budget=accuracy_budget, errors=errors,
                                format_stats=(fmt_stats.to_json()
                                              if fmt_stats else None))

    # Drop losing engines so their device-resident data (reordered copies,
    # densified blocks, ...) doesn't stay alive for the whole CP-ALS run.
    built = {n: e for n, e in built.items()
             if n == overall or n in winners.values()}

    fn = _dispatcher(built, winners, overall, ctx.st.ndim)
    handle = Engine(f"auto:{report.chosen}", fn, context=ctx, report=report)
    return handle, report
