"""Shared partition-plan / chunked-tensor cache.

Chunking is the expensive, mode-agnostic preprocessing step (paper §IV-A:
one chunking serves every MTTKRP mode and every CP-ALS iteration).  The
cache lets every chunk-based backend — and the autotuner, which builds
several backends against the same tensor — share one `PartitionPlan`, one
`ChunkedTensor` and one set of device-resident arrays instead of re-chunking
per backend.  This is the software analogue of the paper's data-residency
argument: the tensor is placed once; only factors move.

Entries are keyed by tensor identity (`id`) and evicted when the tensor is
garbage collected, so the cache never outlives its tensors.
"""
from __future__ import annotations

import dataclasses
import weakref

from ..core.chunking import ChunkedTensor, chunk_tensor, clamp_capacity
from ..core.partition import PartitionPlan, decide_partition
from ..core.sptensor import SparseTensor

__all__ = ["PlanCache", "CacheStats", "default_plan_cache"]


@dataclasses.dataclass
class CacheStats:
    plan_hits: int = 0
    plan_misses: int = 0
    chunk_hits: int = 0
    chunk_misses: int = 0
    device_hits: int = 0
    device_misses: int = 0


class PlanCache:
    """Caches `decide_partition` plans, `chunk_tensor` results and the
    jnp device arrays derived from them, per live tensor."""

    def __init__(self):
        self._plans: dict = {}
        self._chunked: dict = {}
        self._device: dict = {}
        self._tracked: set[int] = set()
        self.stats = CacheStats()

    # -- keys -------------------------------------------------------------
    def _tensor_key(self, st: SparseTensor) -> int:
        key = id(st)
        # Evict every entry for this tensor once it is collected (id() values
        # are recycled by CPython, so stale entries would otherwise alias).
        # One finalizer per live tensor — not per lookup — and the finalizer
        # only weakly references this cache, so a short-lived cache stays
        # collectable while the tensor lives on.
        if key not in self._tracked:
            self._tracked.add(key)
            weakref.finalize(st, _evict_weak, weakref.ref(self), key)
        return key

    def _evict(self, tkey: int) -> None:
        self._tracked.discard(tkey)  # a recycled id() needs a new finalizer
        for cache in (self._plans, self._chunked, self._device):
            for k in [k for k in cache if k[0] == tkey]:
                del cache[k]

    # -- lookups ----------------------------------------------------------
    def plan(self, st: SparseTensor, rank: int, *, mem_bytes: int) -> PartitionPlan:
        k = (self._tensor_key(st), rank, mem_bytes)
        if k in self._plans:
            self.stats.plan_hits += 1
        else:
            self.stats.plan_misses += 1
            self._plans[k] = decide_partition(st, rank, mem_bytes=mem_bytes)
        return self._plans[k]

    def _capacity_key(self, st: SparseTensor, capacity: int | None):
        """Apply chunk_tensor's clamp so capacities that chunk identically
        share one cache entry."""
        if capacity is None:
            return None
        return clamp_capacity(st.nnz, capacity)

    def chunked(self, st: SparseTensor, chunk_shape: tuple[int, ...],
                capacity: int | None) -> ChunkedTensor:
        k = (self._tensor_key(st), tuple(chunk_shape),
             self._capacity_key(st, capacity))
        if k in self._chunked:
            self.stats.chunk_hits += 1
        else:
            self.stats.chunk_misses += 1
            self._chunked[k] = chunk_tensor(st, tuple(chunk_shape), capacity)
        return self._chunked[k]

    def device_arrays(self, st: SparseTensor, chunk_shape: tuple[int, ...],
                      capacity: int | None) -> dict:
        """jnp copies of the chunked arrays (shipped to devices once)."""
        from ..core.mttkrp import chunked_device_arrays
        k = (self._tensor_key(st), tuple(chunk_shape),
             self._capacity_key(st, capacity))
        if k in self._device:
            self.stats.device_hits += 1
        else:
            self.stats.device_misses += 1
            self._device[k] = chunked_device_arrays(
                self.chunked(st, chunk_shape, capacity))
        return self._device[k]

    def clear(self) -> None:
        self._plans.clear()
        self._chunked.clear()
        self._device.clear()
        self._tracked.clear()
        self.stats = CacheStats()


def _evict_weak(cache_ref: "weakref.ref[PlanCache]", tkey: int) -> None:
    cache = cache_ref()
    if cache is not None:
        cache._evict(tkey)


#: Process-wide default used when callers don't thread their own cache.
default_plan_cache = PlanCache()
