"""ALTO: adaptive linearized tensor order as a storage format.

ALTO (Helal et al., ICS'21) replaces per-mode coordinate tuples with ONE
mode-agnostic linearized index per nonzero: the bits of every mode's
coordinate are interleaved (mode-major round-robin, adaptive — a mode drops
out of the rotation once its coordinate width is exhausted), and the nonzeros
are stored sorted by that key.  One copy of the tensor then serves every
MTTKRP mode — unlike FLYCOO-style per-mode reorders — and any mode's
coordinate is recovered at kernel time by gathering its bit positions back
out of the key (`repro.core.mttkrp.mttkrp_alto`).

The key is packed into ceil(bits/32) little-endian uint32 words rather than
one int64: JAX disables 64-bit integers by default, and the word layout is
what a BLCO-style GPU backend (ROADMAP) consumes directly.  Tensors needing
more than 64 key bits are rejected — BLCO's block splitting is the follow-on
that lifts this.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.sptensor import SparseTensor

__all__ = [
    "MAX_KEY_BITS",
    "ALTOTensor",
    "alto_decode_mode",
    "alto_index_bytes",
    "alto_key_bits",
    "alto_positions",
    "alto_to_coo",
    "build_alto",
]

MAX_KEY_BITS = 64


def alto_index_bytes(nnz: int, n_words: int) -> int:
    """Bytes of the packed linearized index — the single key stream (vs
    `nnz·ndim·4` for COO coordinate columns).  Single source for both the
    real layout (`ALTOTensor.index_bytes`) and the cost model's
    `FormatStats`."""
    return 4 * nnz * n_words


def _mode_bits(shape: tuple[int, ...]) -> list[int]:
    """Coordinate width per mode (≥1 bit even for size-1 modes, so every
    mode owns at least one key position and decoding stays uniform)."""
    return [max(1, int(np.ceil(np.log2(max(s, 2))))) for s in shape]


def alto_positions(shape: tuple[int, ...]) -> tuple[tuple[int, ...], ...]:
    """Per-mode key bit positions: `positions[m][b]` is where bit `b` of
    mode `m`'s coordinate lives in the linearized key.  Mode-major
    round-robin over the bits each mode still needs (the ALTO paper's
    adaptive interleave)."""
    bits = _mode_bits(shape)
    positions: list[list[int]] = [[] for _ in shape]
    pos = 0
    for b in range(max(bits)):
        for m in range(len(shape)):
            if b < bits[m]:
                positions[m].append(pos)
                pos += 1
    return tuple(tuple(p) for p in positions)


def alto_key_bits(shape: tuple[int, ...]) -> int:
    return sum(_mode_bits(shape))


@dataclasses.dataclass(frozen=True)
class ALTOTensor:
    """Linearized tensor: one sorted key stream serving every mode.

    key_words — (nnz, W) uint32, W = ceil(key_bits/32) little-endian words
                of the interleaved key; rows sorted ascending by key.
    values    — (nnz,) f32 in key order.
    perm      — (nnz,) position of each row in the source COO arrays.
    positions — per-mode de-interleave bit positions (static: baked into
                the jit kernel's unrolled decode).
    """

    key_words: np.ndarray
    values: np.ndarray
    perm: np.ndarray
    positions: tuple[tuple[int, ...], ...]
    shape: tuple[int, ...]

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    @property
    def key_bits(self) -> int:
        return alto_key_bits(self.shape)

    @property
    def n_words(self) -> int:
        return self.key_words.shape[1]

    @property
    def index_bytes(self) -> int:
        """What the cost model charges as `indexed` traffic."""
        return alto_index_bytes(self.nnz, self.n_words)


def build_alto(st: SparseTensor) -> ALTOTensor:
    """Encode, sort, and word-pack the linearized index."""
    bits = alto_key_bits(st.shape)
    if bits > MAX_KEY_BITS:
        raise ValueError(
            f"ALTO key needs {bits} bits for shape {st.shape}; the packed "
            f"encoding caps at {MAX_KEY_BITS} (BLCO block splitting is the "
            "planned lift — see ROADMAP)")
    positions = alto_positions(st.shape)
    key = np.zeros(st.nnz, dtype=np.uint64)
    for m, pos in enumerate(positions):
        c = st.coords[:, m].astype(np.uint64)
        for b, p in enumerate(pos):
            key |= ((c >> np.uint64(b)) & np.uint64(1)) << np.uint64(p)
    perm = np.argsort(key, kind="stable").astype(np.int64)
    key = key[perm]
    n_words = max(1, -(-bits // 32))
    words = np.empty((st.nnz, n_words), dtype=np.uint32)
    for w in range(n_words):
        words[:, w] = ((key >> np.uint64(32 * w)) & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    return ALTOTensor(
        key_words=words,
        values=st.values[perm].astype(np.float32),
        perm=perm,
        positions=positions,
        shape=st.shape,
    )


def alto_decode_mode(at: ALTOTensor, mode: int) -> np.ndarray:
    """Host-side de-interleave of one mode's coordinates (the jit kernel
    does the same bit gathers on device)."""
    pos = at.positions[mode]
    c = np.zeros(at.nnz, dtype=np.int32)
    for b, p in enumerate(pos):
        word = at.key_words[:, p // 32]
        c |= (((word >> np.uint32(p % 32)) & np.uint32(1)) << b).astype(np.int32)
    return c


def alto_to_coo(at: ALTOTensor) -> SparseTensor:
    """Invert the linearization back to COO (key order; the coordinate/value
    multiset and `to_dense()` are preserved exactly)."""
    coords = np.stack([alto_decode_mode(at, m) for m in range(len(at.shape))],
                      axis=1).astype(np.int32)
    return SparseTensor(coords, at.values.copy(), at.shape)
