"""Pluggable sparse-format subsystem: how the tensor's nonzeros are laid out
in memory, decoupled from how the MTTKRP executes over them.

PRISM's central finding is that spMTTKRP performance is dominated by the
interaction between memory layout and execution strategy; ALTO and Dynasor
show that linearized / tree-compressed layouts beat per-mode COO on exactly
the imbalanced workloads this repo models.  This package makes layout a
first-class, registered axis — mirroring the engine's backend registry — so
the autotuner's candidate space covers (format × execution × preset), and
future layouts (the ROADMAP's BLCO-style GPU format) plug in the same way:

  coo   — the baseline coordinate list (`repro.core.SparseTensor`).
  csf   — per-mode fiber trees (csf.py): fiber-level factor reuse.
  alto  — one bit-interleaved linearized index serving every mode (alto.py).

`FormatStats` summarizes the layout-relevant statistics of a tensor — fiber
counts per mode, interleave key width, index bytes per layout — and is what
the engine cost model's byte terms consume to rank layouts on a cold start
(`repro.engine.costmodel`); the autotuner persists it with each tuned
workload so calibration can train on layouts of tensors that are long gone.
"""
from __future__ import annotations

import dataclasses
import math
from collections.abc import Callable

from ..core.sptensor import SparseTensor
from .alto import (
    MAX_KEY_BITS,
    ALTOTensor,
    alto_index_bytes,
    alto_key_bits,
    alto_positions,
    alto_to_coo,
    build_alto,
)
from .convert import (
    FormatCache,
    FormatCacheStats,
    alto_to_csf,
    coo_to_alto,
    coo_to_csf,
    csf_to_alto,
    default_format_cache,
)
from .csf import (
    CSFModeTree,
    build_csf_tree,
    csf_index_bytes,
    csf_mode_order,
    csf_to_coo,
    fiber_count,
)

__all__ = [
    "ALTOTensor",
    "CSFModeTree",
    "FormatCache",
    "FormatCacheStats",
    "FormatSpec",
    "FormatStats",
    "MAX_KEY_BITS",
    "alto_index_bytes",
    "alto_key_bits",
    "alto_positions",
    "alto_to_coo",
    "alto_to_csf",
    "build_alto",
    "build_csf_tree",
    "coo_to_alto",
    "coo_to_csf",
    "csf_index_bytes",
    "csf_mode_order",
    "csf_to_alto",
    "csf_to_coo",
    "default_format_cache",
    "fiber_count",
    "format_table",
    "get_format",
    "register_format",
    "registered_formats",
]


# ---------------------------------------------------------------------------
# Format registry — the layout analogue of engine/registry.py's backend
# registry: each layout registers a capability declaration + builder, and
# everything downstream (backends, cost model, docs) goes through one API.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FormatSpec:
    """Capability declaration for one registered sparse layout.

    build(st, mode) -> layout object (mode is ignored by mode-agnostic
    layouts — one build serves every MTTKRP mode; per-mode layouts build
    `ndim` structures, typically lazily and cached).

    mode_agnostic — one built structure serves every MTTKRP mode (ALTO's
                    selling point; COO trivially; CSF needs one tree per
                    output mode).
    sorted_reduce — nonzeros are stored so the MTTKRP reduction runs over
                    sorted segments (enables `indices_are_sorted=True`).
    """

    name: str
    build: Callable
    mode_agnostic: bool = True
    sorted_reduce: bool = False
    description: str = ""


_REGISTRY: dict[str, FormatSpec] = {}


def register_format(
    name: str,
    *,
    mode_agnostic: bool = True,
    sorted_reduce: bool = False,
    description: str = "",
):
    """Decorator registering a layout builder under `name` (last wins, as in
    the backend registry, so tests and downstream code can override)."""
    def deco(build: Callable) -> Callable:
        _REGISTRY[name] = FormatSpec(
            name=name,
            build=build,
            mode_agnostic=mode_agnostic,
            sorted_reduce=sorted_reduce,
            description=description,
        )
        return build
    return deco


def get_format(name: str) -> FormatSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown format {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def registered_formats() -> dict[str, FormatSpec]:
    return dict(_REGISTRY)


def format_table(docs_base: str | None = "docs/candidates.md") -> str:
    """Markdown capability table (README / `--help` text).  Each layout row
    cites its candidate documentation anchor (the format registry names
    double as autotune candidate ids); `docs_base=None` for plain text."""
    def _name(n: str) -> str:
        return f"[`{n}`]({docs_base}#{n})" if docs_base else f"`{n}`"

    rows = [
        "| format | mode-agnostic | sorted reduce | description |",
        "|--------|---------------|---------------|-------------|",
    ]
    rows.extend(
        f"| {_name(s.name)} | {'✓' if s.mode_agnostic else '—'} "
        f"| {'✓' if s.sorted_reduce else '—'} "
        f"| {s.description} |"
        for s in sorted(_REGISTRY.values(), key=lambda s: s.name)
    )
    return "\n".join(rows)


@register_format(
    "coo", mode_agnostic=True,
    description="baseline coordinate list (repro.core.SparseTensor)")
def _build_coo(st: SparseTensor, mode: int = 0) -> SparseTensor:
    return st


@register_format(
    "csf", mode_agnostic=False, sorted_reduce=True,
    description="per-mode fiber trees; interior factor rows fetched once per fiber")
def _build_csf(st: SparseTensor, mode: int = 0) -> CSFModeTree:
    return build_csf_tree(st, mode)


@register_format(
    "alto", mode_agnostic=True,
    description="bit-interleaved linearized index, one copy serving all modes")
def _build_alto_fmt(st: SparseTensor, mode: int = 0) -> ALTOTensor:
    return build_alto(st)


# ---------------------------------------------------------------------------
# FormatStats — the layout statistics the cost model's byte terms consume.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FormatStats:
    """Layout-relevant statistics of one tensor.

    fiber_counts — per-mode CSF fiber count (distinct root+interior
                   prefixes under `csf_mode_order`).
    key_bits     — ALTO interleaved key width for the shape.
    key_words    — uint32 words the packed key occupies.
    measured     — True when counted from real coordinates, False for the
                   balls-in-bins estimate (`estimate`) used when only
                   (shape, nnz) survive — e.g. a persisted workload key.
    """

    shape: tuple[int, ...]
    nnz: int
    fiber_counts: tuple[int, ...]
    key_bits: int
    key_words: int
    measured: bool = True

    # -- index bytes per layout (what the cost model charges); the csf/alto
    # formulas delegate to the layouts' own single-source helpers ------------
    def coo_index_bytes(self) -> float:
        return 4.0 * self.nnz * len(self.shape)

    def csf_index_bytes(self, mode: int) -> float:
        """Index bytes of the mode-`mode` tree (`csf.csf_index_bytes`)."""
        return float(csf_index_bytes(self.nnz, len(self.shape),
                                     self.fiber_counts[mode]))

    def alto_index_bytes(self) -> float:
        return float(alto_index_bytes(self.nnz, self.key_words))

    @classmethod
    def from_tensor(cls, st: SparseTensor) -> FormatStats:
        bits = alto_key_bits(st.shape)
        return cls(
            shape=st.shape,
            nnz=st.nnz,
            fiber_counts=tuple(fiber_count(st, m) for m in range(st.ndim)),
            key_bits=bits,
            key_words=max(1, -(-bits // 32)),
            measured=True,
        )

    @classmethod
    def estimate(cls, shape: tuple[int, ...], nnz: int) -> FormatStats:
        """Balls-in-bins fiber estimate from (shape, nnz) alone: `nnz`
        nonzeros thrown uniformly at the K = prod(prefix dims) possible
        fibers occupy K·(1 - (1 - 1/K)^nnz) of them in expectation.  Exact
        for nothing, consistent for everything — the cost model uses the
        same estimator at train and predict time whenever real counts are
        unavailable, so the two can never drift apart."""
        shape = tuple(int(d) for d in shape)
        counts = []
        for mode in range(len(shape)):
            root, mids, _inner = csf_mode_order(shape, mode)
            k = float(math.prod(shape[m] for m in (root, *mids)))
            if nnz == 0:
                occupied = 0
            elif k <= 1.0:
                occupied = 1
            else:
                # -k·expm1(nnz·log1p(-1/k)) = k·(1-(1-1/k)^nnz), stable for
                # k up to ~1e16 where the naive power underflows to 0.
                occupied = int(round(-k * math.expm1(nnz * math.log1p(-1.0 / k))))
            counts.append(max(min(occupied, nnz), 1 if nnz else 0))
        bits = alto_key_bits(shape)
        return cls(
            shape=shape,
            nnz=int(nnz),
            fiber_counts=tuple(min(c, nnz) for c in counts),
            key_bits=bits,
            key_words=max(1, -(-bits // 32)),
            measured=False,
        )

    # -- persistence (rides along in the tuning store, schema v4) -----------
    def to_json(self) -> dict:
        return {
            "shape": list(self.shape),
            "nnz": self.nnz,
            "fiber_counts": list(self.fiber_counts),
            "key_bits": self.key_bits,
            "key_words": self.key_words,
            "measured": self.measured,
        }

    @classmethod
    def from_json(cls, d: dict) -> FormatStats:
        return cls(
            shape=tuple(int(x) for x in d["shape"]),
            nnz=int(d["nnz"]),
            fiber_counts=tuple(int(x) for x in d["fiber_counts"]),
            key_bits=int(d["key_bits"]),
            key_words=int(d.get("key_words", 1)),
            measured=bool(d.get("measured", True)),
        )
