"""COO ↔ CSF ↔ ALTO conversion + the per-tensor format cache.

Layout construction is the expensive, once-per-tensor step (sorts over the
nonzeros); CP-ALS calls MTTKRP `ndim × n_iters` times against the same
tensor, and the autotuner builds several candidate engines against it too.
`FormatCache` is the format analogue of the engine's `PlanCache`: built
layouts (and their device-resident jnp copies) are cached per live tensor
and evicted when the tensor is garbage collected, so no layout is ever
rebuilt across CP-ALS iterations, autotune probes, or repeated
`build_engine` calls.
"""
from __future__ import annotations

import dataclasses
import weakref

from ..core.sptensor import SparseTensor
from .alto import ALTOTensor, alto_to_coo, build_alto
from .csf import CSFModeTree, build_csf_tree, csf_to_coo

__all__ = [
    "FormatCache",
    "FormatCacheStats",
    "alto_to_csf",
    "coo_to_alto",
    "coo_to_csf",
    "csf_to_alto",
    "default_format_cache",
]


# -- conversions -------------------------------------------------------------
# COO is the hub: every layout converts exactly to/from it (multiset of
# (coords, values) preserved), so the cross conversions compose through it.

def coo_to_csf(st: SparseTensor, mode: int) -> CSFModeTree:
    return build_csf_tree(st, mode)


def coo_to_alto(st: SparseTensor) -> ALTOTensor:
    return build_alto(st)


def csf_to_alto(tree: CSFModeTree) -> ALTOTensor:
    return build_alto(csf_to_coo(tree))


def alto_to_csf(at: ALTOTensor, mode: int) -> CSFModeTree:
    return build_csf_tree(alto_to_coo(at), mode)


# -- cache -------------------------------------------------------------------

@dataclasses.dataclass
class FormatCacheStats:
    csf_hits: int = 0
    csf_misses: int = 0
    alto_hits: int = 0
    alto_misses: int = 0
    device_hits: int = 0
    device_misses: int = 0


class FormatCache:
    """Caches CSF mode trees, the ALTO layout, their jnp device arrays and
    the tensor's `FormatStats`, per live tensor (same identity-keyed,
    finalizer-evicted scheme as `repro.engine.plan.PlanCache`)."""

    def __init__(self):
        self._csf: dict = {}
        self._alto: dict = {}
        self._device: dict = {}
        self._stats: dict = {}
        self._tracked: set[int] = set()
        self.stats = FormatCacheStats()

    def _tensor_key(self, st: SparseTensor) -> int:
        key = id(st)
        if key not in self._tracked:
            self._tracked.add(key)
            weakref.finalize(st, _evict_weak, weakref.ref(self), key)
        return key

    def _evict(self, tkey: int) -> None:
        self._tracked.discard(tkey)
        for cache in (self._csf, self._alto, self._device, self._stats):
            for k in [k for k in cache if k[0] == tkey]:
                del cache[k]

    # -- layouts ------------------------------------------------------------
    def csf(self, st: SparseTensor, mode: int) -> CSFModeTree:
        k = (self._tensor_key(st), mode)
        if k in self._csf:
            self.stats.csf_hits += 1
        else:
            self.stats.csf_misses += 1
            self._csf[k] = build_csf_tree(st, mode)
        return self._csf[k]

    def alto(self, st: SparseTensor) -> ALTOTensor:
        k = (self._tensor_key(st),)
        if k in self._alto:
            self.stats.alto_hits += 1
        else:
            self.stats.alto_misses += 1
            self._alto[k] = build_alto(st)
        return self._alto[k]

    # -- device arrays ------------------------------------------------------
    def device_csf(self, st: SparseTensor, mode: int) -> dict:
        """jnp copies of the mode tree's kernel operands (shipped once)."""
        import jax.numpy as jnp
        k = (self._tensor_key(st), "csf", mode)
        if k in self._device:
            self.stats.device_hits += 1
        else:
            self.stats.device_misses += 1
            t = self.csf(st, mode)
            self._device[k] = dict(
                inner_coord=jnp.asarray(t.inner_coord),
                values=jnp.asarray(t.values),
                fiber_ids=jnp.asarray(t.fiber_ids),
                fiber_coords=jnp.asarray(t.fiber_coords),
            )
        return self._device[k]

    def device_alto(self, st: SparseTensor) -> dict:
        import jax.numpy as jnp
        k = (self._tensor_key(st), "alto")
        if k in self._device:
            self.stats.device_hits += 1
        else:
            self.stats.device_misses += 1
            at = self.alto(st)
            self._device[k] = dict(
                key_words=jnp.asarray(at.key_words),
                values=jnp.asarray(at.values),
            )
        return self._device[k]

    # -- stats --------------------------------------------------------------
    def format_stats(self, st: SparseTensor):
        """Measured `FormatStats` for `st` (exact fiber counts; cached)."""
        from . import FormatStats
        k = (self._tensor_key(st), "stats")
        if k not in self._stats:
            self._stats[k] = FormatStats.from_tensor(st)
        return self._stats[k]

    def clear(self) -> None:
        self._csf.clear()
        self._alto.clear()
        self._device.clear()
        self._stats.clear()
        self._tracked.clear()
        self.stats = FormatCacheStats()


def _evict_weak(cache_ref: "weakref.ref[FormatCache]", tkey: int) -> None:
    cache = cache_ref()
    if cache is not None:
        cache._evict(tkey)


#: Process-wide default used when callers don't thread their own cache.
default_format_cache = FormatCache()
