"""CSF (Compressed Sparse Fiber) mode trees.

SPLATT-style CSF compresses a sparse tensor into one tree per MTTKRP output
mode: the output mode is the root level, the remaining modes are interior
levels, and the innermost level holds the leaf coordinates.  Every group of
nonzeros sharing a root+interior prefix is a *fiber* — the unit of factor-row
reuse: during MTTKRP the interior factor rows are fetched once per fiber
instead of once per nonzero, which is exactly where CSF beats COO on tensors
with long fibers (the paper's imbalanced Delicious/LBNL-like workloads).

This module builds the host-side (numpy) tree; the jit kernel consuming it is
`repro.core.mttkrp.mttkrp_csf` (two sorted `segment_sum` levels: nonzeros →
fibers → output rows).  Trees are built once per (tensor, mode) and cached by
`repro.formats.convert.FormatCache`, the format analogue of the engine's
`PlanCache`.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.sptensor import SparseTensor

__all__ = [
    "CSFModeTree",
    "build_csf_tree",
    "csf_index_bytes",
    "csf_mode_order",
    "csf_to_coo",
    "fiber_count",
]


def csf_index_bytes(nnz: int, ndim: int, n_fibers: int) -> int:
    """Bytes a mode tree's index structure occupies — leaf coordinates +
    fiber membership (nnz·2·4) plus fiber prefix coordinates
    (n_fibers·(ndim-1)·4).  Single source for both the real layout
    (`CSFModeTree.index_bytes`) and the cost model's `FormatStats`, so
    predicted and actual index traffic cannot drift apart."""
    return 4 * (nnz * 2 + n_fibers * (ndim - 1))


def csf_mode_order(shape: tuple[int, ...], mode: int) -> tuple[int, tuple[int, ...], int]:
    """Tree level order for the mode-`mode` CSF tree: ``(root, mids, inner)``.

    The root is the output mode (its coordinate addresses the output row);
    the innermost level is the largest remaining mode — pushing the longest
    axis to the leaves minimizes the fiber count, i.e. maximizes how many
    nonzeros share each interior factor-row fetch.  Deterministic ties by
    mode index."""
    others = [m for m in range(len(shape)) if m != mode]
    if not others:
        raise ValueError("CSF needs at least 2 modes")
    inner = max(others, key=lambda m: (shape[m], m))
    mids = tuple(m for m in others if m != inner)
    return mode, mids, inner


@dataclasses.dataclass(frozen=True)
class CSFModeTree:
    """One mode's fiber tree, flattened to rectangular arrays.

    Nonzeros are sorted lexicographically by (root, mids..., inner)
    coordinate, so both `fiber_ids` and the fibers' root coordinates are
    non-decreasing — the kernel's two `segment_sum` levels run with
    `indices_are_sorted=True`.

    perm         — (nnz,) position of each tree-ordered nonzero in the
                   source COO arrays (coords/values round-trip through it).
    inner_coord  — (nnz,) int32 leaf-level coordinate.
    values       — (nnz,) f32, tree order.
    fiber_ids    — (nnz,) int32 fiber of each nonzero, sorted.
    fiber_coords — (n_fibers, N) int32 prefix coordinates of each fiber
                   (the inner column is 0 — a fiber has no leaf coordinate).
    """

    mode: int
    inner_mode: int
    mid_modes: tuple[int, ...]
    perm: np.ndarray
    inner_coord: np.ndarray
    values: np.ndarray
    fiber_ids: np.ndarray
    fiber_coords: np.ndarray
    shape: tuple[int, ...]

    @property
    def nnz(self) -> int:
        return self.values.shape[0]

    @property
    def n_fibers(self) -> int:
        return self.fiber_coords.shape[0]

    @property
    def index_bytes(self) -> int:
        """What the cost model charges as `indexed` traffic."""
        return csf_index_bytes(self.nnz, len(self.shape), self.n_fibers)


def build_csf_tree(st: SparseTensor, mode: int) -> CSFModeTree:
    """Sort the nonzeros into mode-`mode` tree order and delimit fibers."""
    root, mids, inner = csf_mode_order(st.shape, mode)
    prefix = (root, *mids)
    # np.lexsort: last key is most significant → (root, mids..., inner).
    keys = [st.coords[:, inner], *(st.coords[:, m] for m in reversed(prefix))]
    perm = np.lexsort(tuple(keys)).astype(np.int64)
    coords_s = st.coords[perm]

    if st.nnz == 0:
        new_fiber = np.zeros(0, dtype=bool)
    else:
        prev = coords_s[:-1][:, list(prefix)]
        cur = coords_s[1:][:, list(prefix)]
        new_fiber = np.concatenate([[True], (prev != cur).any(axis=1)])
    fiber_ids = (np.cumsum(new_fiber) - 1).astype(np.int32)
    fiber_coords = np.zeros((int(new_fiber.sum()), st.ndim), dtype=np.int32)
    if fiber_coords.shape[0]:
        starts = np.flatnonzero(new_fiber)
        fiber_coords[:, list(prefix)] = coords_s[starts][:, list(prefix)]

    return CSFModeTree(
        mode=mode, inner_mode=inner, mid_modes=mids,
        perm=perm,
        inner_coord=coords_s[:, inner].astype(np.int32),
        values=st.values[perm].astype(np.float32),
        fiber_ids=fiber_ids,
        fiber_coords=fiber_coords,
        shape=st.shape,
    )


def csf_to_coo(tree: CSFModeTree) -> SparseTensor:
    """Invert the tree back to COO (nonzeros come back in tree order; the
    coordinate/value multiset — and therefore `to_dense()` — is preserved
    exactly)."""
    coords = tree.fiber_coords[tree.fiber_ids].copy()
    coords[:, tree.inner_mode] = tree.inner_coord
    return SparseTensor(coords.astype(np.int32), tree.values.copy(), tree.shape)


def fiber_count(st: SparseTensor, mode: int) -> int:
    """Number of fibers the mode-`mode` tree has, without building it:
    distinct (root, mids...) coordinate prefixes."""
    root, mids, _inner = csf_mode_order(st.shape, mode)
    prefix = [root, *mids]
    if st.nnz == 0:
        return 0
    if math.prod(st.shape[m] for m in prefix) < (1 << 62):
        lin = np.zeros(st.nnz, dtype=np.int64)
        for m in prefix:
            lin = lin * st.shape[m] + st.coords[:, m]
        return int(np.unique(lin).size)
    return int(np.unique(st.coords[:, prefix], axis=0).shape[0])
