"""Pallas TPU kernel for chunked spMTTKRP (float path).

TPU codesign of the PRISM "DPU program" (DESIGN.md §2):

  * grid = one step per chunk *task* (the DPU analogue);
  * the task's nonzero block (values + relative coords) is streamed
    HBM→VMEM by the Pallas pipeline — the UPMEM *sequential readers*;
  * the factor blocks each task needs are fetched with **data-dependent
    BlockSpec index maps driven by scalar-prefetched `task_chunk`**: block
    index of factor m at grid step t is `task_chunk[t, m]`.  This is the
    chunked format's defining property (a chunk pins its factor rows) turned
    into a hardware prefetch rule;
  * per-nonzero gathers/scatters are re-expressed as one-hot matmuls so the
    MXU does them (UPMEM's cheap near-memory random access has no TPU
    equivalent; the systolic array is the TPU-native substitute);
  * each task writes a private (S_out, R) partial block; the global sum
    reduction happens outside the kernel — exactly where the paper puts it
    (host-side reduction of per-DPU partials).

VMEM budget per step (defaults P=256, S≤256, R≤128, f32):
  coords (P·N·4) + values (P·4) + one-hots (2·P·S·4 ≈ 512 KB) +
  factor blocks (N·S·R·4 ≤ 384 KB) + out (S·R·4) ≈ ~1 MB ≪ 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mttkrp_pallas_local"]


def _kernel(mode, input_modes, chunk_shape, n_pad_p,
            tc_ref, coords_ref, values_ref, *refs):
    factor_refs, out_ref = refs[:-1], refs[-1]
    p = coords_ref.shape[1]
    part = values_ref[0, :][:, None].astype(jnp.float32)  # (P, 1)
    for j, m in enumerate(input_modes):
        s_m = chunk_shape[m]
        c = coords_ref[0, :, m]
        onehot = (c[:, None] == lax.broadcasted_iota(jnp.int32, (p, s_m), 1))
        rows = jnp.dot(onehot.astype(jnp.float32), factor_refs[j][...],
                       preferred_element_type=jnp.float32)  # (P, R) on MXU
        part = part * rows
    s_out = chunk_shape[mode]
    co = coords_ref[0, :, mode]
    # Padding entries have value 0 → their scatter contribution is 0.
    oh_out = (lax.broadcasted_iota(jnp.int32, (s_out, p), 0) == co[None, :])
    out_ref[0] = jnp.dot(oh_out.astype(jnp.float32), part,
                         preferred_element_type=jnp.float32)  # (S_out, R)


@functools.partial(
    jax.jit, static_argnames=("mode", "chunk_shape", "interpret"))
def mttkrp_pallas_local(
    factors, task_chunk, coords_rel, values, *,
    mode: int, chunk_shape: tuple[int, ...], interpret: bool = False,
):
    """Per-task partial MTTKRP: returns (T, S_mode, R) chunk-local blocks.

    factors   : tuple of (G_m * S_m, R) f32 — rows padded to a whole number
                of chunks (ops.py does the padding).
    task_chunk: (T, N) int32 (scalar-prefetched — drives block fetches).
    coords_rel: (T, P, N) int32; values: (T, P) f32.
    """
    n = len(factors)
    t, p, _ = coords_rel.shape
    rank = factors[0].shape[1]
    input_modes = tuple(m for m in range(n) if m != mode)
    s_out = chunk_shape[mode]

    kernel = functools.partial(_kernel, mode, input_modes, chunk_shape, p)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, p, n), lambda i, tc: (i, 0, 0)),
            pl.BlockSpec((1, p), lambda i, tc: (i, 0)),
            *[
                pl.BlockSpec(
                    (chunk_shape[m], rank),
                    # Data-dependent fetch: which factor block this task needs.
                    functools.partial(lambda i, tc, m=m: (tc[i, m], 0)),
                )
                for m in input_modes
            ],
        ],
        out_specs=pl.BlockSpec((1, s_out, rank), lambda i, tc: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, s_out, rank), jnp.float32),
        interpret=interpret,
    )(task_chunk, coords_rel, values, *[factors[m] for m in input_modes])
