"""jit'd public wrappers around the Pallas MTTKRP kernels.

Handles TPU-friendly padding (factor rows to whole chunks, rank to the
128-lane boundary when compiling for real hardware) and the final global
sum reduction, then unpads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .mttkrp_fixed_kernel import mttkrp_fixed_pallas_local
from .mttkrp_kernel import mttkrp_pallas_local

__all__ = ["mttkrp_pallas", "mttkrp_fixed_pallas", "pad_factor"]

LANE = 128


def pad_factor(f, chunk: int, *, rank_multiple: int = 1):
    """Pad rows to a whole number of chunks and rank to `rank_multiple`."""
    rows, rank = f.shape
    rpad = (-rows) % chunk
    cpad = (-rank) % rank_multiple
    if rpad or cpad:
        f = jnp.pad(f, ((0, rpad), (0, cpad)))
    return f


def mttkrp_pallas(
    factors, task_chunk, coords_rel, values, *,
    mode: int, chunk_shape: tuple[int, ...], out_dim: int,
    interpret: bool = False, rank_multiple: int = 1,
):
    """Chunked spMTTKRP via the Pallas kernel.  Returns (out_dim, R) f32."""
    rank = factors[0].shape[1]
    padded = tuple(
        pad_factor(f, chunk_shape[m], rank_multiple=rank_multiple)
        for m, f in enumerate(factors)
    )
    local = mttkrp_pallas_local(
        padded, task_chunk, coords_rel, values,
        mode=mode, chunk_shape=chunk_shape, interpret=interpret)
    out_pad = -(-out_dim // chunk_shape[mode]) * chunk_shape[mode]
    out = ref.reduce_local(local, task_chunk, mode=mode,
                           chunk_shape=chunk_shape, out_dim=out_pad)
    return out[:out_dim, :rank]


def mttkrp_fixed_pallas(
    qfactors, task_chunk, coords_rel, qvalues, *,
    mode: int, chunk_shape: tuple[int, ...], out_dim: int,
    matrix_frac: int, value_frac: int, prec_shift: int = 0,
    interpret: bool = False, rank_multiple: int = 1,
):
    """Fixed-point chunked spMTTKRP.  Returns (out_dim, R) int32 partial sums
    in Q(·, matrix_frac - prec_shift)."""
    rank = qfactors[0].shape[1]
    padded = tuple(
        pad_factor(f, chunk_shape[m], rank_multiple=rank_multiple)
        for m, f in enumerate(qfactors)
    )
    local = mttkrp_fixed_pallas_local(
        padded, task_chunk, coords_rel, qvalues,
        mode=mode, chunk_shape=chunk_shape,
        matrix_frac=matrix_frac, value_frac=value_frac, prec_shift=prec_shift,
        interpret=interpret)
    out_pad = -(-out_dim // chunk_shape[mode]) * chunk_shape[mode]
    out = ref.reduce_local(local, task_chunk, mode=mode,
                           chunk_shape=chunk_shape, out_dim=out_pad)
    return out[:out_dim, :rank]
