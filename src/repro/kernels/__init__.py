"""Pallas TPU kernels for the PRISM spMTTKRP hot spot.

`mttkrp_kernel` / `mttkrp_fixed_kernel` hold the pallas_call bodies,
`ops` the jit'd public wrappers, `ref` the pure-jnp oracles.
"""
from .ops import mttkrp_fixed_pallas, mttkrp_pallas
