"""Pure-jnp oracles for the Pallas kernels (no pallas imports).

These mirror the kernels' *local* contract — per-task (T, S_mode, R) partial
blocks, before the global sum reduction — so allclose tests compare the
kernel body itself, not the surrounding scatter.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["mttkrp_local_ref", "mttkrp_fixed_local_ref", "reduce_local"]


@partial(jax.jit, static_argnames=("mode", "chunk_shape"))
def mttkrp_local_ref(factors, task_chunk, coords_rel, values, *,
                     mode: int, chunk_shape: tuple[int, ...]):
    """(T, S_mode, R) f32 per-task partials, gather/scatter formulation."""
    n = len(factors)
    rank = factors[0].shape[1]
    offsets = task_chunk * jnp.asarray(chunk_shape, dtype=jnp.int32)
    part = values[..., None].astype(jnp.float32)  # (T, P, 1)
    for m in range(n):
        if m == mode:
            continue
        idx = offsets[:, m][:, None] + coords_rel[:, :, m]  # (T, P)
        idx = jnp.minimum(idx, factors[m].shape[0] - 1)
        part = part * factors[m][idx]
    s_out = chunk_shape[mode]
    local = jnp.zeros((task_chunk.shape[0], s_out, rank), jnp.float32)
    return jax.vmap(lambda l, c, p: l.at[c].add(p, mode="drop"))(
        local, coords_rel[:, :, mode], part)


@partial(jax.jit, static_argnames=("mode", "chunk_shape", "matrix_frac",
                                   "value_frac", "prec_shift"))
def mttkrp_fixed_local_ref(qfactors, task_chunk, coords_rel, qvalues, *,
                           mode: int, chunk_shape: tuple[int, ...],
                           matrix_frac: int, value_frac: int,
                           prec_shift: int = 0):
    """(T, S_mode, R) int32 per-task partials, bit-exact Algorithm 2."""
    n = len(qfactors)
    rank = qfactors[0].shape[1]
    offsets = task_chunk * jnp.asarray(chunk_shape, dtype=jnp.int32)
    part = None
    for m in range(n):
        if m == mode:
            continue
        idx = offsets[:, m][:, None] + coords_rel[:, :, m]
        idx = jnp.minimum(idx, qfactors[m].shape[0] - 1)
        rows = qfactors[m][idx].astype(jnp.int32)
        part = (rows if part is None
                else jax.lax.shift_right_arithmetic(part * rows, matrix_frac))
    part = part * qvalues[..., None].astype(jnp.int32)
    part = jax.lax.shift_right_arithmetic(part, value_frac + prec_shift)
    s_out = chunk_shape[mode]
    local = jnp.zeros((task_chunk.shape[0], s_out, rank), jnp.int32)
    return jax.vmap(lambda l, c, p: l.at[c].add(p, mode="drop"))(
        local, coords_rel[:, :, mode], part)


@partial(jax.jit, static_argnames=("mode", "chunk_shape", "out_dim"))
def reduce_local(local, task_chunk, *, mode: int,
                 chunk_shape: tuple[int, ...], out_dim: int):
    """Global sum reduction of per-task partial blocks (paper's host step)."""
    rank = local.shape[-1]
    s_out = chunk_shape[mode]
    rows = task_chunk[:, mode][:, None] * s_out + jnp.arange(s_out)[None, :]
    out = jnp.zeros((out_dim, rank), local.dtype)
    return out.at[rows.reshape(-1)].add(local.reshape(-1, rank), mode="drop")
