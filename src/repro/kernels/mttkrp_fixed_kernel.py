"""Pallas TPU kernel for fixed-point chunked spMTTKRP (paper Algorithm 2).

Bit-exact port of the paper's DPU kernel to the MXU's integer pipeline:

  * factor gathers are one-hot int matmuls with int32 accumulation — exact,
    because a one-hot row selects a single int16/int32 element;
  * after every factor-factor multiply the partial is requantized with an
    arithmetic right shift by `matrix_frac` (Alg. 2 line 12);
  * the nonzero-value multiply is followed by `value_frac + prec_shift`
    shifts (Alg. 2 line 15) — prec_shift extends the representable range of
    the int32 sum reduction (paper uses 3 for Q17.15);
  * all products fit int32 because L-infinity normalization bounds factor
    magnitudes by 2^frac ≤ 2^15 (this is why the paper's formats work on a
    32-bit DPU, and why they port to the MXU int path unchanged).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["mttkrp_fixed_pallas_local"]


def _kernel(mode, input_modes, chunk_shape, matrix_frac, value_frac, prec_shift,
            tc_ref, coords_ref, values_ref, *refs):
    factor_refs, out_ref = refs[:-1], refs[-1]
    p = coords_ref.shape[1]

    part = None
    for j, m in enumerate(input_modes):
        s_m = chunk_shape[m]
        c = coords_ref[0, :, m]
        onehot = (c[:, None] == lax.broadcasted_iota(jnp.int32, (p, s_m), 1))
        rows = jnp.dot(
            onehot.astype(factor_refs[j].dtype), factor_refs[j][...],
            preferred_element_type=jnp.int32,
        )  # exact row select on the MXU int path
        if part is None:
            part = rows  # Alg. 2 line 9
        else:
            part = part * rows                      # line 11
            part = lax.shift_right_arithmetic(part, matrix_frac)  # line 12
    part = part * values_ref[0, :][:, None].astype(jnp.int32)      # line 14
    part = lax.shift_right_arithmetic(part, value_frac + prec_shift)  # line 15

    s_out = chunk_shape[mode]
    co = coords_ref[0, :, mode]
    oh_out = (lax.broadcasted_iota(jnp.int32, (s_out, p), 0) == co[None, :])
    out_ref[0] = jnp.dot(oh_out.astype(jnp.int32), part,
                         preferred_element_type=jnp.int32)  # line 16 (reduce)


@functools.partial(
    jax.jit,
    static_argnames=("mode", "chunk_shape", "matrix_frac", "value_frac",
                     "prec_shift", "interpret"))
def mttkrp_fixed_pallas_local(
    qfactors, task_chunk, coords_rel, qvalues, *,
    mode: int, chunk_shape: tuple[int, ...],
    matrix_frac: int, value_frac: int, prec_shift: int = 0,
    interpret: bool = False,
):
    """Fixed-point per-task partials: (T, S_mode, R) int32 in
    Q(·, matrix_frac - prec_shift).  qfactors are int16 (Q9.7) or int32
    (Q17.15); qvalues int16/int32.  Padded entries (value 0) contribute 0."""
    n = len(qfactors)
    t, p, _ = coords_rel.shape
    rank = qfactors[0].shape[1]
    input_modes = tuple(m for m in range(n) if m != mode)
    s_out = chunk_shape[mode]

    kernel = functools.partial(
        _kernel, mode, input_modes, chunk_shape,
        matrix_frac, value_frac, prec_shift)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, p, n), lambda i, tc: (i, 0, 0)),
            pl.BlockSpec((1, p), lambda i, tc: (i, 0)),
            *[
                pl.BlockSpec(
                    (chunk_shape[m], rank),
                    functools.partial(lambda i, tc, m=m: (tc[i, m], 0)),
                )
                for m in input_modes
            ],
        ],
        out_specs=pl.BlockSpec((1, s_out, rank), lambda i, tc: (i, 0, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, s_out, rank), jnp.int32),
        interpret=interpret,
    )(task_chunk, coords_rel, qvalues, *[qfactors[m] for m in input_modes])
