"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8,
head_dim=128) d_ff=24576 vocab=65536; Mamba:attention 7:1 interleave (one
attention layer per 8-layer Jamba block), MoE 16 experts top-2 every other
layer, no positional encoding (Mamba carries position).
[arXiv:2403.19887; hf]

Memory policy: bf16 params + 8-bit optimizer state (398B params would not
fit fp32 master + fp32 Adam in 256×16 GB; see DESIGN.md §4).

long_500k: RUN — 7/8 of layers are O(1)-state Mamba; the 9 attention layers
use sequence-sharded KV caches.
"""
from repro.models import LayerSpec, ModelConfig

_Md = LayerSpec(mixer="mamba", mlp="dense")
_Mm = LayerSpec(mixer="mamba", mlp="moe")
_Ad = LayerSpec(mixer="attn", attn_kind="global", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24576, vocab=65536,
        rope=False,
        pattern=(_Md, _Mm, _Md, _Mm, _Ad, _Mm, _Md, _Mm),
        n_experts=16, top_k=2, d_state=16,
        param_dtype="bfloat16", opt_8bit=True,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512,
        rope=False,
        pattern=(_Md, _Mm, _Ad, _Mm),
        n_experts=4, top_k=2, d_state=4,
        q_block=16, kv_block=32, supports_long_context=True,
    )
