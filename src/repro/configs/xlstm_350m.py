"""xlstm-350m [ssm] — 24L d_model=1024 4H vocab=50304; xLSTM[7:1] —
7 mLSTM blocks per 1 sLSTM block, no separate FFN (d_ff=0; the blocks carry
their own up/down projections).  [arXiv:2405.04517; unverified]

long_500k: RUN — recurrent O(1) state (this family is why the shape exists).
"""
from repro.models import LayerSpec, ModelConfig

_M = LayerSpec(mixer="mlstm", mlp="none")
_S = LayerSpec(mixer="slstm", mlp="none")


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4, head_dim=256,
        d_ff=0, vocab=50304, rope=False,
        pattern=(_M, _M, _M, _M, _M, _M, _M, _S),
        tie_embeddings=True, supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m-smoke", family="ssm",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=0, vocab=512, rope=False,
        pattern=(_M, _S),
        tie_embeddings=True, supports_long_context=True,
    )
