"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4, head_dim=128)
d_ff=768/expert vocab=151936; 128 experts top-8, qk_norm.
[hf:Qwen/Qwen3-30B-A3B; hf]

long_500k: SKIP — pure full attention.
"""
from repro.models import LayerSpec, ModelConfig

_G = LayerSpec(mixer="attn", attn_kind="global", mlp="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=768, vocab=151936,
        qk_norm=True, rope_theta=1_000_000.0,
        pattern=(_G,), n_experts=128, top_k=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab=512,
        qk_norm=True, pattern=(_G,), n_experts=8, top_k=2,
        q_block=16, kv_block=32,
    )
