"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8, head_dim=128)
d_ff=17408 vocab=151936; qk_norm, SwiGLU, no bias.  [hf:Qwen/Qwen3-14B; hf]

long_500k: SKIP — pure full attention (noted in DESIGN.md §5).
"""
from repro.models import LayerSpec, ModelConfig

_G = LayerSpec(mixer="attn", attn_kind="global", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=17408, vocab=151936,
        qk_norm=True, rope_theta=1_000_000.0,
        pattern=(_G,), mlp_act="silu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        qk_norm=True, pattern=(_G,), mlp_act="silu",
        q_block=16, kv_block=32,
    )
