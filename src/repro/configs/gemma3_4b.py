"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4, head_dim=256)
d_ff=10240 vocab=262144; 5:1 local(1024):global interleave, GeGLU, tied +
scaled embeddings.  [hf:google/gemma-3-*-pt; unverified]

long_500k: RUN — 5/6 of layers are window-1024 local; global layers use the
blocked attention + sequence-sharded KV (DESIGN.md §5).
"""
from repro.models import LayerSpec, ModelConfig

_L = LayerSpec(mixer="attn", attn_kind="local", mlp="dense")
_G = LayerSpec(mixer="attn", attn_kind="global", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b", family="dense",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=10240, vocab=262144,
        window=1024, qk_norm=True, rope_theta=1_000_000.0,
        pattern=(_L, _L, _L, _L, _L, _G),
        mlp_act="geglu", tie_embeddings=True, scale_embed=True,
        final_softcap=30.0, supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b-smoke", family="dense",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        window=16, qk_norm=True,
        pattern=(_L, _G),
        mlp_act="geglu", tie_embeddings=True, scale_embed=True,
        final_softcap=30.0, q_block=16, kv_block=32,
        supports_long_context=True,
    )
