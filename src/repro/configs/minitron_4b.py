"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8, head_dim=128)
d_ff=9216 vocab=256000; pruned Nemotron: squared-ReLU MLP, no gating.
[arXiv:2407.14679; hf]

long_500k: SKIP — pure full attention.
"""
from repro.models import LayerSpec, ModelConfig

_G = LayerSpec(mixer="attn", attn_kind="global", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b", family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
        d_ff=9216, vocab=256000,
        rope_theta=10000.0, pattern=(_G,), mlp_act="relu2",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(_G,), mlp_act="relu2", q_block=16, kv_block=32,
    )
