"""Assigned architecture configs.  `get_config(name)` returns the exact
published config; `get_smoke_config(name)` a reduced same-family config for
CPU smoke tests."""
from __future__ import annotations

import importlib

ARCHS = [
    "gemma3_4b",
    "qwen3_14b",
    "minitron_4b",
    "command_r_35b",
    "llama4_scout_17b_a16e",
    "qwen3_moe_30b_a3b",
    "xlstm_350m",
    "whisper_medium",
    "internvl2_1b",
    "jamba_1_5_large_398b",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def _mod(name: str):
    name = ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _mod(name).config()


def get_smoke_config(name: str):
    return _mod(name).smoke_config()
