"""command-r-35b [dense] — 40L d_model=8192 64H (GQA kv=8, head_dim=128)
d_ff=22528 vocab=256000; no biases.  [hf:CohereForAI/c4ai-command-r-v01;
unverified]  (Cohere uses a parallel attn+FFN block; we keep the sequential
pre-norm form — a noted simplification, parameter shapes identical.)

long_500k: SKIP — pure full attention.
"""
from repro.models import LayerSpec, ModelConfig

_G = LayerSpec(mixer="attn", attn_kind="global", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b", family="dense",
        n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=22528, vocab=256000,
        rope_theta=8_000_000.0, pattern=(_G,), mlp_act="silu",
        tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-35b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
        d_ff=160, vocab=512,
        pattern=(_G,), mlp_act="silu", tie_embeddings=True,
        q_block=16, kv_block=32,
    )
