"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2, head_dim=64)
d_ff=4864 vocab=151655; Qwen2-0.5B text backbone + InternViT patch frontend
as a STUB (input_specs provides 256 precomputed patch embeddings projected
into the LM width).  [arXiv:2404.16821; hf]

long_500k: SKIP — pure full attention.
"""
from repro.models import LayerSpec, ModelConfig

_G = LayerSpec(mixer="attn", attn_kind="global", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab=151655,
        bias=True, rope_theta=1_000_000.0,
        pattern=(_G,), mlp_act="silu",
        n_image_tokens=256, tie_embeddings=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
        bias=True, pattern=(_G,), mlp_act="silu",
        n_image_tokens=8, tie_embeddings=True, q_block=16, kv_block=32,
    )
