"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8,
head_dim=128) d_ff=8192/expert vocab=202048; MoE 16 experts top-1 + 1 shared
expert every layer; iRoPE: chunked-local attention (8192) with a NoPE global
layer every 4th.  [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

long_500k: RUN — 3/4 of layers are chunk-8192 local.
"""
from repro.models import LayerSpec, ModelConfig

_C = LayerSpec(mixer="attn", attn_kind="chunked", mlp="moe")
_G = LayerSpec(mixer="attn", attn_kind="global", mlp="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab=202048,
        chunk_attn=8192, nope_global=True, rope_theta=500_000.0,
        pattern=(_C, _C, _C, _G),
        n_experts=16, top_k=1, n_shared_experts=1,
        supports_long_context=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke", family="moe",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=512,
        chunk_attn=32, nope_global=True,
        pattern=(_C, _G),
        n_experts=4, top_k=1, n_shared_experts=1,
        q_block=16, kv_block=32, supports_long_context=True,
    )
