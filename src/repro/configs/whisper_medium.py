"""whisper-medium [audio] — enc-dec, 24+24L d_model=1024 16H (MHA, head_dim=64)
d_ff=4096 vocab=51865; conv frontend is a STUB (input_specs provides
precomputed frame embeddings), plain GELU MLP.  [arXiv:2212.04356; unverified]

Adaptations (DESIGN.md): RMSNorm instead of LayerNorm, RoPE on decoder
self-attn instead of learned positional embeddings (parameter-free; the stub
frame embeddings are assumed position-encoded).

Shapes: seq_len drives the ENCODER frame length; decoder length = seq/8.
long_500k: SKIP — full attention.  Decode runs (enc-dec has a decoder).
"""
from repro.models import LayerSpec, ModelConfig

_D = LayerSpec(mixer="attn", attn_kind="global", mlp="dense", causal=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab=51865,
        pattern=(_D,), mlp_act="gelu2",
        encoder_decoder=True, n_enc_layers=24, dec_ratio=8,
        audio_frontend=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=512,
        pattern=(_D,), mlp_act="gelu2",
        encoder_decoder=True, n_enc_layers=2, dec_ratio=4,
        audio_frontend=True, q_block=16, kv_block=32,
    )
