"""Declarative design-space sweep configuration.

The PRISM paper characterizes its (partitioning × format × execution)
design space *offline*, once per workload class (PAPER.md §V), so tuning
never shows up as a runtime cost.  This module is the declarative half of
that idea for this repo: a small schema — TOML file or plain dict —
enumerating a grid over

    (synthetic-tensor band × nnz × rank × chunk capacity)  cells
  × (format × execution × Qm.n preset)                     candidates

where each *cell* is one autotune workload (one `WorkloadKey` fingerprint)
and the candidate axes are tuned *within* the cell by the existing
`autotune_engine` probe machinery.  The runner (runner.py) executes every
cell and records the observations into a `TuningStore`; the report stage
(report.py) turns the filled store into a Pareto front.

TOML schema (every key under a single `[sweep]` table)::

    [sweep]
    name = "ci-pruned"
    ranks = [8]
    capacities = [0, 64]        # 0 means "partition decider chooses"
    candidates = ["ref", "chunked", "csf", "alto", "fixed:int7"]
    accuracy_budget = 0.2       # required when any candidate is lossy
    mem_bytes = 262144          # partition-decider budget (optional)
    warmup = 1
    reps = 2

    [[sweep.tensors]]
    name = "uniform-band"
    shape = [60, 50, 40]
    nnz = [2000, 4000]          # scalar or list — the nnz band
    distribution = "uniform"    # or "powerlaw"
    seed = 0

TOML has no null, so the capacity sentinel is ``0`` (an illegal real
capacity — `EngineContext` requires >= 1), mapped to None = "the Fig.-5
partition decider chooses".  `random_tensor` guarantees the *exact*
requested nnz, so a cell's workload fingerprint is computable from the
config alone — the runner's resume check never builds a tensor for a cell
the store already holds.

Parsing prefers the stdlib ``tomllib`` (3.11+) / ``tomli`` and falls back
to a deliberately small TOML-subset parser (`_toml_subset_loads`) covering
exactly the grammar above — scalar keys, flat arrays, `[table]` and
`[[array-of-tables]]` headers — so the sweep runs on the 3.10 hosts in the
CI matrix without adding a dependency.
"""
from __future__ import annotations

import dataclasses
import itertools
import json

from ..engine.registry import candidate_lossless, parse_candidate

__all__ = [
    "SweepCell",
    "SweepConfig",
    "SweepConfigError",
    "TensorBand",
    "load_config",
]

_DISTRIBUTIONS = ("uniform", "powerlaw")


class SweepConfigError(ValueError):
    """A sweep config that cannot mean what it says."""


@dataclasses.dataclass(frozen=True)
class TensorBand:
    """One synthetic-tensor family: a fixed (shape, distribution, seed)
    swept over an nnz band.  Each nnz in the band is its own grid cell —
    ALTO-style studies (PAPERS.md) show winners flip with nnz, so the band
    is enumerated, never interpolated."""

    name: str
    shape: tuple[int, ...]
    nnz: tuple[int, ...]
    distribution: str = "uniform"
    seed: int = 0

    def __post_init__(self):
        if not self.name:
            raise SweepConfigError("tensor band needs a non-empty name")
        if not self.shape or any(d < 1 for d in self.shape):
            raise SweepConfigError(
                f"tensor band {self.name!r}: shape must be positive dims "
                f"(got {self.shape})")
        if not self.nnz or any(n < 1 for n in self.nnz):
            raise SweepConfigError(
                f"tensor band {self.name!r}: nnz band must be positive "
                f"(got {self.nnz})")
        if self.distribution not in _DISTRIBUTIONS:
            raise SweepConfigError(
                f"tensor band {self.name!r}: unknown distribution "
                f"{self.distribution!r} (choose from {_DISTRIBUTIONS})")

    @classmethod
    def from_dict(cls, d: dict) -> TensorBand:
        d = dict(d)
        nnz = d.get("nnz")
        if isinstance(nnz, (int, float)):
            nnz = [nnz]
        try:
            return cls(
                name=str(d["name"]),
                shape=tuple(int(x) for x in d["shape"]),
                nnz=tuple(int(n) for n in nnz or ()),
                distribution=str(d.get("distribution", "uniform")),
                seed=int(d.get("seed", 0)),
            )
        except KeyError as e:
            raise SweepConfigError(
                f"tensor band is missing required key {e.args[0]!r} "
                f"(got keys {sorted(d)})") from None


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One grid cell = one autotune workload.  The candidate axes live
    inside the cell (the autotuner probes all of them per mode); the cell
    axes are what change the workload fingerprint."""

    band: TensorBand
    nnz: int
    rank: int
    capacity: int | None

    @property
    def label(self) -> str:
        cap = "auto" if self.capacity is None else str(self.capacity)
        return f"{self.band.name}/nnz={self.nnz}/rank={self.rank}/cap={cap}"


@dataclasses.dataclass(frozen=True)
class SweepConfig:
    """The full declared grid.  `cells()` enumerates the cross product in
    a deterministic order (band → nnz → rank → capacity), which is also
    the resume order."""

    name: str
    tensors: tuple[TensorBand, ...]
    ranks: tuple[int, ...]
    candidates: tuple[str, ...]
    capacities: tuple[int | None, ...] = (None,)
    accuracy_budget: float | None = None
    mem_bytes: int = 256 * 1024
    warmup: int = 1
    reps: int = 2

    def __post_init__(self):
        if not self.tensors:
            raise SweepConfigError("sweep declares no tensor bands")
        if not self.ranks or any(r < 1 for r in self.ranks):
            raise SweepConfigError(
                f"ranks must be positive (got {self.ranks})")
        if not self.candidates:
            raise SweepConfigError("sweep declares no candidates")
        for c in self.candidates:
            try:
                parse_candidate(c)
            except ValueError as e:
                raise SweepConfigError(f"bad candidate id {c!r}: {e}") from None
        lossy = [c for c in self.candidates if not candidate_lossless(c)]
        if lossy and self.accuracy_budget is None:
            raise SweepConfigError(
                f"candidates {lossy} are lossy but the sweep declares no "
                "accuracy_budget — format is an accuracy choice, and the "
                "tuner only makes it against a declared error budget")
        if self.accuracy_budget is not None and not self.accuracy_budget > 0:
            raise SweepConfigError(
                f"accuracy_budget must be > 0 (got {self.accuracy_budget})")
        for cap in self.capacities:
            if cap is not None and cap < 1:
                raise SweepConfigError(
                    f"capacity must be >= 1, or 0/None for the partition "
                    f"decider (got {cap})")
        if self.warmup < 0 or self.reps < 1:
            raise SweepConfigError(
                f"need warmup >= 0 and reps >= 1 (got warmup={self.warmup}, "
                f"reps={self.reps})")

    def cells(self) -> list[SweepCell]:
        return [
            SweepCell(band=band, nnz=nnz, rank=rank, capacity=cap)
            for band, rank, cap in itertools.product(
                self.tensors, self.ranks, self.capacities)
            for nnz in band.nnz
        ]

    @classmethod
    def from_dict(cls, d: dict) -> SweepConfig:
        d = dict(d.get("sweep", d))  # accept the [sweep] wrapper or the body
        caps = d.get("capacities", [0])
        budget = d.get("accuracy_budget")
        try:
            return cls(
                name=str(d.get("name", "sweep")),
                tensors=tuple(TensorBand.from_dict(t)
                              for t in d.get("tensors", ())),
                ranks=tuple(int(r) for r in d.get("ranks", ())),
                candidates=tuple(str(c) for c in d.get("candidates", ())),
                # TOML has no null: 0 is the "partition decider" sentinel.
                capacities=tuple(None if int(c) == 0 else int(c)
                                 for c in caps),
                accuracy_budget=float(budget) if budget is not None else None,
                mem_bytes=int(d.get("mem_bytes", 256 * 1024)),
                warmup=int(d.get("warmup", 1)),
                reps=int(d.get("reps", 2)),
            )
        except (TypeError, ValueError) as e:
            if isinstance(e, SweepConfigError):
                raise
            raise SweepConfigError(f"malformed sweep config: {e}") from None


# ---------------------------------------------------------------------------
# TOML loading, with a subset fallback for pythons without tomllib.
# ---------------------------------------------------------------------------

def _strip_comment(line: str) -> str:
    """Drop a trailing ``# comment``, respecting double-quoted strings."""
    out, in_str = [], False
    for ch in line:
        if ch == '"':
            in_str = not in_str
        elif ch == "#" and not in_str:
            break
        out.append(ch)
    return "".join(out).strip()


def _toml_scalar(tok: str, lineno: int):
    if tok.startswith("[") and tok.endswith("]"):
        inner = tok[1:-1].strip()
        if not inner:
            return []
        return [_toml_scalar(t.strip(), lineno) for t in inner.split(",")
                if t.strip()]
    if len(tok) >= 2 and tok[0] == '"' and tok[-1] == '"':
        return tok[1:-1]
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        raise SweepConfigError(
            f"TOML-subset parser: unsupported value {tok!r} on line "
            f"{lineno} (supported: int, float, bool, \"string\", flat "
            "arrays thereof)") from None


def _descend(root: dict, path: list[str]) -> dict:
    node = root
    for k in path:
        node = node.setdefault(k, {})
        if isinstance(node, list):  # array-of-tables: descend into newest
            node = node[-1]
    return node


def _toml_subset_loads(text: str) -> dict:
    """Parse the TOML subset the sweep schema needs: ``key = value`` with
    int/float/bool/string/flat-array values, ``[a.b]`` table headers and
    ``[[a.b]]`` array-of-tables headers, comments.  Multiline arrays,
    inline tables, escapes and dates are out of scope — `load_config`
    prefers the real ``tomllib`` whenever the interpreter has one."""
    root: dict = {}
    current = root
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = _strip_comment(raw)
        if not line:
            continue
        if line.startswith("[["):
            if not line.endswith("]]"):
                raise SweepConfigError(
                    f"TOML-subset parser: bad table header on line {lineno}: "
                    f"{raw.strip()!r}")
            path = [p.strip() for p in line[2:-2].strip().split(".")]
            parent = _descend(root, path[:-1])
            arr = parent.setdefault(path[-1], [])
            if not isinstance(arr, list):
                raise SweepConfigError(
                    f"line {lineno}: {path[-1]!r} is both a table and an "
                    "array of tables")
            current = {}
            arr.append(current)
        elif line.startswith("["):
            if not line.endswith("]"):
                raise SweepConfigError(
                    f"TOML-subset parser: bad table header on line {lineno}: "
                    f"{raw.strip()!r}")
            path = [p.strip() for p in line[1:-1].strip().split(".")]
            current = _descend(root, path)
        else:
            key, sep, val = line.partition("=")
            if not sep or not key.strip():
                raise SweepConfigError(
                    f"TOML-subset parser: expected `key = value` on line "
                    f"{lineno}: {raw.strip()!r}")
            current[key.strip().strip('"')] = _toml_scalar(val.strip(), lineno)
    return root


def _load_toml(path: str) -> dict:
    try:
        import tomllib
    except ImportError:
        try:
            import tomli as tomllib
        except ImportError:
            tomllib = None
    if tomllib is not None:
        with open(path, "rb") as f:
            return tomllib.load(f)
    with open(path, encoding="utf-8") as f:
        return _toml_subset_loads(f.read())


def load_config(path: str) -> SweepConfig:
    """Load a sweep config from a ``.toml`` (or ``.json``) file."""
    if str(path).endswith(".json"):
        with open(path, encoding="utf-8") as f:
            return SweepConfig.from_dict(json.load(f))
    return SweepConfig.from_dict(_load_toml(str(path)))
