"""Sweep runner: execute every declared grid cell through the autotuner.

Each `SweepCell` is one autotune workload.  The runner builds the cell's
synthetic tensor, runs the existing `autotune_engine` probe machinery over
the declared candidates with *elision and probe pruning off* — an offline
sweep wants the complete (candidate × mode) observation grid, not the
cheapest route to a winner — and lets the tuner record the measurements
into the shared `TuningStore`.

Resumability is fingerprint-native: `random_tensor` guarantees the exact
requested nnz, so a cell's `WorkloadKey` is computable from the config
alone (`cell_key`), and a cell whose key the store already holds is skipped
*before any tensor is built* — a killed sweep restarted against the same
store re-probes nothing it completed.  The store must be opened with
`nnz_tol=0` (the runner enforces it): adjacent nnz-band cells are
deliberate design points and must neither serve each other warm nor dedup
each other away.

`resume=False` is a true re-measure: the runner forgets every declared
cell's entry first, so each cell cold-starts and overwrites.
"""
from __future__ import annotations

import dataclasses
import math
import time

from ..core.sptensor import random_tensor
from ..engine.autotune import autotune_engine
from ..engine.persist import (
    TuningStore,
    WorkloadKey,
    device_fingerprint,
    device_fingerprint_id,
)
from ..engine.plan import PlanCache
from ..engine.registry import EngineContext
from ..engine.tunepolicy import TunePolicy
from ..formats.convert import FormatCache
from ..obs.tracing import span
from .config import SweepCell, SweepConfig

__all__ = ["CellOutcome", "SweepResult", "cell_key", "run_sweep"]


def cell_key(cell: SweepCell, config: SweepConfig) -> WorkloadKey:
    """The cell's workload fingerprint, computed WITHOUT building the
    tensor: `random_tensor` guarantees the exact requested nnz, so shape,
    nnz and density are known from the config alone.  Must stay field-for-
    field identical to what `autotune_engine` fingerprints after the build
    (`WorkloadKey.from_tensor`) — test_sweep.py locks the two together."""
    shape = tuple(int(d) for d in cell.band.shape)
    nnz = int(cell.nnz)
    return WorkloadKey(
        shape=shape,
        nnz=nnz,
        density=nnz / math.prod(shape),
        ndim=len(shape),
        rank=int(cell.rank),
        candidates=tuple(sorted(config.candidates)),
        device=tuple(sorted(device_fingerprint().items())),
        capacity=cell.capacity,
    )


@dataclasses.dataclass
class CellOutcome:
    """What happened to one grid cell this run.

    status — "measured"  probed cold and recorded;
             "complete"  resume skip: the store already held the cell;
             "warm"      the tuner itself answered from the store (exact
                         hit the resume check could not claim — kept
                         distinct so `--require-warm` audits stay honest);
             "failed"    every candidate failed, or the cell raised;
             "deferred"  not executed (past `max_cells` this run).
    """

    cell: str
    band: str
    nnz: int
    rank: int
    capacity: int | None
    status: str
    n_probes: int = 0
    winners: dict[int, str] = dataclasses.field(default_factory=dict)
    seconds: float = 0.0
    error: str | None = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SweepResult:
    """One `run_sweep` invocation's ledger (the store holds the data)."""

    config: str
    store_path: str
    device: str                      # device_fingerprint_id()
    outcomes: list[CellOutcome]

    @property
    def n_probes(self) -> int:
        return sum(o.n_probes for o in self.outcomes)

    def count(self, status: str) -> int:
        return sum(1 for o in self.outcomes if o.status == status)

    def to_json(self) -> dict:
        return {
            "config": self.config,
            "store": self.store_path,
            "device": self.device,
            "n_cells": len(self.outcomes),
            "n_probes": self.n_probes,
            "counts": {s: self.count(s)
                       for s in ("measured", "complete", "warm",
                                 "failed", "deferred")},
            "outcomes": [o.to_json() for o in self.outcomes],
        }


def _outcome(cell: SweepCell, status: str, **kw) -> CellOutcome:
    return CellOutcome(cell=cell.label, band=cell.band.name, nnz=cell.nnz,
                       rank=cell.rank, capacity=cell.capacity,
                       status=status, **kw)


def run_sweep(
    config: SweepConfig,
    store: TuningStore | str,
    *,
    resume: bool = True,
    max_cells: int | None = None,
    log=None,
) -> SweepResult:
    """Execute the grid, recording observations into `store`.

    resume    — skip cells whose fingerprint the store already holds (with
                a budget covering the config's).  False forgets every
                declared cell first and re-measures.
    max_cells — stop after executing this many cells (resume skips don't
                count); the rest report "deferred".  The knob CI's pruned
                grid and the kill-and-restart tests lean on.
    log       — optional callable (e.g. `print`) for per-cell progress.
    """
    if not isinstance(store, TuningStore):
        store = TuningStore(store, nnz_tol=0.0)
    if store.nnz_tol != 0.0:
        raise ValueError(
            f"sweep stores need nnz_tol=0 (got {store.nnz_tol}): nnz-band "
            "grid cells are deliberate design points, and a near-match "
            "tolerance would let them warm-serve and supersede each other")
    log = log or (lambda _msg: None)
    cells = config.cells()

    if not resume:
        forgot = sum(store.forget(cell_key(c, config), save=False)
                     for c in cells)
        if forgot:
            store.save()
            log(f"forgot {forgot} existing cell entr"
                f"{'y' if forgot == 1 else 'ies'} (resume off)")

    outcomes: list[CellOutcome] = []
    executed = 0
    for cell in cells:
        key = cell_key(cell, config)
        if resume:
            entry = store.lookup(key, nnz_tol=0.0,
                                 budget=config.accuracy_budget)
            if entry is not None:
                outcomes.append(_outcome(cell, "complete",
                                         winners=dict(entry.winners)))
                log(f"[skip] {cell.label}: already in store")
                continue
        if max_cells is not None and executed >= max_cells:
            outcomes.append(_outcome(cell, "deferred"))
            continue
        executed += 1
        t0 = time.perf_counter()
        # The cell span carries the cell's fingerprint fields, and every
        # probe/decision span the tuner emits for this cell nests under it
        # — a sweep trace is attributable cell-by-cell.
        cell_sp = span("sweep.cell", cell=cell.label, band=cell.band.name,
                       shape=list(cell.band.shape), nnz=int(cell.nnz),
                       rank=int(cell.rank), capacity=cell.capacity,
                       fingerprint=key.fingerprint())
        try:
            with cell_sp:
                st = random_tensor(cell.band.shape, cell.nnz,
                                   distribution=cell.band.distribution,
                                   seed=cell.band.seed)
                # Fresh per-cell caches: chunk plans and format layouts are
                # shared across this cell's candidates but must not pin
                # every swept tensor in memory for the whole grid.
                ctx = EngineContext(st=st, rank=cell.rank,
                                    mem_bytes=config.mem_bytes,
                                    capacity=cell.capacity,
                                    plans=PlanCache(), formats=FormatCache())
                _engine, rep = autotune_engine(ctx, tune=TunePolicy(
                    candidates=tuple(config.candidates),
                    warmup=config.warmup, reps=config.reps,
                    store=store, prior="default",
                    # The sweep's whole point is the complete observation
                    # grid: no probe pruning, no cross-mode elision.
                    max_probes=None, elide=False,
                    accuracy_budget=config.accuracy_budget))
                cell_sp.set(status="warm" if rep.source == "persisted"
                            else "measured", probes=rep.n_probes)
        except Exception as e:  # blind by design: one broken cell must not kill the grid
            outcomes.append(_outcome(
                cell, "failed", seconds=time.perf_counter() - t0,
                error=f"{type(e).__name__}: {e}"))
            log(f"[FAIL] {cell.label}: {type(e).__name__}: {e}")
            continue
        status = "warm" if rep.source == "persisted" else "measured"
        outcomes.append(_outcome(
            cell, status, n_probes=rep.n_probes,
            winners=dict(rep.winners), seconds=time.perf_counter() - t0))
        log(f"[{status}] {cell.label}: probes={rep.n_probes} "
            f"winners={rep.chosen}")

    return SweepResult(config=config.name, store_path=store.path,
                       device=device_fingerprint_id(), outcomes=outcomes)
