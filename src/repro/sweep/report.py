"""Sweep report: Pareto front over (wall time, accuracy, index bytes).

The filled store IS the sweep's dataset — every (cell, candidate, mode)
timing, every measured error, every persisted `FormatStats`.  This module
flattens it into per-(cell, candidate) *points*:

    time_s        — summed per-mode best measured seconds
    rel_error     — worst measured per-mode MTTKRP relative error (0.0 for
                    a lossless candidate: bit-compatible with the COO
                    float reference up to reduction order)
    index_bytes   — resident index-structure footprint of the candidate's
                    layout, from `FormatStats` byte accounting (per-mode
                    CSF trees sum; ALTO holds one copy, falling back to
                    COO accounting past `MAX_KEY_BITS`)
    peak_fraction — roofline context from `repro.roofline`: the model's
                    step-time lower bound over the measured time, against
                    a host target whose peak matches benchmarks/fig7.py's
                    CPU estimate.  Context, not a ranking axis.

and marks the Pareto-efficient set per cell (minimize time, error, bytes
simultaneously): the points a deployer would ever pick, which is exactly
what a shipped warm store should steer dispatch toward.
"""
from __future__ import annotations

from ..engine.costmodel import default_prior
from ..engine.persist import TuningStore, device_fingerprint_id
from ..engine.registry import parse_candidate
from ..formats import MAX_KEY_BITS, FormatStats
from ..roofline.model import HWTarget, roofline_terms

__all__ = ["HOST_HW", "pareto_front", "pareto_report", "sweep_points"]

#: Roofline target for the CPU hosts the sweep actually runs on: peak flops
#: matches benchmarks/fig7.py's HOST_PEAK_FLOPS estimate, bandwidth the cost
#: model's sustained-stream guess.  Single host — no interconnect term.
HOST_HW = HWTarget("cpu-host-estimate", 48e9,
                   default_prior.bandwidth, default_prior.bandwidth)


def _flops(nnz: int, rank: int, ndim: int) -> float:
    """One MTTKRP mode: rank·(ndim-1) multiplies + rank adds + the scatter
    accumulate per nonzero — benchmarks/fig7.py's `mttkrp_flops` per mode."""
    return float(nnz) * rank * (ndim + 1.0)


def _resident_index_bytes(candidate: str, stats: FormatStats) -> float:
    """Index-structure footprint the candidate keeps resident: the Pareto
    memory axis.  Unknown/execution-only candidates consume the COO
    coordinate list."""
    base = candidate.partition(":")[0]
    ndim = len(stats.shape)
    if base == "csf":
        # One fiber tree per output mode — they all stay resident across
        # a CP-ALS iteration.
        return sum(stats.csf_index_bytes(m) for m in range(ndim))
    if base == "alto":
        # Past the packed-key width the builder falls back to COO
        # (docs/candidates.md#alto): account what actually gets built.
        if stats.key_bits <= MAX_KEY_BITS:
            return stats.alto_index_bytes()
        return stats.coo_index_bytes()
    return stats.coo_index_bytes()


def _mode_traffic_bytes(candidate: str, stats: FormatStats, mode: int,
                        rank: int) -> float:
    """Bytes one MTTKRP call of `mode` moves, for the roofline bound:
    index structure read once + f32 values + gathered input-factor rows +
    the output panel.  Deliberately the same flavour of first-order
    accounting as `benchmarks/fig7.py` — a lower bound, not a simulator."""
    ndim = len(stats.shape)
    base = candidate.partition(":")[0]
    if base == "csf":
        index = stats.csf_index_bytes(mode)
    elif base == "alto" and stats.key_bits <= MAX_KEY_BITS:
        index = stats.alto_index_bytes()
    else:
        index = stats.coo_index_bytes()
    values = 4.0 * stats.nnz
    gathers = 4.0 * stats.nnz * rank * (ndim - 1)
    out = 4.0 * stats.shape[mode] * rank
    return index + values + gathers + out


def sweep_points(store: TuningStore, *, hw: HWTarget = HOST_HW) -> list[dict]:
    """Flatten every stored entry into per-(cell, candidate) points.

    Entries from *every* device fingerprint in the store are reported —
    each point carries its short device id, and Pareto grouping keys on it,
    so a store merged across hosts never cross-compares timings measured on
    different silicon."""
    points: list[dict] = []
    for entry in store.entries():
        k = entry.key
        stats = (FormatStats.from_json(entry.format_stats)
                 if entry.format_stats is not None
                 else FormatStats.estimate(k.shape, k.nnz))
        dev = device_fingerprint_id(dict(k.device))
        cell = (f"{dev}/shape={'x'.join(map(str, k.shape))}/nnz={k.nnz}"
                f"/rank={k.rank}"
                f"/cap={'auto' if k.capacity is None else k.capacity}")
        for cand, per_mode in sorted(entry.timings.items()):
            if not per_mode:
                continue
            try:
                parse_candidate(cand)
            except ValueError:
                pass  # foreign/unregistered candidate: still reportable
            modes = sorted(per_mode)
            time_s = sum(per_mode[m] for m in modes)
            errs = entry.errors.get(cand, {})
            rel_error = max((errs[m] for m in errs), default=0.0)
            flops = sum(_flops(k.nnz, k.rank, k.ndim) for _ in modes)
            traffic = sum(_mode_traffic_bytes(cand, stats, m, k.rank)
                          for m in modes)
            roof = roofline_terms(flops, traffic, 0.0, hw=hw)
            bound = roof["step_time_lower_bound_s"]
            points.append({
                "cell": cell,
                "device": dev,
                "shape": list(k.shape),
                "nnz": k.nnz,
                "rank": k.rank,
                "capacity": k.capacity,
                "candidate": cand,
                "modes": modes,
                "winner_modes": sorted(m for m, w in entry.winners.items()
                                       if w == cand),
                "time_s": time_s,
                "rel_error": rel_error,
                "index_bytes": _resident_index_bytes(cand, stats),
                "roofline_bound_s": bound,
                "roofline_dominant": roof["dominant"],
                "peak_fraction": bound / time_s if time_s > 0 else 0.0,
                "budget": entry.budget,
            })
    points.sort(key=lambda p: (p["cell"], p["candidate"]))
    return points


def _dominates(a: dict, b: dict) -> bool:
    """a Pareto-dominates b: no worse on every minimized axis, strictly
    better on at least one."""
    axes = ("time_s", "rel_error", "index_bytes")
    return (all(a[x] <= b[x] for x in axes)
            and any(a[x] < b[x] for x in axes))


def pareto_front(points: list[dict]) -> list[dict]:
    """Mark each point's `pareto` flag (efficiency *within its cell* —
    cross-cell comparisons mix workloads) and return the efficient set."""
    by_cell: dict[str, list[dict]] = {}
    for p in points:
        by_cell.setdefault(p["cell"], []).append(p)
    front: list[dict] = []
    for group in by_cell.values():
        for p in group:
            p["pareto"] = not any(_dominates(q, p) for q in group if q is not p)
            if p["pareto"]:
                front.append(p)
    front.sort(key=lambda p: (p["cell"], p["time_s"]))
    return front


def pareto_report(store: TuningStore, *, hw: HWTarget = HOST_HW) -> dict:
    """The `--report` payload: every point plus the per-cell Pareto front."""
    points = sweep_points(store, hw=hw)
    front = pareto_front(points)
    return {
        "store": store.path,
        "device": device_fingerprint_id(),
        "hw": {"name": hw.name, "peak_flops": hw.peak_flops,
               "hbm_bw": hw.hbm_bw},
        "n_entries": len(store),
        "n_points": len(points),
        "n_pareto": len(front),
        "points": points,
        "front": front,
    }
