"""Offline design-space sweep harness (docs/tuning-pipeline.md#sweep).

The PRISM paper characterizes its design space offline, once per workload
class, so tuning never shows up as a runtime cost.  This package does the
same for this repo's (format × execution × preset × capacity × rank ×
tensor band) space: declare a grid (`config`), execute every cell through
the autotuner into a `TuningStore` (`runner` — resumable, concurrency-safe
via the store's advisory save lock), then ship the filled store so a
production cold start warm-hits instead of probing, and report the Pareto
front over (wall time, accuracy, index bytes) with roofline peak-fraction
context (`report`).

CLI: ``python -m benchmarks.sweep --config grid.toml --store store.json``.
"""
from __future__ import annotations

from .config import (
    SweepCell,
    SweepConfig,
    SweepConfigError,
    TensorBand,
    load_config,
)
from .report import HOST_HW, pareto_front, pareto_report, sweep_points
from .runner import CellOutcome, SweepResult, cell_key, run_sweep

__all__ = [
    "HOST_HW",
    "CellOutcome",
    "SweepCell",
    "SweepConfig",
    "SweepConfigError",
    "SweepResult",
    "TensorBand",
    "cell_key",
    "load_config",
    "pareto_front",
    "pareto_report",
    "run_sweep",
    "sweep_points",
]
