"""Collective-byte accounting from compiled HLO text.

`cost_analysis()` has no collective term, so we parse the SPMD module:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute contributes wire bytes per device computed with the
standard ring formulas from its result shape and replica-group size.
"""
from __future__ import annotations

import math
import re

__all__ = ["parse_collectives", "collective_bytes"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    """Sum byte sizes of every shape in a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [num_groups, group_size]<=[N...]
        return int(m.group(2))
    return 1


def parse_collectives(hlo_text: str) -> list[dict]:
    """Per-collective records: op, result bytes, group size, wire bytes/device."""
    out = []
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # async pair: count the -start only
        size = _type_bytes(type_str)
        g = _group_size(line)
        if op == "collective-permute":
            # permutes carry source_target_pairs, not replica_groups — the
            # payload always crosses a link once
            wire = float(size)
        elif g <= 1:
            wire = 0.0
        elif op == "all-gather":
            wire = size * (g - 1) / g
        elif op == "all-reduce":
            wire = 2.0 * size * (g - 1) / g
        elif op == "reduce-scatter":
            wire = size * (g - 1)          # result is already 1/g of input
        else:  # all-to-all
            wire = size * (g - 1) / g
        out.append(dict(op=op, bytes=size, group=g, wire_bytes=wire))
    return out


def collective_bytes(hlo_text: str) -> dict:
    recs = parse_collectives(hlo_text)
    by_op: dict[str, float] = {}
    for r in recs:
        by_op[r["op"]] = by_op.get(r["op"], 0.0) + r["wire_bytes"]
    return {
        "total_wire_bytes": sum(r["wire_bytes"] for r in recs),
        "count": len(recs),
        "by_op": by_op,
    }
