"""Three-term roofline model for TPU v5e (targets per the brief):
197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.

cost_analysis() of an SPMD module reports PER-DEVICE flops/bytes (verified
empirically in this repo), so each term divides by per-chip peaks directly.
"""
from __future__ import annotations

import dataclasses

__all__ = ["V5E", "roofline_terms", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HWTarget:
    name: str
    peak_flops: float   # per chip, bf16
    hbm_bw: float       # bytes/s per chip
    ici_bw: float       # bytes/s per link


V5E = HWTarget("tpu-v5e", 197e12, 819e9, 50e9)


def roofline_terms(per_device_flops: float, per_device_bytes: float,
                   per_device_wire_bytes: float, hw: HWTarget = V5E) -> dict:
    compute_s = per_device_flops / hw.peak_flops
    memory_s = per_device_bytes / hw.hbm_bw
    collective_s = per_device_wire_bytes / hw.ici_bw
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return dict(
        **terms,
        dominant=dominant,
        step_time_lower_bound_s=bound,
        roofline_fraction=(compute_s / bound) if bound > 0 else 0.0,
    )


def model_flops(n_params_active: float, tokens: float,
                kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for train (fwd+bwd), 2·N·D for inference."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens
