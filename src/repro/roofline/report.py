"""Render the §Roofline table in EXPERIMENTS.md from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> dict:
    if "skipped" in r:
        return dict(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                    status="SKIP (full attention)")
    ro = r["roofline"]
    return dict(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        compute_s=f"{ro['compute_s']:.3g}",
        memory_s=f"{ro['memory_s']:.3g}",
        coll_s=f"{ro['collective_s']:.3g}",
        dominant=ro["dominant"].replace("_s", ""),
        frac=f"{ro['roofline_fraction']:.3f}",
        useful=f"{min(r.get('useful_flops_ratio', 0), 99):.2f}",
        hbm_gb=f"{r['memory']['peak_estimate_bytes']/1e9:.1f}",
    )


COLS = ["arch", "shape", "mesh", "compute_s", "memory_s", "coll_s",
        "dominant", "frac", "useful", "hbm_gb", "status"]


def render(recs: list[dict], md: bool = False) -> str:
    rows = [fmt_row(r) for r in recs]
    cols = [c for c in COLS if any(c in r for r in rows)]
    w = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
         for c in cols}
    sep = " | " if md else " | "
    lines = []
    lines.append(sep.join(c.ljust(w[c]) for c in cols))
    if md:
        lines.insert(0, "| " + lines.pop(0) + " |")
        lines.append("|" + "|".join("-" * (w[c] + 2) for c in cols) + "|")
        lines[0], lines[1] = lines[0], lines[1]
        body = ["| " + sep.join(str(r.get(c, "")).ljust(w[c]) for c in cols)
                + " |" for r in rows]
        return "\n".join([lines[0], lines[1]] + body)
    lines.append("-+-".join("-" * w[c] for c in cols))
    lines += [sep.join(str(r.get(c, "")).ljust(w[c]) for c in cols)
              for r in rows]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load(args.dir)
    print(render(recs, md=args.md))
    done = [r for r in recs if "skipped" not in r]
    skipped = [r for r in recs if "skipped" in r]
    print(f"\n{len(done)} compiled cells, {len(skipped)} documented skips")


if __name__ == "__main__":
    main()
