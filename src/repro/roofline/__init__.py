from .hlo import collective_bytes, parse_collectives
from .model import V5E, roofline_terms
