from .hlo import collective_bytes, parse_collectives
from .model import roofline_terms, V5E
