"""Kernel shape contracts: dataflow rules over `kernels/` + `core/mttkrp.py`.

The public MTTKRP surface has one contract the whole stack leans on —
every variant returns `(dims[mode], rank)` — plus a set of internal
agreements no runtime test states explicitly: `segment_sum` calls must
pass the `num_segments`/`indices_are_sorted` the producing sort
guarantees, the Pallas one-hot matmuls must contract over the chunk
extent, and every BlockSpec block must evenly divide its operand (the
grid would silently read a ragged final block otherwise).

These rules pin that contract in `kernel_contracts.json` (mirroring
`schema_manifest.json`) and *prove* it per function with the
`dataflow.py` abstract interpreter, instantiating each pinned function
over a small case grid of (ndim, mode) so mode-rotation bugs (the
`chunk_shape[m]` vs `chunk_shape[mode]` class) can't hide behind a
symmetric case:

  kernel-contract-drift — the pinned signatures vs the live ASTs: a
      renamed kwarg, a new positional arg, a dropped `static_argnames`
      entry, or a vanished function fails until `--regen-contracts`
      re-pins it (making API drift a reviewed diff, like the persist
      schema).
  kernel-shape-contract — interpreter-derived return shape/dtype vs the
      pinned `(dims[mode], rank)` contract, broadcast/contraction
      mismatches found *inside* the bodies, dtype-demoting stores, and
      `segment_sum` call-site agreement with the pinned
      num_segments/sorted facts.
  pallas-blockspec — BlockSpec rank/divisibility vs the operands
      (including the `rank_multiple=128` lane-padding algebra: padded
      extents are `ceil(x, b)` symbols the divisibility check consumes),
      index_map arity vs grid rank + scalar-prefetch count, and operand
      count vs `in_specs`.

The contract cases deliberately pin `rank_multiple=128` for the Pallas
wrappers so the lane-padding path — the real-TPU ROADMAP precondition —
is the one proven, not the no-op default.
"""
from __future__ import annotations

import ast
import json
import re
from pathlib import Path

from . import dataflow as df
from .engine import Finding, ProjectContext, register_rule

__all__ = [
    "CONTRACT_CASES",
    "CONTRACT_MODULES",
    "check_kernel_contract_drift",
    "check_kernel_shape_contract",
    "check_pallas_blockspec",
    "contract_report",
    "extract_signature",
    "load_contracts",
    "regen_contracts",
]

_CONTRACTS = "src/repro/analysis/kernel_contracts.json"

#: The modules whose `__all__` functions the contract file pins.
CONTRACT_MODULES = (
    "src/repro/core/mttkrp.py",
    "src/repro/core/baselines.py",
    "src/repro/kernels/ops.py",
    "src/repro/kernels/mttkrp_kernel.py",
    "src/repro/kernels/mttkrp_fixed_kernel.py",
    "src/repro/kernels/ref.py",
)

#: (ndim, mode) instantiations every contracted function is proven over.
#: 3-mode covers every mode role (output / inner / mid); the 4-mode case
#: exercises the extra mid-factor multiply in the fixed Alg.-2 chain.
CONTRACT_CASES = ((3, 0), (3, 1), (3, 2), (4, 1))


# ---------------------------------------------------------------------------
# Signature pinning
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def extract_signature(fndef: ast.FunctionDef) -> dict:
    """Static signature fingerprint: arg names/order, kw-only set, which
    params carry defaults, vararg, and the jit/static_argnames wrapper —
    everything a caller can observe without running the function."""
    a = fndef.args
    jit = False
    static: list[str] = []
    for dec in fndef.decorator_list:
        if isinstance(dec, ast.Call):
            fn = _dotted(dec.func) or ""
            if fn.split(".")[-1] == "partial" and dec.args:
                inner = _dotted(dec.args[0]) or ""
                if inner.split(".")[-1] == "jit":
                    jit = True
                    for kw in dec.keywords:
                        if kw.arg == "static_argnames":
                            try:
                                v = ast.literal_eval(kw.value)
                            except ValueError:
                                continue
                            static = [v] if isinstance(v, str) else list(v)
            elif fn.split(".")[-1] == "jit":
                jit = True
        elif (_dotted(dec) or "").split(".")[-1] == "jit":
            jit = True
    return {
        "args": [p.arg for p in a.posonlyargs + a.args],
        "vararg": a.vararg.arg if a.vararg else None,
        "kwonly": [p.arg for p in a.kwonlyargs],
        "defaults": len(a.defaults),
        "kw_defaults": [p.arg for p, d in zip(a.kwonlyargs, a.kw_defaults)
                        if d is not None],
        "jit": jit,
        "static_argnames": static,
    }


def _module_all(tree: ast.Module) -> list[str]:
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    try:
                        return [str(n) for n in ast.literal_eval(node.value)]
                    except ValueError:
                        return []
    return []


def _module_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def load_contracts(root: Path) -> dict | None:
    """The pinned contracts, or None when missing/unparseable (the drift
    rule reports that; the shape rules just go quiet)."""
    p = Path(root) / _CONTRACTS
    if not p.is_file():
        return None
    try:
        data = json.loads(p.read_text(encoding="utf-8"))
    except json.JSONDecodeError:
        return None
    return data if isinstance(data, dict) else None


def regen_contracts(root: Path) -> dict:
    """Re-pin the signature blocks from the live ASTs, preserving the
    hand-written shape/segment-sum contracts of surviving functions and
    dropping entries for vanished ones — the intentional-drift workflow:
    change the API, run `python -m repro.analysis --regen-contracts`,
    review + commit the JSON diff (new functions arrive with
    `"params": null`, i.e. signature-pinned only, until someone writes
    their shape contract)."""
    root = Path(root)
    data = load_contracts(root) or {}
    old = data.get("functions", {})
    functions: dict[str, dict] = {}
    for rel in CONTRACT_MODULES:
        p = root / rel
        if not p.is_file():
            continue
        tree = ast.parse(p.read_text(encoding="utf-8"))
        defs = _module_functions(tree)
        for name in _module_all(tree):
            fndef = defs.get(name)
            if fndef is None:
                continue
            key = f"{rel}::{name}"
            entry = dict(old.get(key) or
                         {"params": None, "returns": None,
                          "segment_sums": None})
            entry["signature"] = extract_signature(fndef)
            functions[key] = entry
    out = {
        "modules": list(CONTRACT_MODULES),
        "functions": {k: functions[k] for k in sorted(functions)},
    }
    if "qformat" in data:
        out["qformat"] = data["qformat"]
    (root / _CONTRACTS).write_text(
        json.dumps(out, indent=2) + "\n", encoding="utf-8")
    return out


# ---------------------------------------------------------------------------
# Contract instantiation
# ---------------------------------------------------------------------------

_CEIL_RE = re.compile(r"^ceil\((.+),\s*(.+)\)$")


def _parse_dim(token, ndim: int, mode: int) -> df.Dim:
    """The contract shape grammar: ints, `N` (tensor order — concrete, it
    must broadcast against literal coordinate columns), `dim[mode]` /
    `S[mode]` (mode-indexed tensor extent / chunk size), `ceil(a,b)`
    (least multiple of b ≥ a — the padding algebra), or a named symbol
    from the per-case table (nnz, T, P, R, F, I0.., S0..)."""
    if isinstance(token, int):
        return df.Dim.const_(int(token))
    if token == "N":
        return df.Dim.const_(ndim)
    if token == "dim[mode]":
        return df.Dim.sym(f"I{mode}")
    if token == "S[mode]":
        return df.Dim.sym(f"S{mode}")
    m = _CEIL_RE.match(token)
    if m:
        return df.Dim.atom(df.CeilMul(_parse_dim(m.group(1), ndim, mode),
                                      _parse_dim(m.group(2), ndim, mode)))
    if token.strip().isdigit():
        return df.Dim.const_(int(token))
    return df.Dim.sym(token)


def _dtype(name: str) -> df.DType:
    dt = df.parse_dtype(name)
    if dt is None:
        raise ValueError(f"unknown dtype {name!r} in kernel contract")
    return df.canonicalize(dt)


def _alto_case_positions(ndim: int) -> tuple[tuple[int, ...], ...]:
    # Mode-major round-robin with 5 bits per mode (shape 32^ndim): every
    # position < 32, so the contract case packs into one key word.
    bits = 5
    return tuple(tuple(m + b * ndim for b in range(bits))
                 for m in range(ndim))


def _build_param(spec: dict, ndim: int, mode: int) -> df.AVal:
    kind = spec["kind"]
    if kind == "factors":
        dt = _dtype(spec.get("dtype", "float32"))
        return df.ATuple([
            df.AArray((df.Dim.sym(f"I{m}"), df.Dim.sym("R")), dt)
            for m in range(ndim)])
    if kind == "factors-padded":
        dt = _dtype(spec.get("dtype", "float32"))
        return df.ATuple([
            df.AArray((df.Dim.atom(df.CeilMul(df.Dim.sym(f"I{m}"),
                                              df.Dim.sym(f"S{m}"))),
                       df.Dim.sym("R")), dt)
            for m in range(ndim)])
    if kind == "array":
        dt = _dtype(spec.get("dtype", "float32"))
        shape = tuple(_parse_dim(t, ndim, mode) for t in spec["shape"])
        return df.AArray(shape, dt)
    if kind == "mode":
        return df.AConst(mode)
    if kind == "out-dim":
        return df.AInt(df.Dim.sym(f"I{mode}"))
    if kind == "dims":
        return df.ATuple([df.AInt(df.Dim.sym(f"S{m}")) for m in range(ndim)])
    if kind == "dim":
        return df.AInt(df.Dim.sym(spec["sym"]))
    if kind == "const":
        return df.AConst(spec["value"])
    if kind == "input-modes":
        return df.AConst(tuple(m for m in range(ndim) if m != mode))
    if kind == "inner-mode":
        return df.AConst(ndim - 1 if mode != ndim - 1 else 0)
    if kind == "mid-modes":
        inner = ndim - 1 if mode != ndim - 1 else 0
        return df.AConst(tuple(m for m in range(ndim)
                               if m not in (mode, inner)))
    if kind == "alto-positions":
        return df.AConst(_alto_case_positions(ndim))
    raise ValueError(f"unknown contract param kind {kind!r}")


def _instantiate(params: dict, sig_args: list[str], sig_kwonly: list[str],
                 ndim: int, mode: int) -> tuple[list, dict]:
    args: list[df.AVal] = []
    for name in sig_args:
        if name not in params:
            break
        args.append(_build_param(params[name], ndim, mode))
    kwargs = {name: _build_param(params[name], ndim, mode)
              for name in sig_kwonly if name in params}
    return args, kwargs


# ---------------------------------------------------------------------------
# The shared interpretation pass (computed once per ProjectContext)
# ---------------------------------------------------------------------------

def contract_report(ctx: ProjectContext) -> dict:
    """Interpret every contracted function over the case grid; cache on the
    context so the three rules consuming it share one pass.  Returns
    {"shape": [...], "pallas": [...]} of (rel, line, message) triples,
    deduplicated — symmetric cases produce identical messages."""
    cached = getattr(ctx, "_kernel_contract_report", None)
    if cached is not None:
        return cached
    shape: set[tuple] = set()
    pallas: set[tuple] = set()
    report = {"shape": shape, "pallas": pallas}
    contracts = load_contracts(ctx.root)
    if contracts is None:
        ctx._kernel_contract_report = report   # drift rule reports the why
        return report

    sources = {fc.rel: fc.source for fc in ctx.walk("src/repro")}
    program = df.Program(sources)

    for key, entry in contracts.get("functions", {}).items():
        params = entry.get("params")
        if params is None:
            continue
        rel, _, name = key.partition("::")
        module = program.module(rel)
        fndef = module.functions.get(name) if module else None
        sig = entry.get("signature") or {}
        if fndef is None or not sig:
            continue                           # drift rule owns these
        for ndim, mode in CONTRACT_CASES:
            interp = df.Interpreter(program)
            try:
                args, kwargs = _instantiate(
                    params, sig.get("args", []), sig.get("kwonly", []),
                    ndim, mode)
                result = interp.call_function(fndef, module, args, kwargs)
            except (ValueError, RecursionError):
                continue
            for p in interp.problems:
                dest = pallas if p.category == "pallas" else shape
                dest.add((p.rel or rel, p.line, p.message))
            _check_returns(entry, result, rel, fndef, ndim, mode, shape)
            _check_segment_sums(entry, interp.segment_sums, rel, fndef,
                                ndim, mode, shape)

    ctx._kernel_contract_report = report
    return report


def _check_returns(entry: dict, result: df.AVal, rel: str,
                   fndef: ast.FunctionDef, ndim: int, mode: int,
                   out: set) -> None:
    ret = entry.get("returns")
    if ret is None:
        return
    expected = tuple(_parse_dim(t, ndim, mode) for t in ret["shape"])
    want_dt = _dtype(ret["dtype"])
    if isinstance(result, df.AUnknown):
        return                                  # quiet on ignorance
    if not isinstance(result, df.AArray):
        out.add((rel, fndef.lineno,
                 f"{fndef.name} is contracted to return an array but the "
                 f"interpreter derives {type(result).__name__}"))
        return
    if len(result.shape) != len(expected):
        out.add((rel, fndef.lineno,
                 f"{fndef.name} returns rank {len(result.shape)} "
                 f"({_fmt(result.shape)}) but the contract pins rank "
                 f"{len(expected)} ({_fmt(expected)})"))
        return
    for i, (got, want) in enumerate(zip(result.shape, expected)):
        if got.has_opaque or want.has_opaque:
            continue
        if got != want:
            out.add((rel, fndef.lineno,
                     f"{fndef.name} return dim {i} is {got} but the "
                     f"contract pins {want}"))
    if result.dtype != want_dt:
        out.add((rel, fndef.lineno,
                 f"{fndef.name} returns dtype {result.dtype} but the "
                 f"contract pins {want_dt}"))


def _check_segment_sums(entry: dict, calls: list, rel: str,
                        fndef: ast.FunctionDef, ndim: int, mode: int,
                        out: set) -> None:
    specs = entry.get("segment_sums")
    if specs is None:
        return
    if len(calls) != len(specs):
        out.add((rel, fndef.lineno,
                 f"{fndef.name} is contracted to make {len(specs)} "
                 f"segment_sum call(s); the interpreter observed "
                 f"{len(calls)}"))
        return
    for i, (call, spec) in enumerate(zip(calls, specs)):
        want_ns = _parse_dim(spec["num_segments"], ndim, mode)
        if call.num_segments is None:
            out.add((call.rel or rel, call.line,
                     f"segment_sum call #{i} passes no num_segments; the "
                     f"contract pins {want_ns} (without it the output is "
                     "sized from the data — a silent shape change)"))
        elif not call.num_segments.has_opaque \
                and call.num_segments != want_ns:
            out.add((call.rel or rel, call.line,
                     f"segment_sum call #{i} passes num_segments="
                     f"{call.num_segments}; the contract pins {want_ns}"))
        if call.indices_are_sorted != bool(spec["sorted"]):
            out.add((call.rel or rel, call.line,
                     f"segment_sum call #{i} has indices_are_sorted="
                     f"{call.indices_are_sorted}; the contract pins "
                     f"{bool(spec['sorted'])} (the flag must match what "
                     "the producing sort guarantees — wrong either way: "
                     "silently wrong sums or a wasted sorted-path win)"))


def _fmt(shape: tuple) -> str:
    return "(" + ", ".join(str(d) for d in shape) + ")"


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

@register_rule(
    "kernel-contract-drift",
    scope="project",
    tier="dataflow",
    description=("public kernel signatures must match the pinned "
                 "analysis/kernel_contracts.json; drift without "
                 "--regen-contracts fails"),
    rationale=("every engine backend and benchmark calls this surface by "
               "keyword; a silent rename or a dropped static_argnames "
               "entry breaks callers (or retraces per call) with no test "
               "naming the contract — pinning makes API drift a reviewed "
               "JSON diff, exactly like the persist schema manifest"),
    example=("signature of mttkrp_chunked drifted from the pinned "
             "contract — run --regen-contracts"),
)
def check_kernel_contract_drift(ctx: ProjectContext):
    contracts = load_contracts(ctx.root)
    if contracts is None:
        yield ctx.finding(
            "kernel-contract-drift", _CONTRACTS, 1,
            "kernel_contracts.json is missing or unparseable — run "
            "`python -m repro.analysis --regen-contracts` and commit it")
        return
    pinned = contracts.get("functions", {})
    if list(contracts.get("modules", [])) != list(CONTRACT_MODULES):
        yield ctx.finding(
            "kernel-contract-drift", _CONTRACTS, 1,
            "pinned module list differs from shape_rules.CONTRACT_MODULES "
            "— run --regen-contracts")
    live: set[str] = set()
    for rel in CONTRACT_MODULES:
        fc = ctx.file(rel)
        if fc is None:
            yield ctx.finding(
                "kernel-contract-drift", _CONTRACTS, 1,
                f"contracted module {rel} is gone — update "
                "CONTRACT_MODULES and --regen-contracts")
            continue
        try:
            tree = fc.tree
        except SyntaxError:
            continue                            # syntax-error meta rule owns it
        defs = _module_functions(tree)
        for name in _module_all(tree):
            fndef = defs.get(name)
            if fndef is None:
                continue
            key = f"{rel}::{name}"
            live.add(key)
            entry = pinned.get(key)
            if entry is None:
                yield ctx.finding(
                    "kernel-contract-drift", rel, fndef.lineno,
                    f"public function {name} has no entry in "
                    "kernel_contracts.json — run --regen-contracts")
                continue
            if entry.get("signature") != extract_signature(fndef):
                yield ctx.finding(
                    "kernel-contract-drift", rel, fndef.lineno,
                    f"signature of {name} drifted from the pinned contract "
                    "— run --regen-contracts (and review the JSON diff)")
    for key in sorted(set(pinned) - live):
        yield ctx.finding(
            "kernel-contract-drift", _CONTRACTS, 1,
            f"pinned entry {key} matches no live public function — run "
            "--regen-contracts to drop it")


@register_rule(
    "kernel-shape-contract",
    scope="project",
    tier="dataflow",
    description=("abstract interpretation proves every contracted kernel "
                 "returns (dims[mode], rank) with the pinned dtype and "
                 "makes exactly the pinned segment_sum calls"),
    rationale=("the MTTKRP variants are interchangeable backends — the "
               "autotuner swaps them per mode, so a shape/dtype deviation "
               "or a wrong num_segments/indices_are_sorted in ONE variant "
               "corrupts results only for the workloads that pick it; "
               "symbolic interpretation over the (ndim, mode) case grid "
               "proves the contract without running a single kernel"),
    example=("segment_sum call #1 passes num_segments=F; the contract "
             "pins I1"),
)
def check_kernel_shape_contract(ctx: ProjectContext):
    for rel, line, message in sorted(contract_report(ctx)["shape"]):
        yield ctx.finding("kernel-shape-contract", rel, line, message)


@register_rule(
    "pallas-blockspec",
    scope="project",
    tier="dataflow",
    description=("Pallas BlockSpecs must divide their operands evenly, "
                 "index_maps must match grid rank + scalar prefetch, and "
                 "operand count must match in_specs"),
    rationale=("interpret=True masks all of this today; on real TPU "
               "(ROADMAP) a non-dividing block or a short index_map is a "
               "compile error at best and silent garbage at worst — the "
               "padded-extent algebra (rows to whole chunks, rank to the "
               "128-lane boundary) is exactly what the divisibility proof "
               "consumes"),
    example=("BlockSpec in_spec dim 0: block size S1 does not evenly "
             "divide operand dim I1"),
)
def check_pallas_blockspec(ctx: ProjectContext):
    for rel, line, message in sorted(contract_report(ctx)["pallas"]):
        yield ctx.finding("pallas-blockspec", rel, line, message)
