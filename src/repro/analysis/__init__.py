"""Repo-aware static-analysis pass suite.

Two tiers of rules over one AST engine (`engine.py`, a rule registry
mirroring `repro.engine.registry`):

**Syntactic tier** — cheap per-node passes:

- **JAX tracing hygiene** (`jax_rules.py`) — retrace hazards, host-device
  syncs, tracer leakage, nondeterminism in the kernel/engine hot paths;
  the pre-flight the ROADMAP's TPU `interpret=False` item needs before
  real hardware makes these bugs expensive.
- **Cross-module invariants** (`invariant_rules.py`) — persist-schema
  manifest pinning, byte-term arity vs the NNLS design matrix, registry ↔
  docs anchor agreement, import-graph orphans + seed-scaffolding
  quarantine.

**Dataflow tier** — abstract interpretation (`dataflow.py`: symbolic
shape lattice with the padding/divisibility algebra, jnp x64-off dtype
promotion):

- **Kernel shape contracts** (`shape_rules.py`) — every public MTTKRP
  variant is proven to return `(dims[mode], rank)` over an (ndim, mode)
  case grid, segment_sum `num_segments`/`indices_are_sorted` agreement,
  Pallas BlockSpec divisibility + index_map arity; the public surface is
  pinned in `kernel_contracts.json` (`--regen-contracts` to re-pin).
- **Integer widths** (`width_rules.py`) — unguarded int64→int32 index
  narrowing at the host/device seam, ALTO key word-geometry agreement
  across modules, fixed-point accumulator overflow bounds re-derived
  from the QFormat preset table.

Run it::

    python -m repro.analysis [--strict] [--format json|sarif]
    python -m repro.analysis --tier syntactic      # the fast pass
    python -m repro.analysis --tier dataflow
    python -m repro.analysis --list-rules
    python -m repro.analysis --baseline FILE       # fail only on findings
                                                   # newer than the baseline
    python -m repro.analysis --regen-manifest      # after an intentional
                                                   # _SCHEMA_VERSION bump
    python -m repro.analysis --regen-contracts     # after an intentional
                                                   # kernel API change

Suppress a finding in place, with a reason::

    x = float(y)  # repro-lint: disable=host-sync -- timing readout, cold path

See docs/static-analysis.md for the rule catalog and how to add a rule.
"""
from __future__ import annotations

from . import invariant_rules, jax_rules  # imported for side effect: register the rules
from . import shape_rules, width_rules  # noqa: F401  (dataflow-tier rules)
from .docanchors import extract_anchor_refs, extract_anchors
from .engine import (
    AnalysisResult,
    FileContext,
    Finding,
    ProjectContext,
    RuleSpec,
    Suppression,
    check_source,
    default_root,
    get_rule,
    register_rule,
    registered_rules,
    rule_table,
    run_analysis,
)
from .invariant_rules import extract_schema, regen_manifest
from .sarif import to_sarif
from .shape_rules import load_contracts, regen_contracts

__all__ = [
    "AnalysisResult",
    "FileContext",
    "Finding",
    "ProjectContext",
    "RuleSpec",
    "Suppression",
    "check_source",
    "default_root",
    "extract_anchor_refs",
    "extract_anchors",
    "extract_schema",
    "get_rule",
    "load_contracts",
    "regen_contracts",
    "regen_manifest",
    "register_rule",
    "registered_rules",
    "rule_table",
    "run_analysis",
    "to_sarif",
]
