"""Repo-aware static-analysis pass suite.

Two rule families over one AST engine (`engine.py`, a rule registry
mirroring `repro.engine.registry`):

- **JAX tracing hygiene** (`jax_rules.py`) — retrace hazards, host-device
  syncs, tracer leakage, nondeterminism in the kernel/engine hot paths;
  the pre-flight the ROADMAP's TPU `interpret=False` item needs before
  real hardware makes these bugs expensive.
- **Cross-module invariants** (`invariant_rules.py`) — persist-schema
  manifest pinning, byte-term arity vs the NNLS design matrix, registry ↔
  docs anchor agreement, import-graph orphans + seed-scaffolding
  quarantine.

Run it::

    python -m repro.analysis [--strict] [--json]   # CI: --strict --json
    python -m repro.analysis --list-rules
    python -m repro.analysis --regen-manifest      # after an intentional
                                                   # _SCHEMA_VERSION bump

Suppress a finding in place, with a reason::

    x = float(y)  # repro-lint: disable=host-sync -- timing readout, cold path

See docs/static-analysis.md for the rule catalog and how to add a rule.
"""
from __future__ import annotations

from . import invariant_rules, jax_rules  # imported for side effect: register the rules
from .docanchors import extract_anchor_refs, extract_anchors
from .engine import (
    AnalysisResult,
    FileContext,
    Finding,
    ProjectContext,
    RuleSpec,
    Suppression,
    check_source,
    default_root,
    get_rule,
    register_rule,
    registered_rules,
    rule_table,
    run_analysis,
)
from .invariant_rules import extract_schema, regen_manifest

__all__ = [
    "AnalysisResult",
    "FileContext",
    "Finding",
    "ProjectContext",
    "RuleSpec",
    "Suppression",
    "check_source",
    "default_root",
    "extract_anchor_refs",
    "extract_anchors",
    "extract_schema",
    "get_rule",
    "regen_manifest",
    "register_rule",
    "registered_rules",
    "rule_table",
    "run_analysis",
]
