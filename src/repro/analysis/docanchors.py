"""Markdown anchor extraction shared by the registry-docs rule and its
round-trip test.

`docs/candidates.md` pins one `<a id="..."></a>` anchor per registered
backend, preset, and format; `engine.registry.backend_table` /
`formats.format_table` emit `[...](docs/candidates.md#anchor)` links into
README and the docs.  The registry-docs rule cross-checks the three — so
this parser is the single definition of "what counts as an anchor", and
`tests/test_doc_anchors.py` proves it round-trips what the table
generators emit (doc regeneration can't silently break the rule).
"""
from __future__ import annotations

import re

__all__ = ["extract_anchor_refs", "extract_anchors"]

#: `<a id="name"></a>` — the explicit-anchor idiom candidates.md uses
#: (GitHub keeps these stable across heading edits, unlike slugs).
_ANCHOR_RE = re.compile(r'<a\s+id="(?P<id>[^"]+)"\s*>\s*</a>')

#: `[text](target#fragment)` markdown links with a fragment.
_REF_RE = re.compile(r"\[[^\]\n]*\]\((?P<target>[^)#\s]*)#(?P<frag>[^)\s]+)\)")


def extract_anchors(markdown: str) -> dict[str, int]:
    """anchor id → first line it is defined on (1-based)."""
    anchors: dict[str, int] = {}
    for lineno, line in enumerate(markdown.splitlines(), start=1):
        for m in _ANCHOR_RE.finditer(line):
            anchors.setdefault(m.group("id"), lineno)
    return anchors


def extract_anchor_refs(markdown: str) -> list[tuple[str, str, int]]:
    """Every `[..](target#fragment)` link as (target, fragment, line).

    `target` is the path part before `#` ("" for same-document links) —
    callers filter on it before resolving fragments against a file's
    anchor set.
    """
    refs: list[tuple[str, str, int]] = []
    for lineno, line in enumerate(markdown.splitlines(), start=1):
        refs.extend((m.group("target"), m.group("frag"), lineno)
                    for m in _REF_RE.finditer(line))
    return refs
