"""Cross-module invariant rules.

Each pass statically extracts facts from two or more modules and
cross-checks them — the drift classes PR 2–6 fixed by hand and a review
would have to re-derive every time:

  schema-manifest   — persist.py's dataclass field sets vs the pinned
                      `analysis/schema_manifest.json` fingerprint: a field
                      change without a `_SCHEMA_VERSION` bump fails (the
                      v4→v5 bump was manual; a miss silently corrupts
                      warm-store lookups).
  byte-terms-arity  — costmodel's `byte_terms` component count vs every
                      arity-typed constant in calibrate's NNLS (design
                      columns, theta slices, coefficient unpack): a 6th
                      term added on one side mis-fits every coefficient
                      without any error.
  registry-docs     — every registered backend/format/preset id parses via
                      `parse_candidate` and owns a `docs/candidates.md`
                      anchor; every link the table generators emit
                      resolves.
  import-orphans    — modules unreachable from `repro/__init__`, tests/,
                      and benchmarks/ (with the configs package's dynamic
                      `importlib.import_module(f"repro.configs.{name}")`
                      edge modeled), plus the quarantine invariant: product
                      packages must not import the legacy LM-scaffolding
                      packages kept only for their seed tests.
"""
from __future__ import annotations

import ast
import json
import re
from collections.abc import Iterator
from pathlib import Path

from .docanchors import extract_anchor_refs, extract_anchors
from .engine import Finding, ProjectContext, register_rule

__all__ = [
    "PRODUCT_PACKAGES",
    "QUARANTINED_PACKAGES",
    "SCHEMA_CLASSES",
    "check_byte_terms_arity",
    "check_import_orphans",
    "check_registry_docs",
    "check_schema_manifest",
    "extract_schema",
    "regen_manifest",
]

_PERSIST = "src/repro/engine/persist.py"
_COSTMODEL = "src/repro/engine/costmodel.py"
_CALIBRATE = "src/repro/engine/calibrate.py"
_CANDIDATES_DOC = "docs/candidates.md"
_MANIFEST = "src/repro/analysis/schema_manifest.json"

#: The persisted-schema types whose field sets the manifest pins — the
#: shapes `TuningStore` serializes (see docs/store-schema.md).
SCHEMA_CLASSES = ("WorkloadKey", "StoredEntry", "Observation")


def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# schema-manifest
# ---------------------------------------------------------------------------

def extract_schema(source: str) -> dict:
    """Static fingerprint of persist.py's schema surface: the declared
    `_SCHEMA_VERSION` and, per schema class, its ordered `field: annotation`
    pairs (order matters — `Observation` is a NamedTuple and `WorkloadKey`
    feeds positional construction in tests)."""
    tree = ast.parse(source)
    version = None
    classes: dict[str, list[str]] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if (isinstance(t, ast.Name) and t.id == "_SCHEMA_VERSION"
                        and isinstance(node.value, ast.Constant)):
                    version = node.value.value
        elif isinstance(node, ast.ClassDef) and node.name in SCHEMA_CLASSES:
            fields = [
                f"{stmt.target.id}: {ast.unparse(stmt.annotation)}"
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            ]
            classes[node.name] = fields
    return {"schema_version": version, "classes": classes}


def regen_manifest(root: Path) -> dict:
    """Regenerate `analysis/schema_manifest.json` from the live persist.py
    — the intentional-bump workflow: bump `_SCHEMA_VERSION`, run
    `python -m repro.analysis --regen-manifest`, commit both."""
    source = (Path(root) / _PERSIST).read_text(encoding="utf-8")
    manifest = extract_schema(source)
    out = Path(root) / _MANIFEST
    out.write_text(json.dumps(manifest, indent=2) + "\n", encoding="utf-8")
    return manifest


@register_rule(
    "schema-manifest",
    scope="project",
    description=("persist.py schema dataclass field sets must match the "
                 "pinned analysis/schema_manifest.json, and any change must "
                 "arrive with a _SCHEMA_VERSION bump"),
    rationale=("the v4→v5 capacity field was added by hand-bumping the "
               "version; forgetting the bump makes old stores deserialize "
               "into the new shape with silently-wrong warm lookups — this "
               "rule turns that miss into a commit-time failure"),
    example=("WorkloadKey fields changed (added: ['layout: str']) but "
             "_SCHEMA_VERSION is still 5"),
)
def check_schema_manifest(ctx: ProjectContext) -> Iterator[Finding]:
    fc = ctx.file(_PERSIST)
    if fc is None:
        yield ctx.finding("schema-manifest", _PERSIST, 1,
                          "persist.py not found — update the rule if the "
                          "schema moved")
        return
    live = extract_schema(fc.source)
    manifest_path = ctx.root / _MANIFEST
    if not manifest_path.is_file():
        yield ctx.finding(
            "schema-manifest", _MANIFEST, 1,
            "schema manifest missing — run `python -m repro.analysis "
            "--regen-manifest` and commit it")
        return
    try:
        pinned = json.loads(manifest_path.read_text(encoding="utf-8"))
    except json.JSONDecodeError as e:
        yield ctx.finding("schema-manifest", _MANIFEST, 1,
                          f"schema manifest is not valid JSON: {e}")
        return

    live_v, pinned_v = live["schema_version"], pinned.get("schema_version")
    if live["classes"] == pinned.get("classes", {}):
        if live_v != pinned_v:
            yield ctx.finding(
                "schema-manifest", _PERSIST, 1,
                f"_SCHEMA_VERSION is {live_v} but the manifest pins "
                f"{pinned_v} with identical fields — regenerate the "
                "manifest (`--regen-manifest`) so the pin follows the bump")
        return

    for cls in SCHEMA_CLASSES:
        lf = live["classes"].get(cls, [])
        pf = pinned.get("classes", {}).get(cls, [])
        if lf == pf:
            continue
        added = [f for f in lf if f not in pf]
        removed = [f for f in pf if f not in lf]
        delta = []
        if added:
            delta.append(f"added {added}")
        if removed:
            delta.append(f"removed {removed}")
        if not delta:
            delta.append("reordered")
        if live_v == pinned_v:
            yield ctx.finding(
                "schema-manifest", _PERSIST, 1,
                f"{cls} fields changed ({'; '.join(delta)}) but "
                f"_SCHEMA_VERSION is still {live_v} — old stores would "
                "deserialize into the new shape silently; bump the version, "
                "extend _READABLE_VERSIONS/migration, then regenerate the "
                "manifest (`--regen-manifest`)")
        else:
            yield ctx.finding(
                "schema-manifest", _MANIFEST, 1,
                f"{cls} fields changed ({'; '.join(delta)}) and "
                f"_SCHEMA_VERSION moved {pinned_v}→{live_v} — regenerate "
                "the manifest (`--regen-manifest`) to pin the new schema")


# ---------------------------------------------------------------------------
# byte-terms-arity
# ---------------------------------------------------------------------------

def _annotation_arity(fn: ast.FunctionDef) -> int | None:
    """Element count of a `tuple[float, ...]` return annotation."""
    ann = fn.returns
    if (isinstance(ann, ast.Subscript)
            and _dotted(ann.value) in ("tuple", "Tuple")
            and isinstance(ann.slice, ast.Tuple)):
        return len(ann.slice.elts)
    return None


def _tuple_returns(fn: ast.FunctionDef) -> list[tuple[int, int]]:
    """(lineno, element count) for every literal-tuple return in `fn`,
    excluding nested defs."""
    out: list[tuple[int, int]] = []

    def visit(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                continue
            if (isinstance(child, ast.Return)
                    and isinstance(child.value, ast.Tuple)):
                out.append((child.lineno, len(child.value.elts)))
            visit(child)

    visit(fn)
    return out


def _calibrate_arity_sites(tree: ast.AST) -> list[tuple[int, int, str]]:
    """Every place calibrate.py hard-codes the byte-term arity, as
    (lineno, value, what):

      `N + len(backends)`   — design-matrix width / dispatch column base
      `theta[:N]`, `a[i,:N]`— coefficient/row slices
      `a0, …, aK = (… theta[:N])` — the sanitize unpack (count of targets)
    """
    sites: list[tuple[int, int, str]] = []
    for node in ast.walk(tree):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add)
                and isinstance(node.left, ast.Constant)
                and isinstance(node.left.value, int)
                and isinstance(node.right, ast.Call)
                and isinstance(node.right.func, ast.Name)
                and node.right.func.id == "len"):
            sites.append((node.lineno, node.left.value,
                          f"`{ast.unparse(node)}`"))
        elif isinstance(node, ast.Slice):
            if (node.lower is None and node.step is None
                    and isinstance(node.upper, ast.Constant)
                    and isinstance(node.upper.value, int)):
                sites.append((getattr(node.upper, "lineno", 0),
                              node.upper.value, "`[:N]` slice"))
        elif (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Tuple)
                and all(isinstance(t, ast.Name)
                        for t in node.targets[0].elts)
                and any(isinstance(s, ast.Slice)
                        for s in ast.walk(node.value))):
            names = [t.id for t in node.targets[0].elts]
            # Only the coefficient unpack (a0, a1, … pattern), not general
            # tuple assignments.
            if all(n.startswith("a") and n[1:].isdigit() for n in names):
                sites.append((node.lineno, len(names),
                              f"coefficient unpack `{', '.join(names)} = …`"))
    return sites


@register_rule(
    "byte-terms-arity",
    scope="project",
    description=("costmodel.byte_terms component count must equal every "
                 "arity constant in calibrate.py's NNLS (design columns, "
                 "theta slices, coefficient unpack) and every tuple "
                 "return in the byte models"),
    rationale=("a 6th byte term added in costmodel without widening the "
               "design matrix mis-fits every coefficient with no error "
               "anywhere — the fit just quietly learns garbage"),
    example=("calibrate.py:239 `5 + len(backends)` disagrees with "
             "byte_terms arity 6"),
)
def check_byte_terms_arity(ctx: ProjectContext) -> Iterator[Finding]:
    cm = ctx.file(_COSTMODEL)
    cal = ctx.file(_CALIBRATE)
    if cm is None or cal is None:
        missing = _COSTMODEL if cm is None else _CALIBRATE
        yield ctx.finding("byte-terms-arity", missing, 1,
                          "file not found — update the rule if the cost "
                          "model moved")
        return

    fns = {node.name: node for node in ast.walk(cm.tree)
           if isinstance(node, ast.FunctionDef)
           and node.name in ("byte_terms", "device_byte_terms")}
    if "byte_terms" not in fns:
        yield ctx.finding("byte-terms-arity", _COSTMODEL, 1,
                          "byte_terms() not found — update the rule if the "
                          "cost model was renamed")
        return
    arity = _annotation_arity(fns["byte_terms"])
    if arity is None:
        yield ctx.finding(
            "byte-terms-arity", _COSTMODEL, fns["byte_terms"].lineno,
            "byte_terms() has no `tuple[...]` return annotation — the "
            "annotation is the authoritative arity this rule pins; "
            "restore it")
        return

    for name, fn in fns.items():
        ann = _annotation_arity(fn)
        if ann is not None and ann != arity:
            yield ctx.finding(
                "byte-terms-arity", _COSTMODEL, fn.lineno,
                f"{name}() annotates arity {ann} but byte_terms() "
                f"declares {arity}")
        for lineno, n in _tuple_returns(fn):
            if n != arity:
                yield ctx.finding(
                    "byte-terms-arity", _COSTMODEL, lineno,
                    f"{name}() returns a {n}-tuple but the declared "
                    f"byte-term arity is {arity} — every byte model must "
                    "emit every component (pad with 0.0)")

    sites = _calibrate_arity_sites(cal.tree)
    if not sites:
        yield ctx.finding(
            "byte-terms-arity", _CALIBRATE, 1,
            "found no arity-typed constants (`N + len(..)`, `theta[:N]`) "
            "in calibrate.py — update the rule's extraction if the NNLS "
            "was restructured")
        return
    for lineno, value, what in sites:
        if value != arity:
            yield ctx.finding(
                "byte-terms-arity", _CALIBRATE, lineno,
                f"{what} uses arity {value} but costmodel.byte_terms "
                f"declares {arity} — widen the design matrix and the "
                "_sanitize unpack together with the byte model")


# ---------------------------------------------------------------------------
# registry-docs
# ---------------------------------------------------------------------------

@register_rule(
    "registry-docs",
    scope="project",
    description=("every registered backend/format/preset id must resolve "
                 "through parse_candidate and own a docs/candidates.md "
                 "anchor; every anchor link the capability tables emit "
                 "must resolve"),
    rationale=("the candidate-id grammar is user-facing API (store files, "
               "--only flags, sweep configs) — an id the docs can't anchor "
               "or the parser can't round-trip is a silent contract break"),
    example="backend 'blco' has no `<a id=\"blco\">` anchor in docs/candidates.md",
)
def check_registry_docs(ctx: ProjectContext) -> Iterator[Finding]:
    doc = ctx.root / _CANDIDATES_DOC
    if not doc.is_file():
        yield ctx.finding("registry-docs", _CANDIDATES_DOC, 1,
                          "docs/candidates.md missing — the candidate-id "
                          "grammar doc every registry anchor points at")
        return
    doc_text = doc.read_text(encoding="utf-8")
    anchors = extract_anchors(doc_text)

    try:
        from repro.engine.registry import (
            backend_table,
            parse_candidate,
            preset_candidates,
            registered_backends,
        )
        from repro.formats import format_table, registered_formats
    except Exception as e:  # pragma: no cover - import environment broken
        yield ctx.finding(
            "registry-docs", _CANDIDATES_DOC, 1,
            f"cannot import the live registries ({type(e).__name__}: {e}) "
            "— run the analysis with src/ on PYTHONPATH")
        return

    reg_py = "src/repro/engine/registry.py"
    for name, spec in sorted(registered_backends().items()):
        try:
            parsed, preset = parse_candidate(name)
        except Exception as e:
            yield ctx.finding(
                "registry-docs", reg_py, 1,
                f"registered backend {name!r} does not parse as a "
                f"candidate id: {e}")
            continue
        if (parsed, preset) != (name, None):
            yield ctx.finding(
                "registry-docs", reg_py, 1,
                f"parse_candidate({name!r}) round-trips to "
                f"({parsed!r}, {preset!r}) instead of ({name!r}, None)")
        if name not in anchors:
            yield ctx.finding(
                "registry-docs", _CANDIDATES_DOC, 1,
                f"backend {name!r} has no `<a id=\"{name}\">` anchor in "
                "docs/candidates.md — document it where backend_table "
                "links point")
        for preset_name in spec.presets:
            cand = f"{name}:{preset_name}"
            try:
                parsed, p = parse_candidate(cand)
            except Exception as e:
                yield ctx.finding(
                    "registry-docs", reg_py, 1,
                    f"preset candidate {cand!r} does not parse: {e}")
                continue
            if (parsed, p) != (name, preset_name):
                yield ctx.finding(
                    "registry-docs", reg_py, 1,
                    f"parse_candidate({cand!r}) round-trips to "
                    f"({parsed!r}, {p!r})")
            anchor = f"preset-{preset_name}"
            if anchor not in anchors:
                yield ctx.finding(
                    "registry-docs", _CANDIDATES_DOC, 1,
                    f"preset {cand!r} has no `<a id=\"{anchor}\">` anchor "
                    "in docs/candidates.md")

    for name in sorted(registered_formats()):
        if name not in anchors:
            yield ctx.finding(
                "registry-docs", _CANDIDATES_DOC, 1,
                f"format {name!r} has no `<a id=\"{name}\">` anchor in "
                "docs/candidates.md")

    # preset_candidates() must only emit parseable ids (the autotuner feeds
    # these straight into build_candidate / store keys).
    for cand in preset_candidates():
        try:
            parse_candidate(cand)
        except Exception as e:
            yield ctx.finding(
                "registry-docs", reg_py, 1,
                f"preset_candidates() emitted unparseable id {cand!r}: {e}")

    # Every anchor link the generated tables emit must resolve against the
    # doc — this is what breaks when someone renames an anchor by hand.
    for table_name, table in (("backend_table", backend_table()),
                              ("format_table", format_table())):
        for target, frag, _line in extract_anchor_refs(table):
            if target != _CANDIDATES_DOC:
                continue
            if frag not in anchors:
                yield ctx.finding(
                    "registry-docs", _CANDIDATES_DOC, 1,
                    f"{table_name}() links #{frag} which is not anchored "
                    "in docs/candidates.md")


# ---------------------------------------------------------------------------
# import-orphans
# ---------------------------------------------------------------------------

#: Packages that carry the product (the paper's system): these must form a
#: closed world — importing quarantined scaffolding from here would smuggle
#: the LM seed code back into the supported surface.
PRODUCT_PACKAGES = (
    "repro.analysis",
    "repro.batch",
    "repro.core",
    "repro.engine",
    "repro.formats",
    "repro.kernels",
    "repro.obs",
    "repro.serve",
    "repro.sweep",
)

#: Legacy LM-training scaffolding from the growth seed (transformer/MoE/SSM
#: model zoo, per-arch configs, optimizer/data/serving stack).  The seed
#: tests exercise it, so the import graph keeps it reachable — but it is
#: quarantined: no product package may import it, and nothing here is part
#: of the repro API (`repro/__init__` re-exports product modules only).
QUARANTINED_PACKAGES = (
    "repro.checkpoint",
    "repro.configs",
    "repro.data",
    "repro.launch.dryrun",
    "repro.launch.elastic",
    "repro.launch.shardings",
    "repro.launch.steps",
    "repro.models",
    "repro.optim",
)
# NOT quarantined: repro.launch.mesh (engine/backends.py uses its device-
# mesh compat shims for the distributed backend) and repro.roofline
# (sweep/report.py prices Pareto points against its peak-fraction model).


def _module_name(rel: str) -> str:
    """src/repro/a/b.py → repro.a.b ; src/repro/a/__init__.py → repro.a"""
    parts = Path(rel).with_suffix("").parts
    parts = parts[parts.index("repro"):]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _in_pkg(module: str, prefixes: tuple[str, ...]) -> bool:
    return any(module == p or module.startswith(p + ".") for p in prefixes)


_STR_IMPORT_RE = re.compile(
    r"^\s*(?:from\s+(repro[\w.]*)\s+import|import\s+(repro[\w.]*))",
    re.MULTILINE)


def _import_edges(tree: ast.AST, module: str, known: set[str]) -> set[str]:
    """Modules under `repro` that `module`'s source imports.  Handles
    absolute and relative imports, and models the two dynamic idioms in the
    tree: `importlib.import_module(f"repro.pkg.{name}")` imports everything
    under `repro.pkg`, and import statements inside string literals (the
    subprocess-exec'd code blocks tests/test_elastic.py drives child
    interpreters with) are scanned textually."""
    pkg_parts = module.split(".")
    edges: set[str] = set()

    def add(name: str) -> None:
        # Resolve to the closest known module: `from repro.engine import
        # build_engine` names an attr, not a module — strip trailing parts
        # until something in `known` matches.
        parts = name.split(".")
        while parts:
            cand = ".".join(parts)
            if cand in known:
                edges.add(cand)
                return
            parts = parts[:-1]

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[0] == "repro":
                    add(alias.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                # relative: level=1 is the containing package
                base = pkg_parts[:len(pkg_parts) - node.level + 1] \
                    if module in known and _is_pkg(module, known) \
                    else pkg_parts[:len(pkg_parts) - node.level]
                prefix = ".".join(base + ([node.module] if node.module else []))
            else:
                prefix = node.module or ""
            if prefix.split(".")[0] == "repro":
                add(prefix)
                for alias in node.names:
                    add(f"{prefix}.{alias.name}")
        elif (isinstance(node, ast.Call)
                and _dotted(node.func) in ("importlib.import_module",
                                           "import_module")
                and node.args and isinstance(node.args[0], ast.JoinedStr)):
            # f"repro.configs.{name}" → depends on all of repro.configs.*
            head = node.args[0].values[0] if node.args[0].values else None
            if isinstance(head, ast.Constant) and isinstance(head.value, str):
                # f"repro.configs.{name}" → the static prefix names the
                # package; a trailing partial segment (no dot) is dropped.
                prefix = (head.value.rstrip(".") if head.value.endswith(".")
                          else head.value.rsplit(".", 1)[0])
                if prefix.split(".")[0] == "repro":
                    edges.update(m for m in known
                                 if m == prefix or m.startswith(prefix + "."))
        elif (isinstance(node, ast.Constant) and isinstance(node.value, str)
                and "repro" in node.value and "import" in node.value):
            for m in _STR_IMPORT_RE.finditer(node.value):
                add(m.group(1) or m.group(2))
    edges.discard(module)
    return edges


def _is_pkg(module: str, known: set[str]) -> bool:
    return any(m.startswith(module + ".") for m in known)


@register_rule(
    "import-orphans",
    scope="project",
    description=("modules unreachable from repro/__init__, tests/, and "
                 "benchmarks/; plus quarantine enforcement — product "
                 "packages must not import the legacy LM seed scaffolding"),
    rationale=("orphans are unreviewed, untested dead weight that still "
               "costs grep time and import-cycle risk; the quarantine "
               "boundary keeps the seed's LM stack from silently becoming "
               "a load-bearing dependency of the paper's system"),
    example=("src/repro/launch/train.py (repro.launch.train) is unreachable "
             "from repro/__init__, tests/, benchmarks/"),
)
def check_import_orphans(ctx: ProjectContext) -> Iterator[Finding]:
    modules: dict[str, object] = {}
    for fc in ctx.walk("src/repro"):
        modules[_module_name(fc.rel)] = fc
    known = set(modules)

    edges: dict[str, set[str]] = {}
    for mod, fc in modules.items():
        try:
            edges[mod] = _import_edges(fc.tree, mod, known)
        except SyntaxError:
            edges[mod] = set()
        # Importing a submodule imports its ancestor packages (their
        # __init__ side effects run), and importing a package executes its
        # __init__ which may import siblings — model both directions the
        # interpreter actually takes.
        for dep in set(edges[mod]):
            parts = dep.split(".")
            for i in range(1, len(parts)):
                anc = ".".join(parts[:i])
                if anc in known:
                    edges[mod].add(anc)

    roots: set[str] = set()
    if "repro" in known:
        roots.add("repro")
    # `python -m repro.x` entrypoints are roots by construction: nothing
    # imports a __main__ module, it is invoked.
    roots |= {m for m in known if m.endswith(".__main__")}
    external_edges: set[str] = set()
    for fc in ctx.walk("tests", "benchmarks"):
        try:
            external_edges |= _import_edges(fc.tree, f"_ext.{fc.rel}", known)
        except SyntaxError:
            continue
    roots |= external_edges
    for dep in set(roots):
        parts = dep.split(".")
        for i in range(1, len(parts)):
            anc = ".".join(parts[:i])
            if anc in known:
                roots.add(anc)

    reachable: set[str] = set()
    stack = sorted(roots)
    while stack:
        mod = stack.pop()
        if mod in reachable:
            continue
        reachable.add(mod)
        stack.extend(edges.get(mod, ()))

    for mod in sorted(known - reachable):
        fc = modules[mod]
        yield ctx.finding(
            "import-orphans", fc.rel, 1,
            f"{mod} is unreachable from repro/__init__, tests/, and "
            "benchmarks/ — delete it or add it to the supported surface")

    # Quarantine invariant: no product module imports a quarantined one.
    for mod in sorted(known):
        if not _in_pkg(mod, PRODUCT_PACKAGES):
            continue
        bad = sorted(dep for dep in edges.get(mod, ())
                     if _in_pkg(dep, QUARANTINED_PACKAGES))
        for dep in bad:
            yield ctx.finding(
                "import-orphans", modules[mod].rel, 1,
                f"product module {mod} imports quarantined seed "
                f"scaffolding {dep} — the LM stack is kept only for its "
                "seed tests and must not become load-bearing (see "
                "docs/static-analysis.md#import-orphans)")
