"""Repo-aware static-analysis pass engine: rule registry + suppression +
reporting.

Six PRs in, the stack's correctness rests on invariants no stock linter
checks: the persist schema version must move with the persist dataclasses,
the cost model's byte-term arity must match the calibration design matrix,
and the kernel/engine hot paths must stay free of host-sync and jit-retrace
hazards before the real-TPU `interpret=False` path makes those bugs
expensive.  This engine is the seam the checks plug into — a rule registry
mirroring `repro.engine.registry`'s backend registry (same register/lookup/
table idiom), so adding a rule is one decorated function, and every
consumer (the `python -m repro.analysis` CLI, the tier-1 pytest gate, the
CI job) goes through one `run_analysis` API.

Rule kinds:

  file     — an AST pass over one Python file (`check(ctx: FileContext)`);
             the engine walks every file under the rule's declared
             `packages` prefixes (default: all of `src/repro`).
  project  — a whole-repo pass (`check(ctx: ProjectContext)`) for
             cross-module invariants: schema manifests, arity cross-checks,
             registry/docs agreement, import-graph reachability.
  meta     — engine-built-in checks (suppression hygiene); registered so
             their ids are documented and valid suppression targets, but
             the engine itself runs them.

Suppression — every finding can be waived *in the file it fires in*:

  x = float(y)  # repro-lint: disable=host-sync -- reason why this is fine
  # repro-lint: disable=host-sync -- applies to the NEXT line
  # repro-lint: disable-file=nondeterminism -- whole-file waiver

The rule-id list is comma-separated; the reason string after ``--`` (or an
em-dash, or ``:``) is required under ``--strict``, and ``--strict`` also
fails on suppressions naming unknown rule ids (stale disables left behind
by a rule rename) and on suppressions that no longer match any finding.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from collections.abc import Callable, Iterable
from pathlib import Path

__all__ = [
    "AnalysisResult",
    "FileContext",
    "Finding",
    "ProjectContext",
    "RuleSpec",
    "Suppression",
    "check_source",
    "default_root",
    "get_rule",
    "register_rule",
    "registered_rules",
    "rule_table",
    "run_analysis",
]

#: Repo-relative package prefixes the JAX-hygiene file rules default to —
#: the kernel/engine hot paths the TPU `interpret=False` ROADMAP item needs
#: clean (see ISSUE 7).  Rules can widen or narrow via `packages=`.
DEFAULT_FILE_TARGETS = ("src/repro",)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a (file, line)."""

    rule: str
    path: str                   # repo-relative, posix separators
    line: int
    message: str
    suppressed: bool = False
    reason: str | None = None   # suppression reason, when suppressed

    def to_json(self) -> dict:
        d = {"rule": self.rule, "path": self.path, "line": self.line,
             "message": self.message}
        if self.suppressed:
            d["suppressed"] = True
            d["reason"] = self.reason
        return d

    def render(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


@dataclasses.dataclass(frozen=True)
class RuleSpec:
    """Capability declaration for one registered analysis pass.

    scope      — "file" (per-file AST pass), "project" (whole-repo
                 invariant), or "meta" (engine-built-in).
    tier       — "syntactic" (cheap per-node passes, every PR) or
                 "dataflow" (abstract-interpretation passes; same CI job,
                 separate timed step).  Meta rules ignore tier.
    packages   — repo-relative path prefixes a file rule walks; () means
                 the engine default (`DEFAULT_FILE_TARGETS`).
    rationale  — why the rule exists (rendered into docs/static-analysis.md
                 by `rule_table`).
    example    — one illustrative finding message for the docs.
    """

    name: str
    check: Callable | None
    scope: str = "file"
    tier: str = "syntactic"
    packages: tuple[str, ...] = ()
    description: str = ""
    rationale: str = ""
    example: str = ""


_RULES: dict[str, RuleSpec] = {}

_ID_RE = re.compile(r"^[a-z][a-z0-9]*(-[a-z0-9]+)*$")


def register_rule(
    name: str,
    *,
    scope: str = "file",
    tier: str = "syntactic",
    packages: tuple[str, ...] = (),
    description: str = "",
    rationale: str = "",
    example: str = "",
):
    """Decorator registering a check under `name` (last wins, so tests and
    downstream code can override a rule — same policy as the backend
    registry)."""
    if not _ID_RE.match(name):
        raise ValueError(
            f"rule id {name!r} must be kebab-case ([a-z0-9-]) — ids appear "
            "in suppression comments and docs anchors")
    if scope not in ("file", "project", "meta"):
        raise ValueError(f"unknown rule scope {scope!r}")
    if tier not in ("syntactic", "dataflow"):
        raise ValueError(f"unknown rule tier {tier!r}")

    def deco(check: Callable | None) -> Callable | None:
        _RULES[name] = RuleSpec(
            name=name, check=check, scope=scope, tier=tier,
            packages=tuple(packages),
            description=description, rationale=rationale, example=example)
        return check
    return deco


def get_rule(name: str) -> RuleSpec:
    try:
        return _RULES[name]
    except KeyError:
        raise ValueError(
            f"unknown rule {name!r}; registered: {sorted(_RULES)}") from None


def registered_rules() -> dict[str, RuleSpec]:
    """Registered rules in id order (never registration order: the listing
    feeds docs and reports, which must not depend on import side-effect
    ordering)."""
    return {name: _RULES[name] for name in sorted(_RULES)}


def rule_table(docs_base: str | None = "docs/static-analysis.md") -> str:
    """Markdown catalog of the registered rules (used by the docs and
    `--list-rules`).  Each rule row anchors to its section of
    `docs/static-analysis.md`, mirroring `engine.registry.backend_table`;
    pass ``docs_base=None`` for plain terminal output."""
    def _name(n: str) -> str:
        return f"[`{n}`]({docs_base}#{n})" if docs_base else f"`{n}`"

    rows = [
        "| rule | scope | description |",
        "|------|-------|-------------|",
    ]
    for spec in registered_rules().values():
        rows.append(f"| {_name(spec.name)} | {spec.scope} | {spec.description} |")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*(?P<kind>disable-file|disable)\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_,\- ]+?)"
    r"(?:\s*(?:--|—|:)\s*(?P<reason>.*\S))?\s*$")


@dataclasses.dataclass(frozen=True)
class Suppression:
    """One parsed `# repro-lint:` comment."""

    path: str
    line: int
    rules: tuple[str, ...]
    scope: str               # "line" | "file"
    reason: str | None
    own_line: bool           # comment-only line: also covers the next line

    def covers(self, f: Finding) -> bool:
        if f.path != self.path or f.rule not in self.rules:
            return False
        if self.scope == "file":
            return True
        return f.line == self.line or (self.own_line and f.line == self.line + 1)


def parse_suppressions(source: str, rel: str) -> list[Suppression]:
    """Extract suppressions from real COMMENT tokens (a `# repro-lint:`
    inside a string literal must not waive anything)."""
    out: list[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(source).readline))
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return out
    line_has_code: dict[int, bool] = {}
    for tok in tokens:
        if tok.type in (tokenize.COMMENT, tokenize.NL, tokenize.NEWLINE,
                        tokenize.INDENT, tokenize.DEDENT, tokenize.ENDMARKER):
            continue
        for ln in range(tok.start[0], tok.end[0] + 1):
            line_has_code[ln] = True
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = tuple(sorted({r.strip() for r in m.group("rules").split(",")
                              if r.strip()}))
        if not rules:
            continue
        out.append(Suppression(
            path=rel, line=tok.start[0], rules=rules,
            scope="file" if m.group("kind") == "disable-file" else "line",
            reason=m.group("reason"),
            own_line=not line_has_code.get(tok.start[0], False)))
    return out


# ---------------------------------------------------------------------------
# Contexts
# ---------------------------------------------------------------------------

class FileContext:
    """One parsed source file, handed to file-scope rules."""

    def __init__(self, root: Path, path: Path):
        self.root = Path(root)
        self.path = Path(path)
        self.rel = self.path.relative_to(self.root).as_posix()
        self.source = self.path.read_text(encoding="utf-8")
        self._tree: ast.AST | None = None
        self._parse_error: SyntaxError | None = None

    @classmethod
    def from_source(cls, source: str, rel: str,
                    root: str | Path = ".") -> FileContext:
        """Build a context from an in-memory snippet (the fixture-test
        path) without touching the filesystem."""
        ctx = cls.__new__(cls)
        ctx.root = Path(root)
        ctx.path = Path(root) / rel
        ctx.rel = rel
        ctx.source = source
        ctx._tree = None
        ctx._parse_error = None
        return ctx

    @property
    def tree(self) -> ast.AST:
        if self._tree is None:
            self._tree = ast.parse(self.source, filename=self.rel)
        return self._tree

    def finding(self, rule: str, node: ast.AST | int, message: str) -> Finding:
        line = node if isinstance(node, int) else getattr(node, "lineno", 1)
        return Finding(rule=rule, path=self.rel, line=line, message=message)


class ProjectContext:
    """The whole repo, handed to project-scope rules."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self._files: dict[str, FileContext] = {}

    def file(self, rel: str) -> FileContext | None:
        """The parsed file at repo-relative `rel`, or None when absent —
        project rules degrade to a finding, not a crash, on a moved file."""
        if rel not in self._files:
            p = self.root / rel
            self._files[rel] = FileContext(self.root, p) if p.is_file() else None
        return self._files[rel]

    def walk(self, *prefixes: str) -> Iterable[FileContext]:
        """Every .py file under the repo-relative `prefixes`, sorted."""
        seen: set[str] = set()
        for prefix in prefixes:
            base = self.root / prefix
            if not base.exists():
                continue
            paths = [base] if base.is_file() else sorted(base.rglob("*.py"))
            for p in paths:
                rel = p.relative_to(self.root).as_posix()
                if rel not in seen:
                    seen.add(rel)
                    fc = self.file(rel)
                    if fc is not None:
                        yield fc

    def finding(self, rule: str, rel: str, line: int, message: str) -> Finding:
        return Finding(rule=rule, path=rel, line=line, message=message)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AnalysisResult:
    """Everything one `run_analysis` pass produced."""

    root: str
    n_files: int
    findings: list[Finding]              # active (unsuppressed), sorted
    suppressed: list[Finding]            # waived findings, with reasons
    unused_suppressions: list[Suppression]
    rules: tuple[str, ...]               # rule ids that ran
    strict: bool = False

    @property
    def ok(self) -> bool:
        return not self.findings

    def to_json(self) -> dict:
        return {
            "root": self.root,
            "strict": self.strict,
            "n_files": self.n_files,
            "rules": list(self.rules),
            "counts": {
                "active": len(self.findings),
                "suppressed": len(self.suppressed),
                "unused_suppressions": len(self.unused_suppressions),
            },
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [f.to_json() for f in self.suppressed],
            "unused_suppressions": [
                {"path": s.path, "line": s.line, "rules": list(s.rules),
                 "scope": s.scope, "reason": s.reason}
                for s in self.unused_suppressions],
        }

    def human(self) -> str:
        lines = [f.render() for f in self.findings]
        if self.suppressed:
            lines.append(f"-- {len(self.suppressed)} finding(s) suppressed:")
            lines.extend(
                f"   {f.path}:{f.line}: {f.rule} ({f.reason or 'no reason'})"
                for f in self.suppressed)
        verdict = "clean" if self.ok else f"{len(self.findings)} finding(s)"
        lines.append(
            f"repro.analysis: {verdict} over {self.n_files} file(s), "
            f"{len(self.rules)} rule(s)"
            + (" [strict]" if self.strict else ""))
        return "\n".join(lines)


def default_root() -> Path:
    """The repo root this installed `repro` package belongs to: the parent
    of the `src/` directory holding `repro/`.  Works for the editable /
    PYTHONPATH=src layouts this repo uses; pass an explicit root (CLI
    `--root`) for anything more exotic."""
    here = Path(__file__).resolve()          # .../src/repro/analysis/engine.py
    src = here.parents[2]                    # .../src
    return src.parent if src.name == "src" else src


def _sort_key(f: Finding) -> tuple:
    return (f.path, f.line, f.rule, f.message)


def _rule_targets(spec: RuleSpec) -> tuple[str, ...]:
    return spec.packages or DEFAULT_FILE_TARGETS


def run_analysis(
    root: str | os.PathLike | None = None,
    *,
    rules: Iterable[str] | None = None,
    tier: str = "all",
    strict: bool = False,
) -> AnalysisResult:
    """Run the registered passes over the repo at `root`.

    `rules` restricts to a subset of rule ids (meta checks always run);
    `tier` restricts to one rule tier ("syntactic" | "dataflow" | "all") so
    CI can time the cheap per-node passes and the abstract-interpretation
    passes as separate steps; `strict` additionally enforces suppression
    hygiene: unknown rule ids in suppression comments, suppressions without
    a reason string, and suppressions that no longer match any finding all
    become findings.
    """
    if tier not in ("syntactic", "dataflow", "all"):
        raise ValueError(f"unknown tier {tier!r}")
    root = Path(root) if root is not None else default_root()
    selected = (registered_rules() if rules is None
                else {n: get_rule(n) for n in rules})
    if tier != "all":
        selected = {n: s for n, s in selected.items()
                    if s.tier == tier or s.scope == "meta"}
    project = ProjectContext(root)

    raw: list[Finding] = []
    suppressions: list[Suppression] = []
    files_seen: set[str] = set()

    file_rules = [s for s in selected.values() if s.scope == "file"]
    targets: dict[str, list[RuleSpec]] = {}
    for spec in file_rules:
        for fc in project.walk(*_rule_targets(spec)):
            targets.setdefault(fc.rel, []).append(spec)

    for rel in sorted(targets):
        fc = project.file(rel)
        files_seen.add(rel)
        try:
            fc.tree
        except SyntaxError as e:
            raw.append(Finding(rule="syntax-error", path=rel,
                               line=e.lineno or 1,
                               message=f"file does not parse: {e.msg}"))
            continue
        suppressions.extend(parse_suppressions(fc.source, rel))
        for spec in targets[rel]:
            raw.extend(spec.check(fc))

    for spec in (s for s in selected.values() if s.scope == "project"):
        raw.extend(spec.check(project))
        # Project-rule findings land in files the file rules may not have
        # walked (docs, json manifests, …) — collect their suppressions too.
        for f in raw:
            if f.path not in files_seen and f.path.endswith(".py"):
                fc = project.file(f.path)
                if fc is not None:
                    files_seen.add(f.path)
                    suppressions.extend(parse_suppressions(fc.source, f.path))

    # -- suppression hygiene (meta rules) ----------------------------------
    known = set(_RULES) | {"syntax-error"}
    if strict:
        for s in suppressions:
            stale = [r for r in s.rules if r not in known]
            if stale:
                raw.append(Finding(
                    rule="unknown-suppression", path=s.path, line=s.line,
                    message=(f"suppression names unregistered rule id(s) "
                             f"{stale} — stale disable? registered ids: "
                             f"run `python -m repro.analysis --list-rules`")))
            if not s.reason:
                raw.append(Finding(
                    rule="suppression-missing-reason", path=s.path,
                    line=s.line,
                    message=("suppression has no reason string — append "
                             "`-- <why this is safe>`")))

    # -- apply suppressions ------------------------------------------------
    active: list[Finding] = []
    waived: list[Finding] = []
    used: set[Suppression] = set()
    for f in raw:
        hit = next((s for s in suppressions if s.covers(f)), None)
        if hit is not None and f.rule not in (
                "unknown-suppression", "suppression-missing-reason"):
            used.add(hit)
            waived.append(dataclasses.replace(
                f, suppressed=True, reason=hit.reason))
        else:
            active.append(f)

    unused = [s for s in suppressions if s not in used]
    if strict:
        for s in unused:
            # Only judge a waiver against rules that actually ran this
            # pass: under `--rules`/`--tier` subsets a suppression for an
            # unselected rule cannot match anything and is not stale.
            if not any(r in selected for r in s.rules):
                continue
            # A waiver matching nothing is a stale disable: either the code
            # was fixed (delete the comment) or the rule id drifted.
            active.append(Finding(
                rule="unused-suppression", path=s.path, line=s.line,
                message=(f"suppression for {list(s.rules)} matches no "
                         "finding — the waived code is gone; delete the "
                         "comment")))

    return AnalysisResult(
        root=str(root), n_files=len(files_seen),
        findings=sorted(active, key=_sort_key),
        suppressed=sorted(waived, key=_sort_key),
        unused_suppressions=sorted(unused, key=lambda s: (s.path, s.line)),
        rules=tuple(sorted(selected)), strict=strict)


def check_source(rule: str, source: str,
                 rel: str = "src/repro/core/fixture.py") -> list[Finding]:
    """Run one file rule over an in-memory snippet — the fixture-test
    entrypoint (`tests/test_analysis.py` proves every rule fires on its bad
    fixture and stays quiet on the good one)."""
    spec = get_rule(rule)
    if spec.scope != "file":
        raise ValueError(f"rule {rule!r} is {spec.scope}-scope; "
                         "check_source only drives file rules")
    ctx = FileContext.from_source(source, rel)
    findings = list(spec.check(ctx))
    sup = parse_suppressions(source, rel)
    return [f for f in findings if not any(s.covers(f) for s in sup)]


# -- meta rules: registered for documentation + suppression-id validity ----

register_rule(
    "unknown-suppression", scope="meta",
    description="a `# repro-lint:` comment names a rule id that is not registered",
    rationale=("a rule rename must not leave silent, stale disables behind "
               "— strict mode fails on them"),
    example="suppression names unregistered rule id(s) ['host-snyc']",
)(None)
register_rule(
    "suppression-missing-reason", scope="meta",
    description="a suppression comment carries no `-- reason` string",
    rationale=("a waiver without a recorded why cannot be audited when the "
               "TPU path makes these hazards expensive"),
    example="suppression has no reason string",
)(None)
register_rule(
    "unused-suppression", scope="meta",
    description="a suppression comment matches no finding (strict mode)",
    rationale="fixed code should drop its waiver, not fossilize it",
    example="suppression for ['host-sync'] matches no finding",
)(None)
register_rule(
    "syntax-error", scope="meta",
    description="a walked file does not parse",
    rationale="every other pass is meaningless on a broken tree",
    example="file does not parse: invalid syntax",
)(None)
