"""SARIF 2.1.0 reporter for the analysis suite.

SARIF (Static Analysis Results Interchange Format) is what GitHub code
scanning ingests: uploading one from CI turns every finding into an
inline PR annotation at the offending line, with the rule's description
and docs link attached — the same report the `--json`/human reporters
print, re-shaped to the OASIS schema.

Only active (unsuppressed) findings are emitted.  Suppressed findings
carry an in-tree waiver with a reason already; re-surfacing them as
annotations would just teach people to ignore the annotations.
"""
from __future__ import annotations

from .engine import AnalysisResult, registered_rules

__all__ = ["to_sarif"]

_SARIF_VERSION = "2.1.0"
_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/master/"
           "Schemata/sarif-schema-2.1.0.json")
_DOCS_BASE = "docs/static-analysis.md"


def to_sarif(result: AnalysisResult) -> dict:
    """Render one analysis pass as a single-run SARIF log."""
    specs = registered_rules()
    rule_ids = sorted({f.rule for f in result.findings} | set(result.rules))
    rules = []
    for rid in rule_ids:
        spec = specs.get(rid)
        rule: dict = {"id": rid}
        if spec is not None and spec.description:
            rule["shortDescription"] = {"text": spec.description}
            if spec.rationale:
                rule["fullDescription"] = {"text": spec.rationale}
        rule["helpUri"] = f"{_DOCS_BASE}#{rid}"
        rules.append(rule)
    index = {r["id"]: i for i, r in enumerate(rules)}

    results = []
    for f in result.findings:
        results.append({
            "ruleId": f.rule,
            "ruleIndex": index[f.rule],
            "level": "error",
            "message": {"text": f.message},
            "locations": [{
                "physicalLocation": {
                    "artifactLocation": {
                        "uri": f.path,
                        "uriBaseId": "SRCROOT",
                    },
                    "region": {"startLine": max(int(f.line), 1)},
                },
            }],
        })

    return {
        "$schema": _SCHEMA,
        "version": _SARIF_VERSION,
        "runs": [{
            "tool": {
                "driver": {
                    "name": "repro.analysis",
                    "informationUri": _DOCS_BASE,
                    "rules": rules,
                },
            },
            "originalUriBaseIds": {"SRCROOT": {"uri": "file:///"}},
            "results": results,
        }],
    }
