"""JAX tracing-hygiene rules.

These passes walk the kernel/engine hot paths (`src/repro/{kernels,core,
engine,formats}` by default) for the hazard classes that stay invisible
under `interpret=True` CPU runs but bite on real hardware (ROADMAP's
TPU `interpret=False` item): silent per-call retraces, host-device syncs
inside loops, tracers escaping a jitted scope, and nondeterministic seeds.

Every rule is a generator over `FileContext` yielding `Finding`s; the
fixture tests in `tests/test_analysis.py` hold one bad snippet (must fire)
and one good snippet (must stay quiet) per rule.
"""
from __future__ import annotations

import ast
from collections.abc import Iterator

from .engine import FileContext, Finding, register_rule

__all__ = [
    "check_dict_order",
    "check_host_sync",
    "check_nondeterminism",
    "check_retrace",
    "check_trace_in_jit",
    "check_tracer_leak",
]

JAX_TARGETS = (
    "src/repro/kernels",
    "src/repro/core",
    "src/repro/engine",
    "src/repro/formats",
    "src/repro/batch",
    "src/repro/serve",
    "src/repro/obs",
)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    """`a.b.c` → "a.b.c" for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _mentions_jax(node: ast.AST) -> bool:
    """Does the subtree reference jax/jnp/lax — i.e. plausibly produce a
    traced/device value?  Purely lexical: we cannot type-infer, so the
    host-sync rule only fires where the device-ness is visible in the
    expression itself (keeps the false-positive rate low enough for a
    zero-findings gate)."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in ("jnp", "jax", "lax"):
            return True
    return False


def _jit_decoration(fn: ast.FunctionDef | ast.AsyncFunctionDef):
    """(is_jitted, static_names, lineno) for a function's decorators.

    Recognizes `@jax.jit`, `@jit`, `@partial(jax.jit, static_argnums=…/
    static_argnames=…)` and `@functools.partial(...)`.  static_argnums are
    mapped through the positional parameter list (self-less functions in
    this tree, but we index args as written).
    """
    static: set[str] = set()
    jitted = False
    for dec in fn.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = _dotted(target) or ""
        inner = None
        if name.endswith("partial") and isinstance(dec, ast.Call) and dec.args:
            inner = _dotted(dec.args[0]) or ""
            if inner not in ("jax.jit", "jit"):
                continue
        elif name not in ("jax.jit", "jit"):
            continue
        jitted = True
        if not isinstance(dec, ast.Call):
            continue
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for kw in dec.keywords:
            if kw.arg not in ("static_argnums", "static_argnames"):
                continue
            values = (kw.value.elts if isinstance(kw.value, (ast.Tuple, ast.List))
                      else [kw.value])
            for v in values:
                if isinstance(v, ast.Constant):
                    if isinstance(v.value, int) and kw.arg == "static_argnums":
                        if 0 <= v.value < len(params):
                            static.add(params[v.value])
                    elif isinstance(v.value, str):
                        static.add(v.value)
    return jitted, static


def _jitted_functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            jitted, static = _jit_decoration(node)
            if jitted:
                yield node, static


_LOOPS = (ast.For, ast.While, ast.AsyncFor)


def _loop_depth_map(tree: ast.AST) -> dict[ast.AST, int]:
    """node → number of enclosing for/while loops (function bodies reset
    the count: a nested def is not 'inside' its enclosing loop at runtime
    until called, and flagging it would double-report)."""
    depth: dict[ast.AST, int] = {}

    def visit(node: ast.AST, d: int) -> None:
        depth[node] = d
        for child in ast.iter_child_nodes(node):
            nd = d
            if isinstance(node, _LOOPS) and child in node.body + getattr(node, "orelse", []):
                nd = d + 1
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                visit(child, 0)
            else:
                visit(child, nd)

    visit(tree, 0)
    return depth


# ---------------------------------------------------------------------------
# retrace-control
# ---------------------------------------------------------------------------

@register_rule(
    "retrace-control",
    packages=JAX_TARGETS,
    description=("jit-retrace hazards: `jax.jit` applied inside a loop "
                 "body, or a non-static parameter of a jitted function "
                 "driving Python `if`/`while`/`range` control flow"),
    rationale=("jitting in a loop recompiles every iteration; Python "
               "control flow on a traced argument either crashes "
               "(ConcretizationTypeError) or silently retraces per value — "
               "either way the compile cache is defeated exactly where the "
               "TPU path is hottest"),
    example=("parameter 'mode' of jitted 'mttkrp' drives `if` at line 12 "
             "but is not in static_argnums/static_argnames"),
)
def check_retrace(ctx: FileContext) -> Iterator[Finding]:
    tree = ctx.tree
    depth = _loop_depth_map(tree)

    # (a) jax.jit(...) evaluated inside a loop body → recompile per iteration
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call)
                and (_dotted(node.func) in ("jax.jit", "jit"))
                and depth.get(node, 0) > 0):
            yield ctx.finding(
                "retrace-control", node,
                "`jax.jit` called inside a loop body — each iteration "
                "builds a fresh jitted callable and retraces; hoist the "
                "jit out of the loop")

    # (b) traced (non-static) parameter driving Python control flow
    for fn, static in _jitted_functions(tree):
        params = {a.arg for a in fn.args.posonlyargs + fn.args.args
                  + fn.args.kwonlyargs} - static
        # Names rebound in the body stop being "the traced parameter".
        rebound = {t.id for node in ast.walk(fn)
                   for t in getattr(node, "targets", [])
                   if isinstance(t, ast.Name)}
        traced = params - rebound

        def param_in(expr: ast.AST) -> str | None:
            for sub in ast.walk(expr):
                if isinstance(sub, ast.Name) and sub.id in traced:
                    return sub.id
            return None

        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                hit = param_in(node.test)
                kind = "if" if isinstance(node, ast.If) else "while"
                if hit:
                    yield ctx.finding(
                        "retrace-control", node,
                        f"parameter '{hit}' of jitted '{fn.name}' drives "
                        f"Python `{kind}` control flow but is not declared "
                        "in static_argnums/static_argnames — this traces "
                        "per value (or raises ConcretizationTypeError); "
                        "mark it static or use lax.cond/lax.while_loop")
            elif (isinstance(node, ast.Call)
                  and isinstance(node.func, ast.Name)
                  and node.func.id == "range"):
                hit = next((h for h in map(param_in, node.args) if h), None)
                if hit:
                    yield ctx.finding(
                        "retrace-control", node,
                        f"parameter '{hit}' of jitted '{fn.name}' sizes a "
                        "Python `range` loop but is not static — the loop "
                        "is unrolled per traced value; mark it static or "
                        "use lax.fori_loop")


# ---------------------------------------------------------------------------
# dict-order-enumeration
# ---------------------------------------------------------------------------

def _module_dicts(tree: ast.AST) -> set[str]:
    """Module-level names bound to dict literals / dict() — the mutable
    registries whose iteration order is registration (import side-effect)
    order."""
    names: set[str] = set()
    body = tree.body if isinstance(tree, ast.Module) else []
    for node in body:
        value = None
        targets: list[ast.expr] = []
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            value, targets = node.value, [node.target]
        if value is None:
            continue
        is_dict = isinstance(value, (ast.Dict, ast.DictComp)) or (
            isinstance(value, ast.Call) and _dotted(value.func) == "dict")
        if not is_dict:
            continue
        for t in targets:
            if isinstance(t, ast.Name):
                names.add(t.id)
    return names


def _sorted_wrapped(node: ast.AST, parents: dict[ast.AST, ast.AST]) -> bool:
    """Is `node` (an iteration source) inside a sorted()/sorted-by-key
    normalization — sorted(...), dict(sorted(...)), min/max, len()?"""
    _ORDER_FREE = ("sorted", "len", "min", "max", "set", "frozenset", "sum",
                   "any", "all")
    cur = node
    for _ in range(6):
        parent = parents.get(cur)
        if parent is None:
            return False
        if isinstance(parent, ast.Call):
            fname = _dotted(parent.func)
            if fname in _ORDER_FREE:
                return True
        cur = parent
    return False


@register_rule(
    "dict-order-enumeration",
    packages=JAX_TARGETS,
    description=("candidate/registry enumeration that iterates a mutable "
                 "module-level dict in insertion (registration) order "
                 "without sorting"),
    rationale=("registration order is an import-side-effect: two processes "
               "importing modules differently enumerate candidates "
               "differently, so autotune tie-breaks, probe budgets, and "
               "persisted winner lists silently diverge between runs"),
    example=("iteration over module-level dict '_REGISTRY' depends on "
             "registration order; wrap in sorted(...)"),
)
def check_dict_order(ctx: FileContext) -> Iterator[Finding]:
    tree = ctx.tree
    dicts = _module_dicts(tree)
    if not dicts:
        return
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node

    def source_name(expr: ast.AST) -> str | None:
        """The registry name if `expr` enumerates one order-dependently:
        NAME, NAME.values(), NAME.items(), NAME.keys(), iter(NAME)…"""
        if isinstance(expr, ast.Name) and expr.id in dicts:
            return expr.id
        if isinstance(expr, ast.Call):
            f = expr.func
            if (isinstance(f, ast.Attribute)
                    and f.attr in ("values", "items", "keys")
                    and isinstance(f.value, ast.Name)
                    and f.value.id in dicts):
                return f.value.id
            if (isinstance(f, ast.Name) and f.id in ("iter", "list", "tuple",
                                                     "enumerate")
                    and expr.args):
                return source_name(expr.args[0])
        return None

    sources: list[tuple[ast.AST, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.For, ast.AsyncFor)):
            name = source_name(node.iter)
            if name:
                sources.append((node.iter, name))
        elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                               ast.GeneratorExp)):
            for gen in node.generators:
                name = source_name(gen.iter)
                if name:
                    sources.append((gen.iter, name))
        elif isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in ("list", "tuple", "next"):
            # list(_REGISTRY.values()) materializes registration order too
            name = source_name(node)
            if name:
                sources.append((node, name))

    seen: set[tuple[int, str]] = set()
    for expr, name in sources:
        if _sorted_wrapped(expr, parents):
            continue
        key = (expr.lineno, name)
        if key in seen:
            continue
        seen.add(key)
        yield ctx.finding(
            "dict-order-enumeration", expr,
            f"iteration over module-level dict '{name}' depends on "
            "registration (import side-effect) order — wrap the "
            "enumeration in sorted(...) or document why order is "
            "load-bearing")


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

_SYNC_METHODS = ("item", "tolist", "block_until_ready")


@register_rule(
    "host-sync",
    packages=JAX_TARGETS,
    description=("host-device synchronization on a visibly-JAX value: "
                 "float()/int() over a jnp/jax expression, .item()/"
                 ".tolist(), np.asarray/np.array of a jax expression, "
                 "block_until_ready, jax.device_get"),
    rationale=("each sync stalls the dispatch pipeline; inside the probe/"
               "iteration hot loops one stray float() serializes the "
               "device queue and the measured timings stop measuring the "
               "kernel — on TPU the stall is a full round-trip"),
    example=("host sync inside a loop: `float(...)` forces a device→host "
             "transfer each iteration"),
)
def check_host_sync(ctx: FileContext) -> Iterator[Finding]:
    tree = ctx.tree
    depth = _loop_depth_map(tree)

    def emit(node: ast.AST, what: str) -> Finding:
        d = depth.get(node, 0)
        where = "inside a loop: " if d else ""
        return ctx.finding(
            "host-sync", node,
            f"host sync {where}{what} forces a device→host transfer"
            + ("; hoist it out of the loop or keep the value on device"
               if d else "; keep the reduction on device if this feeds "
               "further computation"))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _dotted(node.func)
        # float(x)/int(x)/bool(x) over a visibly-jax expression
        if (isinstance(node.func, ast.Name)
                and node.func.id in ("float", "int", "bool")
                and node.args and _mentions_jax(node.args[0])):
            yield emit(node, f"`{node.func.id}(...)` over a jax expression")
        # np.asarray / np.array / np.float64(...) of a jax expression
        elif (fname in ("np.asarray", "np.array", "numpy.asarray",
                        "numpy.array")
                and node.args and _mentions_jax(node.args[0])):
            yield emit(node, f"`{fname}(...)` over a jax expression")
        # jax.device_get / jax.block_until_ready module functions
        elif fname in ("jax.device_get", "jax.block_until_ready"):
            yield emit(node, f"`{fname}(...)`")
        # .item() / .tolist() / .block_until_ready() methods
        elif (isinstance(node.func, ast.Attribute)
                and node.func.attr in _SYNC_METHODS):
            yield emit(node, f"`.{node.func.attr}()`")


# ---------------------------------------------------------------------------
# tracer-leak
# ---------------------------------------------------------------------------

@register_rule(
    "tracer-leak",
    packages=JAX_TARGETS,
    description=("a jitted function stores a value on `self` or a module "
                 "global — the stored object is a tracer that outlives "
                 "its trace"),
    rationale=("a leaked tracer raises UnexpectedTracerError on first "
               "touch after the trace ends, but only on the *second* call "
               "pattern that reuses it — the classic works-once-then-"
               "explodes bug"),
    example=("jitted 'step' assigns to `self.state` — the stored value is "
             "a tracer"),
)
def check_tracer_leak(ctx: FileContext) -> Iterator[Finding]:
    for fn, _static in _jitted_functions(ctx.tree):
        globals_declared: set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Global):
                globals_declared.update(node.names)
        for node in ast.walk(fn):
            targets: list[ast.expr] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            for t in targets:
                if (isinstance(t, ast.Attribute)
                        and isinstance(t.value, ast.Name)
                        and t.value.id == "self"):
                    yield ctx.finding(
                        "tracer-leak", node,
                        f"jitted '{fn.name}' assigns to `self.{t.attr}` — "
                        "the stored value is a tracer that outlives its "
                        "trace (UnexpectedTracerError on reuse); return "
                        "the value instead")
                elif (isinstance(t, ast.Name)
                        and t.id in globals_declared):
                    yield ctx.finding(
                        "tracer-leak", node,
                        f"jitted '{fn.name}' assigns module global "
                        f"'{t.id}' — the stored value is a tracer that "
                        "outlives its trace; return it instead")


# ---------------------------------------------------------------------------
# trace-in-jit
# ---------------------------------------------------------------------------

#: Observability entrypoints (repro.obs) that must never run under a trace:
#: bare-name calls and attribute-call leaves, matched lexically.
_OBS_NAME_CALLS = ("span", "record_span")
_OBS_ATTR_CALLS = ("span", "record", "record_span", "observe", "inc",
                   "set_value")


@register_rule(
    "trace-in-jit",
    packages=JAX_TARGETS,
    description=("a span or metric emission (`span(...)`, `record_span`, "
                 "`.observe()`, `.inc()`, `.set_value()`, `tracer.record`) "
                 "inside the body of a jitted function"),
    rationale=("span/metric calls are host-side Python: under `jax.jit` "
               "they run once at trace time — recording bogus trace-time "
               "durations instead of per-call ones — and any data they "
               "capture is a tracer; instrumentation belongs around the "
               "jitted call, never inside it (the repro.obs overhead "
               "contract assumes the disabled check is host code)"),
    example=("jitted 'step' calls `span(...)` at line 7 — the span runs at "
             "trace time, not per call; move it around the jitted call"),
)
def check_trace_in_jit(ctx: FileContext) -> Iterator[Finding]:
    for fn, _static in _jitted_functions(ctx.tree):
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            what = None
            if (isinstance(node.func, ast.Name)
                    and node.func.id in _OBS_NAME_CALLS):
                what = f"`{node.func.id}(...)`"
            elif (isinstance(node.func, ast.Attribute)
                    and node.func.attr in _OBS_ATTR_CALLS):
                what = f"`.{node.func.attr}(...)`"
            if what:
                yield ctx.finding(
                    "trace-in-jit", node,
                    f"jitted '{fn.name}' calls {what} — span/metric "
                    "emission inside a jitted body runs at trace time, not "
                    "per call; move the instrumentation around the jitted "
                    "call")


# ---------------------------------------------------------------------------
# nondeterminism
# ---------------------------------------------------------------------------

#: numpy.random module-level calls that draw from the hidden global state;
#: Generator construction (default_rng/Generator/SeedSequence) and state
#: plumbing are the sanctioned seeded paths.
_NP_RANDOM_OK = ("default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "get_state", "set_state", "seed")
_RANDOM_OK = ("Random", "SystemRandom", "seed", "getstate", "setstate")


@register_rule(
    "nondeterminism",
    packages=("src/repro",),
    description=("wall-clock or hidden-global-state randomness in product "
                 "code: `time.time()`, module-level `random.*`, legacy "
                 "`np.random.*` (global RNG) outside bench timing code"),
    rationale=("the sweep/persist pipeline promises exact-fingerprint "
               "resumability and parity gates at 1e-5 — an unseeded draw "
               "or wall-clock dependency anywhere in the data path makes "
               "reruns incomparable and CI flaky"),
    example=("`np.random.rand(...)` draws from the hidden global RNG; use "
             "np.random.default_rng(seed)"),
)
def check_nondeterminism(ctx: FileContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = _dotted(node.func)
        if fname is None:
            continue
        if fname in ("time.time", "time.time_ns"):
            yield ctx.finding(
                "nondeterminism", node,
                f"`{fname}()` wall clock in product code — timestamps in "
                "persisted/compared data make reruns diverge; use "
                "time.perf_counter() for intervals or thread a timestamp "
                "in from the caller")
        elif fname.startswith("random.") and fname.count(".") == 1:
            leaf = fname.split(".")[1]
            if leaf not in _RANDOM_OK:
                yield ctx.finding(
                    "nondeterminism", node,
                    f"`{fname}()` draws from the process-global `random` "
                    "state — seedless and shared across callers; use "
                    "random.Random(seed) or np.random.default_rng(seed)")
        elif (fname.startswith(("np.random.", "numpy.random."))
                and fname.split(".")[-1] not in _NP_RANDOM_OK):
            yield ctx.finding(
                "nondeterminism", node,
                f"`{fname}(...)` draws from numpy's hidden global RNG; "
                "use np.random.default_rng(seed) so every draw is "
                "reproducible from the workload fingerprint")
