"""Symbolic shape/dtype abstract interpretation over kernel ASTs.

The syntactic rules (PR 7) see one AST node at a time; the defect class
that actually bites a sparse-MTTKRP stack — shape mismatches between
kernels, silent dtype demotion, integer-width overflow on linearized
keys — needs *dataflow*: what shape/dtype does this expression have,
given symbolic input shapes?  This module is that layer:

  * a **dtype lattice** (`DType`, `promote`) matching jnp's promotion
    under the x64-disabled defaults this repo runs with (float64 and
    int64 canonicalize to their 32-bit forms everywhere);
  * a **symbolic dim algebra** (`Dim`) over named sizes (`nnz`, `T`,
    `P`, `R`, per-mode `I_m`/`S_m`) with just enough affine structure to
    reason about the padding idioms the kernel stack uses —
    `rows + (-rows) % chunk` and `-(-n // c) * c` both normalize to
    "least multiple of `c` ≥ n" (`CeilMul`), which is what BlockSpec
    divisibility checks need;
  * an **intraprocedural abstract interpreter** (`Interpreter`) over
    function ASTs: flow-sensitive statements with branch joins, concrete
    loop unrolling, jnp/lax primitive models, `jax.vmap`, `.at[].add`
    scatter checks, `jax.ops.segment_sum` call recording, and a
    structural model of `pl.pallas_call` + `PrefetchScalarGridSpec` that
    validates BlockSpecs and then interprets the kernel body with
    block-shaped refs.

`shape_rules.py` drives this against the contracts pinned in
`kernel_contracts.json`; `width_rules.py` reuses the dtype lattice.
The interpreter is deliberately *quiet on ignorance*: anything it does
not model evaluates to Unknown and produces no finding — only positive
evidence of a mismatch is reported (the zero-findings CI gate cannot
afford speculative noise).
"""
from __future__ import annotations

import ast
import dataclasses
import itertools

__all__ = [
    "AArray",
    "AConst",
    "ADType",
    "AInt",
    "ATuple",
    "AUnknown",
    "CeilDiv",
    "CeilMul",
    "DType",
    "Dim",
    "Interpreter",
    "ModNeg",
    "ModuleEnv",
    "Opaque",
    "Problem",
    "Program",
    "SegmentSum",
    "Sym",
    "UNKNOWN",
    "canonicalize",
    "join_dims",
    "parse_dtype",
    "promote",
]


# ---------------------------------------------------------------------------
# DType lattice
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DType:
    """One element of the dtype lattice.  `weak` marks Python-scalar
    provenance (jnp's weak types): a weak scalar adopts the other
    operand's type instead of forcing a promotion."""

    kind: str            # "bool" | "int" | "uint" | "float"
    bits: int
    weak: bool = False

    def __str__(self) -> str:
        if self.kind == "bool":
            return "bool"
        return f"{'weak ' if self.weak else ''}{self.kind}{self.bits}"


_DTYPE_NAMES = {
    "bool_": DType("bool", 8), "bool": DType("bool", 8),
    "int8": DType("int", 8), "int16": DType("int", 16),
    "int32": DType("int", 32), "int64": DType("int", 64),
    "uint8": DType("uint", 8), "uint16": DType("uint", 16),
    "uint32": DType("uint", 32), "uint64": DType("uint", 64),
    "float16": DType("float", 16), "float32": DType("float", 32),
    "float64": DType("float", 64),
}


def parse_dtype(name: str) -> DType | None:
    return _DTYPE_NAMES.get(name)


def canonicalize(dt: DType) -> DType:
    """jax.config x64 disabled: every 64-bit type narrows to 32 bits on
    array creation — the width seam `width_rules` exists for."""
    if dt.bits == 64 and dt.kind in ("int", "uint", "float"):
        return DType(dt.kind, 32, dt.weak)
    return dt


def _strong_promote(a: DType, b: DType) -> DType:
    """Promotion of two strong (array) dtypes, matching what
    `jnp.zeros((), a) + jnp.zeros((), b)` produces under x64-off —
    verified empirically against the jax in this container
    (tests/test_dataflow.py samples the grid)."""
    if a == b:
        return a
    if a.kind == "bool":
        return b
    if b.kind == "bool":
        return a
    if a.kind == "float" or b.kind == "float":
        fa = a.bits if a.kind == "float" else 0
        fb = b.bits if b.kind == "float" else 0
        bits = max(fa, fb)
        # int participation promotes float16 only per jnp's lattice when
        # both are float; int + float16 stays float16?  jnp: int32 +
        # float16 -> float16 (value-preserving is off in default mode).
        return canonicalize(DType("float", bits))
    if a.kind == b.kind:  # int/int or uint/uint
        return canonicalize(DType(a.kind, max(a.bits, b.bits)))
    # mixed signed/unsigned
    i, u = (a, b) if a.kind == "int" else (b, a)
    if i.bits > u.bits:
        return canonicalize(DType("int", i.bits))
    return canonicalize(DType("int", min(2 * u.bits, 32)))


def promote(a: DType, b: DType) -> DType:
    """jnp result dtype of a binary op between `a` and `b` (x64 off)."""
    a, b = canonicalize(a), canonicalize(b)
    if a.weak and b.weak:
        if "float" in (a.kind, b.kind):
            return DType("float", 32, weak=True)
        return DType(a.kind if a.kind == b.kind else "int", 32, weak=True)
    if a.weak or b.weak:
        w, s = (a, b) if a.weak else (b, a)
        if w.kind == "float" and s.kind in ("bool", "int", "uint"):
            return DType("float", 32)
        if w.kind == "int" and s.kind == "bool":
            return DType("int", 32)
        return dataclasses.replace(s, weak=False)
    return _strong_promote(a, b)


# ---------------------------------------------------------------------------
# Symbolic dims
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Sym:
    """A named size: `nnz`, `T`, `R`, `I0`, `S1`, ..."""

    name: str

    def __str__(self) -> str:
        return self.name


@dataclasses.dataclass(frozen=True)
class CeilDiv:
    """ceil(base / div) — `-(-n // c)`."""

    base: "Dim"
    div: "Dim"

    def __str__(self) -> str:
        return f"ceildiv({self.base},{self.div})"


@dataclasses.dataclass(frozen=True)
class CeilMul:
    """Least multiple of `mult` that is ≥ `base` — the padded extent.
    Divisible by `mult` by construction; that fact is what BlockSpec
    divisibility checks consume."""

    base: "Dim"
    mult: "Dim"

    def __str__(self) -> str:
        return f"ceil({self.base},{self.mult})"


@dataclasses.dataclass(frozen=True)
class ModNeg:
    """(-base) % mod — the `rpad = (-rows) % chunk` padding amount."""

    base: "Dim"
    mod: "Dim"

    def __str__(self) -> str:
        return f"padto({self.base},{self.mod})"


_OPAQUE_COUNTER = itertools.count()


@dataclasses.dataclass(frozen=True)
class Opaque:
    """A size the algebra cannot express; fresh per creation, equal only
    to itself — two unknowns must never compare equal."""

    tag: str
    uid: int

    def __str__(self) -> str:
        return f"?{self.tag}"


def _fresh(tag: str = "dim") -> "Dim":
    return Dim.atom(Opaque(tag, next(_OPAQUE_COUNTER)))


def _akey(a) -> tuple:
    return (type(a).__name__, str(a), getattr(a, "uid", 0))


class Dim:
    """A symbolic nonnegative integer: `const + Σ coeff·mono` where each
    mono is a sorted product of atoms.  Hashable/structural equality."""

    __slots__ = ("terms", "const")

    def __init__(self, terms: dict | tuple = (), const: int = 0):
        if isinstance(terms, dict):
            items = {m: c for m, c in terms.items() if c != 0}
            self.terms = tuple(sorted(
                items.items(), key=lambda mc: tuple(_akey(a) for a in mc[0])))
        else:
            self.terms = tuple(terms)
        self.const = const

    # -- constructors ------------------------------------------------------
    @staticmethod
    def const_(n: int) -> "Dim":
        return Dim((), int(n))

    @staticmethod
    def sym(name: str) -> "Dim":
        return Dim({(Sym(name),): 1})

    @staticmethod
    def atom(a) -> "Dim":
        return Dim({(a,): 1})

    @staticmethod
    def of(x) -> "Dim":
        if isinstance(x, Dim):
            return x
        if isinstance(x, bool):
            return Dim.const_(int(x))
        if isinstance(x, int):
            return Dim.const_(x)
        if isinstance(x, str):
            return Dim.sym(x)
        return Dim.atom(x)

    # -- identity ----------------------------------------------------------
    def __eq__(self, other) -> bool:
        return (isinstance(other, Dim) and self.terms == other.terms
                and self.const == other.const)

    def __hash__(self) -> int:
        return hash((self.terms, self.const))

    def __repr__(self) -> str:
        return f"Dim({self})"

    def __str__(self) -> str:
        parts = []
        for mono, c in self.terms:
            m = "*".join(str(a) for a in mono)
            parts.append(m if c == 1 else f"{c}*{m}")
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts)

    @property
    def is_const(self) -> bool:
        return not self.terms

    @property
    def has_opaque(self) -> bool:
        return any(isinstance(a, Opaque) for mono, _ in self.terms
                   for a in mono)

    # -- arithmetic --------------------------------------------------------
    def __add__(self, other) -> "Dim":
        other = Dim.of(other)
        terms = dict(self.terms)
        for m, c in other.terms:
            terms[m] = terms.get(m, 0) + c
        out = Dim(terms, self.const + other.const)
        return _recognize_ceil(out)

    __radd__ = __add__

    def __neg__(self) -> "Dim":
        return Dim({m: -c for m, c in self.terms}, -self.const)

    def __sub__(self, other) -> "Dim":
        return self + (-Dim.of(other))

    def __rsub__(self, other) -> "Dim":
        return Dim.of(other) + (-self)

    def __mul__(self, other) -> "Dim":
        other = Dim.of(other)
        terms: dict = {}
        const = self.const * other.const
        for m, c in self.terms:
            terms[m] = terms.get(m, 0) + c * other.const
        for m, c in other.terms:
            terms[m] = terms.get(m, 0) + c * self.const
        for (m1, c1), (m2, c2) in itertools.product(self.terms, other.terms):
            mono = _mul_monos(m1, m2)
            terms[mono] = terms.get(mono, 0) + c1 * c2
        return Dim(terms, const)

    __rmul__ = __mul__

    def __floordiv__(self, other) -> "Dim":
        other = Dim.of(other)
        exact = _try_exact_div(self, other)
        if exact is not None:
            return exact
        neg = -self
        if all(c > 0 for _, c in neg.terms) and neg.const >= 0 and neg.terms:
            # (-x) // d == -ceil(x / d) for d > 0 — the `-(-n // c)`
            # ceil idiom's inner half.
            return -Dim.atom(CeilDiv(neg, other))
        return _fresh("floordiv")

    def __mod__(self, other) -> "Dim":
        other = Dim.of(other)
        if self.divisible_by(other):
            return Dim.const_(0)
        neg = -self
        if all(c > 0 for _, c in neg.terms) and neg.const >= 0 and neg.terms:
            return Dim.atom(ModNeg(neg, other))
        return _fresh("mod")

    # -- divisibility ------------------------------------------------------
    def divisible_by(self, other) -> bool:
        """Provably divisible (False means "cannot prove", not "no")."""
        other = Dim.of(other)
        if other == Dim.const_(1) or self == other:
            return True
        if other.is_const and other.const > 0:
            k = other.const
            return (self.const % k == 0
                    and all(c % k == 0 or _mono_divisible(m, other)
                            for m, c in self.terms))
        if other.const == 0 and len(other.terms) == 1:
            return (self.const == 0
                    and all(_mono_divisible(m, other) for m, _ in self.terms))
        return False


def _mul_monos(m1: tuple, m2: tuple) -> tuple:
    # ceildiv(b, d) * d  →  ceil(b, d): the outer half of `-(-n//c)*c`.
    for a, b in ((m1, m2), (m2, m1)):
        if len(a) == 1 and isinstance(a[0], CeilDiv):
            if Dim({b: 1}) == a[0].div:
                return (CeilMul(a[0].base, a[0].div),)
    return tuple(sorted(m1 + m2, key=_akey))


def _mono_divisible(mono: tuple, d: "Dim") -> bool:
    """Does some atom of `mono` guarantee divisibility by `d`?"""
    for a in mono:
        if Dim({(a,): 1}) == d:
            return True
        if isinstance(a, CeilMul) and (a.mult == d or a.mult.divisible_by(d)):
            return True
    return False


def _try_exact_div(dim: Dim, d: Dim) -> Dim | None:
    if d == Dim.const_(1):
        return dim
    if d.is_const and d.const > 0:
        k = d.const
        if dim.const % k == 0 and all(c % k == 0 for _, c in dim.terms):
            return Dim({m: c // k for m, c in dim.terms}, dim.const // k)
        return None
    if d.const == 0 and len(d.terms) == 1 and d.terms[0][1] == 1:
        datoms = d.terms[0][0]
        if dim.const != 0:
            return None
        out: dict = {}
        for mono, c in dim.terms:
            rest = list(mono)
            for a in datoms:
                if a in rest:
                    rest.remove(a)
                else:
                    for x in rest:
                        # ceil(b, m) / m == ceildiv(b, m)
                        if isinstance(x, CeilMul) and Dim({(a,): 1}) == x.mult:
                            rest.remove(x)
                            rest.append(CeilDiv(x.base, x.mult))
                            break
                    else:
                        return None
            mono2 = tuple(sorted(rest, key=_akey)) or ()
            key = mono2 if mono2 else None
            if key is None:
                return None if c != 1 and out else Dim.const_(c)
            out[mono2] = out.get(mono2, 0) + c
        return Dim(out, 0)
    return None


def _recognize_ceil(dim: Dim) -> Dim:
    """`x + (-x) % b` → ceil-multiple of b — the `pad_factor` idiom."""
    for mono, c in dim.terms:
        if c == 1 and len(mono) == 1 and isinstance(mono[0], ModNeg):
            mn = mono[0]
            rest = Dim({m: k for m, k in dim.terms if m != mono},
                       dim.const)
            if rest == mn.base:
                return Dim.atom(CeilMul(mn.base, mn.mod))
    return dim


def join_dims(a: Dim, b: Dim) -> Dim | None:
    """Join of two branch values; None = no common refinement.

    `x ⊔ ceil(x, b) = ceil(x, b)` is sound here because the unpadded
    branch is only taken when x is already a multiple of b (that is what
    `if rpad or cpad:` tests), so both branches are multiples of b and
    both are ≥ x's padded-down value — every property the checks consume
    (divisibility by b, equality with the other operand's padded dim)
    holds for the join."""
    if a == b:
        return a
    for x, y in ((a, b), (b, a)):
        if (len(x.terms) == 1 and x.const == 0 and x.terms[0][1] == 1
                and len(x.terms[0][0]) == 1
                and isinstance(x.terms[0][0][0], CeilMul)
                and x.terms[0][0][0].base == y):
            return x
    return None


# ---------------------------------------------------------------------------
# Abstract values
# ---------------------------------------------------------------------------

class AVal:
    """Base of the abstract-value hierarchy."""


@dataclasses.dataclass
class AUnknown(AVal):
    def __repr__(self) -> str:
        return "Unknown"


UNKNOWN = AUnknown()


@dataclasses.dataclass
class AConst(AVal):
    """A concrete Python value (int, str, bool, None, tuple of such)."""

    value: object


@dataclasses.dataclass
class AInt(AVal):
    """A symbolic Python integer (sizes, offsets)."""

    dim: Dim


@dataclasses.dataclass
class AArray(AVal):
    """A device array: symbolic shape + lattice dtype."""

    shape: tuple
    dtype: DType

    @property
    def ndim(self) -> int:
        return len(self.shape)


@dataclasses.dataclass
class ATuple(AVal):
    items: list
    mutable: bool = False


@dataclasses.dataclass
class ADType(AVal):
    dtype: DType


@dataclasses.dataclass
class AFunc(AVal):
    """A known primitive (canonical dotted name) with optional payload
    (e.g. the mapped closure for jax.vmap)."""

    name: str
    payload: tuple = ()


@dataclasses.dataclass
class AClosure(AVal):
    node: object          # ast.FunctionDef | ast.Lambda
    env: dict             # captured enclosing scope (lambdas)
    name: str
    module: object        # ModuleEnv it was defined in


@dataclasses.dataclass
class APartial(AVal):
    func: AVal
    args: list
    kwargs: dict


@dataclasses.dataclass
class AModule(AVal):
    module: object        # ModuleEnv


@dataclasses.dataclass
class ABound(AVal):
    """A method bound to an abstract receiver (`x.astype`, `l.at[c].add`,
    `rows.append`)."""

    base: AVal
    attr: str


@dataclasses.dataclass
class AAtIndexed(AVal):
    """`arr.at[idx]` — scatter target; `.add/.set/...` validates."""

    base: AArray
    index_shape: tuple    # shape of the selected region


@dataclasses.dataclass
class ABlockSpec(AVal):
    block_shape: AVal
    index_map: AVal
    line: int


@dataclasses.dataclass
class AGridSpec(AVal):
    grid: AVal
    in_specs: AVal
    out_specs: AVal
    num_scalar_prefetch: int
    line: int


@dataclasses.dataclass
class AShapeDtype(AVal):
    shape: tuple
    dtype: DType


@dataclasses.dataclass
class APallasCall(AVal):
    kernel: AVal
    grid_spec: AVal
    out_shape: AVal
    line: int


@dataclasses.dataclass
class SegmentSum:
    """One recorded `jax.ops.segment_sum` call site."""

    line: int
    data_shape: tuple
    ids_shape: tuple
    num_segments: Dim | None
    indices_are_sorted: bool
    rel: str = ""         # repo-relative file the call lives in


@dataclasses.dataclass
class Problem:
    """One positive finding from interpretation."""

    line: int
    message: str
    category: str         # "shape" | "pallas"
    rel: str = ""         # repo-relative file the defect lives in


def as_dim(v: AVal) -> Dim | None:
    if isinstance(v, AInt):
        return v.dim
    if isinstance(v, AConst) and isinstance(v.value, int) \
            and not isinstance(v.value, bool):
        return Dim.const_(v.value)
    return None


def _shape_str(shape: tuple) -> str:
    return "(" + ", ".join(str(d) for d in shape) + ")"


# ---------------------------------------------------------------------------
# Modules / import resolution
# ---------------------------------------------------------------------------

#: leading dotted paths → canonical short prefix used in the primitive table
_CANON_PREFIXES = [
    ("jax.experimental.pallas.tpu", "pltpu"),
    ("jax.experimental.pallas", "pl"),
    ("jax.numpy", "jnp"),
    ("jax.lax", "lax"),
    ("jax.ops", "jax.ops"),
    ("numpy", "np"),
    ("functools", "functools"),
    ("jax", "jax"),
    ("math", "math"),
]


def _canon(dotted: str) -> str:
    for prefix, short in _CANON_PREFIXES:
        if dotted == prefix or dotted.startswith(prefix + "."):
            return short + dotted[len(prefix):]
    return dotted


class ModuleEnv:
    """Import aliases + top-level defs of one source file, resolved
    lazily so interpreting one function never parses the world."""

    def __init__(self, rel: str, tree: ast.Module, program: "Program"):
        self.rel = rel
        self.program = program
        self.functions: dict[str, ast.FunctionDef] = {}
        self.aliases: dict[str, str] = {}          # name -> external dotted
        self.internal: dict[str, tuple[str, str | None]] = {}
        self.constants: dict[str, AVal] = {}
        for node in tree.body:
            if isinstance(node, ast.FunctionDef):
                self.functions[node.name] = node
            elif isinstance(node, ast.Import):
                for alias in node.names:
                    self.aliases[alias.asname or alias.name.split(".")[0]] = (
                        alias.name if alias.asname else alias.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom):
                self._import_from(node)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Constant):
                self.constants[node.targets[0].id] = AConst(node.value.value)

    def _import_from(self, node: ast.ImportFrom) -> None:
        if node.level == 0:
            for alias in node.names:
                self.aliases[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}" if node.module else alias.name)
            return
        # relative: resolve against this file's package directory
        parts = self.rel.split("/")[:-1]
        parts = parts[: len(parts) - (node.level - 1)]
        if node.module:
            parts = parts + node.module.split(".")
        base = "/".join(parts)
        for alias in node.names:
            # `from . import ref` → sibling module; `from .m import f` →
            # member of module file m.py (or package __init__.py)
            key = alias.asname or alias.name
            mod_as_file = f"{base}/{alias.name}.py"
            if self.program.has_module(mod_as_file):
                self.internal[key] = (mod_as_file, None)
            elif self.program.has_module(f"{base}.py"):
                self.internal[key] = (f"{base}.py", alias.name)
            elif self.program.has_module(f"{base}/__init__.py"):
                self.internal[key] = (f"{base}/__init__.py", alias.name)

    def resolve(self, name: str) -> AVal | None:
        if name in self.functions:
            return AClosure(self.functions[name], {}, name, self)
        if name in self.constants:
            return self.constants[name]
        if name in self.aliases:
            return AFunc(_canon(self.aliases[name]))
        if name in self.internal:
            rel, member = self.internal[name]
            target = self.program.module(rel)
            if target is None:
                return UNKNOWN
            if member is None:
                return AModule(target)
            return target.resolve(member) or UNKNOWN
        return None


class Program:
    """A set of parseable source files (repo-relative path → source),
    usually supplied by the analysis ProjectContext."""

    def __init__(self, sources: dict[str, str]):
        self._sources = sources
        self._modules: dict[str, ModuleEnv | None] = {}

    def has_module(self, rel: str) -> bool:
        return rel in self._sources

    def module(self, rel: str) -> ModuleEnv | None:
        if rel not in self._modules:
            src = self._sources.get(rel)
            if src is None:
                self._modules[rel] = None
            else:
                try:
                    self._modules[rel] = ModuleEnv(rel, ast.parse(src), self)
                except SyntaxError:
                    self._modules[rel] = None
        return self._modules[rel]


# ---------------------------------------------------------------------------
# Interpreter
# ---------------------------------------------------------------------------

_NORMAL, _RETURN, _RAISE, _BREAK, _CONTINUE = range(5)

_BUILTINS = {"range", "len", "enumerate", "zip", "reversed", "tuple", "list",
             "sum", "max", "min", "int", "abs", "isinstance", "print",
             "sorted"}

_INT32 = DType("int", 32)
_F32 = DType("float", 32)


class Interpreter:
    """Abstract interpreter for one function call.  Produces a return
    value, a list of `Problem`s, and the `SegmentSum` call record."""

    def __init__(self, program: Program, max_depth: int = 10):
        self.program = program
        self.problems: list[Problem] = []
        self.segment_sums: list[SegmentSum] = []
        self.max_depth = max_depth
        self._depth = 0
        self._steps = 0
        self._rel_stack: list[str] = []

    @property
    def current_rel(self) -> str:
        return self._rel_stack[-1] if self._rel_stack else ""

    # -- entry -------------------------------------------------------------
    def call_function(self, fndef: ast.FunctionDef, module: ModuleEnv,
                      args: list, kwargs: dict) -> AVal:
        env = self._bind(fndef, module, args, kwargs)
        if env is None:
            return UNKNOWN
        self._depth += 1
        self._rel_stack.append(module.rel)
        try:
            if self._depth > self.max_depth:
                return UNKNOWN
            returns: list[AVal] = []
            self._exec_block(fndef.body, env, module, returns)
            if not returns:
                return AConst(None)
            out = returns[0]
            for r in returns[1:]:
                out = self._join(out, r)
            return out
        finally:
            self._rel_stack.pop()
            self._depth -= 1

    def problem(self, node, message: str, category: str = "shape") -> None:
        line = node if isinstance(node, int) else getattr(node, "lineno", 0)
        self.problems.append(Problem(line, message, category,
                                     rel=self.current_rel))

    # -- binding -----------------------------------------------------------
    def _bind(self, fndef, module: ModuleEnv, args: list,
              kwargs: dict) -> dict | None:
        a = fndef.args
        env: dict[str, AVal] = {}
        names = [p.arg for p in a.posonlyargs + a.args]
        pos = list(args)
        for i, name in enumerate(names):
            if i < len(pos):
                env[name] = pos[i]
            elif name in kwargs:
                env[name] = kwargs.pop(name)
        if a.vararg is not None:
            env[a.vararg.arg] = ATuple(pos[len(names):])
        defaults = a.defaults
        for i, d in enumerate(defaults):
            name = names[len(names) - len(defaults) + i]
            if name not in env:
                env[name] = self._eval(d, dict(env), module)
        for p, d in zip(a.kwonlyargs, a.kw_defaults):
            if p.arg in kwargs:
                env[p.arg] = kwargs.pop(p.arg)
            elif d is not None:
                env[p.arg] = self._eval(d, dict(env), module)
        for name in names:
            env.setdefault(name, UNKNOWN)
        for p in a.kwonlyargs:
            env.setdefault(p.arg, UNKNOWN)
        return env

    # -- statements --------------------------------------------------------
    def _exec_block(self, stmts, env, module, returns) -> int:
        for stmt in stmts:
            flow = self._exec(stmt, env, module, returns)
            if flow != _NORMAL:
                return flow
        return _NORMAL

    def _exec(self, stmt, env, module, returns) -> int:
        self._steps += 1
        if self._steps > 200_000:
            return _RETURN                      # runaway guard: give up quietly
        if isinstance(stmt, ast.Return):
            returns.append(self._eval(stmt.value, env, module)
                           if stmt.value is not None else AConst(None))
            return _RETURN
        if isinstance(stmt, ast.Raise):
            return _RAISE
        if isinstance(stmt, (ast.Break,)):
            return _BREAK
        if isinstance(stmt, (ast.Continue,)):
            return _CONTINUE
        if isinstance(stmt, (ast.Pass, ast.Global, ast.Nonlocal,
                             ast.Import, ast.ImportFrom, ast.Assert)):
            return _NORMAL
        if isinstance(stmt, ast.FunctionDef):
            env[stmt.name] = AClosure(stmt, dict(env), stmt.name, module)
            return _NORMAL
        if isinstance(stmt, ast.Expr):
            self._eval(stmt.value, env, module)
            return _NORMAL
        if isinstance(stmt, ast.Assign):
            val = self._eval(stmt.value, env, module)
            for t in stmt.targets:
                self._assign(t, val, env, module)
            return _NORMAL
        if isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._assign(stmt.target,
                             self._eval(stmt.value, env, module), env, module)
            return _NORMAL
        if isinstance(stmt, ast.AugAssign):
            cur = self._eval(stmt.target, env, module)
            val = self._eval(stmt.value, env, module)
            self._assign(stmt.target,
                         self._binop(cur, stmt.op, val, stmt), env, module)
            return _NORMAL
        if isinstance(stmt, ast.If):
            return self._exec_if(stmt, env, module, returns)
        if isinstance(stmt, ast.For):
            return self._exec_for(stmt, env, module, returns)
        if isinstance(stmt, ast.While):
            self._havoc(stmt, env)
            return _NORMAL
        if isinstance(stmt, ast.With):
            return self._exec_block(stmt.body, env, module, returns)
        if isinstance(stmt, ast.Try):
            flow = self._exec_block(stmt.body, env, module, returns)
            self._exec_block(stmt.finalbody, env, module, returns)
            return _NORMAL if flow == _RAISE else flow
        return _NORMAL

    def _exec_if(self, stmt, env, module, returns) -> int:
        t = self._truth(self._eval(stmt.test, env, module))
        if t is True:
            return self._exec_block(stmt.body, env, module, returns)
        if t is False:
            return self._exec_block(stmt.orelse, env, module, returns)
        env_t, env_f = dict(env), dict(env)
        flow_t = self._exec_block(stmt.body, env_t, module, returns)
        flow_f = self._exec_block(stmt.orelse, env_f, module, returns)
        live = [(f, e) for f, e in ((flow_t, env_t), (flow_f, env_f))
                if f == _NORMAL]
        if not live:
            return flow_t if flow_t != _NORMAL else flow_f
        env.clear()
        if len(live) == 1:
            env.update(live[0][1])
            return _NORMAL
        merged = {}
        for k in set(env_t) | set(env_f):
            if k in env_t and k in env_f:
                merged[k] = self._join(env_t[k], env_f[k])
            else:
                merged[k] = UNKNOWN
        env.update(merged)
        return _NORMAL

    def _exec_for(self, stmt, env, module, returns) -> int:
        it = self._eval(stmt.iter, env, module)
        items = None
        if isinstance(it, ATuple):
            items = list(it.items)
        elif isinstance(it, AConst) and isinstance(it.value, (range, tuple, list)):
            items = [AConst(v) for v in it.value]
        if items is None or len(items) > 256:
            self._havoc(stmt, env)
            return _NORMAL
        for item in items:
            self._assign(stmt.target, item, env, module)
            flow = self._exec_block(stmt.body, env, module, returns)
            if flow == _BREAK:
                return _NORMAL
            if flow in (_RETURN, _RAISE):
                return flow
        self._exec_block(stmt.orelse, env, module, returns)
        return _NORMAL

    def _havoc(self, stmt, env) -> None:
        for node in ast.walk(stmt):
            if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
                env[node.id] = UNKNOWN

    def _assign(self, target, val, env, module) -> None:
        if isinstance(target, ast.Name):
            env[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            items = (list(val.items) if isinstance(val, ATuple)
                     else [AConst(v) for v in val.value]
                     if isinstance(val, AConst)
                     and isinstance(val.value, (tuple, list))
                     else None)
            if items is not None and len(items) == len(target.elts):
                for t, v in zip(target.elts, items):
                    self._assign(t, v, env, module)
            else:
                for t in target.elts:
                    if isinstance(t, ast.Starred):
                        t = t.value
                    self._assign(t, UNKNOWN, env, module)
        elif isinstance(target, ast.Subscript):
            base = self._eval(target.value, env, module)
            if isinstance(base, AArray):
                idx = self._eval_index(target.slice, env, module)
                region = self._index_shape(base, idx, target)
                self._check_store(base, region, val, target)
            elif isinstance(base, ATuple) and base.mutable:
                i = self._concrete_int(self._eval(target.slice, env, module))
                if i is not None and -len(base.items) <= i < len(base.items):
                    base.items[i] = val
        # attribute stores and the rest: ignore

    def _check_store(self, base: AArray, region: tuple | None, val, node):
        if region is None:
            return
        if isinstance(val, AArray):
            self._broadcast(region, val.shape, node,
                            what="stored value vs target slice")
            res = promote(val.dtype, base.dtype)
            if dataclasses.replace(res, weak=False) != \
                    dataclasses.replace(base.dtype, weak=False):
                self.problem(node,
                             f"store of {val.dtype} into {base.dtype} ref "
                             "silently demotes the value")

    # -- joins -------------------------------------------------------------
    def _join(self, a: AVal, b: AVal) -> AVal:
        if a is b:
            return a
        if isinstance(a, AArray) and isinstance(b, AArray):
            if a.ndim != b.ndim:
                return UNKNOWN
            dims = tuple(join_dims(x, y) or _fresh("join")
                         for x, y in zip(a.shape, b.shape))
            dt = a.dtype if a.dtype == b.dtype else promote(a.dtype, b.dtype)
            return AArray(dims, dt)
        if isinstance(a, AInt) and isinstance(b, AInt):
            return AInt(join_dims(a.dim, b.dim) or _fresh("join"))
        if isinstance(a, AConst) and isinstance(b, AConst):
            return a if a.value == b.value else UNKNOWN
        if isinstance(a, ATuple) and isinstance(b, ATuple) \
                and len(a.items) == len(b.items):
            return ATuple([self._join(x, y)
                           for x, y in zip(a.items, b.items)])
        return UNKNOWN

    def _truth(self, v: AVal) -> bool | None:
        if isinstance(v, AConst):
            try:
                return bool(v.value)
            except Exception:
                return None
        if isinstance(v, AInt) and v.dim.is_const:
            return bool(v.dim.const)
        return None

    def _concrete_int(self, v: AVal) -> int | None:
        if isinstance(v, AConst) and isinstance(v.value, int) \
                and not isinstance(v.value, bool):
            return v.value
        if isinstance(v, AInt) and v.dim.is_const:
            return v.dim.const
        return None

    # -- expressions -------------------------------------------------------
    def _eval(self, node, env, module: ModuleEnv) -> AVal:
        self._steps += 1
        if self._steps > 200_000:
            return UNKNOWN
        if isinstance(node, ast.Constant):
            return AConst(node.value)
        if isinstance(node, ast.Name):
            if node.id in env:
                return env[node.id]
            resolved = module.resolve(node.id)
            if resolved is not None:
                return resolved
            if node.id in _BUILTINS:
                return AFunc(f"builtin.{node.id}")
            return UNKNOWN
        if isinstance(node, ast.Attribute):
            return self._eval_attribute(node, env, module)
        if isinstance(node, ast.Subscript):
            return self._eval_subscript(node, env, module)
        if isinstance(node, ast.Call):
            return self._eval_call(node, env, module)
        if isinstance(node, (ast.Tuple, ast.List)):
            items: list[AVal] = []
            for e in node.elts:
                if isinstance(e, ast.Starred):
                    v = self._eval(e.value, env, module)
                    if isinstance(v, ATuple):
                        items.extend(v.items)
                    else:
                        return UNKNOWN
                else:
                    items.append(self._eval(e, env, module))
            return ATuple(items, mutable=isinstance(node, ast.List))
        if isinstance(node, ast.BinOp):
            return self._binop(self._eval(node.left, env, module), node.op,
                               self._eval(node.right, env, module), node)
        if isinstance(node, ast.UnaryOp):
            v = self._eval(node.operand, env, module)
            if isinstance(node.op, ast.USub):
                d = as_dim(v)
                if isinstance(v, AConst) and isinstance(v.value, (int, float)):
                    return AConst(-v.value)
                if d is not None:
                    return AInt(-d)
                if isinstance(v, AArray):
                    return v
            if isinstance(node.op, ast.Not):
                t = self._truth(v)
                return AConst(not t) if t is not None else UNKNOWN
            return UNKNOWN if not isinstance(v, AArray) else v
        if isinstance(node, ast.Compare):
            return self._compare(node, env, module)
        if isinstance(node, ast.BoolOp):
            vals = [self._eval(v, env, module) for v in node.values]
            truths = [self._truth(v) for v in vals]
            if all(t is not None for t in truths):
                if isinstance(node.op, ast.And):
                    return AConst(all(truths))
                return AConst(any(truths))
            return UNKNOWN
        if isinstance(node, ast.IfExp):
            t = self._truth(self._eval(node.test, env, module))
            if t is True:
                return self._eval(node.body, env, module)
            if t is False:
                return self._eval(node.orelse, env, module)
            return self._join(self._eval(node.body, env, module),
                              self._eval(node.orelse, env, module))
        if isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            return self._eval_comp(node, env, module)
        if isinstance(node, ast.Lambda):
            return AClosure(node, dict(env), "<lambda>", module)
        if isinstance(node, ast.JoinedStr):
            return AConst("<fstring>")
        if isinstance(node, ast.Starred):
            return self._eval(node.value, env, module)
        return UNKNOWN

    def _eval_comp(self, node, env, module) -> AVal:
        if len(node.generators) != 1:
            return UNKNOWN
        gen = node.generators[0]
        it = self._eval(gen.iter, env, module)
        if isinstance(it, AConst) and isinstance(it.value, (range, tuple, list)):
            it = ATuple([AConst(v) for v in it.value])
        if not isinstance(it, ATuple) or len(it.items) > 256:
            return UNKNOWN
        out: list[AVal] = []
        inner = dict(env)
        for item in it.items:
            self._assign(gen.target, item, inner, module)
            keep = True
            for cond in gen.ifs:
                t = self._truth(self._eval(cond, inner, module))
                if t is False:
                    keep = False
                    break
                if t is None:
                    return UNKNOWN
            if keep:
                out.append(self._eval(node.elt, inner, module))
        return ATuple(out, mutable=isinstance(node, ast.ListComp))

    # -- attributes --------------------------------------------------------
    _ARRAY_METHODS = {"astype", "reshape", "sum", "copy", "transpose",
                      "ravel", "flatten", "item", "mean", "min", "max"}

    def _eval_attribute(self, node, env, module) -> AVal:
        base = self._eval(node.value, env, module)
        attr = node.attr
        if isinstance(base, AFunc):
            name = f"{base.name}.{attr}"
            short = name.rsplit(".", 1)
            if short[0] in ("jnp", "np") and attr in _DTYPE_NAMES:
                dt = _DTYPE_NAMES[attr]
                return ADType(canonicalize(dt) if short[0] == "jnp" else dt)
            if name == "np.newaxis" or name == "jnp.newaxis":
                return AConst(None)
            return AFunc(name)
        if isinstance(base, AModule):
            return base.module.resolve(attr) or UNKNOWN
        if isinstance(base, AArray):
            if attr == "shape":
                return ATuple([AInt(d) for d in base.shape])
            if attr == "ndim":
                return AConst(base.ndim)
            if attr == "dtype":
                return ADType(base.dtype)
            if attr == "size":
                total = Dim.const_(1)
                for d in base.shape:
                    total = total * d
                return AInt(total)
            if attr == "T":
                return AArray(tuple(reversed(base.shape)), base.dtype)
            if attr == "at":
                return ABound(base, "at")
            if attr in self._ARRAY_METHODS:
                return ABound(base, attr)
            return UNKNOWN
        if isinstance(base, AAtIndexed) and attr in ("add", "set", "max",
                                                     "min", "mul"):
            return ABound(base, attr)
        if isinstance(base, ATuple) and attr in ("append", "extend", "index"):
            return ABound(base, attr)
        if isinstance(base, AShapeDtype):
            if attr == "shape":
                return ATuple([AInt(d) for d in base.shape])
            if attr == "dtype":
                return ADType(base.dtype)
        return UNKNOWN

    # -- subscripts --------------------------------------------------------
    def _eval_index(self, slc, env, module) -> list:
        """Normalize an index expression into a list of index items."""
        if isinstance(slc, ast.Tuple):
            return [self._eval_index_item(e, env, module) for e in slc.elts]
        return [self._eval_index_item(slc, env, module)]

    def _eval_index_item(self, node, env, module):
        if isinstance(node, ast.Slice):
            lo = self._eval(node.lower, env, module) if node.lower else None
            hi = self._eval(node.upper, env, module) if node.upper else None
            step = self._eval(node.step, env, module) if node.step else None
            return ("slice", lo, hi, step)
        v = self._eval(node, env, module)
        if isinstance(v, AConst) and v.value is Ellipsis:
            return ("ellipsis",)
        return v

    def _eval_subscript(self, node, env, module) -> AVal:
        base = self._eval(node.value, env, module)
        if isinstance(base, (ATuple,)):
            idx = self._eval(node.slice, env, module) \
                if not isinstance(node.slice, ast.Slice) else None
            if isinstance(node.slice, ast.Slice):
                lo = self._concrete_int(self._eval(node.slice.lower, env, module)) \
                    if node.slice.lower else None
                hi = self._concrete_int(self._eval(node.slice.upper, env, module)) \
                    if node.slice.upper else None
                if (node.slice.lower is None or lo is not None) and \
                        (node.slice.upper is None or hi is not None):
                    return ATuple(base.items[slice(lo, hi)], base.mutable)
                return UNKNOWN
            i = self._concrete_int(idx)
            if i is not None and -len(base.items) <= i < len(base.items):
                return base.items[i]
            return UNKNOWN
        if isinstance(base, AConst) and isinstance(base.value, (tuple, list, dict)):
            i = self._eval(node.slice, env, module)
            key = i.value if isinstance(i, AConst) else self._concrete_int(i)
            try:
                return AConst(base.value[key])
            except Exception:
                return UNKNOWN
        if isinstance(base, ABound) and base.attr == "at":
            items = self._eval_index(node.slice, env, module)
            region = self._index_shape(base.base, items, node)
            if region is None:
                return UNKNOWN
            return AAtIndexed(base.base, region)
        if isinstance(base, AArray):
            items = self._eval_index(node.slice, env, module)
            region = self._index_shape(base, items, node)
            if region is None:
                return UNKNOWN
            if not region:
                # fully indexed → 0-d; int arrays yield symbolic ints so
                # index_map results stay checkable
                if base.dtype.kind in ("int", "uint"):
                    return AInt(_fresh("elt"))
                return AArray((), base.dtype)
            return AArray(region, base.dtype)
        return UNKNOWN

    def _index_shape(self, base: AArray, items: list, node) -> tuple | None:
        """Result shape of indexing `base` with `items` (read semantics);
        None = unmodeled index."""
        # expand ellipsis
        n_consuming = sum(1 for it in items
                          if not (isinstance(it, AConst) and it.value is None)
                          and not (isinstance(it, tuple) and it[0] == "ellipsis"))
        out: list = []
        pos = 0
        expanded: list = []
        for it in items:
            if isinstance(it, tuple) and it[0] == "ellipsis":
                expanded.extend([("slice", None, None, None)]
                                * (base.ndim - n_consuming))
            else:
                expanded.append(it)
        while len([i for i in expanded
                   if not (isinstance(i, AConst) and i.value is None)]) \
                < base.ndim:
            expanded.append(("slice", None, None, None))
        for it in expanded:
            if isinstance(it, AConst) and it.value is None:
                out.append(Dim.const_(1))
                continue
            if pos >= base.ndim:
                return None
            dim = base.shape[pos]
            pos += 1
            if isinstance(it, tuple) and it[0] == "slice":
                _, lo, hi, step = it
                if step is not None:
                    out.append(_fresh("strided"))
                    continue
                lo_d = as_dim(lo) if lo is not None else Dim.const_(0)
                hi_d = as_dim(hi) if hi is not None else dim
                if lo_d is None or hi_d is None:
                    out.append(_fresh("slice"))
                elif lo_d == Dim.const_(0):
                    out.append(hi_d if not hi_d.is_const or not dim.is_const
                               else Dim.const_(min(hi_d.const, dim.const))
                               if hi_d.const >= 0 else _fresh("slice"))
                else:
                    delta = hi_d - lo_d
                    out.append(delta if not delta.has_opaque
                               else _fresh("slice"))
                continue
            if isinstance(it, AArray):
                # advanced integer index: its dims splice in here
                out.extend(it.shape)
                continue
            if as_dim(it) is not None or isinstance(it, AUnknown):
                continue  # scalar index: drops the axis
            return None
        return tuple(out)

    # -- operators ---------------------------------------------------------
    def _binop(self, lv: AVal, op, rv: AVal, node) -> AVal:
        if isinstance(lv, AConst) and isinstance(rv, AConst):
            try:
                return AConst(_PYOPS[type(op)](lv.value, rv.value))
            except Exception:
                return UNKNOWN
        ld, rd = as_dim(lv), as_dim(rv)
        if ld is not None and rd is not None \
                and not isinstance(lv, AArray) and not isinstance(rv, AArray):
            if isinstance(op, ast.Add):
                return AInt(ld + rd)
            if isinstance(op, ast.Sub):
                return AInt(ld - rd)
            if isinstance(op, ast.Mult):
                return AInt(ld * rd)
            if isinstance(op, ast.FloorDiv):
                return AInt(ld // rd)
            if isinstance(op, ast.Mod):
                return AInt(ld % rd)
            return UNKNOWN
        if isinstance(lv, AArray) or isinstance(rv, AArray):
            return self._array_binop(lv, op, rv, node)
        return UNKNOWN

    def _array_binop(self, lv, op, rv, node) -> AVal:
        def coerce(v):
            if isinstance(v, AArray):
                return v
            if isinstance(v, AInt):
                return AArray((), DType("int", 32, weak=True))
            if isinstance(v, AConst) and isinstance(v.value, bool):
                return AArray((), DType("bool", 8, weak=True))
            if isinstance(v, AConst) and isinstance(v.value, int):
                return AArray((), DType("int", 32, weak=True))
            if isinstance(v, AConst) and isinstance(v.value, float):
                return AArray((), DType("float", 32, weak=True))
            return None
        la, ra = coerce(lv), coerce(rv)
        if la is None or ra is None:
            return UNKNOWN
        shape = self._broadcast(la.shape, ra.shape, node)
        if isinstance(op, (ast.LShift, ast.RShift)):
            return AArray(shape, la.dtype if isinstance(lv, AArray)
                          else ra.dtype)
        dt = promote(la.dtype, ra.dtype)
        if isinstance(op, (ast.Div,)):
            dt = DType("float", 32) if dt.kind != "float" else dt
        return AArray(shape, dt)

    def _broadcast(self, sa: tuple, sb: tuple, node,
                   what: str = "operands") -> tuple:
        out: list = []
        la, lb = len(sa), len(sb)
        for i in range(max(la, lb)):
            a = sa[la - 1 - i] if i < la else Dim.const_(1)
            b = sb[lb - 1 - i] if i < lb else Dim.const_(1)
            if a == b:
                out.append(a)
            elif a == Dim.const_(1):
                out.append(b)
            elif b == Dim.const_(1):
                out.append(a)
            elif a.has_opaque or b.has_opaque:
                out.append(_fresh("bcast"))
            else:
                self.problem(
                    node, f"shape mismatch broadcasting {what}: "
                    f"{_shape_str(sa)} vs {_shape_str(sb)} "
                    f"(dim {a} vs {b})")
                out.append(_fresh("bcast"))
        return tuple(reversed(out))

    def _compare(self, node, env, module) -> AVal:
        if len(node.ops) != 1:
            return UNKNOWN
        lv = self._eval(node.left, env, module)
        rv = self._eval(node.comparators[0], env, module)
        op = node.ops[0]
        if isinstance(op, (ast.Is, ast.IsNot)):
            def known_none(v):
                if isinstance(v, AConst):
                    return v.value is None
                if isinstance(v, (AArray, ATuple, AInt, ADType, AClosure)):
                    return False
                return None
            ln, rn = known_none(lv), known_none(rv)
            if isinstance(rv, AConst) and rv.value is None and ln is not None:
                return AConst(ln if isinstance(op, ast.Is) else not ln)
            if isinstance(lv, AConst) and lv.value is None and rn is not None:
                return AConst(rn if isinstance(op, ast.Is) else not rn)
            return UNKNOWN
        if isinstance(lv, AConst) and isinstance(rv, AConst):
            try:
                return AConst(_PYCMP[type(op)](lv.value, rv.value))
            except Exception:
                return UNKNOWN
        li, ri = self._concrete_int(lv), self._concrete_int(rv)
        if li is not None and ri is not None:
            return AConst(_PYCMP[type(op)](li, ri))
        if isinstance(lv, AArray) or isinstance(rv, AArray):
            la = lv if isinstance(lv, AArray) else AArray((), _INT32)
            ra = rv if isinstance(rv, AArray) else AArray((), _INT32)
            shape = self._broadcast(la.shape, ra.shape, node,
                                    what="comparison operands")
            return AArray(shape, DType("bool", 8))
        return UNKNOWN

    # -- calls -------------------------------------------------------------
    def _eval_call(self, node, env, module) -> AVal:
        func = self._eval(node.func, env, module)
        args: list[AVal] = []
        for a in node.args:
            if isinstance(a, ast.Starred):
                v = self._eval(a.value, env, module)
                if isinstance(v, ATuple):
                    args.extend(v.items)
                else:
                    args.append(UNKNOWN)
            else:
                args.append(self._eval(a, env, module))
        kwargs: dict[str, AVal] = {}
        for kw in node.keywords:
            if kw.arg is None:
                continue
            kwargs[kw.arg] = self._eval(kw.value, env, module)
        return self._call(func, args, kwargs, node)

    def _call(self, func: AVal, args: list, kwargs: dict, node) -> AVal:
        if isinstance(func, AClosure):
            return self._call_closure(func, args, kwargs, node)
        if isinstance(func, APartial):
            merged_kwargs = dict(func.kwargs)
            merged_kwargs.update(kwargs)
            return self._call(func.func, list(func.args) + args,
                              merged_kwargs, node)
        if isinstance(func, ABound):
            return self._call_method(func, args, kwargs, node)
        if isinstance(func, ADType):
            return AArray((), func.dtype)
        if isinstance(func, APallasCall):
            return self._run_pallas(func, args, node)
        if isinstance(func, AFunc):
            handler = _PRIMITIVES.get(func.name)
            if handler is not None:
                return handler(self, func, args, kwargs, node)
            return UNKNOWN
        return UNKNOWN

    def _call_closure(self, c: AClosure, args, kwargs, node) -> AVal:
        fn = c.node
        if isinstance(fn, ast.Lambda):
            env = dict(c.env)
            a = fn.args
            names = [p.arg for p in a.posonlyargs + a.args]
            for i, name in enumerate(names):
                if i < len(args):
                    env[name] = args[i]
                elif name in kwargs:
                    env[name] = kwargs[name]
            defaults = a.defaults
            for i, d in enumerate(defaults):
                name = names[len(names) - len(defaults) + i]
                if name not in env:
                    env[name] = self._eval(d, dict(c.env), c.module)
            for name in names:
                env.setdefault(name, UNKNOWN)
            self._depth += 1
            self._rel_stack.append(c.module.rel)
            try:
                if self._depth > self.max_depth:
                    return UNKNOWN
                return self._eval(fn.body, env, c.module)
            finally:
                self._rel_stack.pop()
                self._depth -= 1
        return self.call_function(fn, c.module, args, dict(kwargs))

    def _call_method(self, bound: ABound, args, kwargs, node) -> AVal:
        base, attr = bound.base, bound.attr
        if isinstance(base, AArray):
            if attr == "astype":
                dt = args[0] if args else kwargs.get("dtype")
                if isinstance(dt, ADType):
                    return AArray(base.shape, dt.dtype)
                return AArray(base.shape, base.dtype)
            if attr == "reshape":
                shape_args = (args[0].items
                              if len(args) == 1 and isinstance(args[0], ATuple)
                              else args)
                return self._reshape(base, shape_args, node)
            if attr in ("copy", "ravel", "flatten"):
                if attr == "copy":
                    return base
                total = Dim.const_(1)
                for d in base.shape:
                    total = total * d
                return AArray((total,), base.dtype)
            if attr in ("sum", "mean", "min", "max"):
                return UNKNOWN
            return UNKNOWN
        if isinstance(base, AAtIndexed):
            if args:
                self._check_store(base.base, base.index_shape, args[0], node)
            return base.base
        if isinstance(base, ATuple):
            if attr == "append" and base.mutable and args:
                base.items.append(args[0])
                return AConst(None)
            if attr == "extend" and base.mutable and args \
                    and isinstance(args[0], ATuple):
                base.items.extend(args[0].items)
                return AConst(None)
            return UNKNOWN
        return UNKNOWN

    def _reshape(self, base: AArray, shape_args: list, node) -> AVal:
        total = Dim.const_(1)
        for d in base.shape:
            total = total * d
        dims: list[Dim | None] = []
        hole = None
        for i, a in enumerate(shape_args):
            v = self._concrete_int(a)
            if v == -1:
                hole = i
                dims.append(None)
                continue
            d = as_dim(a)
            dims.append(d if d is not None else _fresh("reshape"))
        if hole is not None:
            known = Dim.const_(1)
            for d in dims:
                if d is not None:
                    known = known * d
            rem = _try_exact_div(total, known)
            dims[hole] = rem if rem is not None else _fresh("reshape")
        return AArray(tuple(dims), base.dtype)

    # -- pallas ------------------------------------------------------------
    def _run_pallas(self, pc: APallasCall, operands: list, node) -> AVal:
        gs = pc.grid_spec
        out_shapes = (pc.out_shape.items
                      if isinstance(pc.out_shape, ATuple)
                      else [pc.out_shape])
        out_shapes = [o for o in out_shapes if isinstance(o, AShapeDtype)]
        if not isinstance(gs, AGridSpec):
            return (AArray(out_shapes[0].shape, out_shapes[0].dtype)
                    if out_shapes else UNKNOWN)
        nsp = gs.num_scalar_prefetch
        grid = gs.grid.items if isinstance(gs.grid, ATuple) else []
        in_specs = gs.in_specs.items if isinstance(gs.in_specs, ATuple) else []
        data_ops = operands[nsp:]
        if len(in_specs) != len(data_ops):
            self.problem(
                node, f"pallas_call got {len(data_ops)} data operand(s) "
                f"after {nsp} scalar-prefetch arg(s) but the grid spec "
                f"declares {len(in_specs)} in_spec(s)", category="pallas")
            return (AArray(out_shapes[0].shape, out_shapes[0].dtype)
                    if out_shapes else UNKNOWN)
        refs: list[AVal] = list(operands[:nsp])
        for spec, op in zip(in_specs, data_ops):
            refs.append(self._check_spec(spec, op, len(grid), nsp,
                                         operands[:nsp], node, "in_spec"))
        out_specs = (gs.out_specs.items
                     if isinstance(gs.out_specs, ATuple) else [gs.out_specs])
        out_refs: list[AVal] = []
        for spec, osd in zip(out_specs, out_shapes):
            op = AArray(osd.shape, osd.dtype)
            out_refs.append(self._check_spec(spec, op, len(grid), nsp,
                                             operands[:nsp], node, "out_spec"))
        kernel = pc.kernel
        if isinstance(kernel, (AClosure, APartial)):
            self._call(kernel, refs + out_refs, {}, node)
        if out_shapes:
            result = [AArray(o.shape, o.dtype) for o in out_shapes]
            return result[0] if len(result) == 1 else ATuple(result)
        return UNKNOWN

    def _check_spec(self, spec, op, n_grid, nsp, prefetch, node,
                    what: str) -> AVal:
        if not isinstance(spec, ABlockSpec) or not isinstance(op, AArray):
            return op if isinstance(op, AArray) else UNKNOWN
        bs = spec.block_shape
        bdims_v = bs.items if isinstance(bs, ATuple) else None
        if bdims_v is None:
            return op
        bdims = [as_dim(v) for v in bdims_v]
        line = spec.line or node
        if len(bdims) != op.ndim:
            self.problem(
                line, f"BlockSpec {what} has rank {len(bdims)} but the "
                f"operand is rank {op.ndim} ({_shape_str(op.shape)})",
                category="pallas")
            return op
        for i, (b, o) in enumerate(zip(bdims, op.shape)):
            if b is None or b.has_opaque or o.has_opaque:
                continue
            if not o.divisible_by(b):
                self.problem(
                    line, f"BlockSpec {what} dim {i}: block size {b} does "
                    f"not evenly divide operand dim {o} — the grid would "
                    "read a ragged final block", category="pallas")
        im = spec.index_map
        if isinstance(im, (AClosure, APartial)):
            arity = _callable_arity(im)
            want = n_grid + nsp
            if arity is not None and not (arity[0] <= want <= arity[1]):
                self.problem(
                    line, f"BlockSpec {what} index_map takes "
                    f"{arity[0]}..{arity[1]} arg(s) but the grid supplies "
                    f"{want} (grid rank {n_grid} + {nsp} scalar-prefetch)",
                    category="pallas")
            else:
                idx_args = [AInt(_fresh("grid")) for _ in range(n_grid)]
                res = self._call(im, idx_args + list(prefetch), {}, node)
                if isinstance(res, ATuple) and len(res.items) != len(bdims):
                    self.problem(
                        line, f"BlockSpec {what} index_map returns "
                        f"{len(res.items)} indices for a rank-{len(bdims)} "
                        "block", category="pallas")
        block_dims = [d if d is not None else _fresh("block") for d in bdims]
        return AArray(tuple(block_dims), op.dtype)


def _callable_arity(f) -> tuple[int, int] | None:
    while isinstance(f, APartial):
        inner = _callable_arity(f.func)
        if inner is None:
            return None
        return (max(0, inner[0] - len(f.args)), inner[1] - len(f.args))
    if isinstance(f, AClosure):
        a = f.node.args
        names = a.posonlyargs + a.args
        return (len(names) - len(a.defaults), len(names))
    return None


_PYOPS = {
    ast.Add: lambda a, b: a + b, ast.Sub: lambda a, b: a - b,
    ast.Mult: lambda a, b: a * b, ast.Div: lambda a, b: a / b,
    ast.FloorDiv: lambda a, b: a // b, ast.Mod: lambda a, b: a % b,
    ast.Pow: lambda a, b: a ** b, ast.LShift: lambda a, b: a << b,
    ast.RShift: lambda a, b: a >> b, ast.BitOr: lambda a, b: a | b,
    ast.BitAnd: lambda a, b: a & b, ast.BitXor: lambda a, b: a ^ b,
}

_PYCMP = {
    ast.Eq: lambda a, b: a == b, ast.NotEq: lambda a, b: a != b,
    ast.Lt: lambda a, b: a < b, ast.LtE: lambda a, b: a <= b,
    ast.Gt: lambda a, b: a > b, ast.GtE: lambda a, b: a >= b,
    ast.In: lambda a, b: a in b, ast.NotIn: lambda a, b: a not in b,
}


# ---------------------------------------------------------------------------
# Primitive models
# ---------------------------------------------------------------------------

def _shape_from(v: AVal) -> tuple | None:
    if isinstance(v, ATuple):
        dims = [as_dim(i) for i in v.items]
        if all(d is not None for d in dims):
            return tuple(dims)
        return tuple(d if d is not None else _fresh("shape") for d in dims)
    d = as_dim(v)
    if d is not None:
        return (d,)
    return None


def _dtype_from(v: AVal | None, default: DType) -> DType:
    if isinstance(v, ADType):
        return v.dtype
    return default


def _p_zeros(self, func, args, kwargs, node):
    shape = _shape_from(args[0]) if args else None
    dt = _dtype_from(args[1] if len(args) > 1 else kwargs.get("dtype"), _F32)
    if shape is None:
        return UNKNOWN
    return AArray(shape, dt)


def _p_asarray(self, func, args, kwargs, node):
    if not args:
        return UNKNOWN
    v = args[0]
    dt_arg = args[1] if len(args) > 1 else kwargs.get("dtype")
    if isinstance(v, AArray):
        dt = _dtype_from(dt_arg, v.dtype)
        return AArray(v.shape, canonicalize(dt) if func.name.startswith("jnp")
                      else dt)
    if isinstance(v, ATuple):
        dims = [as_dim(i) for i in v.items]
        if all(d is not None for d in dims):
            dt = _dtype_from(dt_arg, _INT32)
            return AArray((Dim.const_(len(dims)),), dt)
    d = as_dim(v)
    if d is not None:
        return AArray((), _dtype_from(dt_arg, DType("int", 32, weak=True)))
    return UNKNOWN


def _p_arange(self, func, args, kwargs, node):
    if len(args) == 1:
        d = as_dim(args[0])
        if d is not None:
            return AArray((d,), _dtype_from(kwargs.get("dtype"), _INT32))
    if len(args) == 2:
        lo, hi = as_dim(args[0]), as_dim(args[1])
        if lo is not None and hi is not None:
            return AArray((hi - lo,), _dtype_from(kwargs.get("dtype"), _INT32))
    return UNKNOWN


def _p_pad(self, func, args, kwargs, node):
    if len(args) < 2 or not isinstance(args[0], AArray):
        return UNKNOWN
    arr, spec = args[0], args[1]
    if not isinstance(spec, ATuple):
        return UNKNOWN
    pads = []
    for item in spec.items:
        if isinstance(item, ATuple) and len(item.items) == 2:
            lo, hi = as_dim(item.items[0]), as_dim(item.items[1])
            if lo is None or hi is None:
                return UNKNOWN
            pads.append((lo, hi))
        else:
            return UNKNOWN
    if len(pads) != arr.ndim:
        self.problem(node, f"jnp.pad gives {len(pads)} pad pairs for a "
                           f"rank-{arr.ndim} array")
        return UNKNOWN
    shape = tuple(d + lo + hi for d, (lo, hi) in zip(arr.shape, pads))
    return AArray(shape, arr.dtype)


def _p_dot(self, func, args, kwargs, node):
    if len(args) < 2 or not isinstance(args[0], AArray) \
            or not isinstance(args[1], AArray):
        return UNKNOWN
    a, b = args[0], args[1]
    if a.ndim == 0 or b.ndim == 0:
        return UNKNOWN
    ka = a.shape[-1]
    kb = b.shape[-2] if b.ndim >= 2 else b.shape[0]
    if not (ka.has_opaque or kb.has_opaque) and ka != kb:
        self.problem(node, f"jnp.dot contraction mismatch: "
                           f"{_shape_str(a.shape)} · {_shape_str(b.shape)} "
                           f"(contracting dim {ka} vs {kb})")
    out = a.shape[:-1] + (b.shape[:-2] + b.shape[-1:] if b.ndim >= 2 else ())
    dt = _dtype_from(kwargs.get("preferred_element_type"),
                     promote(a.dtype, b.dtype))
    return AArray(out, dt)


def _p_take_along_axis(self, func, args, kwargs, node):
    if len(args) < 2 or not isinstance(args[0], AArray) \
            or not isinstance(args[1], AArray):
        return UNKNOWN
    arr, idx = args[0], args[1]
    axis = self._concrete_int(args[2] if len(args) > 2 else kwargs.get("axis"))
    if axis is None or arr.ndim != idx.ndim:
        if axis is not None and arr.ndim != idx.ndim:
            self.problem(node, "jnp.take_along_axis needs equal ranks: "
                               f"{_shape_str(arr.shape)} vs "
                               f"{_shape_str(idx.shape)}")
        return UNKNOWN
    axis = axis % arr.ndim
    out = []
    for i in range(arr.ndim):
        if i == axis:
            out.append(idx.shape[i])
        else:
            a, b = arr.shape[i], idx.shape[i]
            if a == b or b == Dim.const_(1):
                out.append(a)
            elif a == Dim.const_(1):
                out.append(b)
            elif a.has_opaque or b.has_opaque:
                out.append(_fresh("taa"))
            else:
                self.problem(node, "jnp.take_along_axis non-axis dim "
                                   f"{i} mismatch: {a} vs {b}")
                out.append(_fresh("taa"))
    return AArray(tuple(out), arr.dtype)


def _p_elementwise(self, func, args, kwargs, node):
    arrays = [a for a in args if isinstance(a, AArray)]
    if not arrays:
        return UNKNOWN
    shape = arrays[0].shape
    dt = arrays[0].dtype
    for other in arrays[1:]:
        shape = self._broadcast(shape, other.shape, node,
                                what=func.name.split(".")[-1] + " operands")
        dt = promote(dt, other.dtype)
    return AArray(shape, dt)


def _p_shift(self, func, args, kwargs, node):
    if args and isinstance(args[0], AArray):
        return args[0]
    return UNKNOWN


def _p_segment_sum(self, func, args, kwargs, node):
    if len(args) < 2 or not isinstance(args[0], AArray) \
            or not isinstance(args[1], AArray):
        return UNKNOWN
    data, ids = args[0], args[1]
    ns = kwargs.get("num_segments",
                    args[2] if len(args) > 2 else None)
    ns_dim = as_dim(ns) if ns is not None else None
    sorted_flag = False
    s = kwargs.get("indices_are_sorted")
    if isinstance(s, AConst):
        sorted_flag = bool(s.value)
    self.segment_sums.append(SegmentSum(
        line=getattr(node, "lineno", 0), data_shape=data.shape,
        ids_shape=ids.shape, num_segments=ns_dim,
        indices_are_sorted=sorted_flag, rel=self.current_rel))
    if ids.ndim >= 1 and data.ndim >= 1:
        a, b = data.shape[0], ids.shape[0]
        if not (a.has_opaque or b.has_opaque) and a != b:
            self.problem(node, "segment_sum data/segment_ids leading dims "
                               f"differ: {a} vs {b}")
    lead = (ns_dim,) if ns_dim is not None else (_fresh("segments"),)
    return AArray(lead + data.shape[ids.ndim:], data.dtype)


def _p_vmap(self, func, args, kwargs, node):
    if args:
        return AFunc("jax.vmap#mapped", payload=(args[0],))
    return UNKNOWN


def _p_vmapped(self, func, args, kwargs, node):
    target = func.payload[0]
    arrays = [a for a in args if isinstance(a, AArray) and a.ndim >= 1]
    if not arrays:
        return UNKNOWN
    lead = arrays[0].shape[0]
    for other in arrays[1:]:
        j = join_dims(lead, other.shape[0])
        if j is None and not (lead.has_opaque or other.shape[0].has_opaque):
            self.problem(node, "jax.vmap operands disagree on the mapped "
                               f"axis: {lead} vs {other.shape[0]}")
        lead = j if j is not None else lead
    inner = [AArray(a.shape[1:], a.dtype) if isinstance(a, AArray)
             and a.ndim >= 1 else a for a in args]
    res = self._call(target, inner, {}, node)
    if isinstance(res, AArray):
        return AArray((lead,) + res.shape, res.dtype)
    if isinstance(res, ATuple):
        return ATuple([AArray((lead,) + r.shape, r.dtype)
                       if isinstance(r, AArray) else UNKNOWN
                       for r in res.items])
    return UNKNOWN


def _p_iota(self, func, args, kwargs, node):
    if len(args) >= 2:
        dt = _dtype_from(args[0], _INT32)
        shape = _shape_from(args[1])
        if shape is not None:
            return AArray(shape, dt)
    return UNKNOWN


def _p_partial(self, func, args, kwargs, node):
    if not args:
        return UNKNOWN
    return APartial(args[0], args[1:], dict(kwargs))


def _p_shape_dtype(self, func, args, kwargs, node):
    shape = _shape_from(args[0] if args else kwargs.get("shape"))
    dt = _dtype_from(args[1] if len(args) > 1 else kwargs.get("dtype"), _F32)
    if shape is None:
        return UNKNOWN
    return AShapeDtype(shape, dt)


def _p_blockspec(self, func, args, kwargs, node):
    bs = args[0] if args else kwargs.get("block_shape", UNKNOWN)
    im = args[1] if len(args) > 1 else kwargs.get("index_map", UNKNOWN)
    return ABlockSpec(bs, im, getattr(node, "lineno", 0))


def _p_gridspec(self, func, args, kwargs, node):
    nsp = self._concrete_int(kwargs.get("num_scalar_prefetch", AConst(0)))
    return AGridSpec(
        grid=kwargs.get("grid", UNKNOWN),
        in_specs=kwargs.get("in_specs", UNKNOWN),
        out_specs=kwargs.get("out_specs", UNKNOWN),
        num_scalar_prefetch=nsp if nsp is not None else 0,
        line=getattr(node, "lineno", 0))


def _p_pallas_call(self, func, args, kwargs, node):
    return APallasCall(
        kernel=args[0] if args else UNKNOWN,
        grid_spec=kwargs.get("grid_spec", UNKNOWN),
        out_shape=kwargs.get("out_shape", UNKNOWN),
        line=getattr(node, "lineno", 0))


def _p_len(self, func, args, kwargs, node):
    if args and isinstance(args[0], ATuple):
        return AConst(len(args[0].items))
    if args and isinstance(args[0], AConst) and \
            isinstance(args[0].value, (tuple, list, str, range)):
        return AConst(len(args[0].value))
    return UNKNOWN


def _p_range(self, func, args, kwargs, node):
    vals = [self._concrete_int(a) for a in args]
    if all(v is not None for v in vals) and vals:
        return ATuple([AConst(v) for v in range(*vals)])
    return UNKNOWN


def _as_atuple(v: AVal) -> ATuple | None:
    if isinstance(v, ATuple):
        return v
    if isinstance(v, AConst) and isinstance(v.value, (tuple, list, range)):
        return ATuple([AConst(x) for x in v.value])
    return None


def _p_enumerate(self, func, args, kwargs, node):
    it = _as_atuple(args[0]) if args else None
    if it is not None:
        return ATuple([ATuple([AConst(i), v])
                       for i, v in enumerate(it.items)])
    return UNKNOWN


def _p_zip(self, func, args, kwargs, node):
    cols = []
    for a in args:
        t = _as_atuple(a)
        if t is None:
            return UNKNOWN
        cols.append(t.items)
    return ATuple([ATuple(list(row)) for row in zip(*cols)])


def _p_reversed(self, func, args, kwargs, node):
    it = _as_atuple(args[0]) if args else None
    if it is not None:
        return ATuple(list(reversed(it.items)))
    return UNKNOWN


def _p_tuple(self, func, args, kwargs, node):
    if not args:
        return ATuple([], mutable=func.name == "builtin.list")
    it = _as_atuple(args[0])
    if it is not None:
        return ATuple(list(it.items),
                      mutable=func.name == "builtin.list")
    return UNKNOWN


def _p_minmax(self, func, args, kwargs, node):
    vals = [self._concrete_int(a) for a in args]
    if len(args) >= 2 and all(v is not None for v in vals):
        f = max if func.name.endswith("max") else min
        return AConst(f(*vals))
    # symbolic max/min: no algebra; pass through a single unambiguous arg
    if len(args) == 2:
        da, db = as_dim(args[0]), as_dim(args[1])
        if da is not None and da == db:
            return AInt(da)
    return UNKNOWN


def _p_int(self, func, args, kwargs, node):
    if args:
        v = self._concrete_int(args[0])
        if v is not None:
            return AConst(v)
        d = as_dim(args[0])
        if d is not None:
            return AInt(d)
    return UNKNOWN


def _p_jit(self, func, args, kwargs, node):
    return args[0] if args else UNKNOWN


def _p_identity_array(self, func, args, kwargs, node):
    if args and isinstance(args[0], AArray):
        return args[0]
    return UNKNOWN


_PRIMITIVES = {
    "jnp.zeros": _p_zeros, "jnp.ones": _p_zeros, "jnp.empty": _p_zeros,
    "jnp.full": _p_zeros,
    "jnp.asarray": _p_asarray, "jnp.array": _p_asarray,
    "np.asarray": _p_asarray, "np.array": _p_asarray,
    "jnp.arange": _p_arange,
    "jnp.pad": _p_pad,
    "jnp.dot": _p_dot, "jnp.matmul": _p_dot,
    "jnp.take_along_axis": _p_take_along_axis,
    "jnp.minimum": _p_elementwise, "jnp.maximum": _p_elementwise,
    "jnp.where": _p_elementwise, "jnp.clip": _p_elementwise,
    "jnp.add": _p_elementwise, "jnp.multiply": _p_elementwise,
    "jnp.right_shift": _p_shift, "jnp.left_shift": _p_shift,
    "lax.shift_right_arithmetic": _p_shift,
    "lax.shift_right_logical": _p_shift, "lax.shift_left": _p_shift,
    "jnp.round": _p_identity_array, "jnp.abs": _p_identity_array,
    "jnp.exp": _p_identity_array, "jnp.sqrt": _p_identity_array,
    "lax.broadcasted_iota": _p_iota,
    "jax.ops.segment_sum": _p_segment_sum,
    "jax.vmap": _p_vmap, "jax.vmap#mapped": _p_vmapped,
    "jax.jit": _p_jit,
    "jax.ShapeDtypeStruct": _p_shape_dtype,
    "pl.BlockSpec": _p_blockspec,
    "pltpu.PrefetchScalarGridSpec": _p_gridspec,
    "pl.pallas_call": _p_pallas_call,
    "functools.partial": _p_partial,
    "builtin.len": _p_len, "builtin.range": _p_range,
    "builtin.enumerate": _p_enumerate, "builtin.zip": _p_zip,
    "builtin.reversed": _p_reversed, "builtin.tuple": _p_tuple,
    "builtin.list": _p_tuple, "builtin.max": _p_minmax,
    "builtin.min": _p_minmax, "builtin.int": _p_int,
}
