"""Integer-width rules: the places where a silently wrapped index loses.

The stack has exactly one deliberate width seam: host-side packing code
(`core/chunking.py`, `core/baselines.py`, `formats/*.py`) runs its
linearization arithmetic in `np.int64`/`np.uint64` — linearized chunk
keys, ALTO bit-packed keys, lexsort permutations — while everything a
device ever touches is `jnp.int32` (coordinates) or `jnp.uint32` (key
words).  Each crossing of that seam is a narrowing cast whose safety is
an argument about reachable magnitudes, and nothing at runtime checks
it: NumPy's `astype` wraps, device int arithmetic wraps, and the wrong
answer looks like a plausible tensor.

Three rules pin the arguments down:

  int32-index-width — dataflow over each host function: names holding
      64-bit signed values (explicit ``dtype=np.int64`` creation,
      ``.astype(np.int64)``, ``np.argsort`` — which returns the platform
      64-bit index type) are tracked through assignments, and every
      ``.astype(np.int32)`` whose operand mentions a tracked name is
      flagged unless the function visibly guards the magnitude (an
      ``if``-gated ``raise`` mentioning the int32 limit).  The
      chunking-grid downcast this PR guards is the canonical site.
  alto-key-width — the ALTO key-bit accounting is one invariant spread
      over two modules: `formats/alto.py` packs `sum(ceil(log2(dim)))`
      bits into 32-bit words behind a ``> MAX_KEY_BITS`` raise, and
      `core/mttkrp.py::_alto_decode` unpacks with the same word
      geometry.  Every hard-coded word constant (``// 32``, ``% 32``,
      ``32 * w``, the ``0xFFFFFFFF`` mask, the 4-bytes-per-word size
      model) must agree — the BLCO 64-bit lift on the ROADMAP will touch
      all of them at once, and this rule is what makes touching only
      some of them fail.
  qformat-accumulator — re-derives the int32 accumulator overflow bound
      of the fixed path from `core/qformat.py`'s preset table (factor
      products must fit int32, and nnz-per-row beyond
      ``(2^31-1) >> (frac + 15 - value_frac - prec_shift)`` can wrap),
      cross-checks the values pinned in `kernel_contracts.json`, and
      checks the Alg.-2 renormalizing shifts are still present in the
      three fixed inner loops the derivation assumes.
"""
from __future__ import annotations

import ast
import re

from .engine import FileContext, ProjectContext, register_rule
from .shape_rules import load_contracts

__all__ = [
    "check_alto_key_width",
    "check_int32_index_width",
    "check_qformat_accumulator",
]

_WIDTH_TARGETS = ("src/repro/core", "src/repro/formats")


# ---------------------------------------------------------------------------
# int32-index-width
# ---------------------------------------------------------------------------

def _dotted(node: ast.AST) -> str | None:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


_INT64_CREATORS = {"np.asarray", "np.array", "np.zeros", "np.empty",
                   "np.full", "np.arange"}


def _mentions_int64(node: ast.AST) -> bool:
    return any(_dotted(n) == "np.int64" for n in ast.walk(node))


def _mentions_int32(node: ast.AST) -> bool:
    return any(_dotted(n) in ("np.int32", "jnp.int32")
               for n in ast.walk(node))


def _is_wide_expr(node: ast.AST, wide: set[str]) -> bool:
    """Does this RHS *itself* produce a 64-bit signed value?  Deliberately
    shallow — a producer call, a tracked name, index/slice/arithmetic on
    one — so a value laundered through an untracked library call drops
    out of the analysis instead of producing speculative findings."""
    if isinstance(node, ast.Name):
        return node.id in wide
    if isinstance(node, ast.Subscript):
        return _is_wide_expr(node.value, wide)
    if isinstance(node, ast.BinOp):
        return (_is_wide_expr(node.left, wide)
                or _is_wide_expr(node.right, wide))
    if isinstance(node, ast.UnaryOp):
        return _is_wide_expr(node.operand, wide)
    if isinstance(node, ast.Call):
        fn = _dotted(node.func)
        if fn == "np.argsort":
            return True
        if fn in _INT64_CREATORS and any(
                kw.arg == "dtype" and _mentions_int64(kw.value)
                for kw in node.keywords):
            return True
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "astype" \
                and any(_mentions_int64(a) for a in node.args):
            return True
    return False


def _wide_names(fn: ast.FunctionDef) -> set[str]:
    wide: set[str] = set()
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)):
                continue
            name = node.targets[0].id
            if name not in wide and _is_wide_expr(node.value, wide):
                wide.add(name)
                changed = True
    return wide


_GUARD_RE = re.compile(r"iinfo\s*\(\s*np\.int32\s*\)|2\s*\*\*\s*31"
                       r"|2147483647|1\s*<<\s*31")


def _has_int32_guard(fn: ast.FunctionDef, source: str) -> bool:
    """An `if`-gated `raise` whose test talks about the int32 limit — the
    shape of the chunking-grid guard.  Per-function: one guard vouches
    for every downcast after it in the same function."""
    for node in ast.walk(fn):
        if isinstance(node, ast.If) \
                and any(isinstance(n, ast.Raise) for n in ast.walk(node)):
            seg = ast.get_source_segment(source, node.test) or ""
            if _GUARD_RE.search(seg):
                return True
    return False


@register_rule(
    "int32-index-width",
    scope="file",
    tier="dataflow",
    packages=_WIDTH_TARGETS,
    description=("a 64-bit index value (int64 creation, .astype(np.int64), "
                 "np.argsort) narrowed with .astype(np.int32) in a function "
                 "with no visible int32 magnitude guard"),
    rationale=("host packing code linearizes in np.int64 while device "
               "coordinates are jnp.int32 — NumPy's astype wraps silently, "
               "so an unguarded narrowing turns a >2^31 extent into "
               "negative coordinates that scatter into wrong output rows "
               "with no error anywhere; an explicit if/raise naming the "
               "int32 limit is both the fix and what quiets the rule"),
    example=("chunking.py: `st.coords // cs.astype(np.int32)` where "
             "cs = np.asarray(chunk_shape, dtype=np.int64)"),
)
def check_int32_index_width(ctx: FileContext):
    for fn in ast.walk(ctx.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        wide = _wide_names(fn)
        if not wide:
            continue
        guarded = _has_int32_guard(fn, ctx.source)
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype"
                    and any(_mentions_int32(a) for a in node.args)):
                continue
            names = sorted({n.id for n in ast.walk(node.func.value)
                            if isinstance(n, ast.Name) and n.id in wide})
            if not names or guarded:
                continue
            yield ctx.finding(
                "int32-index-width", node,
                f"{fn.name} narrows 64-bit index value(s) "
                f"{', '.join(names)} with .astype(np.int32) and has no "
                "int32 magnitude guard — astype wraps silently past 2^31; "
                "gate the cast with an if/raise naming np.iinfo(np.int32)")


# ---------------------------------------------------------------------------
# alto-key-width
# ---------------------------------------------------------------------------

_ALTO_FILE = "src/repro/formats/alto.py"
_ALTO_DECODE_FILE = "src/repro/core/mttkrp.py"
#: functions whose word-geometry constants must agree with the 32-bit pack
_ALTO_WORD_FNS = {
    _ALTO_FILE: ("build_alto", "alto_decode_mode"),
    _ALTO_DECODE_FILE: ("_alto_decode",),
}
_WORD_SUSPECTS = (8, 16, 64, 128)          # a //,% or shift by these ≠ 32
_MASK_SUSPECTS = {(1 << 8) - 1, (1 << 16) - 1, (1 << 64) - 1}


def _module_const(tree: ast.Module, name: str):
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name \
                        and isinstance(node.value, ast.Constant):
                    return node.value.value
    return None


def _fn(tree: ast.Module, name: str) -> ast.FunctionDef | None:
    for node in tree.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


@register_rule(
    "alto-key-width",
    scope="project",
    tier="dataflow",
    description=("ALTO key-bit accounting: MAX_KEY_BITS capacity raise in "
                 "build_alto, 32-bit word geometry (// 32, % 32, 32*w, "
                 "0xFFFFFFFF, 4 bytes/word) consistent across alto.py and "
                 "core/mttkrp.py::_alto_decode"),
    rationale=("the packed key layout is one invariant implemented twice — "
               "host pack/decode in formats/alto.py, device decode in "
               "core/mttkrp.py — plus a byte-size model the autotuner "
               "costs with; the ROADMAP BLCO lift to >64-bit keys must "
               "change every one of these together, and a partial edit "
               "decodes garbage coordinates with no runtime error"),
    example="_alto_decode splits words with p // 64 but alto.py packs 32-bit words",
)
def check_alto_key_width(ctx: ProjectContext):
    alto = ctx.file(_ALTO_FILE)
    if alto is None:
        yield ctx.finding("alto-key-width", _ALTO_FILE, 1,
                          "formats/alto.py is gone — update alto-key-width's "
                          "anchors if the format moved")
        return
    try:
        tree = alto.tree
    except SyntaxError:
        return                              # syntax-error meta rule owns it

    max_bits = _module_const(tree, "MAX_KEY_BITS")
    if max_bits is None:
        yield ctx.finding(
            "alto-key-width", _ALTO_FILE, 1,
            "MAX_KEY_BITS constant not found in formats/alto.py — the "
            "capacity raise and this rule both key off it")
    elif max_bits > 64:
        yield ctx.finding(
            "alto-key-width", _ALTO_FILE, 1,
            f"MAX_KEY_BITS={max_bits} exceeds 64, but the packed key is "
            "built in a np.uint64 before word-splitting — lifting the cap "
            "(BLCO) needs a multi-word build path first")

    build = _fn(tree, "build_alto")
    if build is None:
        yield ctx.finding("alto-key-width", _ALTO_FILE, 1,
                          "build_alto not found in formats/alto.py")
    else:
        has_guard = any(
            isinstance(n, ast.If)
            and any(isinstance(r, ast.Raise) for r in ast.walk(n))
            and any(isinstance(m, ast.Name) and m.id == "MAX_KEY_BITS"
                    for m in ast.walk(n.test))
            for n in ast.walk(build))
        if not has_guard:
            yield ctx.finding(
                "alto-key-width", _ALTO_FILE, build.lineno,
                "build_alto has no `raise` gated on MAX_KEY_BITS — tensors "
                "whose key exceeds the uint64 build word would pack "
                "truncated keys silently")

    for rel, names in _ALTO_WORD_FNS.items():
        fc = ctx.file(rel)
        if fc is None:
            continue
        try:
            ftree = fc.tree
        except SyntaxError:
            continue
        for name in names:
            fn = _fn(ftree, name)
            if fn is None:
                yield ctx.finding(
                    "alto-key-width", rel, 1,
                    f"{name} not found in {rel} — alto-key-width anchors "
                    "the word-geometry check there")
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.BinOp) \
                        and isinstance(node.op, (ast.FloorDiv, ast.Mod)) \
                        and isinstance(node.right, ast.Constant) \
                        and node.right.value in _WORD_SUSPECTS:
                    yield ctx.finding(
                        "alto-key-width", rel, node.lineno,
                        f"{name} splits key words by {node.right.value}, "
                        "but the pack geometry is 32-bit words — every "
                        "`// 32`/`% 32` site must change together")
                if isinstance(node, ast.Constant) \
                        and node.value in _MASK_SUSPECTS:
                    yield ctx.finding(
                        "alto-key-width", rel, node.lineno,
                        f"{name} masks with {node.value:#x}; the 32-bit "
                        "word mask is 0xFFFFFFFF")

    size_fn = _fn(tree, "alto_index_bytes")
    if size_fn is None:
        yield ctx.finding("alto-key-width", _ALTO_FILE, 1,
                          "alto_index_bytes not found in formats/alto.py")
    else:
        bad = [n for n in ast.walk(size_fn)
               if isinstance(n, ast.Constant) and n.value in (2, 8, 16)]
        has4 = any(isinstance(n, ast.Constant) and n.value == 4
                   for n in ast.walk(size_fn))
        if bad or not has4:
            yield ctx.finding(
                "alto-key-width", _ALTO_FILE, size_fn.lineno,
                "alto_index_bytes must cost 4 bytes per uint32 key word — "
                "the autotuner's footprint model reads this; it drifted "
                "from the 32-bit word geometry")


# ---------------------------------------------------------------------------
# qformat-accumulator
# ---------------------------------------------------------------------------

_QFORMAT_FILE = "src/repro/core/qformat.py"
#: (rel, function) triples that implement the Alg.-2 shift discipline the
#: overflow derivation assumes: one `>> matrix_frac` per factor multiply,
#: one `>> (value_frac + prec_shift)` after the value multiply.
_SHIFT_SITES = (
    ("src/repro/core/mttkrp.py", "_fixed_partials"),
    ("src/repro/kernels/mttkrp_fixed_kernel.py", "_kernel"),
    ("src/repro/kernels/ref.py", "mttkrp_fixed_local_ref"),
)


def _qformat_presets(tree: ast.Module) -> dict[str, tuple[int, int, int]]:
    """FIXED_PRESETS as {name: (int_bits, frac_bits, prec_shift)}, read
    straight off the AST (analysis never imports the runtime)."""
    qdefs: dict[str, tuple[int, int]] = {}
    presets: dict[str, tuple[int, int, int]] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name, v = node.targets[0].id, node.value
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and node.value is not None:
            name, v = node.target.id, node.value
        else:
            continue
        if isinstance(v, ast.Call) and _dotted(v.func) == "QFormat" \
                and len(v.args) == 2 \
                and all(isinstance(a, ast.Constant) for a in v.args):
            qdefs[name] = (v.args[0].value, v.args[1].value)
        elif name == "FIXED_PRESETS" and isinstance(v, ast.Dict):
            for k, item in zip(v.keys, v.values):
                if not (isinstance(k, ast.Constant)
                        and isinstance(item, ast.Tuple)
                        and len(item.elts) == 2
                        and isinstance(item.elts[0], ast.Name)
                        and isinstance(item.elts[1], ast.Constant)):
                    continue
                q = qdefs.get(item.elts[0].id)
                if q is not None:
                    presets[k.value] = (q[0], q[1], item.elts[1].value)
    return presets


def _is_shift_by(node: ast.AST, match) -> bool:
    """A right shift — `>>`, jnp.right_shift, lax.shift_right_arithmetic —
    whose shift amount satisfies `match`."""
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.RShift):
        return match(node.right)
    if isinstance(node, ast.Call) and _dotted(node.func) in (
            "jnp.right_shift", "lax.shift_right_arithmetic",
            "jax.lax.shift_right_arithmetic") and len(node.args) == 2:
        return match(node.args[1])
    return False


def _names_in(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


@register_rule(
    "qformat-accumulator",
    scope="project",
    tier="dataflow",
    description=("fixed-point overflow bounds: factor products fit int32 "
                 "for every FIXED_PRESETS entry, the pinned "
                 "accumulator_safe_nnz values match a re-derivation from "
                 "the preset table, and the Alg.-2 renormalizing shifts "
                 "are present in all three fixed inner loops"),
    rationale=("device int32 arithmetic wraps without trapping, so a "
               "preset whose Q format breaks `2*frac+1 <= 31`, a pinned "
               "safe-nnz bound that no longer follows from the presets, or "
               "a dropped `>> matrix_frac` all corrupt results only on "
               "inputs big enough that nobody unit-tests them — the bound "
               "must be re-derived statically every run"),
    example=("FIXED_PRESETS entry Q20.18 breaks the int32 product bound "
             "(2*18+1 > 31)"),
)
def check_qformat_accumulator(ctx: ProjectContext):
    fc = ctx.file(_QFORMAT_FILE)
    if fc is None:
        yield ctx.finding("qformat-accumulator", _QFORMAT_FILE, 1,
                          "core/qformat.py is gone — update the rule anchors")
        return
    try:
        tree = fc.tree
    except SyntaxError:
        return

    presets = _qformat_presets(tree)
    if not presets:
        yield ctx.finding(
            "qformat-accumulator", _QFORMAT_FILE, 1,
            "could not read FIXED_PRESETS / QFormat literals from "
            "core/qformat.py — the overflow derivation has nothing to "
            "check against")
        return

    contracts = load_contracts(ctx.root) or {}
    qpin = contracts.get("qformat") or {}
    value_frac = qpin.get("value_frac", 7)
    pinned = qpin.get("safe_nnz") or {}

    for name, (int_bits, frac, shift) in sorted(presets.items()):
        if int_bits + frac > 32:
            yield ctx.finding(
                "qformat-accumulator", _QFORMAT_FILE, 1,
                f"preset {name}: Q{int_bits}.{frac} needs "
                f"{int_bits + frac} storage bits (> 32)")
        if 2 * frac + 1 > 31:
            yield ctx.finding(
                "qformat-accumulator", _QFORMAT_FILE, 1,
                f"preset {name}: the product of two Q·.{frac} factor "
                f"values spans {2 * frac + 1} bits and overflows the "
                "int32 multiply Alg. 2 renormalizes (2*frac+1 must be "
                "<= 31)")
        if frac + 15 + 1 > 31:
            yield ctx.finding(
                "qformat-accumulator", _QFORMAT_FILE, 1,
                f"preset {name}: a Q·.{frac} partial times a 16-bit "
                "value spans more than 31 bits before the value shift")
        derived = (2**31 - 1) >> max(frac + 15 - value_frac - shift, 0)
        if name not in pinned:
            yield ctx.finding(
                "qformat-accumulator", _QFORMAT_FILE, 1,
                f"preset {name} has no pinned safe_nnz in "
                f"kernel_contracts.json (derived bound: {derived}) — add "
                "it to the qformat block")
        elif pinned[name] != derived:
            yield ctx.finding(
                "qformat-accumulator", _QFORMAT_FILE, 1,
                f"pinned safe_nnz[{name}]={pinned[name]} but the preset "
                f"table derives {derived} — a preset changed; update the "
                "qformat block in kernel_contracts.json (and any callers "
                "sized by the old bound)")

    for stale in sorted(set(pinned) - set(presets)):
        yield ctx.finding(
            "qformat-accumulator", _QFORMAT_FILE, 1,
            f"pinned safe_nnz entry {stale!r} matches no FIXED_PRESETS "
            "preset — drop it from kernel_contracts.json")

    if not any(isinstance(n, ast.FunctionDef)
               and n.name == "accumulator_safe_nnz"
               for n in ast.walk(tree)):
        yield ctx.finding(
            "qformat-accumulator", _QFORMAT_FILE, 1,
            "accumulator_safe_nnz is missing from core/qformat.py — "
            "callers must be able to ask for the bound the analysis "
            "proves")

    for rel, fname in _SHIFT_SITES:
        sfc = ctx.file(rel)
        if sfc is None:
            continue
        try:
            stree = sfc.tree
        except SyntaxError:
            continue
        fn = None
        for node in ast.walk(stree):
            if isinstance(node, ast.FunctionDef) and node.name == fname:
                fn = node
                break
        if fn is None:
            yield ctx.finding(
                "qformat-accumulator", rel, 1,
                f"{fname} not found in {rel} — the Alg.-2 shift check "
                "anchors there; update _SHIFT_SITES if it moved")
            continue
        has_matrix = any(
            _is_shift_by(n, lambda a: isinstance(a, ast.Name)
                         and a.id == "matrix_frac")
            for n in ast.walk(fn))
        has_value = any(
            _is_shift_by(n, lambda a: {"value_frac", "prec_shift"}
                         <= _names_in(a))
            for n in ast.walk(fn))
        if not has_matrix:
            yield ctx.finding(
                "qformat-accumulator", rel, fn.lineno,
                f"{fname} has no right shift by matrix_frac — without the "
                "per-multiply renormalization the int32 product bound "
                "(and accumulator_safe_nnz) no longer holds")
        if not has_value:
            yield ctx.finding(
                "qformat-accumulator", rel, fn.lineno,
                f"{fname} has no right shift by value_frac + prec_shift — "
                "the accumulator magnitude derivation assumes it")
