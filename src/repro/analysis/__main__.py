"""`python -m repro.analysis` — run the static-analysis pass suite.

Exit codes: 0 clean, 1 findings, 2 usage/setup error (mirrors the
benchmark CLIs' convention).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import default_root, registered_rules, rule_table, run_analysis
from .invariant_rules import regen_manifest


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX tracing hygiene + cross-module invariant checks "
                    "(see docs/static-analysis.md)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on suppression hygiene: unknown rule "
                         "ids in disables, missing reasons, unused "
                         "suppressions (the CI gate)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="emit the machine-readable report on stdout")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: inferred from the installed "
                         "package location)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--regen-manifest", action="store_true",
                    help="regenerate analysis/schema_manifest.json from "
                         "the live persist.py (the intentional-bump "
                         "workflow) and exit")
    args = ap.parse_args(argv)

    root = args.root if args.root is not None else default_root()
    if not (root / "src" / "repro").is_dir():
        print(f"error: {root} does not look like the repo root "
              "(no src/repro/) — pass --root", file=sys.stderr)
        return 2

    if args.list_rules:
        print(rule_table(docs_base=None))
        return 0

    if args.regen_manifest:
        manifest = regen_manifest(root)
        print(f"wrote src/repro/analysis/schema_manifest.json "
              f"(schema_version={manifest['schema_version']}, "
              f"{len(manifest['classes'])} classes)")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in registered_rules()]
        if unknown:
            print(f"error: unknown rule id(s) {unknown}; see --list-rules",
                  file=sys.stderr)
            return 2
        # Keep project/file rules as named; meta checks always apply.
        rules = [r for r in rules
                 if registered_rules()[r].scope in ("file", "project")]

    result = run_analysis(root, rules=rules, strict=args.strict)
    if args.as_json:
        print(json.dumps(result.to_json(), indent=2))
    else:
        print(result.human())
    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
