"""`python -m repro.analysis` — run the static-analysis pass suite.

Exit codes: 0 clean, 1 findings, 2 usage/setup error (mirrors the
benchmark CLIs' convention).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .engine import default_root, registered_rules, rule_table, run_analysis
from .invariant_rules import regen_manifest
from .sarif import to_sarif
from .shape_rules import regen_contracts


def _baseline_key(f: dict) -> tuple:
    # Keyed without the line number: a baseline must survive unrelated
    # edits shifting a known finding up or down the file.
    return (f["rule"], f["path"], f["message"])


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="JAX tracing hygiene, cross-module invariant, and "
                    "shape/dtype/width dataflow checks "
                    "(see docs/static-analysis.md)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on suppression hygiene: unknown rule "
                         "ids in disables, missing reasons, unused "
                         "suppressions (the CI gate)")
    ap.add_argument("--format", choices=("human", "json", "sarif"),
                    default=None,
                    help="report format (default: human)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="alias for --format json")
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: inferred from the installed "
                         "package location)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--tier", choices=("syntactic", "dataflow", "all"),
                    default="all",
                    help="run only one rule tier: 'syntactic' is the "
                         "cheap per-node pass, 'dataflow' the abstract-"
                         "interpretation pass (default: all)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="JSON report of accepted findings (from "
                         "--write-baseline); only findings NOT in it fail "
                         "the run — lets a new rule family land before "
                         "every legacy finding is fixed")
    ap.add_argument("--write-baseline", type=Path, default=None,
                    help="write the current findings to FILE as a baseline "
                         "and exit 0")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--regen-manifest", action="store_true",
                    help="regenerate analysis/schema_manifest.json from "
                         "the live persist.py (the intentional-bump "
                         "workflow) and exit")
    ap.add_argument("--regen-contracts", action="store_true",
                    help="re-pin analysis/kernel_contracts.json signatures "
                         "from the live kernel ASTs (the intentional "
                         "API-drift workflow) and exit")
    args = ap.parse_args(argv)

    fmt = args.format or ("json" if args.as_json else "human")

    root = args.root if args.root is not None else default_root()
    if not (root / "src" / "repro").is_dir():
        print(f"error: {root} does not look like the repo root "
              "(no src/repro/) — pass --root", file=sys.stderr)
        return 2

    if args.list_rules:
        print(rule_table(docs_base=None))
        return 0

    if args.regen_manifest:
        manifest = regen_manifest(root)
        print(f"wrote src/repro/analysis/schema_manifest.json "
              f"(schema_version={manifest['schema_version']}, "
              f"{len(manifest['classes'])} classes)")
        return 0

    if args.regen_contracts:
        contracts = regen_contracts(root)
        pinned = sum(1 for e in contracts["functions"].values()
                     if e.get("params") is not None)
        print(f"wrote src/repro/analysis/kernel_contracts.json "
              f"({len(contracts['functions'])} functions, "
              f"{pinned} with shape contracts)")
        return 0

    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
        unknown = [r for r in rules if r not in registered_rules()]
        if unknown:
            print(f"error: unknown rule id(s) {unknown}; see --list-rules",
                  file=sys.stderr)
            return 2
        # Keep project/file rules as named; meta checks always apply.
        rules = [r for r in rules
                 if registered_rules()[r].scope in ("file", "project")]

    result = run_analysis(root, rules=rules, tier=args.tier,
                          strict=args.strict)

    if args.write_baseline is not None:
        payload = {"findings": [f.to_json() for f in result.findings]}
        args.write_baseline.write_text(
            json.dumps(payload, indent=2) + "\n", encoding="utf-8")
        print(f"wrote baseline {args.write_baseline} "
              f"({len(result.findings)} finding(s))")
        return 0

    new = result.findings
    if args.baseline is not None:
        try:
            recorded = json.loads(args.baseline.read_text(encoding="utf-8"))
        except (OSError, json.JSONDecodeError) as e:
            print(f"error: cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2
        known = {_baseline_key(f) for f in recorded.get("findings", [])}
        new = [f for f in result.findings
               if _baseline_key(f.to_json()) not in known]

    if fmt == "json":
        report = result.to_json()
        if args.baseline is not None:
            report["counts"]["new"] = len(new)
            report["new_findings"] = [f.to_json() for f in new]
        print(json.dumps(report, indent=2))
    elif fmt == "sarif":
        print(json.dumps(to_sarif(result), indent=2))
    else:
        print(result.human())
        if args.baseline is not None and result.findings:
            print(f"-- baseline: {len(result.findings) - len(new)} known, "
                  f"{len(new)} new")

    return 0 if not new else 1


if __name__ == "__main__":
    sys.exit(main())
