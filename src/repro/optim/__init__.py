from .adamw import AdamWConfig, adamw_init, adamw_update
from .cp_compress import cp_compress_state, cp_compressed_mean
