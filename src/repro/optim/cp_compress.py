"""CP-rank-R gradient compression with error feedback (beyond-paper use of
the paper's own machinery; DESIGN.md §5.2).

For a 2-D gradient G, one CP-ALS sweep IS one alternating-least-squares
low-rank step (P ← G Q (QᵀQ)⁻¹; Q ← Gᵀ P (PᵀP)⁻¹) — the PowerSGD iteration.
Cross-pod gradient traffic drops from |G| to R·(rows+cols) per tensor: for
an 8192×24576 Jamba expert slice at R=16, that is ~380× fewer DCN bytes.

Error feedback keeps the residual locally and re-adds it next step, which is
the same "the iterative algorithm absorbs small per-step imprecision"
argument the paper uses for lock removal (§IV-C).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["cp_compress_state", "cp_compressed_mean", "compress_grad"]

MIN_SIZE = 1 << 16  # don't compress tiny tensors


def _as2d(g):
    if g.ndim == 1:
        return None
    return g.reshape(g.shape[0], -1) if g.ndim != 2 else g


def cp_compress_state(params, rank: int = 16, seed: int = 0):
    """Per-tensor error-feedback buffer + fixed random right factor init."""
    def init(path, p):
        g2 = _as2d(jnp.zeros(p.shape))
        if g2 is None or p.size < MIN_SIZE:
            return None
        key = jax.random.fold_in(jax.random.key(seed), abs(hash(str(path))) % (2**31))
        q = jax.random.normal(key, (g2.shape[1], rank), jnp.float32)
        return {"err": jnp.zeros(p.shape, jnp.float32), "q": q}
    return jax.tree_util.tree_map_with_path(init, params)


def compress_grad(g, st, axis_name: str | None):
    """One ALS sweep (= CP-ALS on a matrix) + error feedback.  When
    `axis_name` is given, the *factors* are psum-averaged across it instead
    of the full gradient — that is the compressed collective."""
    if st is None:
        if axis_name is not None:
            g = jax.lax.pmean(g, axis_name)
        return g, st
    shape = g.shape
    gf = g.astype(jnp.float32) + st["err"]
    g2 = _as2d(gf)
    q = st["q"]
    # ALS half-step 1: P = G Q, orthonormalized (stabilises like pinv(QᵀQ))
    p = g2 @ q
    if axis_name is not None:
        p = jax.lax.pmean(p, axis_name)
    p, _ = jnp.linalg.qr(p)
    # ALS half-step 2: Q = Gᵀ P
    q_new = g2.T @ p
    if axis_name is not None:
        q_new = jax.lax.pmean(q_new, axis_name)
    approx = (p @ q_new.T).reshape(shape)
    err = gf - approx
    return approx.astype(g.dtype), {"err": err, "q": q_new}


def cp_compressed_mean(grads, state, axis_name: str | None):
    """Apply compress_grad across a grad pytree. Returns (grads, new_state)."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_s = treedef.flatten_up_to(state)
    out_g, out_s = [], []
    for g, s in zip(flat_g, flat_s, strict=True):
        ng, ns = compress_grad(g, s, axis_name)
        out_g.append(ng)
        out_s.append(ns)
    return (jax.tree_util.tree_unflatten(treedef, out_g),
            jax.tree_util.tree_unflatten(treedef, out_s))
