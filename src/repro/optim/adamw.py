"""AdamW with optionally 8-bit-quantized moments (blockwise absmax scales).

8-bit states are the distributed-optimization lever that lets jamba-398B fit
the 256-chip pod (DESIGN.md §4): m and v are stored int8 with one fp32 scale
per 256-element block; dequant → update → requant every step.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]

_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    use_8bit: bool = False


def _q8(x):
    """int8 blockwise quantization along the LAST axis only, so the int8
    buffer keeps the parameter's shape (up to last-dim padding) and therefore
    its sharding — a flattened block layout would force XLA to all-gather
    every tensor at each optimizer step (measured: ~6 TB/step on jamba).
    Returns (q (*lead, padded_last) int8, scales (*lead, n_blocks) f32)."""
    if x.ndim == 0:
        x = x[None]
    *lead, last = x.shape
    pad = (-last) % _BLOCK
    xp = jnp.pad(x, [*([(0, 0)] * len(lead)), (0, pad)])
    blocks = xp.reshape(*lead, -1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12).astype(jnp.float32)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return q.astype(jnp.int8).reshape(*lead, last + pad), scale


def _dq8(q, scale, shape):
    if len(shape) == 0:
        shape = (1,)
    *lead, last = shape
    blocks = q.reshape(*lead, -1, _BLOCK).astype(jnp.float32) * scale[..., None]
    return blocks.reshape(*lead, -1)[..., :last].reshape(shape)


def adamw_init(params, cfg: AdamWConfig):
    if cfg.use_8bit:
        def zeros8(p):
            q, s = _q8(jnp.zeros(p.shape, jnp.float32))
            return {"q": q, "s": s}
        return {
            "m": jax.tree.map(zeros8, params),
            "v": jax.tree.map(zeros8, params),
            "step": jnp.zeros((), jnp.int32),
        }
    z = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    if cfg.use_8bit:
        def upd(g, m8, v8, p):
            g = g.astype(jnp.float32) * scale
            m = _dq8(m8["q"], m8["s"], p.shape)
            v = _dq8(v8["q"], v8["s"], p.shape)
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            # int8 v underestimates small entries (block absmax quant), which
            # explodes m/√v — bound the step like bnb/Adafactor do.
            u = jnp.clip(u, -4.0, 4.0)
            u = u / jnp.maximum(1.0, jnp.sqrt(jnp.mean(u * u)))
            newp = (p.astype(jnp.float32)
                    - cfg.lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
            mq, ms = _q8(m)
            vq, vs = _q8(v)
            return newp.astype(p.dtype), {"q": mq, "s": ms}, {"q": vq, "s": vs}
        out = jax.tree.map(upd, grads, state["m"], state["v"], params,
                           is_leaf=lambda x: isinstance(x, jnp.ndarray))
        newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return newp, {"m": newm, "v": newv, "step": step}

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        newp = (p.astype(jnp.float32)
                - cfg.lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    newp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "step": step}
