"""spMTTKRP reference + chunked implementations (float and fixed point).

Three layers, all jit-able and shape-static:

  * `mttkrp_coo`          — plain element-wise reference over COO (paper Fig. 1).
  * `mttkrp_chunked`      — the PRISM design: vmap over chunk *tasks*; per task
                            gather the chunk's factor blocks, compute partials,
                            reduce into a chunk-local output, scatter-add to the
                            global output (the "sum reduction").
  * `mttkrp_chunked_fixed`— paper Algorithm 2, bit-exact Qm.n arithmetic:
                            int32 products (safe because L-inf normalization
                            bounds factors to [-1,1]) with arithmetic-shift
                            requantization after every multiply.

The chunked format is mode-agnostic: one chunking serves every MTTKRP mode
(unlike FLYCOO's per-mode reorder) — only the gather/scatter roles rotate.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import ChunkedTensor
from .qformat import QFormat

__all__ = [
    "mttkrp_coo",
    "mttkrp_chunked",
    "mttkrp_coo_fixed",
    "mttkrp_chunked_fixed",
    "mttkrp_csf",
    "mttkrp_alto",
    "chunked_device_arrays",
    "gather_factor_blocks",
]


# ---------------------------------------------------------------------------
# Plain COO reference (paper Fig. 1, element-wise definition).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "out_dim"))
def mttkrp_coo(factors, coords, values, *, mode: int, out_dim: int):
    """Reference spMTTKRP.  factors: tuple of (I_m, R); coords (nnz, N) int32;
    values (nnz,) f32.  Returns (out_dim, R) f32."""
    part = values[:, None].astype(jnp.float32)
    for m, f in enumerate(factors):
        if m == mode:
            continue
        part = part * f[coords[:, m]]
    out = jnp.zeros((out_dim, factors[0].shape[1]), jnp.float32)
    return out.at[coords[:, mode]].add(part, mode="drop")


# ---------------------------------------------------------------------------
# Chunked (PRISM) implementation.
# ---------------------------------------------------------------------------

def chunked_device_arrays(ct: ChunkedTensor) -> dict:
    """The static per-run arrays shipped to devices once (the paper keeps the
    tensor resident across CP-ALS iterations; only factors move)."""
    return dict(
        task_chunk=jnp.asarray(ct.task_chunk),
        coords_rel=jnp.asarray(ct.coords_rel),
        values=jnp.asarray(ct.values),
    )


def gather_factor_blocks(factor, offsets, size: int):
    """factor (I, R), offsets (T,) → (T, size, R) chunk-local blocks.
    Boundary chunks clamp; clamped rows are never addressed by live nonzeros."""
    idx = offsets[:, None] + jnp.arange(size)[None, :]
    idx = jnp.minimum(idx, factor.shape[0] - 1)
    return factor[idx]


@partial(jax.jit, static_argnames=("mode", "chunk_shape", "out_dim"))
def mttkrp_chunked(
    factors,
    task_chunk,
    coords_rel,
    values,
    *,
    mode: int,
    chunk_shape: tuple[int, ...],
    out_dim: int,
):
    """PRISM chunked spMTTKRP (float path).

    factors : tuple of (I_m, R) f32
    task_chunk : (T, N) int32; coords_rel : (T, P, N) int32; values : (T, P) f32
    """
    n = len(factors)
    rank = factors[0].shape[1]
    offsets = task_chunk * jnp.asarray(chunk_shape, dtype=jnp.int32)  # (T, N)

    # Per-task partials: (T, P, R).  Padded entries have value 0 → no-op.
    part = values[..., None].astype(jnp.float32)
    for m in range(n):
        if m == mode:
            continue
        blocks = gather_factor_blocks(factors[m], offsets[:, m], chunk_shape[m])
        rows = jnp.take_along_axis(
            blocks, coords_rel[:, :, m][..., None], axis=1
        )  # (T, P, R)
        part = part * rows

    # Chunk-local reduction: (T, S_mode, R) — each task is its own "DPU".
    s_out = chunk_shape[mode]
    local = jnp.zeros((task_chunk.shape[0], s_out, rank), jnp.float32)
    local = jax.vmap(lambda l, c, p: l.at[c].add(p, mode="drop"))(
        local, coords_rel[:, :, mode], part
    )

    # Sum reduction of chunk-local partials into the global output.
    out = jnp.zeros((out_dim, rank), jnp.float32)
    rows = offsets[:, mode : mode + 1] + jnp.arange(s_out)[None, :]  # (T, S)
    return out.at[rows.reshape(-1)].add(local.reshape(-1, rank), mode="drop")


# ---------------------------------------------------------------------------
# Format-subsystem kernels (repro.formats): CSF fiber trees and the ALTO
# linearized index.  Both are exact (lossless) float paths — they change the
# *memory access structure*, not the arithmetic.
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode", "inner_mode", "mid_modes",
                                   "out_dim", "n_fibers"))
def mttkrp_csf(
    factors,
    inner_coord,
    values,
    fiber_ids,
    fiber_coords,
    *,
    mode: int,
    inner_mode: int,
    mid_modes: tuple[int, ...],
    out_dim: int,
    n_fibers: int,
):
    """spMTTKRP over a CSF mode tree (see `repro.formats.csf`): two sorted
    segment reductions, nonzeros → fibers → output rows.

    The interior (root + mid) factor rows are gathered once per *fiber*
    instead of once per nonzero — the fiber-reuse win CSF exists for; only
    the innermost factor is gathered per nonzero.

    inner_coord (nnz,), values (nnz,), fiber_ids (nnz, sorted),
    fiber_coords (n_fibers, N; inner column unused).  Returns (out_dim, R).
    """
    part = values[:, None].astype(jnp.float32) * factors[inner_mode][inner_coord]
    fib = jax.ops.segment_sum(part, fiber_ids, num_segments=n_fibers,
                              indices_are_sorted=True)
    for m in mid_modes:
        fib = fib * factors[m][fiber_coords[:, m]]
    return jax.ops.segment_sum(fib, fiber_coords[:, mode],
                               num_segments=out_dim, indices_are_sorted=True)


def _alto_decode(key_words, positions: tuple[int, ...]):
    """Gather one mode's coordinate bits back out of the packed linearized
    key: `positions[b]` is the key bit holding coordinate bit `b`.  The
    loop is unrolled at trace time (positions are static), so the decode
    compiles to a handful of shift/mask/or ops per word."""
    c = jnp.zeros(key_words.shape[0], jnp.int32)
    for b, p in enumerate(positions):
        bit = (key_words[:, p // 32] >> jnp.uint32(p % 32)) & jnp.uint32(1)
        c = c | (bit.astype(jnp.int32) << b)
    return c


@partial(jax.jit, static_argnames=("mode", "positions", "out_dim"))
def mttkrp_alto(factors, key_words, values, *, mode: int,
                positions: tuple[tuple[int, ...], ...], out_dim: int):
    """spMTTKRP over the ALTO linearized index (see `repro.formats.alto`):
    every mode's coordinates are de-interleaved from ONE key stream
    (`key_words`, (nnz, W) uint32, sorted by key), so a single tensor copy
    serves all modes.  The key order clusters spatially-near nonzeros,
    which is where the gather locality comes from."""
    part = values[:, None].astype(jnp.float32)
    for m, f in enumerate(factors):
        if m == mode:
            continue
        part = part * f[_alto_decode(key_words, positions[m])]
    seg = _alto_decode(key_words, positions[mode])
    return jax.ops.segment_sum(part, seg, num_segments=out_dim)


# ---------------------------------------------------------------------------
# Fixed point (paper Algorithm 2) — bit-exact Q arithmetic.
# ---------------------------------------------------------------------------

def _fixed_partials(qfactor_rows, qvalues, mode, matrix_frac, value_frac, prec_shift):
    """Shared Alg.-2 inner loop.  qfactor_rows: list over modes of (..., R)
    int32 gathered factor rows (entry at `mode` ignored); qvalues (...,) int32.
    Returns int32 partial results in Q(.., matrix_frac - prec_shift)."""
    n = len(qfactor_rows)
    inputs = [m for m in range(n) if m != mode]
    part = qfactor_rows[inputs[0]].astype(jnp.int32)
    for m in inputs[1:]:
        part = part * qfactor_rows[m].astype(jnp.int32)
        part = jnp.right_shift(part, matrix_frac)  # arithmetic shift (Alg.2 l.12)
    part = part * qvalues[..., None].astype(jnp.int32)
    return jnp.right_shift(part, value_frac + prec_shift)  # Alg.2 l.15


@partial(jax.jit, static_argnames=("mode", "out_dim", "matrix_frac", "value_frac", "prec_shift"))
def mttkrp_coo_fixed(
    qfactors, coords, qvalues, *,
    mode: int, out_dim: int,
    matrix_frac: int, value_frac: int, prec_shift: int = 0,
):
    """Fixed-point COO reference (oracle for the Pallas fixed kernel)."""
    rows = [f[coords[:, m]] for m, f in enumerate(qfactors)]
    part = _fixed_partials(rows, qvalues, mode, matrix_frac, value_frac, prec_shift)
    out = jnp.zeros((out_dim, qfactors[0].shape[1]), jnp.int32)
    return out.at[coords[:, mode]].add(part, mode="drop")


@partial(jax.jit, static_argnames=("mode", "chunk_shape", "out_dim", "matrix_frac", "value_frac", "prec_shift"))
def mttkrp_chunked_fixed(
    qfactors, task_chunk, coords_rel, qvalues, *,
    mode: int, chunk_shape: tuple[int, ...], out_dim: int,
    matrix_frac: int, value_frac: int, prec_shift: int = 0,
):
    """Chunked fixed-point spMTTKRP (paper Alg. 2 on the chunked format).

    qfactors: tuple of (I_m, R) int arrays (int16 for Q9.7, int32 for Q17.15);
    qvalues: (T, P) int16/int32.  Output int32 in Q(·, matrix_frac-prec_shift).
    """
    n = len(qfactors)
    rank = qfactors[0].shape[1]
    offsets = task_chunk * jnp.asarray(chunk_shape, dtype=jnp.int32)

    rows = []
    for m in range(n):
        if m == mode:
            rows.append(None)
            continue
        blocks = gather_factor_blocks(qfactors[m], offsets[:, m], chunk_shape[m])
        rows.append(
            jnp.take_along_axis(blocks, coords_rel[:, :, m][..., None], axis=1)
        )
    rows = [r if r is not None else jnp.zeros((), jnp.int32) for r in rows]
    part = _fixed_partials(rows, qvalues, mode, matrix_frac, value_frac, prec_shift)

    s_out = chunk_shape[mode]
    local = jnp.zeros((task_chunk.shape[0], s_out, rank), jnp.int32)
    local = jax.vmap(lambda l, c, p: l.at[c].add(p, mode="drop"))(
        local, coords_rel[:, :, mode], part
    )
    out = jnp.zeros((out_dim, rank), jnp.int32)
    out_rows = offsets[:, mode : mode + 1] + jnp.arange(s_out)[None, :]
    return out.at[out_rows.reshape(-1)].add(local.reshape(-1, rank), mode="drop")


def dequantize_output(qout, matrix_frac: int, prec_shift: int) -> jnp.ndarray:
    """Output of the fixed kernels is Q(·, matrix_frac - prec_shift)."""
    return qout.astype(jnp.float32) / (1 << (matrix_frac - prec_shift))
