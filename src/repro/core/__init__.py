"""PRISM core: chunked sparse tensor format, hierarchical partitioning,
fixed-point spMTTKRP, CP-ALS, heterogeneous + distributed execution."""
from .sptensor import SparseTensor, random_tensor, table1_tensor, TABLE1
from .chunking import ChunkedTensor, chunk_tensor, replication_stats
from .partition import PartitionPlan, decide_partition
from .qformat import QFormat, Q5_3, Q9_7, Q17_15, value_qformat, FIXED_PRESETS
from .mttkrp import (
    mttkrp_coo,
    mttkrp_chunked,
    mttkrp_coo_fixed,
    mttkrp_chunked_fixed,
)
from .cpals import cp_als, CPResult, make_engine, init_factors, avg_abs_diff, fit_value
from .hetero import split_tasks, mttkrp_hetero, HeteroSplit
from .distributed import DistributedMTTKRP, distributed_mttkrp_fn
