"""PRISM core: chunked sparse tensor format, hierarchical partitioning,
fixed-point spMTTKRP, CP-ALS, heterogeneous + distributed execution."""
from .chunking import ChunkedTensor, chunk_tensor, replication_stats
from .cpals import CPResult, avg_abs_diff, cp_als, fit_value, init_factors, make_engine
from .distributed import DistributedMTTKRP, distributed_mttkrp_fn
from .hetero import HeteroSplit, mttkrp_hetero, split_tasks
from .mttkrp import (
    mttkrp_chunked,
    mttkrp_chunked_fixed,
    mttkrp_coo,
    mttkrp_coo_fixed,
)
from .partition import PartitionPlan, decide_partition
from .qformat import FIXED_PRESETS, Q17_15, Q5_3, Q9_7, QFormat, value_qformat
from .sptensor import TABLE1, SparseTensor, random_tensor, table1_tensor


def __getattr__(name):
    # Lazy (PEP 562): `repro.batch` itself imports from `repro.core.cpals`,
    # so an eager import here would be circular.
    if name == "cp_als_batched":
        from ..batch import cp_als_batched
        return cp_als_batched
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
