"""Distributed spMTTKRP over a (data, model) mesh (paper §IV-B on TPU).

Mapping of the paper's partitioning hierarchy onto mesh axes:

  * rank partitioning       → factor matrices sharded on the R axis over the
                              `model` axis.  Zero factor replication and ZERO
                              collectives in the kernel — exactly the paper's
                              "favored" property.  The tensor (tasks) is
                              replicated across `model`, resident across
                              CP-ALS iterations.
  * dimension-size + nonzero partitioning
                             → the task axis sharded over `data`.  Each device
                              computes chunk-local partials for its tasks; the
                              paper's host-side "sum reduction" becomes an
                              on-fabric psum (baseline, paper-faithful) or
                              psum_scatter (optimized — reduces ICI bytes by
                              (g-1)/g; see EXPERIMENTS.md §Perf).

The shard_map body is the "DPU program": it touches only device-local data
until the final reduction, mirroring UPMEM's no-inter-DPU-communication model.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import shard_map
from .chunking import ChunkedTensor
from .mttkrp import mttkrp_chunked

__all__ = ["distributed_mttkrp_fn", "shard_chunked", "DistributedMTTKRP"]


def shard_chunked(ct: ChunkedTensor, n_data: int) -> ChunkedTensor:
    """Pad the task axis so it splits evenly over the data axis."""
    return ct.pad_tasks(n_data)


def distributed_mttkrp_fn(
    mesh,
    *,
    mode: int,
    chunk_shape: tuple[int, ...],
    out_dim: int,
    data_axis: str = "data",
    model_axis: str = "model",
    reduce: str = "psum_scatter",
):
    """Build a jit-able distributed MTTKRP.

    Input shardings:
      factors[m] : (I_m, R)  sharded P(None, model)   — rank partitioning
      task_chunk : (T, N)    sharded P(data, None)
      coords_rel : (T, P, N) sharded P(data, None, None)
      values     : (T, P)    sharded P(data, None)
    Output: (out_dim, R) sharded P(data, model) for reduce="psum_scatter"
            (row-blocks owned by data shards), or P(None, model) for "psum".
    """
    axes = dict(mesh.shape)
    n_data = axes[data_axis]

    def body(factors, task_chunk, coords_rel, values):
        local = mttkrp_chunked(
            factors, task_chunk, coords_rel, values,
            mode=mode, chunk_shape=chunk_shape, out_dim=_pad_dim(out_dim, n_data),
        )
        if reduce == "psum":
            return jax.lax.psum(local, data_axis)
        if reduce == "psum_scatter":
            # Each data shard ends up owning a contiguous row block:
            # ICI bytes drop from 2·(g-1)/g·|out| (all-reduce) to (g-1)/g·|out|.
            return jax.lax.psum_scatter(
                local, data_axis, scatter_dimension=0, tiled=True
            )
        raise ValueError(reduce)

    out_rows = P(data_axis, model_axis) if reduce == "psum_scatter" else P(None, model_axis)
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(None, model_axis),            # factors (each)
            P(data_axis, None),
            P(data_axis, None, None),
            P(data_axis, None),
        ),
        out_specs=out_rows,
    )
    return jax.jit(fn), out_rows


def _pad_dim(d: int, mult: int) -> int:
    return -(-d // mult) * mult


class DistributedMTTKRP:
    """Convenience wrapper: places the chunked tensor + factors on the mesh
    once, then serves per-mode MTTKRP calls (CP-ALS engine compatible)."""

    def __init__(self, mesh, ct: ChunkedTensor, rank: int,
                 data_axis: str = "data", model_axis: str = "model",
                 reduce: str = "psum_scatter"):
        self.mesh = mesh
        self.data_axis, self.model_axis, self.reduce = data_axis, model_axis, reduce
        n_data = dict(mesh.shape)[data_axis]
        self.ct = shard_chunked(ct, n_data)
        self.rank = rank
        sh = lambda spec: NamedSharding(mesh, spec)
        self.task_chunk = jax.device_put(self.ct.task_chunk, sh(P(data_axis, None)))
        self.coords_rel = jax.device_put(self.ct.coords_rel, sh(P(data_axis, None, None)))
        self.values = jax.device_put(self.ct.values, sh(P(data_axis, None)))
        self._fns = {}

    def __call__(self, factors, mode: int):
        out_dim = self.ct.tensor_shape[mode]
        key = mode
        if key not in self._fns:
            self._fns[key] = distributed_mttkrp_fn(
                self.mesh, mode=mode, chunk_shape=self.ct.chunk_shape,
                out_dim=out_dim, data_axis=self.data_axis,
                model_axis=self.model_axis, reduce=self.reduce,
            )[0]
        sh = NamedSharding(self.mesh, P(None, self.model_axis))
        factors = tuple(jax.device_put(f, sh) for f in factors)
        out = self._fns[key](factors, self.task_chunk, self.coords_rel, self.values)
        n_data = dict(self.mesh.shape)[self.data_axis]
        return out[: self.ct.tensor_shape[mode]] if self.reduce == "psum" else out
