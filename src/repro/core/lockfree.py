"""Lock-removal emulation (paper §IV-C, Fig. 6).

On UPMEM, PRISM removes the locks guarding the shared per-DPU output buffer:
when two of the 16 tasklets write the same output row in the same cycle, one
update is lost.  The paper shows CP-ALS absorbs this imprecision.

XLA scatter-adds are conflict-free by construction, so there is nothing to
"remove" on TPU (DESIGN.md §2.1).  To still reproduce the paper's accuracy
study, this module *emulates* the lost updates: nonzeros are grouped into
waves of `n_tasklets` consecutive entries (tasklets advance in lock-step over
the contiguous, sequential-reader-fed nonzero stream); within a wave, if two
entries target the same output row, only the last writer survives.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["wave_collision_mask"]

N_TASKLETS = 16  # the paper's tasklet count


@partial(jax.jit, static_argnames=("n_tasklets",))
def wave_collision_mask(out_rows, nnz_per_task, *, n_tasklets: int = N_TASKLETS):
    """out_rows: (T, P) int32 chunk-local output row per nonzero;
    nnz_per_task: (T,).  Returns (T, P) f32 mask — 0 where an update is lost.

    UPMEM tasklets each take a CONTIGUOUS block of P/G nonzeros (the paper
    computes the partition with an arithmetic shift), so at "time" t the G
    simultaneous writers are entries {j·P/G + t}.  An entry is lost iff a
    higher-numbered tasklet writes the same row in the same wave
    (last-writer-wins race)."""
    t, p = out_rows.shape
    g = n_tasklets
    pad = (-p) % g
    rows = jnp.pad(out_rows, ((0, 0), (0, pad)), constant_values=-1)
    pp = p + pad
    valid = (jnp.arange(pp)[None, :] < nnz_per_task[:, None])
    rows = jnp.where(valid, rows, -1 - jnp.arange(pp)[None, :])  # uniquify pads
    waves = rows.reshape(t, g, pp // g).transpose(0, 2, 1)  # (T, W, G)
    same = waves[:, :, :, None] == waves[:, :, None, :]     # (T, W, G, G)
    later = jnp.triu(jnp.ones((g, g), bool), k=1)
    lost = jnp.any(same & later[None, None], axis=3)        # later dup exists
    mask = ~lost.transpose(0, 2, 1).reshape(t, pp)[:, :p]
    return mask.astype(jnp.float32)
