"""Hierarchical partition decider (paper §IV-B, Fig. 5).

Partitioning preference order (replication-minimizing):
  1. rank partitioning      — free: no factor replication, tensor replicated
                              once and resident across CP-ALS iterations;
  2. dimension-size part.   — bounds factor bytes per device, replicates
                              factor rows at chunk boundaries;
  3. nonzero partitioning   — bounds tensor bytes per device, maximal
                              replication + output sum reduction.

The decider iteratively shrinks the chunk shape (halving the largest chunk
dim) until the *device density* — nonzeros a device can hold given the factor
slice it must also hold — reaches the tensor density.  For balanced tensors
this lands on the minimum number of chunks with no nonzero partitioning; for
imbalanced tensors it stops early and lets nonzero partitioning absorb the
hot chunks rather than over-shrinking the grid (paper Fig. 5).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .sptensor import SparseTensor

__all__ = ["PartitionPlan", "decide_partition", "DPU_MRAM_BYTES"]

DPU_MRAM_BYTES = 64 * 1024 * 1024  # UPMEM per-DPU MRAM; the per-PE budget knob.


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    chunk_shape: tuple[int, ...]
    capacity: int                  # max nonzeros per task
    rank_block: int                # ranks per device (rank partitioning)
    n_rank_partitions: int
    est_chunks: int                # grid size (upper bound on nonempty chunks)
    factor_bytes_per_device: int
    tensor_bytes_per_device: int
    device_density: float
    tensor_density: float
    kernel_iterations: int         # >1 when partitions exceed device count

    @property
    def mem_bytes_per_device(self) -> int:
        return self.factor_bytes_per_device + self.tensor_bytes_per_device


def decide_partition(
    st: SparseTensor,
    rank: int,
    *,
    mem_bytes: int = DPU_MRAM_BYTES,
    factor_elt_bytes: int = 2,     # Q9.7 int16 (paper's preferred mode-3 format)
    value_bytes: int = 2,          # 16-bit tensor values (paper §IV-C)
    coord_bytes: int = 4,
    n_devices: int = 2560,
    rank_axis: int | None = None,  # fixed rank partitions (mesh model axis)
) -> PartitionPlan:
    """Run the Fig. 5 decider. Returns a PartitionPlan; the actual chunking is
    done by `chunking.chunk_tensor(st, plan.chunk_shape, plan.capacity)`."""
    n = st.ndim
    nnz_bytes = value_bytes + coord_bytes * n
    tensor_density = st.density

    # Rank partitioning first (paper: favored — no replication).  Each rank
    # partition handles `rank_block` columns of every factor matrix; default:
    # as many rank partitions as possible while one tensor partition can
    # still use all devices (the decider below refines tensor partitions).
    n_rank = (rank_axis if rank_axis is not None
              else max(1, min(rank, n_devices)))
    rank_block = -(-rank // n_rank)

    chunk_shape = [int(d) for d in st.shape]

    def factor_bytes(cs):
        # One factor slice per mode, rank_block columns each.
        return sum(s * rank_block * factor_elt_bytes for s in cs)

    def capacity_for(cs):
        avail = mem_bytes - factor_bytes(cs)
        return avail // nnz_bytes

    while True:
        cap = capacity_for(chunk_shape)
        if cap >= 1:
            device_density = cap / math.prod(chunk_shape)
            if device_density >= tensor_density:
                break
        # Halve the largest chunk dimension (paper: iterative dim-size step).
        m = int(np.argmax(chunk_shape))
        if chunk_shape[m] == 1:
            # Cannot shrink further — tensor region denser than a device can
            # mirror; rely on nonzero partitioning.
            cap = max(int(cap), 1)
            device_density = cap / math.prod(chunk_shape)
            break
        chunk_shape[m] = -(-chunk_shape[m] // 2)

    cap = max(int(capacity_for(chunk_shape)), 1)
    grid = [int(-(-i // s)) for i, s in zip(st.shape, chunk_shape, strict=True)]
    est_chunks = math.prod(grid)
    # Expected tasks ≈ nonempty chunks (+ splits); bound by nnz.
    est_tasks = min(est_chunks, st.nnz)
    total_partitions = est_tasks * n_rank
    kernel_iterations = max(1, -(-total_partitions // n_devices))

    return PartitionPlan(
        chunk_shape=tuple(chunk_shape),
        capacity=cap,
        rank_block=rank_block,
        n_rank_partitions=n_rank,
        est_chunks=est_chunks,
        factor_bytes_per_device=factor_bytes(chunk_shape),
        tensor_bytes_per_device=cap * nnz_bytes,
        device_density=float(cap / math.prod(chunk_shape)),
        tensor_density=float(tensor_density),
        kernel_iterations=int(kernel_iterations),
    )
