"""Baselines the paper compares against, re-implemented in JAX.

  * ALTO [Helal et al., ICS'21] — linearized coordinate order: every nonzero
    keyed by a bit-interleaved (Morton-like) linearization of its coords and
    processed in that order.  On CPU the win is cache locality; in XLA the
    honest analogue is sorted-segment reductions (`indices_are_sorted=True`)
    over the linearized order.
  * Plain COO ("BLCO-like" GPU style) — unsorted atomic scatter-add.

Both compute bit-identical results to `mttkrp_coo`; they differ in memory
access structure, which the benchmarks measure.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["alto_order", "mttkrp_alto", "mttkrp_plain_coo"]


def alto_order(coords: np.ndarray, shape: tuple[int, ...]) -> np.ndarray:
    """ALTO linearization: interleave the bits of each mode's coordinate,
    mode-major round-robin over the bits each mode actually needs (adaptive —
    modes with fewer bits drop out early, as in the ALTO paper)."""
    n = len(shape)
    bits = [max(1, int(np.ceil(np.log2(max(s, 2))))) for s in shape]
    maxbits = max(bits)
    key = np.zeros(coords.shape[0], dtype=np.int64)
    pos = 0
    for b in range(maxbits):
        for m in range(n):
            if b < bits[m]:
                key |= ((coords[:, m].astype(np.int64) >> b) & 1) << pos
                pos += 1
    return np.argsort(key, kind="stable")


@partial(jax.jit, static_argnames=("mode", "out_dim"))
def mttkrp_alto(factors, coords, values, *, mode: int, out_dim: int):
    """spMTTKRP over ALTO-ordered nonzeros with sorted segment reduction.
    `coords`/`values` must already be in ALTO order (see `alto_order`)."""
    part = values[:, None].astype(jnp.float32)
    for m, f in enumerate(factors):
        if m == mode:
            continue
        part = part * f[coords[:, m]]
    seg = coords[:, mode]
    return jax.ops.segment_sum(part, seg, num_segments=out_dim)


@partial(jax.jit, static_argnames=("mode", "out_dim"))
def mttkrp_plain_coo(factors, coords, values, *, mode: int, out_dim: int):
    """Unsorted scatter-add COO (GPU-atomics style)."""
    part = values[:, None].astype(jnp.float32)
    for m, f in enumerate(factors):
        if m == mode:
            continue
        part = part * f[coords[:, m]]
    out = jnp.zeros((out_dim, factors[0].shape[1]), jnp.float32)
    return out.at[coords[:, mode]].add(part, mode="drop")
