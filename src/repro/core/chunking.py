"""The PRISM chunked tensor format (paper §IV-A) and task packing.

The tensor is cut into equal-size chunks; each nonzero's coordinates become
*relative* to its chunk.  A chunk pins down exactly which factor-matrix rows
it touches, so factor matrices can be partitioned together with the nonzeros
— the property that maps spMTTKRP onto a distributed-memory machine.

A *task* is the unit handed to one processing element ("DPU" ≡ one grid step
of the Pallas kernel / one shard_map slot): one chunk, or — when a chunk's
nonzeros exceed the capacity — one capacity-sized slice of a chunk (the
paper's *nonzero partitioning*).  Tasks are padded to a uniform nonzero
capacity so the whole structure is rectangular and jit/vmap/pallas friendly.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .sptensor import SparseTensor

__all__ = ["ChunkedTensor", "chunk_tensor", "clamp_capacity", "replication_stats"]


def clamp_capacity(nnz: int, capacity: int) -> int:
    """Clamp a task capacity to [1, nnz].  Capacity above the total nonzero
    count is pure padding — no task can ever hold more than nnz entries.
    (The Fig.-5 decider can hand a sparse tensor a device-memory-sized
    capacity that exceeds nnz by orders of magnitude; without the clamp
    every task's arrays get that wide.)  Shared by chunk_tensor and the
    engine plan cache so cache keys always agree with chunking behavior."""
    return max(min(int(capacity), max(int(nnz), 1)), 1)


@dataclasses.dataclass(frozen=True)
class ChunkedTensor:
    """Rectangular packed chunk/task layout.

    task_chunk : (T, N) int32 — chunk-grid coordinate of each task.
    coords_rel : (T, P, N) int32 — chunk-relative nonzero coords, padded.
    values     : (T, P) float32 — nonzero values, padded with 0.
    nnz_per_task : (T,) int32 — live entries per task (≤ P).
    chunk_shape  : per-mode chunk size S_m.
    tensor_shape : original tensor dims I_m.
    """

    task_chunk: np.ndarray
    coords_rel: np.ndarray
    values: np.ndarray
    nnz_per_task: np.ndarray
    chunk_shape: tuple[int, ...]
    tensor_shape: tuple[int, ...]

    @property
    def num_tasks(self) -> int:
        return self.task_chunk.shape[0]

    @property
    def capacity(self) -> int:
        return self.coords_rel.shape[1]

    @property
    def ndim(self) -> int:
        return len(self.tensor_shape)

    @property
    def grid(self) -> tuple[int, ...]:
        return tuple(
            -(-i // s) for i, s in zip(self.tensor_shape, self.chunk_shape, strict=True)
        )

    @property
    def nnz(self) -> int:
        return int(self.nnz_per_task.sum())

    def row_offsets(self) -> np.ndarray:
        """(T, N) global row offset of each task's chunk in every mode."""
        return self.task_chunk * np.asarray(self.chunk_shape, dtype=np.int32)

    def coords_global(self) -> np.ndarray:
        """(T, P, N) absolute coordinates (padding rows map inside chunk 0)."""
        return self.coords_rel + self.row_offsets()[:, None, :]

    def pad_tasks(self, multiple: int) -> ChunkedTensor:
        """Pad the task axis to a multiple (for even mesh sharding). Padding
        tasks point at chunk 0 with zero live nonzeros and zero values."""
        t = self.num_tasks
        tt = -(-t // multiple) * multiple
        if tt == t:
            return self
        pad = tt - t
        return ChunkedTensor(
            np.concatenate([self.task_chunk, np.zeros((pad, self.ndim), np.int32)]),
            np.concatenate([self.coords_rel, np.zeros((pad, self.capacity, self.ndim), np.int32)]),
            np.concatenate([self.values, np.zeros((pad, self.capacity), np.float32)]),
            np.concatenate([self.nnz_per_task, np.zeros((pad,), np.int32)]),
            self.chunk_shape,
            self.tensor_shape,
        )


def chunk_tensor(
    st: SparseTensor,
    chunk_shape: tuple[int, ...],
    capacity: int | None = None,
) -> ChunkedTensor:
    """Build the chunked format (Fig. 3b) with nonzero partitioning applied.

    `capacity` is the max nonzeros a task may hold (DPU-memory analogue).
    None → capacity = the largest chunk population (no nonzero partitioning).
    """
    n = st.ndim
    cs = np.asarray(chunk_shape, dtype=np.int64)
    assert cs.shape == (n,) and np.all(cs >= 1)
    grid = tuple(int(-(-i // s)) for i, s in zip(st.shape, cs, strict=True))

    # Device-side coordinates (coords_rel, task_chunk, and every row index
    # derived from them as task_chunk * chunk_shape + local) are jnp.int32,
    # while all host arithmetic here is np.int64.  Refuse to chunk anything
    # whose padded per-mode extent the device could not address.
    for m, (g, s) in enumerate(zip(grid, cs, strict=True)):
        if g * int(s) - 1 > np.iinfo(np.int32).max:
            raise ValueError(
                f"mode {m}: padded extent {g * int(s)} (grid {g} x chunk "
                f"{int(s)}) exceeds int32 — device coordinates are jnp.int32; "
                "use a smaller chunk_shape or split the mode")
    if math.prod(grid) >= 1 << 62:
        raise ValueError(
            f"chunk grid {grid} linearizes past int64; coarsen chunk_shape")
    cs32 = cs.astype(np.int32)

    chunk_coord = st.coords // cs32  # (nnz, N)
    # Linearize chunk coordinates to group nonzeros by chunk.
    lin = np.zeros(st.nnz, dtype=np.int64)
    for m in range(n):
        lin = lin * grid[m] + chunk_coord[:, m]
    order = np.argsort(lin, kind="stable")
    lin_s = lin[order]
    coords_s = st.coords[order]
    values_s = st.values[order]

    uniq, start = np.unique(lin_s, return_index=True)
    counts = np.diff(np.append(start, st.nnz))
    if capacity is None:
        capacity = int(counts.max()) if counts.size else 1
    capacity = clamp_capacity(st.nnz, capacity)

    # Split over-full chunks into multiple tasks (nonzero partitioning).
    task_chunk, task_start, task_count = [], [], []
    for u, s0, c in zip(uniq, start, counts, strict=True):
        cc = np.zeros(n, dtype=np.int32)
        rem = u
        for m in reversed(range(n)):
            cc[m] = rem % grid[m]
            rem //= grid[m]
        off = 0
        while off < c:
            take = min(capacity, c - off)
            task_chunk.append(cc)
            task_start.append(s0 + off)
            task_count.append(take)
            off += take

    t = len(task_chunk)
    task_chunk = np.asarray(task_chunk, dtype=np.int32).reshape(t, n)
    coords_rel = np.zeros((t, capacity, n), dtype=np.int32)
    values = np.zeros((t, capacity), dtype=np.float32)
    nnz_per_task = np.asarray(task_count, dtype=np.int32)
    for i, (s0, c) in enumerate(zip(task_start, task_count, strict=True)):
        abs_coords = coords_s[s0 : s0 + c]
        coords_rel[i, :c] = abs_coords - task_chunk[i] * cs32
        values[i, :c] = values_s[s0 : s0 + c]

    return ChunkedTensor(
        task_chunk, coords_rel, values, nnz_per_task,
        tuple(int(s) for s in cs), st.shape,
    )


def replication_stats(ct: ChunkedTensor, rank: int, mode: int) -> dict:
    """Data-replication / reduction accounting (paper §IV-B trade-off).

    Returns factor elements transferred per mode-`mode` MTTKRP, the
    replication factor vs. the unpartitioned factors, and the number of
    partial-output rows that need sum reduction."""
    n = ct.ndim
    transferred = 0
    ideal = 0
    for m in range(n):
        if m == mode:
            continue
        transferred += ct.num_tasks * ct.chunk_shape[m] * rank
        ideal += ct.tensor_shape[m] * rank
    out_chunks = np.unique(ct.task_chunk[:, mode])
    partial_rows = ct.num_tasks * ct.chunk_shape[mode]
    final_rows = ct.tensor_shape[mode]
    return dict(
        factor_elements_transferred=int(transferred),
        factor_elements_ideal=int(ideal),
        replication_factor=float(transferred / max(ideal, 1)),
        partial_output_rows=int(partial_rows),
        final_output_rows=int(final_rows),
        reduction_factor=float(partial_rows / max(final_rows, 1)),
        nonempty_output_chunks=int(out_chunks.size),
    )
