"""Heterogeneous execution (paper §IV-D), adapted to TPU.

The paper splits spMTTKRP between UPMEM PIM (chunks dense enough to fill a
DPU) and the CPU (the rest, via ALTO).  The TPU-native analogue keeps the
same *scheduler* but retargets the two executors:

  * dense path  — chunks above a density threshold are densified into small
    dense blocks and dispatched to an einsum that runs on the MXU at full
    systolic throughput (the "device the work fits best" ≡ PIM role);
  * sparse path — remaining chunks run the gather/scatter chunked kernel
    (≡ CPU/ALTO role).

The split is decided statically from per-task density with a FLOP/byte cost
model, mirroring the paper's densest-first, fits-in-one-DPU ordering.
"""
from __future__ import annotations

import dataclasses
import math
import string
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .chunking import ChunkedTensor
from .mttkrp import gather_factor_blocks, mttkrp_chunked

__all__ = ["HeteroSplit", "split_tasks", "mttkrp_hetero", "dense_path_cost", "sparse_path_cost"]


def dense_path_cost(chunk_shape, rank: int) -> float:
    """MACs for one densified chunk on the MXU (all modes share one block)."""
    return math.prod(chunk_shape) * rank * (len(chunk_shape) - 1)


def sparse_path_cost(capacity: int, chunk_shape, rank: int) -> float:
    """MACs + gather overhead for one task on the sparse path."""
    n = len(chunk_shape)
    mults = capacity * rank * n
    gather_overhead = capacity * rank * 2  # index arithmetic / one-hot waste
    return mults + gather_overhead


@dataclasses.dataclass(frozen=True)
class HeteroSplit:
    dense_idx: np.ndarray   # task indices on the dense (MXU) path
    sparse_idx: np.ndarray  # task indices on the sparse path
    threshold: float

    @property
    def dense_fraction(self) -> float:
        total = self.dense_idx.size + self.sparse_idx.size
        return self.dense_idx.size / max(total, 1)


MAX_DENSE_VOLUME = 1 << 22  # dense blocks must fit the executor (the DPU-
                            # capacity analogue for the MXU path)


def split_tasks(
    ct: ChunkedTensor,
    rank: int,
    *,
    dense_fraction: float | None = None,
    max_dense_volume: int = MAX_DENSE_VOLUME,
) -> HeteroSplit:
    """Static split.  Default threshold from the cost model: a task goes dense
    when densifying is cheaper than gathering.  `dense_fraction` overrides the
    threshold with a paper-style static workload fraction (densest-first).
    Chunks whose dense form exceeds `max_dense_volume` elements never go
    dense — mirroring the paper's only-what-fits-a-DPU rule."""
    density = ct.nnz_per_task / max(math.prod(ct.chunk_shape), 1)
    if math.prod(ct.chunk_shape) > max_dense_volume:
        return HeteroSplit(np.zeros((0,), np.int32),
                           np.arange(ct.num_tasks, dtype=np.int32),
                           float("inf"))
    if dense_fraction is not None:
        k = int(round(dense_fraction * ct.num_tasks))
        order = np.argsort(-density, kind="stable")
        dense = order[:k]
        sparse = order[k:]
        thr = float(density[dense[-1]]) if k else float("inf")
    else:
        cost_d = dense_path_cost(ct.chunk_shape, rank)
        # Per-task sparse cost scales with its live nonzeros.
        cost_s = np.array(
            [sparse_path_cost(int(c), ct.chunk_shape, rank) for c in ct.nnz_per_task]
        )
        dense_mask = cost_d < cost_s
        dense = np.nonzero(dense_mask)[0]
        sparse = np.nonzero(~dense_mask)[0]
        thr = cost_d / max(
            sparse_path_cost(1, ct.chunk_shape, rank) * math.prod(ct.chunk_shape), 1
        )
    # repro-lint: disable=int32-index-width -- task-index permutation; task count is nnz/capacity and nnz is itself int32-bounded (coords are int32)
    return HeteroSplit(dense.astype(np.int32), sparse.astype(np.int32), thr)


def densify_tasks(ct: ChunkedTensor, idx: np.ndarray) -> np.ndarray:
    """(Td, S_0, ..., S_{N-1}) dense blocks for the selected tasks."""
    n = ct.ndim
    out = np.zeros((idx.size, *ct.chunk_shape), dtype=np.float32)
    for o, i in enumerate(idx):
        c = int(ct.nnz_per_task[i])
        if c:
            np.add.at(out[o], tuple(ct.coords_rel[i, :c].T), ct.values[i, :c])
    return out


@partial(jax.jit, static_argnames=("mode", "chunk_shape", "out_dim"))
def _dense_path(
    factors, dense_blocks, dense_task_chunk, *, mode, chunk_shape, out_dim
):
    """einsum over densified chunks: e.g. mode-2 3D → 'tij k,tir,tjr->tkr'."""
    n = len(factors)
    rank = factors[0].shape[1]
    offsets = dense_task_chunk * jnp.asarray(chunk_shape, dtype=jnp.int32)
    letters = string.ascii_lowercase
    t_sub = "t" + "".join(letters[m] for m in range(n))
    operands, subs = [dense_blocks], [t_sub]
    for m in range(n):
        if m == mode:
            continue
        blk = gather_factor_blocks(factors[m], offsets[:, m], chunk_shape[m])
        operands.append(blk)
        subs.append(f"t{letters[m]}r")
    out_sub = f"t{letters[mode]}r"
    local = jnp.einsum(",".join(subs) + "->" + out_sub, *operands)  # (Td, S, R)
    out = jnp.zeros((out_dim, rank), jnp.float32)
    rows = offsets[:, mode : mode + 1] + jnp.arange(chunk_shape[mode])[None, :]
    return out.at[rows.reshape(-1)].add(local.reshape(-1, rank), mode="drop")


def mttkrp_hetero(
    factors,
    ct: ChunkedTensor,
    split: HeteroSplit,
    dense_blocks,
    *,
    mode: int,
    out_dim: int,
):
    """Run both paths and sum (the paper's final CPU+PIM combine)."""
    out = jnp.zeros((out_dim, factors[0].shape[1]), jnp.float32)
    if split.dense_idx.size:
        out = out + _dense_path(
            factors,
            dense_blocks,
            jnp.asarray(ct.task_chunk[split.dense_idx]),
            mode=mode,
            chunk_shape=ct.chunk_shape,
            out_dim=out_dim,
        )
    if split.sparse_idx.size:
        out = out + mttkrp_chunked(
            factors,
            jnp.asarray(ct.task_chunk[split.sparse_idx]),
            jnp.asarray(ct.coords_rel[split.sparse_idx]),
            jnp.asarray(ct.values[split.sparse_idx]),
            mode=mode,
            chunk_shape=ct.chunk_shape,
            out_dim=out_dim,
        )
    return out
