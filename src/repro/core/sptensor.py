"""Sparse tensor container + synthetic dataset generators.

The paper evaluates on FROSTT tensors (Table I). The offline container cannot
ship FROSTT, so `table1_tensor` generates synthetic tensors whose mode count,
relative dimension shape, and nonzero *distribution* (balanced vs imbalanced)
match each Table-I entry, scaled to CPU-runnable sizes.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "SparseTensor",
    "random_tensor",
    "table1_tensor",
    "TABLE1",
]


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """COO sparse tensor. Coordinates are (nnz, N) int32, values (nnz,) f32."""

    coords: np.ndarray
    values: np.ndarray
    shape: tuple[int, ...]

    def __post_init__(self):
        assert self.coords.ndim == 2 and self.coords.shape[1] == len(self.shape)
        assert self.values.shape == (self.coords.shape[0],)

    @property
    def nnz(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def density(self) -> float:
        return self.nnz / math.prod(self.shape)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.float64)
        np.add.at(out, tuple(self.coords.T), self.values.astype(np.float64))
        return out.astype(np.float32)

    def norm(self) -> float:
        return float(np.linalg.norm(self.values.astype(np.float64)))

    def permuted(self, order: np.ndarray) -> SparseTensor:
        """Reorder the nonzeros by `order`, which must be a permutation of
        ``arange(nnz)`` — fancy indexing happily accepts short, repeated or
        boolean indexers and silently drops/duplicates nonzeros."""
        order = np.asarray(order)
        if (order.shape != (self.nnz,)
                or not np.issubdtype(order.dtype, np.integer)):
            raise ValueError(
                f"order must be an integer permutation of arange(nnz="
                f"{self.nnz}); got shape {order.shape} dtype {order.dtype}")
        seen = np.zeros(self.nnz, dtype=bool)
        in_range = (order >= 0) & (order < self.nnz)
        seen[order[in_range]] = True
        if not (in_range.all() and seen.all()):
            raise ValueError(
                f"order is not a permutation of arange(nnz={self.nnz}): "
                "every nonzero must appear exactly once")
        return SparseTensor(self.coords[order], self.values[order], self.shape)


#: Collision top-up policy (see `random_tensor`): after this many exact-
#: shortfall rejection rounds, small tensors switch to an exact fill from
#: the not-yet-used cells; tensors too large to enumerate raise after the
#: round cap instead of hanging (statistically unreachable for any sparse
#: request — stalls need density near 1, which implies an enumerable shape).
_TOPUP_EXACT_AFTER = 16
_TOPUP_EXACT_CELLS = 1 << 24
_TOPUP_MAX_ROUNDS = 1024


def _dedup(coords: np.ndarray, values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Merge duplicate coordinates by summing values (keeps COO canonical)."""
    uniq, inv = np.unique(coords, axis=0, return_inverse=True)
    out = np.zeros(uniq.shape[0], dtype=values.dtype)
    np.add.at(out, inv, values)
    return uniq.astype(np.int32), out


def random_tensor(
    shape: tuple[int, ...],
    nnz: int,
    *,
    distribution: str = "uniform",
    value_scale: float = 1.0,
    seed: int = 0,
    zipf_a: float = 1.3,
) -> SparseTensor:
    """Synthetic sparse tensor.

    distribution:
      "uniform"  — nonzeros spread evenly (the paper's "well-balanced",
                   like 5D_large).
      "powerlaw" — Zipf-distributed coordinates per mode (imbalanced, like
                   Delicious), which stresses the partition decider.

    The returned tensor has EXACTLY `nnz` nonzeros (capped at the number of
    cells): `_dedup` merges duplicate draws, so a single batch would come up
    short — powerlaw tensors by up to ~10% — and every consumer sized off
    the request (TABLE1 workload fingerprints, benchmark labels) would be
    silently wrong.  Collision shortfall is topped up with fresh draws until
    the target is met.
    """
    rng = np.random.default_rng(seed)
    shape = tuple(int(d) for d in shape)
    target = min(int(nnz), math.prod(shape))
    # Powerlaw scatter permutations are drawn once per mode and shared by
    # every draw batch, so top-ups hit the same hot rows as the first batch
    # (the imbalanced character must survive the top-up).
    perms = [rng.permutation(dim) if distribution == "powerlaw" else None
             for dim in shape]

    def draw(n: int) -> np.ndarray:
        cols = []
        for dim, perm in zip(shape, perms, strict=True):
            if distribution == "uniform":
                c = rng.integers(0, dim, size=n, dtype=np.int64)
            elif distribution == "powerlaw":
                # Zipf over the dimension, shuffled so hot rows are scattered.
                raw = rng.zipf(zipf_a, size=n) - 1
                c = perm[np.minimum(raw, dim - 1)]
            else:
                raise ValueError(f"unknown distribution {distribution!r}")
            cols.append(c)
        return np.stack(cols, axis=1).astype(np.int32)

    def values_for(n: int) -> np.ndarray:
        return rng.uniform(-value_scale, value_scale, size=n).astype(np.float32)

    coords, values = _dedup(draw(int(nnz)), values_for(int(nnz)))
    for rounds in range(_TOPUP_MAX_ROUNDS):
        if coords.shape[0] >= target:
            break
        # Drawing exactly the shortfall adds at most that many new uniques,
        # so the loop converges to `target` from below and never overshoots.
        need = target - coords.shape[0]
        # Rejection sampling stalls when the request approaches the cell
        # count (a zipf tail makes the last unseen cells nearly
        # unreachable — a coupon-collector hang); such requests only arise
        # on small, enumerable tensors, so fill the shortfall exactly from
        # the missing cells instead.
        if rounds >= _TOPUP_EXACT_AFTER and math.prod(shape) <= _TOPUP_EXACT_CELLS:
            missing = np.setdiff1d(
                np.arange(math.prod(shape), dtype=np.int64),
                np.ravel_multi_index(tuple(coords.T), shape).astype(np.int64),
                assume_unique=True)
            pick = rng.choice(missing, size=need, replace=False)
            extra = np.stack(np.unravel_index(pick, shape), axis=1).astype(np.int32)
        else:
            extra = draw(need)
        coords, values = _dedup(
            np.concatenate([coords, extra]),
            np.concatenate([values, values_for(need)]))
    else:
        raise ValueError(
            f"random_tensor could not reach nnz={target} on shape {shape} "
            f"({distribution!r}) within {_TOPUP_MAX_ROUNDS} top-up rounds — "
            "the request is too dense for rejection sampling on a tensor "
            "too large to fill exactly; lower nnz")
    return SparseTensor(coords, values, shape)


# Table I of the paper, scaled so the *relative* mode sizes and the balanced /
# imbalanced character survive while staying CPU-runnable.  `scale` divides
# each dimension; nnz is chosen to keep a few tens of thousands of nonzeros.
TABLE1: dict[str, dict] = {
    # name: (paper dims), scaled dims, nnz, distribution
    "nell2": dict(shape=(605, 460, 1440), nnz=50_000, distribution="uniform"),
    "nell1": dict(shape=(2900, 2100, 25500), nnz=60_000, distribution="powerlaw"),
    "amazon": dict(shape=(4800, 1800, 1800), nnz=60_000, distribution="uniform"),
    "delicious": dict(shape=(533, 17300, 2500, 140), nnz=40_000, distribution="powerlaw"),
    "lbnl": dict(shape=(160, 420, 160, 420, 868), nnz=30_000, distribution="powerlaw"),
    "5d_large": dict(shape=(10000, 1000, 3000, 4000, 500), nnz=80_000, distribution="uniform"),
}


def table1_tensor(name: str, *, seed: int = 0, nnz: int | None = None) -> SparseTensor:
    spec = TABLE1[name]
    return random_tensor(
        tuple(spec["shape"]),
        nnz if nnz is not None else spec["nnz"],
        distribution=spec["distribution"],
        seed=seed,
    )
