"""Sparse tensor container + synthetic dataset generators.

The paper evaluates on FROSTT tensors (Table I). The offline container cannot
ship FROSTT, so `table1_tensor` generates synthetic tensors whose mode count,
relative dimension shape, and nonzero *distribution* (balanced vs imbalanced)
match each Table-I entry, scaled to CPU-runnable sizes.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = [
    "SparseTensor",
    "random_tensor",
    "table1_tensor",
    "TABLE1",
]


@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """COO sparse tensor. Coordinates are (nnz, N) int32, values (nnz,) f32."""

    coords: np.ndarray
    values: np.ndarray
    shape: tuple[int, ...]

    def __post_init__(self):
        assert self.coords.ndim == 2 and self.coords.shape[1] == len(self.shape)
        assert self.values.shape == (self.coords.shape[0],)

    @property
    def nnz(self) -> int:
        return self.coords.shape[0]

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def density(self) -> float:
        return self.nnz / math.prod(self.shape)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=np.float64)
        np.add.at(out, tuple(self.coords.T), self.values.astype(np.float64))
        return out.astype(np.float32)

    def norm(self) -> float:
        return float(np.linalg.norm(self.values.astype(np.float64)))

    def permuted(self, order: np.ndarray) -> SparseTensor:
        return SparseTensor(self.coords[order], self.values[order], self.shape)


def _dedup(coords: np.ndarray, values: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Merge duplicate coordinates by summing values (keeps COO canonical)."""
    uniq, inv = np.unique(coords, axis=0, return_inverse=True)
    out = np.zeros(uniq.shape[0], dtype=values.dtype)
    np.add.at(out, inv, values)
    return uniq.astype(np.int32), out


def random_tensor(
    shape: tuple[int, ...],
    nnz: int,
    *,
    distribution: str = "uniform",
    value_scale: float = 1.0,
    seed: int = 0,
    zipf_a: float = 1.3,
) -> SparseTensor:
    """Synthetic sparse tensor.

    distribution:
      "uniform"  — nonzeros spread evenly (the paper's "well-balanced",
                   like 5D_large).
      "powerlaw" — Zipf-distributed coordinates per mode (imbalanced, like
                   Delicious), which stresses the partition decider.
    """
    rng = np.random.default_rng(seed)
    cols = []
    for dim in shape:
        if distribution == "uniform":
            c = rng.integers(0, dim, size=nnz, dtype=np.int64)
        elif distribution == "powerlaw":
            # Zipf over the dimension, shuffled so hot rows are scattered.
            raw = rng.zipf(zipf_a, size=nnz) - 1
            c = np.minimum(raw, dim - 1)
            perm = rng.permutation(dim)
            c = perm[c]
        else:
            raise ValueError(f"unknown distribution {distribution!r}")
        cols.append(c)
    coords = np.stack(cols, axis=1).astype(np.int32)
    values = rng.uniform(-value_scale, value_scale, size=nnz).astype(np.float32)
    coords, values = _dedup(coords, values)
    return SparseTensor(coords, values, tuple(int(d) for d in shape))


# Table I of the paper, scaled so the *relative* mode sizes and the balanced /
# imbalanced character survive while staying CPU-runnable.  `scale` divides
# each dimension; nnz is chosen to keep a few tens of thousands of nonzeros.
TABLE1: dict[str, dict] = {
    # name: (paper dims), scaled dims, nnz, distribution
    "nell2": dict(shape=(605, 460, 1440), nnz=50_000, distribution="uniform"),
    "nell1": dict(shape=(2900, 2100, 25500), nnz=60_000, distribution="powerlaw"),
    "amazon": dict(shape=(4800, 1800, 1800), nnz=60_000, distribution="uniform"),
    "delicious": dict(shape=(533, 17300, 2500, 140), nnz=40_000, distribution="powerlaw"),
    "lbnl": dict(shape=(160, 420, 160, 420, 868), nnz=30_000, distribution="powerlaw"),
    "5d_large": dict(shape=(10000, 1000, 3000, 4000, 500), nnz=80_000, distribution="uniform"),
}


def table1_tensor(name: str, *, seed: int = 0, nnz: int | None = None) -> SparseTensor:
    spec = TABLE1[name]
    return random_tensor(
        tuple(spec["shape"]),
        nnz if nnz is not None else spec["nnz"],
        distribution=spec["distribution"],
        seed=seed,
    )
