"""Qm.n fixed-point formats (paper §IV-C).

UPMEM DPUs have no floating-point hardware, so PRISM runs the MTTKRP inner
loop in fixed point.  On TPU the same formats attack the *memory* roofline
term instead (narrow ints halve HBM bytes of a memory-bound kernel) and map
onto the MXU's native int8/int16→int32 multiply path.

Key paper facts encoded here:
  * factor matrices are L-infinity normalized to [-1, 1], so a QX.f factor
    value has magnitude ≤ 2^f; the product of two factor values fits int32
    for every format the paper uses (the DPU is a 32-bit core — this is why
    the paper's formats work at all).
  * Q5.3 (8-bit) is too coarse to converge; Q9.7 (16-bit) is the preferred
    mode-3 format; Q17.15 with prec_shift=3 is used for mode-4/5.
  * tensor values are quantized to 16 bits with a runtime-determined
    precision (the value range is only known after reading the tensor).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

__all__ = [
    "CROSS_MODE_SLACK",
    "FIXED_PRESETS",
    "Q5_3",
    "Q9_7",
    "Q17_15",
    "QFormat",
    "accumulator_safe_nnz",
    "cross_mode_error_bound",
    "preset_error_bound",
    "value_qformat",
]


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Signed fixed point with `int_bits` integer bits (incl. sign) and
    `frac_bits` fractional bits; stored in `storage_bits` two's complement."""

    int_bits: int
    frac_bits: int

    @property
    def storage_bits(self) -> int:
        return self.int_bits + self.frac_bits

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def storage_dtype(self):
        bits = self.storage_bits
        if bits <= 8:
            return jnp.int8
        if bits <= 16:
            return jnp.int16
        return jnp.int32

    @property
    def np_dtype(self):
        bits = self.storage_bits
        if bits <= 8:
            return np.int8
        if bits <= 16:
            return np.int16
        return np.int32

    @property
    def max_abs_error(self) -> float:
        """Worst-case round-trip error for an in-range value: round-to-nearest
        quantization is off by at most half a step, 1/(2·scale)."""
        return 1.0 / (2 * self.scale)

    @property
    def max_int(self) -> int:
        return (1 << (self.storage_bits - 1)) - 1

    @property
    def min_int(self) -> int:
        return -(1 << (self.storage_bits - 1))

    def quantize_np(self, x: np.ndarray) -> np.ndarray:
        q = np.round(np.asarray(x, dtype=np.float64) * self.scale)
        return np.clip(q, self.min_int, self.max_int).astype(self.np_dtype)

    def quantize(self, x) -> jnp.ndarray:
        q = jnp.round(x.astype(jnp.float32) * self.scale)
        return jnp.clip(q, self.min_int, self.max_int).astype(self.storage_dtype)

    def dequantize(self, q) -> jnp.ndarray:
        return q.astype(jnp.float32) / self.scale

    def __str__(self):
        return f"Q{self.int_bits}.{self.frac_bits}"


# The paper's formats.
Q5_3 = QFormat(5, 3)      # 8-bit — shown not to converge; kept for the study.
Q9_7 = QFormat(9, 7)      # 16-bit — preferred for mode-3 tensors.
Q17_15 = QFormat(17, 15)  # 32-bit — preferred for mode-4/5, prec_shift=3.

# (factor format, prec_shift) presets named as in the paper's Fig. 6.
FIXED_PRESETS: dict[str, tuple[QFormat, int]] = {
    "int3": (Q5_3, 0),
    "int7": (Q9_7, 0),
    "int15-12": (Q17_15, 3),
}


#: Headroom when extrapolating a measured anchor-mode MTTKRP error to the
#: un-measured modes.  The quantization noise itself is mode-uniform (the
#: factors are quantized identically whichever mode is solved for), but the
#: gather/accumulate pattern — and so how rounding errors align — changes
#: with the mode; a 2x cushion over the worst measured mode covers that
#: rearrangement without surrendering to the (much looser) analytic bound.
CROSS_MODE_SLACK = 2.0


def preset_error_bound(preset: str, ndim: int, *, value_frac: int = 7) -> float:
    """First-order element-wise estimate of the relative error of one
    fixed-point MTTKRP (paper Alg. 2) under `FIXED_PRESETS[preset]`, for an
    `ndim`-mode tensor with L∞-normalized factors.

    Three independent rounding sources add at first order:
      * each of the `ndim - 1` gathered factor values carries up to
        `1/(2·scale)` quantization error on a magnitude-≤1 value;
      * the tensor value is quantized to a runtime 16-bit format with
        `value_frac` fractional bits (`value_qformat`; 7 is the floor the
        synthetic [0, 1) tensors see);
      * dequantizing the accumulator truncates `prec_shift` extra bits,
        worth `2^prec_shift / (2·scale)`.

    This is a per-*element* estimate, NOT a guaranteed bound on the
    output-norm relative error (rows whose exact output is small amplify
    absolute rounding noise arbitrarily) — it orders the presets correctly
    and seeds the no-measurement fallback, but the autotuner's measured
    anchor error always overrides it (`cross_mode_error_bound`).
    """
    qf, prec_shift = FIXED_PRESETS[preset]
    factor_err = (ndim - 1) * qf.max_abs_error
    value_err = 0.5 ** (value_frac + 1)
    dequant_err = (1 << prec_shift) * qf.max_abs_error
    return factor_err + value_err + dequant_err


def accumulator_safe_nnz(preset: str, *, value_frac: int = 7) -> int:
    """Largest per-output-row nonzero count for which the int32 accumulator
    of the fixed MTTKRP (paper Alg. 2) provably cannot overflow.

    After Alg. 2's renormalizing shifts each accumulated partial is an
    integer of magnitude at most `2^(frac + 15 - value_frac - prec_shift)`:
    the factor product stays ≤ 1.0 (i.e. ≤ `scale` as an integer) because
    factors are L∞-normalized and every multiply is followed by a
    `>> matrix_frac`; the 16-bit tensor value contributes up to `2^15`
    before its `>> (value_frac + prec_shift)`.  The int32 accumulator holds
    `2^31 - 1`, so summing more than this many partials into one output row
    can wrap — silently, since device int arithmetic does not trap.

    The analysis suite pins these values per preset (int3: 1048575,
    int7: 65535, int15-12: 2047) in `kernel_contracts.json` and re-derives
    them from `FIXED_PRESETS`, so a preset change that shrinks the headroom
    fails static analysis instead of corrupting large-tensor runs."""
    qf, prec_shift = FIXED_PRESETS[preset]
    headroom = qf.frac_bits + 15 - value_frac - prec_shift
    return (2**31 - 1) >> max(headroom, 0)


def cross_mode_error_bound(
    measured: dict[int, float], preset: str, ndim: int, *,
    value_frac: int = 7,
) -> float:
    """Bound the relative MTTKRP error of the modes *not* measured from the
    ones that were: the worst measured mode times `CROSS_MODE_SLACK` — the
    noise source (factor quantization) is mode-uniform, the slack covers how
    the gather/accumulate pattern rearranges it.  Only with no measurement
    at all (which the autotuner never allows for an admitted lossy
    candidate — the anchor probe always measures) does the analytic
    estimate stand in, with the same headroom."""
    if measured:
        return CROSS_MODE_SLACK * max(measured.values())
    return CROSS_MODE_SLACK * preset_error_bound(preset, ndim,
                                                 value_frac=value_frac)


def value_qformat(values: np.ndarray, storage_bits: int = 16) -> QFormat:
    """Runtime-determined precision for tensor nonzero values (paper §IV-C:
    'the range of nonzero values cannot be determined before reading the
    tensor').  Chooses the Q format with the most fractional bits that still
    represents max|value| in `storage_bits`."""
    vmax = float(np.max(np.abs(values))) if values.size else 1.0
    int_bits = max(1, math.ceil(math.log2(vmax + 1e-12)) + 1) + 1  # +sign
    int_bits = min(int_bits, storage_bits - 1)
    return QFormat(int_bits, storage_bits - int_bits)
