"""Full CP-ALS (paper Algorithm 1) with pluggable MTTKRP engines.

Everything except MTTKRP — gram matrices, Hadamard products, the pseudo-
inverse solve, normalization, convergence — runs in float on the host side,
exactly as the paper leaves them on the CPU.  The MTTKRP engine is swappable:

  engine="ref"       plain COO (paper Fig. 1 definition)
  engine="alto"      ALTO-ordered baseline
  engine="chunked"   PRISM chunked format (float)
  engine="fixed"     PRISM chunked + paper Alg. 2 fixed point ("int7"/"int15-12")
  engine="hetero"    dense(MXU)/sparse split (paper §IV-D analogue)
  engine="pallas"    Pallas TPU kernel (kernels/ops.py), interpret on CPU
  engine=callable    custom: f(factors, mode) -> (I_mode, R)

Normalization is L-infinity by default (paper §IV-C: uses the full [-1, 1]
range, which fixed point needs); L2 is available for comparison.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import baselines, hetero, lockfree, mttkrp
from .chunking import ChunkedTensor, chunk_tensor
from .partition import decide_partition
from .qformat import FIXED_PRESETS, QFormat, value_qformat
from .sptensor import SparseTensor

__all__ = [
    "CPResult",
    "cp_als",
    "make_engine",
    "init_factors",
    "avg_abs_diff",
    "fit_value",
    "reconstruct_nnz",
]


@dataclasses.dataclass
class CPResult:
    factors: list[np.ndarray]
    lam: np.ndarray
    fit_history: list[float]
    diff_history: list[float]
    iter_times: list[float]
    engine: str


def init_factors(shape, rank: int, seed: int = 0) -> list[jnp.ndarray]:
    """Random init in [0, 1) — respects the [-1, 1] fixed-point range."""
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.uniform(0, 1, size=(d, rank)).astype(np.float32)) for d in shape]


def _normalize(f: jnp.ndarray, norm: str):
    if norm == "linf":
        lam = jnp.max(jnp.abs(f), axis=0)
    elif norm == "l2":
        lam = jnp.linalg.norm(f, axis=0)
    else:
        raise ValueError(norm)
    lam = jnp.where(lam == 0, 1.0, lam)
    return f / lam, lam


def reconstruct_nnz(factors, lam, coords) -> jnp.ndarray:
    """x̂ at the given coordinates: Σ_r λ_r ∏_m F_m[c_m, r]."""
    prod = jnp.asarray(lam)[None, :]
    for m, f in enumerate(factors):
        prod = prod * jnp.asarray(f)[coords[:, m]]
    return prod.sum(axis=1)


def avg_abs_diff(st: SparseTensor, factors, lam, *, dense_limit: int = 1 << 22) -> float:
    """Paper Fig. 6 metric: mean |X - X̂| over all elements when the tensor is
    small enough, else over the nonzeros only (as done for Delicious/Lbnl)."""
    if math.prod(st.shape) <= dense_limit:
        dense = jnp.asarray(st.to_dense())
        letters = "abcdefg"[: st.ndim]
        sub = ",".join(f"{c}r" for c in letters)
        approx = jnp.einsum(f"r,{sub}->{''.join(letters)}", jnp.asarray(lam),
                            *[jnp.asarray(f) for f in factors])
        return float(jnp.mean(jnp.abs(dense - approx)))
    approx = reconstruct_nnz(factors, lam, jnp.asarray(st.coords))
    return float(jnp.mean(jnp.abs(jnp.asarray(st.values) - approx)))


def fit_value(st: SparseTensor, factors, lam, mlast=None, last_mode=None) -> float:
    """fit = 1 - ||X - X̂||_F / ||X||_F, using the standard sparse identity
    ||X - X̂||² = ||X||² - 2<X, X̂> + ||X̂||²."""
    norm_x2 = st.norm() ** 2
    grams = [jnp.asarray(f).T @ jnp.asarray(f) for f in factors]
    had = jnp.asarray(lam)[:, None] * jnp.asarray(lam)[None, :]
    for g in grams:
        had = had * g
    norm_approx2 = float(jnp.sum(had))
    if mlast is not None and last_mode is not None:
        inner = float(jnp.sum(mlast * (jnp.asarray(factors[last_mode]) * jnp.asarray(lam)[None, :])))
    else:
        inner = float(
            jnp.dot(reconstruct_nnz(factors, lam, jnp.asarray(st.coords)), jnp.asarray(st.values))
        )
    resid = max(norm_x2 - 2 * inner + norm_approx2, 0.0)
    return 1.0 - math.sqrt(resid) / max(math.sqrt(norm_x2), 1e-30)


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------

def make_engine(
    st: SparseTensor,
    method: str,
    rank: int,
    *,
    mem_bytes: int | None = None,
    chunk_shape: tuple[int, ...] | None = None,
    capacity: int | None = None,
    fixed_preset: str = "int7",
    lockfree_mode: bool = False,
    dense_fraction: float | None = None,
) -> Callable:
    """Build an MTTKRP engine closure: f(factors, mode) -> (I_mode, R) f32.

    Chunk-based engines chunk the tensor ONCE (the chunked format is
    mode-agnostic) — the tensor stays resident, only factors move per call,
    matching the paper's rank-partitioning data-residency argument.
    """
    coords = jnp.asarray(st.coords)
    values = jnp.asarray(st.values)

    if method == "ref":
        def engine(factors, mode):
            return mttkrp.mttkrp_coo(tuple(factors), coords, values,
                                      mode=mode, out_dim=st.shape[mode])
        return engine

    if method == "alto":
        order = baselines.alto_order(st.coords, st.shape)
        a_coords = jnp.asarray(st.coords[order])
        a_values = jnp.asarray(st.values[order])
        def engine(factors, mode):
            return baselines.mttkrp_alto(tuple(factors), a_coords, a_values,
                                         mode=mode, out_dim=st.shape[mode])
        return engine

    if method in ("chunked", "fixed", "hetero", "pallas"):
        if chunk_shape is None:
            plan = decide_partition(st, rank, mem_bytes=mem_bytes or 64 * 1024 * 1024)
            chunk_shape = plan.chunk_shape
            capacity = capacity or plan.capacity
        ct = chunk_tensor(st, chunk_shape, capacity)
        dev = mttkrp.chunked_device_arrays(ct)
        cs, nd = ct.chunk_shape, ct.ndim

        if method == "chunked":
            mask = None
            if lockfree_mode:
                nnz_pt = jnp.asarray(ct.nnz_per_task)
            def engine(factors, mode):
                vals = dev["values"]
                if lockfree_mode:
                    m = lockfree.wave_collision_mask(dev["coords_rel"][:, :, mode], nnz_pt)
                    vals = vals * m
                return mttkrp.mttkrp_chunked(
                    tuple(factors), dev["task_chunk"], dev["coords_rel"], vals,
                    mode=mode, chunk_shape=cs, out_dim=st.shape[mode])
            return engine

        if method == "fixed":
            qf, prec_shift = FIXED_PRESETS[fixed_preset]
            vq = value_qformat(st.values, storage_bits=16)
            qvalues = jnp.asarray(vq.quantize_np(ct.values))
            nnz_pt = jnp.asarray(ct.nnz_per_task)
            def engine(factors, mode):
                qfactors = tuple(qf.quantize(f) for f in factors)
                qvals = qvalues
                if lockfree_mode:
                    m = lockfree.wave_collision_mask(dev["coords_rel"][:, :, mode], nnz_pt)
                    qvals = (qvals * m.astype(qvals.dtype))
                qout = mttkrp.mttkrp_chunked_fixed(
                    qfactors, dev["task_chunk"], dev["coords_rel"], qvals,
                    mode=mode, chunk_shape=cs, out_dim=st.shape[mode],
                    matrix_frac=qf.frac_bits, value_frac=vq.frac_bits,
                    prec_shift=prec_shift)
                return mttkrp.dequantize_output(qout, qf.frac_bits, prec_shift)
            return engine

        if method == "hetero":
            split = hetero.split_tasks(ct, rank, dense_fraction=dense_fraction)
            dense_blocks = jnp.asarray(hetero.densify_tasks(ct, split.dense_idx))
            def engine(factors, mode):
                return hetero.mttkrp_hetero(
                    tuple(factors), ct, split, dense_blocks,
                    mode=mode, out_dim=st.shape[mode])
            return engine

        if method == "pallas":
            from ..kernels import ops as kops
            def engine(factors, mode):
                return kops.mttkrp_pallas(
                    tuple(factors), dev["task_chunk"], dev["coords_rel"],
                    dev["values"], mode=mode, chunk_shape=cs,
                    out_dim=st.shape[mode], interpret=True)
            return engine

    raise ValueError(f"unknown engine {method!r}")


# ---------------------------------------------------------------------------
# CP-ALS driver (Algorithm 1)
# ---------------------------------------------------------------------------

def cp_als(
    st: SparseTensor,
    rank: int,
    n_iters: int = 5,
    *,
    engine: str | Callable = "ref",
    norm: str = "linf",
    seed: int = 0,
    track_diff: bool = True,
    tol: float | None = None,
    **engine_kwargs,
) -> CPResult:
    n = st.ndim
    factors = init_factors(st.shape, rank, seed)
    lam = jnp.ones((rank,), jnp.float32)
    eng = engine if callable(engine) else make_engine(st, engine, rank, **engine_kwargs)
    eng_name = engine if isinstance(engine, str) else getattr(engine, "__name__", "custom")

    fit_history, diff_history, iter_times = [], [], []
    prev_fit = -np.inf
    for it in range(n_iters):
        t0 = time.perf_counter()
        mlast = None
        for mode in range(n):
            m = eng([jnp.asarray(f) for f in factors], mode)
            # Pseudo-inverse step: A = M (∘_{k≠mode} F_kᵀF_k)†  (Alg. 1 l.5-7)
            v = jnp.ones((rank, rank), jnp.float32)
            for k in range(n):
                if k == mode:
                    continue
                fk = jnp.asarray(factors[k])
                v = v * (fk.T @ fk)
            a = m @ jnp.linalg.pinv(v)
            a, lam = _normalize(a, norm)
            factors[mode] = a
            mlast = m
        jax.block_until_ready(factors[-1])
        iter_times.append(time.perf_counter() - t0)

        f = fit_value(st, factors, lam, mlast=None, last_mode=None)
        fit_history.append(f)
        if track_diff:
            diff_history.append(avg_abs_diff(st, factors, lam))
        if tol is not None and abs(f - prev_fit) < tol:
            break
        prev_fit = f

    return CPResult(
        [np.asarray(f) for f in factors], np.asarray(lam),
        fit_history, diff_history, iter_times, eng_name,
    )
