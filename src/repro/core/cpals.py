"""Full CP-ALS (paper Algorithm 1) with pluggable MTTKRP engines.

Everything except MTTKRP — gram matrices, Hadamard products, the pseudo-
inverse solve, normalization, convergence — runs in float on the host side,
exactly as the paper leaves them on the CPU.  The MTTKRP engine is swappable
— any name registered in `repro.engine` (see its backend registry):

  engine="ref"         plain COO (paper Fig. 1 definition)
  engine="alto"        ALTO linearized format (repro.formats.alto): one
                       bit-interleaved index serving every mode
  engine="csf"         CSF fiber trees (repro.formats.csf): interior factor
                       rows fetched once per fiber
  engine="chunked"     PRISM chunked format (float)
  engine="fixed"       PRISM chunked + paper Alg. 2 fixed point ("int7"/"int15-12")
  engine="hetero"      dense(MXU)/sparse split (paper §IV-D analogue)
  engine="pallas"      Pallas TPU kernel (kernels/ops.py), interpret on CPU
  engine="distributed" shard_map over a (data, model) mesh (paper §IV-B)
  engine="auto"        empirical autotuner: measures the eligible backends
                       per (tensor, rank, mode) and dispatches to the winner;
                       pass store=True/path/TuningStore (forwarded via
                       **engine_kwargs) to persist winners across processes,
                       max_probes=k to cap cold-start probing to the
                       cost-model prior's top-k, and prior="calibrated" to
                       fit the prior to the store's measurements (which also
                       turns on cross-mode probe elision)
  engine=callable      custom: f(factors, mode) -> (I_mode, R)

Normalization is L-infinity by default (paper §IV-C: uses the full [-1, 1]
range, which fixed point needs); L2 is available for comparison.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.tracing import span
from .sptensor import SparseTensor

__all__ = [
    "CPResult",
    "cp_als",
    "make_engine",
    "init_factors",
    "avg_abs_diff",
    "fit_value",
    "reconstruct_nnz",
]


@dataclasses.dataclass
class CPResult:
    factors: list[np.ndarray]
    lam: np.ndarray
    fit_history: list[float]
    diff_history: list[float]
    iter_times: list[float]
    engine: str
    #: Measured MTTKRP relative error of the quantized (lossy) engine that
    #: produced the factors — the autotuner's per-mode error measurements
    #: when available, else one direct comparison against the float COO
    #: reference on the final factors.  None for exact engines.
    quant_error: float | None = None
    #: The autotuner's report (winners, timings, errors) when engine="auto"
    #: built the engine in this call; None otherwise.
    tune_report: object | None = None


def init_factors(shape, rank: int, seed: int = 0) -> list[jnp.ndarray]:
    """Random init in [0, 1) — respects the [-1, 1] fixed-point range."""
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.uniform(0, 1, size=(d, rank)).astype(np.float32)) for d in shape]


def _normalize(f: jnp.ndarray, norm: str):
    if norm == "linf":
        lam = jnp.max(jnp.abs(f), axis=0)
    elif norm == "l2":
        lam = jnp.linalg.norm(f, axis=0)
    else:
        raise ValueError(norm)
    lam = jnp.where(lam == 0, 1.0, lam)
    return f / lam, lam


def reconstruct_nnz(factors, lam, coords) -> jnp.ndarray:
    """x̂ at the given coordinates: Σ_r λ_r ∏_m F_m[c_m, r]."""
    prod = jnp.asarray(lam)[None, :]
    for m, f in enumerate(factors):
        prod = prod * jnp.asarray(f)[coords[:, m]]
    return prod.sum(axis=1)


def avg_abs_diff(st: SparseTensor, factors, lam, *, dense_limit: int = 1 << 22) -> float:
    """Paper Fig. 6 metric: mean |X - X̂| over all elements when the tensor is
    small enough, else over the nonzeros only (as done for Delicious/Lbnl).

    The dense path builds einsum subscripts from "abcdefg", so it only
    serves tensors up to 7 modes; higher orders take the nonzero-only path
    regardless of size (a small 8-D tensor must not crash on a subscript
    overrun)."""
    if math.prod(st.shape) <= dense_limit and st.ndim <= 7:
        dense = jnp.asarray(st.to_dense())
        letters = "abcdefg"[: st.ndim]
        sub = ",".join(f"{c}r" for c in letters)
        approx = jnp.einsum(f"r,{sub}->{''.join(letters)}", jnp.asarray(lam),
                            *[jnp.asarray(f) for f in factors])
        # repro-lint: disable=host-sync -- diagnostic API returning a host scalar; called once per decomposition, not per iteration
        return float(jnp.mean(jnp.abs(dense - approx)))
    approx = reconstruct_nnz(factors, lam, jnp.asarray(st.coords))
    # repro-lint: disable=host-sync -- diagnostic API returning a host scalar; called once per decomposition, not per iteration
    return float(jnp.mean(jnp.abs(jnp.asarray(st.values) - approx)))


def fit_value(st: SparseTensor, factors, lam, mlast=None, last_mode=None) -> float:
    """fit = 1 - ||X - X̂||_F / ||X||_F, using the standard sparse identity
    ||X - X̂||² = ||X||² - 2<X, X̂> + ||X̂||²."""
    norm_x2 = st.norm() ** 2
    grams = [jnp.asarray(f).T @ jnp.asarray(f) for f in factors]
    had = jnp.asarray(lam)[:, None] * jnp.asarray(lam)[None, :]
    for g in grams:
        had = had * g
    norm_approx2 = jnp.sum(had)
    inner = (
        jnp.sum(mlast * (jnp.asarray(factors[last_mode])
                         * jnp.asarray(lam)[None, :]))
        if mlast is not None and last_mode is not None
        else jnp.dot(reconstruct_nnz(factors, lam, jnp.asarray(st.coords)),
                     jnp.asarray(st.values)))
    # Both reductions stay on device and fuse into ONE residual readout —
    # fit is a host scalar by contract, so exactly one sync is the floor
    # (this used to read norm_approx2 and inner back separately).
    resid = max(float(norm_x2 - 2.0 * inner + norm_approx2), 0.0)
    return 1.0 - math.sqrt(resid) / max(math.sqrt(norm_x2), 1e-30)


# ---------------------------------------------------------------------------
# Engines — the implementations live in repro.engine (backend registry);
# make_engine survives as a thin deprecated shim over build_engine.
# ---------------------------------------------------------------------------

def make_engine(
    st: SparseTensor,
    method: str,
    rank: int,
    **options,
) -> Callable:
    """DEPRECATED: use `repro.engine.build_engine` instead.

    Builds an MTTKRP engine closure `f(factors, mode) -> (I_mode, R) f32`
    through the backend registry (same semantics as the old if/elif ladder,
    plus `"auto"` and `"distributed"`)."""
    warnings.warn(
        "make_engine is deprecated; use repro.engine.build_engine",
        DeprecationWarning, stacklevel=2)
    from ..engine import build_engine
    return build_engine(st, method, rank, **options)


# ---------------------------------------------------------------------------
# CP-ALS driver (Algorithm 1)
# ---------------------------------------------------------------------------

def _exact_mttkrp(eng) -> bool:
    """True when the engine's MTTKRP output is the exact float operand, so
    the fit fast path (inner product from `mlast`) matches the slow path.
    Lossy backends (fixed point — whether named "fixed" or as a preset
    candidate id like "fixed:int7") and lock-free collision dropping produce
    approximate MTTKRPs — their noise must not bias the reported fit, so
    they keep the factors-only slow path."""
    ctx = getattr(eng, "context", None)
    if ctx is not None and ctx.lockfree_mode:
        return False
    spec = getattr(eng, "spec", None)
    if spec is not None:
        return spec.lossless
    report = getattr(eng, "report", None)
    if report is not None:  # autotuned: every dispatched winner must be exact
        from ..engine import candidate_lossless
        return all(candidate_lossless(n) for n in set(report.winners.values()))
    return False  # bare callable: nothing is known about its output


def _lossy_winners(eng) -> list[str]:
    """The quantized candidates an engine dispatches to: the spec itself for
    an explicit lossy engine, the lossy subset of the autotuned winners."""
    spec = getattr(eng, "spec", None)
    if spec is not None:
        return [] if spec.lossless else [eng.name]
    report = getattr(eng, "report", None)
    if report is not None:
        from ..engine import candidate_lossless
        return [n for n in sorted(set(report.winners.values()))
                if not candidate_lossless(n)]
    return []


def _measured_quant_error(eng, st: SparseTensor, factors) -> float | None:
    """Measured MTTKRP relative error of a lossy engine, for CPResult.

    Prefers the autotuner's per-mode error probes (measured against the
    float reference during tuning); without them — an explicit fixed-point
    engine, or a legacy lossy candidate admitted with no budget — compares
    the engine's last-mode output against the float COO reference on the
    final factors directly."""
    lossy = _lossy_winners(eng)
    if not lossy:
        return None
    report = getattr(eng, "report", None)
    mode = st.ndim - 1
    if report is not None:
        errs = [e for n in lossy
                for e in getattr(report, "errors", {}).get(n, {}).values()]
        if errs:
            return max(errs)
        # No recorded errors (legacy lossy candidate, no budget): measure a
        # mode the lossy winner actually serves — the dispatcher may route
        # other modes to a lossless backend, whose float noise would be
        # reported as "quantization error".
        mode = max(m for m, w in report.winners.items() if w in lossy)
    jfactors = [jnp.asarray(f) for f in factors]
    from .mttkrp import mttkrp_coo
    ref = mttkrp_coo(tuple(jfactors), jnp.asarray(st.coords),
                     jnp.asarray(st.values), mode=mode, out_dim=st.shape[mode])
    out = jnp.asarray(eng(jfactors, mode))
    # repro-lint: disable=host-sync -- one-shot quant-error readout after tuning, reported on CPResult; never in the iteration loop
    return float(jnp.linalg.norm(out - ref)
                 / (jnp.linalg.norm(ref) + 1e-30))


def cp_als(
    st: SparseTensor,
    rank: int,
    n_iters: int = 5,
    *,
    engine: str | Callable = "ref",
    norm: str = "linf",
    seed: int = 0,
    track_diff: bool = True,
    tol: float | None = None,
    tune=None,
    **engine_kwargs,
) -> CPResult:
    """`tune` is a `repro.engine.TunePolicy` bundling the autotuner's knobs
    (candidates, warmup, reps, store, prior, max_probes, elide,
    elide_margin, accuracy_budget); its `accuracy_budget` (with
    engine="auto") admits fixed-point preset candidates to the autotuner,
    each held to that max per-mode MTTKRP relative error — the paper's
    Fig. 6 format trade-off made empirically, per workload.  The result's
    `quant_error` reports the measured quantization error whenever a lossy
    engine produced the factors, and the fit fast path stays disabled for
    it (quantization noise must not bias the reported fit).

    The nine tuning keywords are still accepted inside `**engine_kwargs` as
    deprecated shims (one `DeprecationWarning` per call folds them into the
    policy); the rest of `engine_kwargs` must be `build_engine` options
    (mem_bytes, chunk_shape, capacity, fixed_preset, ... — unknown keywords
    raise a `TypeError` naming the nearest valid spelling)."""
    from ..engine import validate_engine_kwargs
    from ..engine.tunepolicy import TunePolicy, split_tune_kwargs

    legacy = split_tune_kwargs(engine_kwargs)
    validate_engine_kwargs("cp_als", engine_kwargs,
                           extra=("plans", "autotune_modes"))
    policy = TunePolicy.resolve(tune, caller="cp_als", **legacy)

    n = st.ndim
    factors = init_factors(st.shape, rank, seed)
    lam = jnp.ones((rank,), jnp.float32)
    if callable(engine):
        if policy.accuracy_budget is not None:
            raise ValueError(
                "accuracy_budget only applies to engine='auto'; a prebuilt "
                "engine has already made its format decision")
        eng = engine
        eng_name = getattr(engine, "name", None) or getattr(
            engine, "__name__", "custom")
    else:
        from ..engine import build_engine
        eng = build_engine(st, engine, rank, tune=policy, **engine_kwargs)
        eng_name = eng.name  # e.g. "chunked", "auto:hetero"

    fit_fast = _exact_mttkrp(eng)
    fit_history, diff_history, iter_times = [], [], []
    prev_fit = -np.inf
    decompose_sp = span("cp_als.decompose", engine=eng_name,
                        shape=list(st.shape), nnz=int(st.nnz), rank=rank,
                        n_iters=n_iters)
    with decompose_sp:
        for it in range(n_iters):
            iter_sp = span("cp_als.iter", iter=it)
            with iter_sp:
                t0 = time.perf_counter()
                mlast = None
                for mode in range(n):
                    # Mode spans bound host dispatch time only — the device
                    # barrier sits at iteration end, so a mode span closing
                    # does not mean the mode's kernels finished.
                    with span("cp_als.mode", mode=mode):
                        m = eng([jnp.asarray(f) for f in factors], mode)
                        # Pseudo-inverse step:
                        # A = M (∘_{k≠mode} F_kᵀF_k)†  (Alg. 1 l.5-7)
                        v = jnp.ones((rank, rank), jnp.float32)
                        for k in range(n):
                            if k == mode:
                                continue
                            fk = jnp.asarray(factors[k])
                            v = v * (fk.T @ fk)
                        a = m @ jnp.linalg.pinv(v)
                        a, lam = _normalize(a, norm)
                        factors[mode] = a
                        mlast = m
                # repro-lint: disable=host-sync -- timing barrier: iter_times must measure completed device work, not dispatch
                jax.block_until_ready(factors[-1])
                dt = time.perf_counter() - t0
                # One measurement, two views: `iter_times` on the CPResult
                # and the span's `seconds` attr carry the same number (the
                # span's own duration adds only its bookkeeping).
                iter_times.append(dt)
                iter_sp.set(seconds=dt)

            # Fast-path fit: <X, X̂> = Σ λ_r Σ_i M[i,r]·F_last[i,r] reuses
            # the last mode's MTTKRP output (M is independent of F_last,
            # which was updated after M was computed), skipping the
            # O(nnz·R) reconstruct_nnz pass that the slow path pays every
            # iteration.  Only exact engines qualify (see _exact_mttkrp).
            with span("cp_als.fit", iter=it, fast=fit_fast):
                f = fit_value(st, factors, lam,
                              mlast=mlast if fit_fast else None,
                              last_mode=n - 1 if fit_fast else None)
            fit_history.append(f)
            if track_diff:
                diff_history.append(avg_abs_diff(st, factors, lam))
            if tol is not None and abs(f - prev_fit) < tol:
                break
            prev_fit = f
        decompose_sp.set(fit=fit_history[-1] if fit_history else None)

    return CPResult(
        [np.asarray(f) for f in factors], np.asarray(lam),
        fit_history, diff_history, iter_times, eng_name,
        quant_error=_measured_quant_error(eng, st, factors),
        tune_report=getattr(eng, "report", None),
    )
