"""Production mesh builders.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(n_data: int | None = None, n_model: int = 1):
    """Whatever this host has (tests / examples / elastic resume)."""
    n = len(jax.devices())
    n_data = n_data or max(n // n_model, 1)
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_axes(mesh) -> dict:
    return dict(mesh.shape)


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-parallel axes: pod (if present) + data."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
