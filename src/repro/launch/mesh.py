"""Production mesh builders + JAX version-compat shims.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).

The compat layer papers over API drift between JAX releases:

  * ``jax.sharding.AxisType`` (and the ``axis_types=`` kwarg of
    ``jax.make_mesh``) only exist on newer JAX; older releases build the
    same Auto-typed mesh without the kwarg.
  * ``jax.shard_map`` (with ``check_vma=``) replaced
    ``jax.experimental.shard_map.shard_map`` (with ``check_rep=``).

Everything in this repo goes through ``make_mesh_compat`` / ``shard_map``
below instead of calling the raw jax APIs.
"""
from __future__ import annotations

import jax

__all__ = [
    "make_mesh_compat",
    "shard_map",
    "make_production_mesh",
    "make_local_mesh",
    "mesh_axes",
    "dp_axes",
]


def _axis_types_kwargs(n_axes: int) -> dict:
    """``axis_types=(Auto,)*n`` where supported, ``{}`` on older JAX."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh_compat(shape: tuple[int, ...], axes: tuple[str, ...]):
    """``jax.make_mesh`` with Auto axis types when the installed JAX has
    them, plain mesh otherwise (older JAX is Auto-by-default)."""
    try:
        return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))
    except TypeError:  # very old jax.make_mesh without axis_types kwarg
        return jax.make_mesh(shape, axes)


def shard_map(body, *, mesh, in_specs, out_specs):
    """Version-portable shard_map with replication checking disabled
    (all bodies in this repo do their own collectives)."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
        return fn(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    try:
        return fn(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        # mid-window releases expose jax.shard_map but still spell the
        # replication-check kwarg check_rep
        return fn(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(n_data: int | None = None, n_model: int = 1):
    """Whatever this host has (tests / examples / elastic resume)."""
    n = len(jax.devices())
    n_data = n_data or max(n // n_model, 1)
    return make_mesh_compat((n_data, n_model), ("data", "model"))


def mesh_axes(mesh) -> dict:
    return dict(mesh.shape)


def dp_axes(mesh) -> tuple[str, ...]:
    """Batch-parallel axes: pod (if present) + data."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
