"""End-to-end training driver with fault tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt [--simulate-failure-at 20]

Fault-tolerance behaviour exercised here (and in examples/train_lm.py):
  * checkpoint every N steps via the async double-buffered checkpointer;
  * on restart, resume from the latest COMMITTED checkpoint;
  * `--simulate-failure-at K` kills the process at step K mid-run — rerunning
    the same command resumes and finishes, proving checkpoint/restart;
  * elastic: restore works on a different device count (launch/elastic.py
    re-places arrays under the new mesh's shardings).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import (AsyncCheckpointer, latest_step, restore_checkpoint)
from ..configs import get_config, get_smoke_config
from ..data import SyntheticBatches
from ..models import LM
from ..optim import AdamWConfig, adamw_init
from .mesh import make_local_mesh
from .shardings import batch_shardings, init_shapes, opt_shardings, \
    param_shardings
from .steps import init_opt_shapes, make_ctx, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--simulate-failure-at", type=int, default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    lm = LM(cfg)
    mesh = make_local_mesh()
    ctx = make_ctx(mesh, seq_sharded=False)
    opt_cfg = AdamWConfig(lr=args.lr, use_8bit=cfg.opt_8bit)

    structs, specs = init_shapes(lm, jax.random.key(0))
    p_sh = param_shardings(mesh, structs, specs)
    o_sh = opt_shardings(mesh, init_opt_shapes(structs, opt_cfg), p_sh)

    start = latest_step(args.ckpt_dir)
    if start is not None:
        print(f"[train] resuming from checkpoint step {start}", flush=True)
        params, _ = lm.init(jax.random.key(0))
        opt_state = adamw_init(params, opt_cfg)
        state = restore_checkpoint(
            args.ckpt_dir, start, {"params": params, "opt": opt_state},
            shardings={"params": p_sh, "opt": o_sh})
        params, opt_state = state["params"], state["opt"]
    else:
        start = 0
        params, _ = lm.init(jax.random.key(0))
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = adamw_init(params, opt_cfg)

    data = SyntheticBatches(cfg, args.seq_len, args.global_batch)
    step_fn = jax.jit(make_train_step(lm, ctx, opt_cfg,
                                      grad_accum=args.grad_accum),
                      donate_argnums=(0, 1))
    ckpt = AsyncCheckpointer(args.ckpt_dir)

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if (step + 1) % args.log_every == 0:
            rate = (step + 1 - start) / (time.time() - t0)
            print(f"[train] step {step+1} loss={losses[-1]:.4f} "
                  f"({rate:.2f} steps/s)", flush=True)
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if args.simulate_failure_at is not None and \
                step + 1 == args.simulate_failure_at:
            ckpt.wait()
            print(f"[train] SIMULATED FAILURE at step {step+1}", flush=True)
            os._exit(42)
    ckpt.wait()
    if losses:
        print(f"[train] done: first loss {losses[0]:.4f} "
              f"→ last {losses[-1]:.4f}")
    else:
        print(f"[train] nothing to do: checkpoint step {start} ≥ "
              f"--steps {args.steps}")
    return losses


if __name__ == "__main__":
    main()
