"""Elastic scaling: resume a run on a different device count.

At 1000+ node scale, node loss is routine; waiting for a full-size
replacement wastes the cluster.  The recipe here: checkpoints are
host-layout (numpy) snapshots; on restart we rebuild the mesh from the
devices that are actually alive, recompute shardings against the new mesh,
and re-place every array (`restore_checkpoint(..., shardings=new)`).  The
deterministic data pipeline (seed, step, shard) makes batch boundaries
reproducible across the re-shard, so no data server or shard registry has to
survive the failure (straggler mitigation falls out of the same property:
any host can recompute any shard).
"""
from __future__ import annotations

import jax

from ..checkpoint import latest_step, restore_checkpoint
from ..optim import AdamWConfig, adamw_init
from .mesh import make_local_mesh
from .shardings import init_shapes, opt_shardings, param_shardings
from .steps import init_opt_shapes

__all__ = ["elastic_restore"]


def elastic_restore(lm, ckpt_dir: str, opt_cfg: AdamWConfig,
                    n_model: int = 1):
    """Rebuild mesh from live devices, restore latest ckpt re-sharded to it.
    Returns (mesh, params, opt_state, step) or None if no checkpoint."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    mesh = make_local_mesh(n_model=n_model)
    structs, specs = init_shapes(lm, jax.random.key(0))
    p_sh = param_shardings(mesh, structs, specs)
    o_sh = opt_shardings(mesh, init_opt_shapes(structs, opt_cfg), p_sh)
    params, _ = lm.init(jax.random.key(0))
    opt_state = adamw_init(params, opt_cfg)
    state = restore_checkpoint(ckpt_dir, step,
                               {"params": params, "opt": opt_state},
                               shardings={"params": p_sh, "opt": o_sh})
    return mesh, state["params"], state["opt"], step
