"""Launch layer: meshes, shardings, train/serve entrypoints.

Deliberately does NOT import the heavier submodules (steps, train, serve)
at package-import time — they pull in the model stack; import them directly.
"""
from .mesh import (
    dp_axes,
    make_local_mesh,
    make_mesh_compat,
    make_production_mesh,
    mesh_axes,
    shard_map,
)

__all__ = [
    "dp_axes",
    "make_local_mesh",
    "make_mesh_compat",
    "make_production_mesh",
    "mesh_axes",
    "shard_map",
]
