"""Logical-axis → mesh sharding resolution.

Params carry logical axis names from init ("embed", "heads", "ffn", "vocab",
"expert", "expert_ffn", "inner"); this module resolves them against the mesh
with divisibility checking (a non-divisible axis falls back to replication —
e.g. whisper's vocab 51865 is not 16-divisible, so its unembed replicates).

Cache shardings are path-based: KV caches shard batch over dp and sequence
over `model` (and over `data` too for the batch-1 long_500k shape); recurrent
states shard batch over dp and the inner dim over `model` when divisible.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import dp_axes

__all__ = ["LOGICAL_RULES", "param_shardings", "cache_shardings",
           "batch_shardings", "init_shapes"]

LOGICAL_RULES = {
    "embed": "data",        # FSDP
    "heads": "model",       # TP
    "ffn": "model",
    "vocab": "model",
    "expert": "model",      # EP (the paper's rank-axis analogue)
    "expert_ffn": "data",   # FSDP inside the MoE shard_map
    "inner": "model",       # mamba/xlstm inner dim
}


def _axis_size(mesh, axis) -> int:
    sizes = dict(mesh.shape)
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(sizes[a] for a in axis)
    return sizes[axis]


def _resolve(mesh, shape, logical_axes):
    spec, used = [], set()
    for dim, ax in zip(shape, logical_axes, strict=True):
        mesh_ax = LOGICAL_RULES.get(ax) if ax is not None else None
        if (mesh_ax is not None and mesh_ax not in used
                and dim % _axis_size(mesh, mesh_ax) == 0):
            spec.append(mesh_ax)
            used.add(mesh_ax)
        else:
            spec.append(None)
    return P(*spec)


def param_shardings(mesh, param_structs, spec_tree):
    """spec_tree leaves are tuples of logical axis names (len == ndim)."""
    def leaf(struct, axes):
        assert len(axes) == len(struct.shape), (struct.shape, axes)
        return NamedSharding(mesh, _resolve(mesh, struct.shape, axes))
    return jax.tree.map(
        leaf, param_structs, spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


def _path_keys(path) -> tuple:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(k.key)
        elif hasattr(k, "idx"):
            out.append(k.idx)
        else:
            out.append(str(k))
    return tuple(out)


def opt_shardings(mesh, opt_structs, param_sh):
    """Optimizer state mirrors param shardings; 8-bit q/s leaves and the step
    counter fall back to shape-matched or replicated."""
    flat_p = {_path_keys(path): s
              for path, s in jax.tree_util.tree_flatten_with_path(param_sh)[0]}

    def leaf(path, struct):
        keys = _path_keys(path)
        # state paths look like ("m", <param path...>) or ("m", ..., "q"/"s")
        inner = keys[1:]
        if inner and inner[-1] in ("q", "s"):
            # 8-bit moments: q keeps the parameter's shape (last dim padded),
            # so it inherits the parameter's sharding where divisibility
            # still holds; scales shard like the leading param axes.
            psh = flat_p.get(inner[:-1])
            spec = [None] * len(struct.shape)
            if psh is not None:
                base = list(psh.spec) + [None] * len(struct.shape)
                for i, dim in enumerate(struct.shape):
                    ax = base[i] if i < len(psh.spec) else None
                    if ax is not None and dim % _axis_size(mesh, ax) == 0:
                        spec[i] = ax
            return NamedSharding(mesh, P(*spec))
        sh = flat_p.get(inner)
        if sh is not None and len(sh.spec) == len(struct.shape):
            return sh
        return NamedSharding(mesh, P(*([None] * len(struct.shape))))
    return jax.tree_util.tree_map_with_path(leaf, opt_structs)


def batch_shardings(mesh, batch_structs):
    dp = dp_axes(mesh)
    def leaf(struct):
        spec = [None] * len(struct.shape)
        if struct.shape and struct.shape[0] % _axis_size(mesh, dp) == 0:
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(leaf, batch_structs)


def cache_shardings(mesh, cache_structs, *, long_context: bool = False):
    """Decode-cache placement. Leaves are stacked (reps, B, ...) arrays."""
    dp = dp_axes(mesh)
    axes = dict(mesh.shape)

    def leaf(path, struct):
        names = [getattr(k, "key", "") for k in path]
        shape = struct.shape
        spec = [None] * len(shape)
        batch_ok = len(shape) > 1 and shape[1] % _axis_size(mesh, dp) == 0
        if "kv" in names and names[-1] in ("k", "v", "pos"):
            # (reps, B, S, KH, hd) / pos (reps, B, S)
            if batch_ok and not long_context:
                spec[1] = dp
            seq_axes = ("data", "model") if long_context else ("model",)
            if shape[2] % _axis_size(mesh, seq_axes) == 0:
                spec[2] = seq_axes if long_context else "model"
        elif names[-1] in ("xk", "xv"):
            if batch_ok:
                spec[1] = dp
            if shape[2] % axes.get("model", 1) == 0:
                spec[2] = "model"
        else:
            # recurrent states: (reps, B, inner...) — inner over model
            if batch_ok:
                spec[1] = dp
            if len(shape) > 2 and shape[2] % axes.get("model", 1) == 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(leaf, cache_structs)


def init_shapes(lm, key):
    """(param ShapeDtypeStructs, logical spec tree) without allocating."""
    captured = {}

    def f(k):
        p, s = lm.init(k)
        captured["specs"] = s
        return p

    structs = jax.eval_shape(f, key)
    return structs, captured["specs"]
