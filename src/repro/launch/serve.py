"""Serving driver: batched prefill + decode loop with KV caches.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..data import SyntheticTokens
from ..models import LM
from .mesh import make_local_mesh
from .steps import make_ctx, make_decode_step


def generate(lm: LM, params, ctx, prompts: jnp.ndarray, gen: int,
             max_len: int | None = None, greedy: bool = True):
    """Prefill via teacher-forced decode of the prompt, then generate `gen`
    tokens greedily.  Returns (B, gen) int32."""
    b, s = prompts.shape
    max_len = max_len or (s + gen + 8)
    cache = lm.init_cache(b, max_len=max_len, dtype=jnp.float32)
    step = jax.jit(make_decode_step(lm, ctx))
    tok = prompts[:, :1]
    out = []
    for t in range(s + gen - 1):
        logits, cache = step(params, tok, cache, jnp.int32(t))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if t + 1 < s:
            tok = prompts[:, t + 1:t + 2]  # teacher forcing over the prompt
        else:
            tok = nxt
            out.append(nxt)
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not cfg.encoder_decoder, "use examples/ for enc-dec serving"
    lm = LM(cfg)
    mesh = make_local_mesh()
    ctx = make_ctx(mesh, seq_sharded=False)
    params, _ = lm.init(jax.random.key(0))
    prompts = jnp.asarray(SyntheticTokens(
        cfg.vocab, args.prompt_len, args.batch).batch(0))
    t0 = time.perf_counter()
    toks = generate(lm, params, ctx, prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks[:2]))
    return toks


if __name__ == "__main__":
    main()
