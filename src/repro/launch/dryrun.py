import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import — jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, record memory/cost/collective analysis for §Roofline.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import math
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config
from ..models import LM
from ..optim import AdamWConfig
from ..roofline import collective_bytes, roofline_terms
from ..roofline.model import model_flops
from .mesh import dp_axes, make_production_mesh
from .shardings import (batch_shardings, cache_shardings, init_shapes,
                        opt_shardings, param_shardings)
from .steps import (init_opt_shapes, make_ctx, make_decode_step,
                    make_prefill_step, make_train_step)

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def input_structs(cfg, kind: str, seq: int, batch: int):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    if kind in ("train", "prefill"):
        if cfg.encoder_decoder:
            return {
                "frames": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                               jnp.float32),
                "tokens": tok(batch, max(seq // cfg.dec_ratio, 16)),
            }
        if cfg.n_image_tokens:
            return {
                "tokens": tok(batch, seq - cfg.n_image_tokens),
                "image_embeds": jax.ShapeDtypeStruct(
                    (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32),
            }
        return {"tokens": tok(batch, seq)}
    raise ValueError(kind)


def count_active_params(cfg, structs) -> float:
    """Non-embedding params, MoE experts scaled by activated fraction."""
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(structs)[0]
    for path, leaf in flat:
        keys = [getattr(k, "key", "") for k in path]
        if "embed" in keys or "unembed" in keys:
            continue
        n = math.prod(leaf.shape)
        if any(k in ("wg", "wu", "wd") for k in keys) and "moe" in keys:
            frac = (cfg.top_k) / max(cfg.n_experts, 1)
            n *= frac
        total += n
    return total


def should_skip(cfg, shape_name: str) -> str | None:
    if shape_name == "long_500k" and not cfg.supports_long_context:
        return ("pure full attention — long_500k needs a sub-quadratic path "
                "(DESIGN.md §5)")
    return None


def _lower_cell(cfg, kind, seq, batch, mesh, grad_accum):
    """Build + lower one cell. Returns (lowered, extras dict)."""
    lm = LM(cfg)
    key = jax.random.key(0)
    p_structs, p_specs = init_shapes(lm, key)
    p_sh = param_shardings(mesh, p_structs, p_specs)
    extras = dict(lm=lm, p_structs=p_structs)
    if kind == "train":
        ctx = make_ctx(mesh, seq_sharded=True)
        opt_cfg = AdamWConfig(use_8bit=cfg.opt_8bit)
        o_structs = init_opt_shapes(p_structs, opt_cfg)
        o_sh = opt_shardings(mesh, o_structs, p_sh)
        batch_structs = input_structs(cfg, "train", seq, batch)
        b_sh = batch_shardings(mesh, batch_structs)
        step = make_train_step(lm, ctx, opt_cfg, grad_accum=grad_accum)
        lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                          donate_argnums=(0, 1)).lower(
            p_structs, o_structs, batch_structs)
    elif kind == "prefill":
        ctx = make_ctx(mesh, seq_sharded=True)
        batch_structs = input_structs(cfg, "prefill", seq, batch)
        b_sh = batch_shardings(mesh, batch_structs)
        step = make_prefill_step(lm, ctx)
        lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
            p_structs, batch_structs)
    else:
        ctx = make_ctx(mesh, seq_sharded=False)
        enc_len = seq if cfg.encoder_decoder else 0
        max_len = max(seq // cfg.dec_ratio, 448) if cfg.encoder_decoder else seq
        c_structs = jax.eval_shape(
            partial(lm.init_cache, batch, max_len, enc_len))
        c_sh = cache_shardings(mesh, c_structs,
                               long_context=seq >= 500_000)
        tok_struct = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        tok_sh = batch_shardings(mesh, tok_struct)
        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)
        step = make_decode_step(lm, ctx)
        lowered = jax.jit(
            step, in_shardings=(p_sh, tok_sh, c_sh, NamedSharding(mesh, P())),
            donate_argnums=(2,),
        ).lower(p_structs, tok_struct, c_structs, pos_struct)
    return lowered, extras


def _cell_costs(lowered):
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    text = compiled.as_text()
    coll = collective_bytes(text)
    return compiled, dict(flops=float(ca.get("flops", 0.0)),
                          bytes=float(ca.get("bytes accessed", 0.0)),
                          wire=coll["total_wire_bytes"], coll=coll, text=text)


def scan_correction(cfg, kind, seq, batch, mesh, grad_accum):
    """XLA cost_analysis counts a while/scan body ONCE, not × trip count —
    verified: scan(1) and scan(16) of the same body report identical flops.

    Calibration: lower an EXACT-COST variant of the model — layer loop
    unrolled, attention single-block (q_block=kv_block=seq), mamba/mlstm
    single-chunk — at 1× and 2× the layer pattern.  The (flops, bytes, wire)
    delta is the exact per-pattern-repeat cost; total = base₁ + delta ×
    (n_layers - plen)/plen.  Remaining undercount: the sLSTM per-token scan
    (xlstm only; documented in EXPERIMENTS.md)."""
    import dataclasses as dc
    plen = len(cfg.pattern)
    mk = lambda n: dc.replace(
        cfg, n_layers=n, unroll_stack=True,
        n_enc_layers=(min(n, cfg.n_enc_layers)
                      if cfg.encoder_decoder else 0))
    _, c1 = _cell_costs(_lower_cell(mk(plen), kind, seq, batch, mesh,
                                    grad_accum)[0])
    _, c2 = _cell_costs(_lower_cell(mk(2 * plen), kind, seq, batch, mesh,
                                    grad_accum)[0])
    scale = (cfg.n_layers - plen) / plen

    def correct(v_full: dict) -> dict:
        out = {}
        for key in ("flops", "bytes", "wire"):
            delta = max(c2[key] - c1[key], 0.0)
            out[key] = c1[key] + delta * scale
        return out
    return correct


def inner_loop_correction(cfg, kind: str, seq: int, batch: int):
    """Analytic add-back for costs hidden inside mixer-internal loops (the
    flash q/kv block loops, mamba/mlstm chunk scans, sLSTM step scan), whose
    bodies XLA counts once.  Collectives need no add-back (the mixers'
    inner loops are collective-free by construction).  Returns GLOBAL
    (flops, bytes); the caller divides by chip count.

    mult: train = fwd(1) + remat recompute(1) + bwd(2); prefill = fwd only.
    Decode paths have no inner loops — exact, no correction."""
    if kind == "decode":
        return 0.0, 0.0
    mult = 4.0 if kind == "train" else 1.0
    from ..models.transformer import segment_layout
    b = batch
    add_f, add_by = 0.0, 0.0

    def attn_cost(s_q, s_kv, eff, n_heads, n_kv, dh, q_block):
        f = 4.0 * b * s_q * eff * n_heads * dh            # QKᵀ + PV
        nq = -(-s_q // min(q_block, s_q))
        by = nq * s_kv * b * n_kv * dh * 2 * 2            # K,V re-read / block
        return f, by

    pattern_layers = []
    for pat, reps in segment_layout(cfg.n_layers, cfg.pattern):
        pattern_layers += list(pat) * reps
    d = cfg.d_model
    for spec in pattern_layers:
        if spec.mixer == "attn":
            s_q = max(seq // cfg.dec_ratio, 16) if cfg.encoder_decoder else seq
            if spec.attn_kind == "local" and cfg.window:
                eff = min(cfg.window, s_q)
            elif spec.attn_kind == "chunked" and cfg.chunk_attn:
                eff = min(cfg.chunk_attn, s_q) / 2
            else:
                eff = s_q / 2 if spec.causal else s_q
            f, by = attn_cost(s_q, s_q, eff, cfg.n_heads, cfg.n_kv_heads,
                              cfg.head_dim, cfg.q_block)
            add_f += mult * f
            add_by += mult * by
            if spec.cross_attn:
                f, by = attn_cost(s_q, seq, seq, cfg.n_heads, cfg.n_kv_heads,
                                  cfg.head_dim, cfg.q_block)
                add_f += mult * f
                add_by += mult * by
        elif spec.mixer == "mamba":
            di, n = 2 * d, cfg.d_state
            add_f += mult * 10.0 * b * seq * di * n       # discretize+scan+C
            add_by += mult * 3.0 * b * seq * di * n * 4   # chunk state IO
        elif spec.mixer == "mlstm":
            di = 2 * d
            l = min(cfg.mlstm_chunk, seq)
            add_f += mult * 4.0 * b * seq * l * di        # intra-chunk scores
            add_by += mult * 2.0 * b * seq * l * cfg.n_heads * 4
        elif spec.mixer == "slstm":
            dh = d // cfg.n_heads
            add_f += mult * b * seq * (8.0 * d * dh + 30.0 * d)
            add_by += mult * b * seq * d * 4 * 4
    if cfg.encoder_decoder:  # encoder stack (bidirectional full attention)
        f, by = attn_cost(seq, seq, seq, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, cfg.q_block)
        add_f += cfg.n_enc_layers * mult * f
        add_by += cfg.n_enc_layers * mult * by
    return add_f, add_by


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "results/dryrun", grad_accum: int = 1,
             reduced: int | None = None, extra_tag: str = "",
             calibrate: bool = True, optimized: bool = False) -> dict:
    cfg = get_config(arch)
    if optimized:  # beyond-paper §Perf variant (manual-SP MLP collectives)
        import dataclasses as _dc
        cfg = _dc.replace(cfg, manual_sp=True)
        extra_tag = extra_tag or "opt"
    sh = SHAPES[shape_name]
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec = dict(arch=arch, shape=shape_name, mesh=mesh_tag)
    skip = should_skip(cfg, shape_name)
    if skip:
        rec["skipped"] = skip
        _write(out_dir, rec, extra_tag)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = math.prod(mesh.shape.values())
    seq, batch = sh["seq"], sh["batch"]
    if reduced:  # fast-iteration mode for perf experiments
        seq, batch = max(seq // reduced, 128), max(batch // reduced, 1)
    kind = sh["kind"]

    t0 = time.perf_counter()
    lowered, extras = _lower_cell(cfg, kind, seq, batch, mesh, grad_accum)
    rec["lower_s"] = time.perf_counter() - t0
    p_structs = extras["p_structs"]
    n_active = count_active_params(cfg, p_structs)
    rec["n_params"] = float(sum(math.prod(l.shape)
                                for l in jax.tree.leaves(p_structs)))
    rec["n_params_active_nonembed"] = float(n_active)
    tokens = batch * seq if kind in ("train", "prefill") else batch
    rec["model_flops"] = model_flops(
        n_active, tokens, "train" if kind == "train" else "serve")

    t0 = time.perf_counter()
    compiled, costs = _cell_costs(lowered)
    rec["compile_s"] = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    rec["memory"] = dict(
        argument_bytes=mem.argument_size_in_bytes,
        output_bytes=mem.output_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        alias_bytes=mem.alias_size_in_bytes,
        peak_estimate_bytes=(mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes),
    )
    rec["cost"] = dict(per_device_flops=costs["flops"],
                       per_device_bytes=costs["bytes"])
    rec["collectives"] = costs["coll"]
    from ..roofline import parse_collectives
    top = sorted(parse_collectives(costs["text"]),
                 key=lambda r: -r["wire_bytes"])
    rec["top_collectives"] = top[:10]

    # Scan-trip-count correction (XLA counts a while body once — calibrate
    # with 1× and 2× pattern-length unrolled models, extrapolate linearly),
    # plus analytic add-back of mixer-internal loop bodies.
    flops, bytes_acc, wire = costs["flops"], costs["bytes"], costs["wire"]
    if calibrate:
        if cfg.n_layers > len(cfg.pattern):
            correct = scan_correction(cfg, kind, seq, batch, mesh, grad_accum)
            fixed = correct(costs)
            flops, bytes_acc, wire = (fixed["flops"], fixed["bytes"],
                                      fixed["wire"])
        add_f, add_by = inner_loop_correction(cfg, kind, seq, batch)
        flops += add_f / n_chips
        bytes_acc += add_by / n_chips
        rec["cost_scan_corrected"] = dict(
            flops=flops, bytes=bytes_acc, wire=wire,
            inner_loop_flops_global=add_f, inner_loop_bytes_global=add_by)
    rec["roofline_uncorrected"] = roofline_terms(
        costs["flops"], costs["bytes"], costs["wire"])
    rec["roofline"] = roofline_terms(flops, bytes_acc, wire)
    rec["useful_flops_ratio"] = (
        rec["model_flops"] / (flops * n_chips) if flops else 0.0)
    rec["n_chips"] = n_chips
    _write(out_dir, rec, extra_tag)
    return rec


def _write(out_dir, rec, extra_tag=""):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{extra_tag}" if extra_tag else ""
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1, default=float)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--opt", action="store_true",
                    help="lower the beyond-paper optimized variant "
                         "(manual-SP MLP); records tagged __opt")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}×{shape}×{'2x16x16' if mp else '16x16'}"
                try:
                    t0 = time.perf_counter()
                    rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                                   grad_accum=args.grad_accum,
                                   optimized=args.opt)
                    status = ("SKIP: " + rec["skipped"]) if "skipped" in rec \
                        else (f"ok lower={rec['lower_s']:.0f}s "
                              f"compile={rec['compile_s']:.0f}s "
                              f"dominant={rec['roofline']['dominant']}")
                    print(f"[dryrun] {tag}: {status}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[dryrun] {tag}: FAIL {e}", flush=True)
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
