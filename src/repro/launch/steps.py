"""Train / serve step builders (the functions the dry-run lowers)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..models import LM, MeshCtx
from ..optim import AdamWConfig, adamw_init, adamw_update
from .mesh import dp_axes

__all__ = ["make_ctx", "make_train_step", "make_prefill_step",
           "make_decode_step", "generate"]


def make_ctx(mesh, *, seq_sharded: bool = True) -> MeshCtx:
    return MeshCtx(mesh=mesh, dp=dp_axes(mesh), tp="model",
                   seq_sharded=seq_sharded)


def make_train_step(lm: LM, ctx: MeshCtx, opt_cfg: AdamWConfig,
                    *, grad_accum: int = 1):
    """Full step: fwd + bwd + AdamW update (+ microbatch accumulation, which
    doubles as the compute/comm-overlap lever: per-microbatch grads start
    reducing while the next microbatch computes)."""

    def loss_fn(params, batch):
        return lm.loss(params, ctx, batch)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (jax.tree.map(jnp.add, acc, g),), l
            micro_batches = jax.tree.map(
                lambda a: a.reshape((grad_accum, a.shape[0] // grad_accum)
                                    + a.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum,), losses = jax.lax.scan(micro, (zeros,), micro_batches)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = losses.mean()
        params, opt_state = adamw_update(grads, opt_state, params, opt_cfg)
        return params, opt_state, loss

    return train_step


def make_prefill_step(lm: LM, ctx: MeshCtx):
    def prefill_step(params, batch):
        return lm.prefill(params, ctx, batch)
    return prefill_step


def make_decode_step(lm: LM, ctx: MeshCtx):
    def decode_step(params, token, cache, pos):
        return lm.decode_step(params, ctx, token, cache, pos)
    return decode_step


def init_opt_shapes(param_structs, opt_cfg: AdamWConfig):
    return jax.eval_shape(partial(adamw_init, cfg=opt_cfg), param_structs)


def generate(lm: LM, params, ctx, prompts: jnp.ndarray, gen: int,
             max_len: int | None = None, greedy: bool = True):
    """Prefill via teacher-forced decode of the prompt, then generate `gen`
    tokens greedily.  Returns (B, gen) int32.

    (Moved here from the retired `launch/serve.py` driver: the repo's
    serving surface is `repro.serve.DecomposeService` now; this LM loop is
    only kept for the seed tests and `examples/serve_lm.py`.)"""
    b, s = prompts.shape
    max_len = max_len or (s + gen + 8)
    cache = lm.init_cache(b, max_len=max_len, dtype=jnp.float32)
    step = jax.jit(make_decode_step(lm, ctx))
    tok = prompts[:, :1]
    out = []
    for t in range(s + gen - 1):
        logits, cache = step(params, tok, cache, jnp.int32(t))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        if t + 1 < s:
            tok = prompts[:, t + 1:t + 2]  # teacher forcing over the prompt
        else:
            tok = nxt
            out.append(nxt)
    return jnp.concatenate(out, axis=1)
