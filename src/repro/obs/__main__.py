"""CLI: `python -m repro.obs summarize <trace.jsonl>` and
`python -m repro.obs export <trace.jsonl> -o trace.json` (Chrome/Perfetto).

Exit codes: 0 OK, 1 invalid trace, 2 usage error (argparse).
"""
from __future__ import annotations

import argparse
import sys

from .export import (
    read_jsonl,
    summarize_text,
    validate_spans,
    write_chrome_trace,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Inspect and convert repro trace JSONL files "
                    "(docs/observability.md)")
    sub = ap.add_subparsers(dest="command", required=True)

    p_sum = sub.add_parser(
        "summarize",
        help="per-span-kind latency table + tune-decision breakdown")
    p_sum.add_argument("trace", help="trace JSONL path (REPRO_TRACE_PATH "
                                     "output or export.write_jsonl)")

    p_exp = sub.add_parser(
        "export", help="convert to Chrome trace-event JSON for Perfetto")
    p_exp.add_argument("trace")
    p_exp.add_argument("-o", "--out", required=True,
                       help="output .json path (load at ui.perfetto.dev)")

    args = ap.parse_args(argv)
    try:
        meta, spans = read_jsonl(args.trace)
        validate_spans(spans)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 1

    if args.command == "summarize":
        print(summarize_text(meta, spans))
    else:
        out = write_chrome_trace(spans, args.out, meta)
        print(f"wrote {out} ({len(spans)} spans) — open in ui.perfetto.dev "
              "or chrome://tracing")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
