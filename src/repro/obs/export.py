"""Trace export: JSONL on disk, Chrome trace-event JSON for Perfetto, and
the `python -m repro.obs summarize` latency tables.

JSONL schema (version 1) — line-delimited JSON, one meta line first::

    {"type": "meta", "version": 1, "epoch_wall": 1754..., "pid": 1234}
    {"type": "span", "name": "cp_als.iter", "t_start": 0.0123,
     "duration": 0.0045, "span_id": 7, "parent_id": 3,
     "thread_id": 140.., "thread_name": "MainThread", "attrs": {...}}

`t_start`/`duration` are seconds; `t_start` is an offset from the tracer's
monotonic epoch, and `epoch_wall` anchors it in absolute time.  The Chrome
trace-event export emits complete ("ph": "X") events in microseconds plus
thread-name metadata, loadable directly in Perfetto (ui.perfetto.dev) or
`chrome://tracing` — see docs/observability.md for the how-to.
"""
from __future__ import annotations

import json
import os
from collections.abc import Iterable, Sequence
from pathlib import Path

from .tracing import SCHEMA_VERSION, SpanRecord, Tracer, get_tracer

__all__ = [
    "read_jsonl",
    "span_kind_summary",
    "summarize_text",
    "to_chrome_trace",
    "tune_decision_summary",
    "validate_spans",
    "write_chrome_trace",
    "write_jsonl",
]

#: Required keys of a "span" JSONL line (the CI obs-smoke job validates
#: emitted traces against this).
SPAN_FIELDS = ("name", "t_start", "duration", "span_id", "parent_id",
               "thread_id", "thread_name", "attrs")


def write_jsonl(spans: Iterable[SpanRecord], path: str | os.PathLike, *,
                tracer: Tracer | None = None) -> str:
    """Write `spans` (+ one meta header line) as JSONL; returns the path."""
    tracer = tracer if tracer is not None else get_tracer()
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with p.open("w", encoding="utf-8") as fh:
        fh.write(json.dumps({"type": "meta", "version": SCHEMA_VERSION,
                             "epoch_wall": tracer.epoch_wall,
                             "pid": os.getpid()}) + "\n")
        for rec in spans:
            fh.write(json.dumps({"type": "span", **rec.to_json()}) + "\n")
    return str(p)


def read_jsonl(path: str | os.PathLike) -> tuple[dict, list[SpanRecord]]:
    """Parse a trace JSONL file back into `(meta, spans)`.  Raises
    ValueError on a malformed line or a missing/incompatible meta header."""
    meta: dict | None = None
    spans: list[SpanRecord] = []
    with Path(path).open(encoding="utf-8") as fh:
        for lineno, line in enumerate(fh, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not JSON: {e}") from None
            kind = d.get("type")
            if kind == "meta":
                if d.get("version") != SCHEMA_VERSION:
                    raise ValueError(
                        f"{path}:{lineno}: trace schema version "
                        f"{d.get('version')!r} != {SCHEMA_VERSION}")
                meta = d
            elif kind == "span":
                missing = [k for k in SPAN_FIELDS if k not in d]
                if missing:
                    raise ValueError(
                        f"{path}:{lineno}: span line missing {missing}")
                spans.append(SpanRecord.from_json(d))
            else:
                raise ValueError(
                    f"{path}:{lineno}: unknown line type {kind!r}")
    if meta is None:
        raise ValueError(f"{path}: no meta header line")
    return meta, spans


def validate_spans(spans: Sequence[SpanRecord]) -> None:
    """Structural checks over parsed spans: unique ids, resolvable parents,
    non-negative times.  Raises ValueError on the first violation."""
    ids = [s.span_id for s in spans]
    if len(set(ids)) != len(ids):
        raise ValueError("duplicate span ids in trace")
    known = set(ids)
    for s in spans:
        if s.duration < 0:
            raise ValueError(f"span {s.span_id} ({s.name}) has negative "
                             f"duration {s.duration}")
        if s.parent_id and s.parent_id not in known:
            raise ValueError(f"span {s.span_id} ({s.name}) references "
                             f"unknown parent {s.parent_id}")
        if not s.name:
            raise ValueError(f"span {s.span_id} has an empty name")


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------

def to_chrome_trace(spans: Sequence[SpanRecord],
                    meta: dict | None = None) -> dict:
    """Chrome trace-event JSON: complete events in µs, with thread-name
    metadata so Perfetto labels the serve worker vs client threads."""
    pid = (meta or {}).get("pid", os.getpid())
    events: list[dict] = []
    for tid, tname in sorted({(s.thread_id, s.thread_name) for s in spans}):
        events.append({"ph": "M", "name": "thread_name", "pid": pid,
                       "tid": tid, "args": {"name": tname}})
    for s in spans:
        args = {k: v for k, v in sorted(s.attrs.items())}
        args["span_id"] = s.span_id
        if s.parent_id:
            args["parent_id"] = s.parent_id
        events.append({
            "ph": "X", "name": s.name, "cat": s.name.split(".")[0],
            "pid": pid, "tid": s.thread_id,
            "ts": s.t_start * 1e6, "dur": s.duration * 1e6,
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(spans: Sequence[SpanRecord],
                       path: str | os.PathLike,
                       meta: dict | None = None) -> str:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(to_chrome_trace(spans, meta)), encoding="utf-8")
    return str(p)


# ---------------------------------------------------------------------------
# summarize: per-span-kind latency table + tune-decision breakdown
# ---------------------------------------------------------------------------

def _pct(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over already-sorted values (the summarizer
    holds the samples, so no bucketing is needed here)."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1,
              max(0, round(q / 100.0 * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def span_kind_summary(spans: Sequence[SpanRecord]) -> list[dict]:
    """One row per span name: count, total seconds, p50/p95/p99 ms."""
    by_name: dict[str, list[float]] = {}
    for s in spans:
        by_name.setdefault(s.name, []).append(s.duration)
    rows = []
    for name in sorted(by_name):
        vals = sorted(by_name[name])
        rows.append({
            "span": name,
            "count": len(vals),
            "total_s": sum(vals),
            "p50_ms": _pct(vals, 50) * 1e3,
            "p95_ms": _pct(vals, 95) * 1e3,
            "p99_ms": _pct(vals, 99) * 1e3,
            "max_ms": vals[-1] * 1e3,
        })
    return rows


def tune_decision_summary(spans: Sequence[SpanRecord]) -> dict:
    """The tuning story a trace tells: decisions by source
    (measured/persisted/cached), probes by provenance (measured/elided),
    and total probe seconds."""
    decisions: dict[str, int] = {}
    probes: dict[str, int] = {}
    probe_seconds = 0.0
    for s in spans:
        if s.name in ("autotune.decision", "autotune.bucket"):
            src = str(s.attrs.get("source", "measured"))
            decisions[src] = decisions.get(src, 0) + 1
        elif s.name == "autotune.probe":
            prov = str(s.attrs.get("provenance", "measured"))
            probes[prov] = probes.get(prov, 0) + 1
            if prov == "measured":
                probe_seconds += s.duration
    return {"decisions": decisions, "probes": probes,
            "probe_seconds": probe_seconds}


def _render_table(rows: list[dict], columns: list[str]) -> str:
    cells = [[str(c) for c in columns]]
    for r in rows:
        cells.append([
            f"{r.get(c):.3f}" if isinstance(r.get(c), float) else str(r.get(c, ""))
            for c in columns])
    widths = [max(len(row[i]) for row in cells) for i in range(len(columns))]
    lines = []
    for j, row in enumerate(cells):
        lines.append("  ".join(c.ljust(w) for c, w in zip(row, widths,
                                                          strict=True)))
        if j == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def summarize_text(meta: dict, spans: Sequence[SpanRecord]) -> str:
    """The `python -m repro.obs summarize` report body."""
    lines = [f"trace: {len(spans)} span(s), schema v{meta.get('version')}, "
             f"pid {meta.get('pid')}"]
    rows = span_kind_summary(spans)
    if rows:
        lines.append("")
        lines.append(_render_table(
            rows, ["span", "count", "total_s", "p50_ms", "p95_ms",
                   "p99_ms", "max_ms"]))
    tune = tune_decision_summary(spans)
    if tune["decisions"] or tune["probes"]:
        lines.append("")
        lines.append("tune decisions: " + (" ".join(
            f"{k}={v}" for k, v in sorted(tune["decisions"].items()))
            or "none"))
        lines.append(
            "probes: " + (" ".join(f"{k}={v}"
                                   for k, v in sorted(tune["probes"].items()))
                          or "none")
            + f"  ({tune['probe_seconds'] * 1e3:.2f}ms measuring)")
    return "\n".join(lines)
