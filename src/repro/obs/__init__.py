"""repro.obs — tracing + metrics for the tune/decompose/serve stack.

Three pieces (see docs/observability.md for the span catalog, metric
inventory, and the Perfetto how-to):

- `tracing` — a process-global, thread-aware span tracer that is a true
  no-op when disabled (one attribute check on the hot path).  Enable with
  `enable_tracing()`, the `capture()` scope, or ``REPRO_TRACE=1`` /
  ``REPRO_TRACE_PATH=trace.jsonl`` in the environment.
- `metrics` — counters/gauges/histograms; histograms use fixed log-spaced
  buckets so p50/p95/p99 come without storing samples, and registry
  snapshots are consistent cuts.
- `export` — trace JSONL read/write, Chrome trace-event JSON for Perfetto,
  and the tables behind ``python -m repro.obs summarize``.

The instrumented surface: `autotune_engine` emits per-candidate probe
spans and a decision span, `cp_als`/`cp_als_batched` emit per-iteration
and per-mode spans (the same measurement `CPResult.iter_times` reports),
`DecomposeService` records queue-wait/dispatch/request-latency histograms
(p50/p99 surfaced in `ServeStats`), and `sweep.runner` wraps each cell in
a fingerprint-tagged span.

Never emit spans or metrics inside jitted code — the `trace-in-jit`
analysis rule (docs/static-analysis.md#trace-in-jit) enforces it.
"""
from __future__ import annotations

from .export import (
    read_jsonl,
    span_kind_summary,
    summarize_text,
    to_chrome_trace,
    tune_decision_summary,
    validate_spans,
    write_chrome_trace,
    write_jsonl,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_histogram_bounds,
    default_registry,
)
from .tracing import (
    TRACE_ENV,
    TRACE_PATH_ENV,
    SpanRecord,
    Tracer,
    capture,
    disable_tracing,
    enable_tracing,
    get_tracer,
    record_span,
    span,
    traced,
    tracing_enabled,
)

__all__ = [
    "TRACE_ENV",
    "TRACE_PATH_ENV",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SpanRecord",
    "Tracer",
    "capture",
    "default_histogram_bounds",
    "default_registry",
    "disable_tracing",
    "enable_tracing",
    "get_tracer",
    "read_jsonl",
    "record_span",
    "span",
    "span_kind_summary",
    "summarize_text",
    "to_chrome_trace",
    "traced",
    "tracing_enabled",
    "tune_decision_summary",
    "validate_spans",
    "write_chrome_trace",
    "write_jsonl",
]
