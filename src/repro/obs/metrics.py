"""Counters, gauges, and log-bucketed histograms with consistent snapshots.

The paper reports efficiency as a fraction of peak measured over whole
workloads; the serving path needs the same kind of aggregate — request
latency p50/p99, queue depth, probe counts — without keeping every sample.
`Histogram` therefore bins observations into **fixed log-spaced buckets**
(8 per decade from 1µs to 1000s by default): percentiles come from the
cumulative bucket counts with log-linear interpolation inside the landing
bucket, so memory is O(buckets) forever and the worst-case percentile
error is one bucket width (a factor of `10^(1/8) ≈ 1.33`; the accuracy
test in `tests/test_obs.py` gates it).

Thread-safety: every mutation takes the owning registry's lock, and
`MetricsRegistry.snapshot()` takes the same lock — a snapshot is a
consistent cut across all metrics, never a torn read of a histogram whose
counts moved under it.  Metric mutation is host-side Python: never call
`.inc`/`.observe` inside jitted code (the `trace-in-jit` analysis rule
fires on it).
"""
from __future__ import annotations

import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_histogram_bounds",
]


def default_histogram_bounds(lo: float = 1e-6, hi: float = 1e3,
                             per_decade: int = 8) -> tuple[float, ...]:
    """Log-spaced bucket upper bounds covering [lo, hi] at `per_decade`
    buckets per decade — the fixed geometry every latency histogram shares
    so snapshots from different services aggregate bucket-for-bucket."""
    if not (lo > 0 and hi > lo and per_decade >= 1):
        raise ValueError(f"bad bounds spec: lo={lo} hi={hi} "
                         f"per_decade={per_decade}")
    n = int(round((math.log10(hi) - math.log10(lo)) * per_decade))
    return tuple(10 ** (math.log10(lo) + i / per_decade)
                 for i in range(n + 1))


class Counter:
    """Monotonic counter.  Mutate via `.inc(n)`; read `.value`."""

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def _snapshot(self) -> dict:
        return {"type": "counter", "value": self._value}


class Gauge:
    """Last-write-wins instantaneous value (queue depth, live buckets)."""

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self._lock = lock
        self._value = 0.0

    def set_value(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, delta: float) -> None:
        with self._lock:
            self._value += delta

    @property
    def value(self) -> float:
        with self._lock:
            return self._value

    def _snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Fixed log-spaced-bucket histogram: p50/p95/p99 without samples.

    `bounds[i]` is bucket i's inclusive upper edge; a final overflow bucket
    catches anything past `bounds[-1]`, and observations at or below
    `bounds[0]` land in bucket 0 (sub-resolution values cannot be told
    apart anyway).
    """

    def __init__(self, name: str, lock: threading.Lock,
                 bounds: tuple[float, ...] | None = None):
        self.name = name
        self._lock = lock
        self.bounds = tuple(bounds) if bounds is not None \
            else default_histogram_bounds()
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be strictly increasing")
        self._counts = [0] * (len(self.bounds) + 1)
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def _bucket(self, v: float) -> int:
        # Binary search over the fixed edges; the common latency range is
        # small enough that this stays cheap on the dispatch path.
        lo, hi = 0, len(self.bounds)
        while lo < hi:
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, v: float) -> None:
        v = float(v)
        idx = self._bucket(v)
        with self._lock:
            self._counts[idx] += 1
            self._n += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)

    @property
    def count(self) -> int:
        with self._lock:
            return self._n

    @property
    def total(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Estimate the q-th percentile (q in [0, 100]) from the bucket
        cumulative counts, log-interpolating inside the landing bucket.
        Returns 0.0 on an empty histogram."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        with self._lock:
            return self._percentile_locked(q)

    def _percentile_locked(self, q: float) -> float:
        if self._n == 0:
            return 0.0
        target = q / 100.0 * self._n
        cum = 0
        for i, c in enumerate(self._counts):
            cum += c
            if cum >= target and c > 0:
                frac = 1.0 - (cum - target) / c
                lo = self.bounds[i - 1] if i >= 1 else None
                hi = self.bounds[i] if i < len(self.bounds) else None
                if hi is None:           # overflow bucket: no upper edge
                    return self._max
                if lo is None or lo <= 0:  # first bucket
                    lo = min(self._min, hi) if self._min < math.inf else hi
                    lo = max(lo, hi * 1e-9)
                est = 10 ** (math.log10(lo)
                             + frac * (math.log10(hi) - math.log10(lo)))
                # Clamp to the observed range: interpolation must never
                # invent a value outside what was actually seen.
                return min(max(est, self._min), self._max)
        return self._max

    def _snapshot(self) -> dict:
        quantiles = {f"p{q:g}": self._percentile_locked(q)
                     for q in (50, 95, 99)}
        return {
            "type": "histogram",
            "count": self._n,
            "sum": self._sum,
            "min": self._min if self._n else 0.0,
            "max": self._max if self._n else 0.0,
            "mean": self._sum / self._n if self._n else 0.0,
            **quantiles,
            "bounds": list(self.bounds),
            "counts": list(self._counts),
        }


class MetricsRegistry:
    """Named get-or-create home for a subsystem's metrics.

    One lock guards every metric in the registry: increments serialize
    briefly (they are host-side bookkeeping, far off the device dispatch
    path), and `snapshot()` reads all metrics under the same lock so the
    returned dict is one consistent cut — counters and the histograms they
    describe can never disagree inside a snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get_or_create(self, name: str, kind, **kwargs):
        with self._lock:
            existing = self._metrics.get(name)
        if existing is not None:
            if not isinstance(existing, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(existing).__name__}, not {kind.__name__}")
            return existing
        made = kind(name, self._lock, **kwargs)
        with self._lock:
            # Lost race: keep the first registration (shares our lock).
            return self._metrics.setdefault(name, made)

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str,
                  bounds: tuple[float, ...] | None = None) -> Histogram:
        return self._get_or_create(name, Histogram, bounds=bounds)

    def snapshot(self) -> dict[str, dict]:
        """{name: rendered metric} in name order, one consistent cut."""
        with self._lock:
            return {name: self._metrics[name]._snapshot()
                    for name in sorted(self._metrics)}


#: Process-global default registry (subsystems that want isolation — the
#: serve service — construct their own).
default_registry = MetricsRegistry()
