"""Span tracing: a process-global, thread-aware tracer with a no-op fast path.

PRISM's contribution is measurement — the paper characterizes partitioning
strategies and number formats by profiling — and this module gives the
runtime the same discipline: every interesting region (a tuning probe, a
CP-ALS iteration, a coalesced serve batch) is a *span* with wall/monotonic
times, nesting, and structured attributes, exportable to Perfetto
(`repro.obs.export`).

The contract that keeps this safe to leave in the hot paths:

- **Disabled is a true no-op.**  `span(...)` with tracing off costs one
  module-global attribute check and returns a shared singleton whose
  `__enter__`/`__exit__`/`set` do nothing — no allocation, no clock read,
  no lock.  `tests/test_obs.py` gates the per-call budget and that zero
  spans are emitted.
- **Thread-aware nesting.**  Each thread keeps its own open-span stack
  (`threading.local`), so the serve worker's batch span parents the batched
  ALS iterations it dispatches while client threads' request records stay
  independent.
- **Monotonic timestamps.**  Span times are `time.perf_counter()` offsets
  from the tracer's epoch; one wall-clock anchor (`epoch_wall`) taken at
  enable time lets the exporter place the trace in absolute time without
  wall clocks ever steering a measurement.

Enable programmatically (`enable_tracing()` / the `capture()` context
manager) or by environment: ``REPRO_TRACE=1`` turns the tracer on at
import, and ``REPRO_TRACE_PATH=/path/trace.jsonl`` additionally flushes
the buffer there at interpreter exit.

Never call `span`/`record_span`/metric mutations inside jitted code — each
emission is host-side Python and would host-sync per trace; the
`trace-in-jit` analysis rule enforces this (docs/static-analysis.md).
"""
from __future__ import annotations

import atexit
import dataclasses
import functools
import os
import threading
import time

__all__ = [
    "SpanRecord",
    "Tracer",
    "TRACE_ENV",
    "TRACE_PATH_ENV",
    "capture",
    "disable_tracing",
    "enable_tracing",
    "get_tracer",
    "record_span",
    "span",
    "traced",
    "tracing_enabled",
]

TRACE_ENV = "REPRO_TRACE"
TRACE_PATH_ENV = "REPRO_TRACE_PATH"

#: JSONL schema version stamped into the meta line by `repro.obs.export`.
SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One completed span.

    `t_start` and `duration` are seconds; `t_start` is an offset from the
    tracer's monotonic epoch (`Tracer.epoch_wall` anchors it to wall time
    for export).  `parent_id` is the enclosing span on the same thread (or
    an explicit parent for cross-thread records), 0 for a root.
    """

    name: str
    t_start: float
    duration: float
    span_id: int
    parent_id: int
    thread_id: int
    thread_name: str
    attrs: dict

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "t_start": self.t_start,
            "duration": self.duration,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "thread_id": self.thread_id,
            "thread_name": self.thread_name,
            "attrs": self.attrs,
        }

    @classmethod
    def from_json(cls, d: dict) -> SpanRecord:
        return cls(
            name=d["name"], t_start=float(d["t_start"]),
            duration=float(d["duration"]), span_id=int(d["span_id"]),
            parent_id=int(d["parent_id"]), thread_id=int(d["thread_id"]),
            thread_name=str(d.get("thread_name", "")),
            attrs=dict(d.get("attrs", {})))


class _NullSpan:
    """The disabled-path singleton: every operation is a no-op.  Shared,
    stateless, allocation-free — the whole point of the fast path."""

    __slots__ = ()

    def __enter__(self) -> _NullSpan:
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **attrs) -> _NullSpan:
        return self

    @property
    def duration(self) -> float | None:
        return None


_NULL_SPAN = _NullSpan()


class _Span:
    """An open span (the enabled path of `span(...)`)."""

    __slots__ = ("_attrs", "_name", "_t0", "_tracer", "duration",
                 "parent_id", "span_id")

    def __init__(self, tracer: Tracer, name: str, attrs: dict):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self.duration: float | None = None

    def __enter__(self) -> _Span:
        tr = self._tracer
        stack = tr._stack()
        self.parent_id = stack[-1] if stack else 0
        self.span_id = tr._next_id()
        stack.append(self.span_id)
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        t1 = time.perf_counter()
        tr = self._tracer
        stack = tr._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        self.duration = t1 - self._t0
        if exc_type is not None:
            self._attrs.setdefault("error", exc_type.__name__)
        th = threading.current_thread()
        tr._append(SpanRecord(
            name=self._name, t_start=self._t0 - tr.epoch_mono,
            duration=self.duration, span_id=self.span_id,
            parent_id=self.parent_id, thread_id=th.ident or 0,
            thread_name=th.name, attrs=self._attrs))

    def set(self, **attrs) -> _Span:
        """Attach attributes discovered mid-span (a probe's measured time,
        a candidate's rel-error, ...)."""
        self._attrs.update(attrs)
        return self


class Tracer:
    """Process-global span collector.  `enabled` is a plain attribute so the
    hot path pays exactly one attribute check when tracing is off."""

    def __init__(self):
        self.enabled = False
        self.epoch_mono = 0.0
        self.epoch_wall = 0.0
        self._lock = threading.Lock()
        self._spans: list[SpanRecord] = []
        self._ids = 0
        self._local = threading.local()

    # -- lifecycle ---------------------------------------------------------
    def enable(self, *, clear: bool = True) -> None:
        with self._lock:
            if clear:
                self._spans.clear()
                self._ids = 0
            self.epoch_mono = time.perf_counter()
            # One wall-clock anchor per enable: observability metadata that
            # places the monotonic span offsets in absolute time for the
            # Perfetto export; it never enters a measurement or a persisted
            # tuning artifact.
            self.epoch_wall = time.time()  # repro-lint: disable=nondeterminism -- trace epoch anchor: export metadata only, never compared or persisted into tuning state
            self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._ids = 0

    # -- recording ---------------------------------------------------------
    def span(self, name: str, **attrs):
        """Context manager timing a region.  The disabled path returns the
        shared no-op singleton — one attribute check, nothing else."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def record(self, name: str, duration: float, *,
               t_start: float | None = None, parent_id: int | None = None,
               **attrs) -> int:
        """Record an already-measured region as a completed span.

        The seam for measurements whose boundaries exist anyway (CP-ALS
        `iter_times`, serve request latencies): the caller's perf_counter
        reading becomes the span, so the trace is a *view over the same
        measurement*, not a second clock.  `t_start` is an absolute
        `perf_counter()` reading (defaults to now minus `duration`);
        `parent_id` overrides the thread-local nesting for cross-thread
        records (a request span parenting its queue-wait).  Returns the
        span id (0 when disabled)."""
        if not self.enabled:
            return 0
        if t_start is None:
            t_start = time.perf_counter() - duration
        if parent_id is None:
            stack = self._stack()
            parent_id = stack[-1] if stack else 0
        sid = self._next_id()
        th = threading.current_thread()
        self._append(SpanRecord(
            name=name, t_start=t_start - self.epoch_mono, duration=duration,
            span_id=sid, parent_id=parent_id, thread_id=th.ident or 0,
            thread_name=th.name, attrs=attrs))
        return sid

    # -- reading -----------------------------------------------------------
    def spans(self) -> list[SpanRecord]:
        """Consistent snapshot of everything recorded so far."""
        with self._lock:
            return list(self._spans)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spans)

    # -- internals ---------------------------------------------------------
    def _stack(self) -> list[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _next_id(self) -> int:
        with self._lock:
            self._ids += 1
            return self._ids

    def _append(self, rec: SpanRecord) -> None:
        with self._lock:
            self._spans.append(rec)


_TRACER = Tracer()


def get_tracer() -> Tracer:
    return _TRACER


def tracing_enabled() -> bool:
    return _TRACER.enabled


def enable_tracing(*, clear: bool = True) -> Tracer:
    _TRACER.enable(clear=clear)
    return _TRACER


def disable_tracing() -> None:
    _TRACER.disable()


def span(name: str, **attrs):
    """`with span("cp_als.iter", iter=3): ...` — see the module docstring.
    One attribute check when tracing is off."""
    if not _TRACER.enabled:
        return _NULL_SPAN
    return _Span(_TRACER, name, attrs)


def record_span(name: str, duration: float, *, t_start: float | None = None,
                parent_id: int | None = None, **attrs) -> int:
    """Module-level `Tracer.record` on the global tracer (no-op when off)."""
    if not _TRACER.enabled:
        return 0
    return _TRACER.record(name, duration, t_start=t_start,
                          parent_id=parent_id, **attrs)


def traced(name: str | None = None, **static_attrs):
    """Decorator form: `@traced("engine.build")` wraps calls in a span named
    after the function (module-qualified by default).  Keyword attrs are
    attached to every span; the disabled path adds one attribute check on
    top of the call."""
    def deco(fn):
        span_name = name or f"{fn.__module__.rsplit('.', 1)[-1]}.{fn.__qualname__}"

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not _TRACER.enabled:
                return fn(*args, **kwargs)
            with _Span(_TRACER, span_name, dict(static_attrs)):
                return fn(*args, **kwargs)
        return wrapper
    return deco


class capture:
    """`with capture() as spans:` — enable tracing for a scope and collect
    the spans it emitted (restoring the previous enabled state after).  The
    test/bench harness entrypoint."""

    def __enter__(self) -> list[SpanRecord]:
        self._was_enabled = _TRACER.enabled
        self._start = len(_TRACER)
        _TRACER.enable(clear=False)
        self._spans: list[SpanRecord] = []
        return self._spans

    def __exit__(self, *exc) -> None:
        self._spans.extend(_TRACER.spans()[self._start:])
        if not self._was_enabled:
            _TRACER.disable()


def _truthy(value: str | None) -> bool:
    return (value or "").strip().lower() in ("1", "true", "on", "yes")


def _flush_env_trace() -> None:
    path = os.environ.get(TRACE_PATH_ENV)
    if not path or not len(_TRACER):
        return
    from .export import write_jsonl
    write_jsonl(_TRACER.spans(), path, tracer=_TRACER)


if _truthy(os.environ.get(TRACE_ENV)) or os.environ.get(TRACE_PATH_ENV):
    _TRACER.enable()
    if os.environ.get(TRACE_PATH_ENV):
        atexit.register(_flush_env_trace)
