"""Decomposition serving: request coalescing over the batched CP-ALS path.

A `DecomposeService` accepts single-tensor decomposition requests from any
number of threads, coalesces them into batches (up to `max_batch` requests
or `max_wait_ms` of linger, whichever first), and dispatches each batch
through `repro.batch.cp_als_batched` — so concurrent requests that land in
the same (shape class, nnz band) bucket share one compiled kernel, one
autotune decision, and one ALS loop.

This is the product replacement for the growth-seed `repro.launch` LM
serving scaffold: it serves the repo's actual workload (tensor
decomposition), and it is built on the supported surface (`repro.batch`,
`TunePolicy`, `TuningStore`) rather than quarantined code.
"""
from __future__ import annotations

from .service import DecomposeService, ServeStats

__all__ = ["DecomposeService", "ServeStats"]
