"""The decomposition service loop: coalesce, bucket, dispatch.

Shape of the loop (one background worker thread):

  1. Block for the first pending request.
  2. Linger up to `max_wait_ms` collecting more, stopping early at
     `max_batch` — the classic latency/throughput knob pair: linger long
     enough to fill buckets, short enough to keep the tail bounded.
  3. Hand the collected tensors to `repro.batch.cp_als_batched` with the
     service's shared `TunePolicy` and `BucketPlanCache` — members of a
     bucket share one kernel and one ALS loop; a bucket seen before (this
     process or a warm `TuningStore`) dispatches with zero probes.
  4. Resolve each request's `Future` with its own `CPResult` (input order
     within the batch is preserved by `cp_als_batched`).

Every clock in this module is monotonic (`time.monotonic` for deadlines,
`time.perf_counter` for durations) — wall-clock time never steers batching.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from concurrent.futures import Future

from ..batch import BucketPlanCache, cp_als_batched
from ..core.cpals import CPResult
from ..core.sptensor import SparseTensor
from ..engine.tunepolicy import TunePolicy
from ..obs.metrics import MetricsRegistry
from ..obs.tracing import record_span, span, tracing_enabled

__all__ = ["DecomposeService", "ServeStats"]


@dataclasses.dataclass
class ServeStats:
    """Service-lifetime counters (a snapshot copy — see `stats()`).

    `n_probes` counts autotune timing probes charged across all dispatched
    buckets; a service running entirely against a warm store holds it at 0.
    `n_bucket_decisions` counts bucket tuning decisions by source:
    "measured" decisions probed, "persisted"/"cached" ones did not.

    `queue_wait_ms` / `dispatch_ms` / `request_ms` carry p50/p99
    milliseconds estimated from the service's latency histograms
    (`DecomposeService.metrics`) — empty dicts until the first completed
    dispatch.  Queue wait is submit→dispatch-start, dispatch is one
    batch's `cp_als_batched` call, request is submit→result.
    """

    n_requests: int = 0
    n_completed: int = 0
    n_failed: int = 0
    n_batches: int = 0
    n_buckets: int = 0
    n_probes: int = 0
    n_bucket_decisions: dict[str, int] = dataclasses.field(default_factory=dict)
    max_batch_seen: int = 0
    dispatch_seconds: float = 0.0
    queue_wait_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    dispatch_ms: dict[str, float] = dataclasses.field(default_factory=dict)
    request_ms: dict[str, float] = dataclasses.field(default_factory=dict)


class DecomposeService:
    """Coalescing CP-ALS decomposition service.

    Parameters
    ----------
    rank, n_iters, norm, seed:
        Decomposition parameters, shared by every request (requests with
        different parameters belong on different services — mixing ranks in
        one batch would defeat the shared-kernel geometry).
    tune:
        A `TunePolicy` for the per-bucket autotune decision; give it a
        `store=` to share decisions across processes.
    max_batch:
        Dispatch as soon as this many requests are pending.
    max_wait_ms:
        Linger this long after the first pending request before dispatching
        a partial batch.  0 disables coalescing (every request dispatches
        alone — the sequential baseline, useful for benchmarking).

    Use as a context manager, or call `close()`; `submit` returns a
    `concurrent.futures.Future` resolving to the request's `CPResult`.
    """

    def __init__(
        self,
        rank: int,
        n_iters: int = 5,
        *,
        tune: TunePolicy | None = None,
        max_batch: int = 64,
        max_wait_ms: float = 5.0,
        norm: str = "linf",
        seed: int = 0,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
        if max_wait_ms < 0:
            raise ValueError(f"max_wait_ms must be >= 0 (got {max_wait_ms})")
        self.rank = int(rank)
        self.n_iters = int(n_iters)
        self.tune = tune if tune is not None else TunePolicy()
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self.norm = norm
        self.seed = int(seed)
        self.plans = BucketPlanCache()
        # Per-service registry (not the process default): two services'
        # latencies must not blend.  Queue-wait and request latency observe
        # one sample per request, dispatch one per batch.
        self.metrics = MetricsRegistry()
        self._h_queue_wait = self.metrics.histogram("serve.queue_wait_seconds")
        self._h_dispatch = self.metrics.histogram("serve.dispatch_seconds")
        self._h_request = self.metrics.histogram("serve.request_seconds")
        self._queue: queue.Queue = queue.Queue()
        self._stats = ServeStats()
        self._lock = threading.Lock()
        self._closed = False
        self._worker = threading.Thread(target=self._run,
                                        name="repro-decompose-service",
                                        daemon=True)
        self._worker.start()

    # -- client surface ----------------------------------------------------
    def submit(self, st: SparseTensor) -> Future:
        """Enqueue one tensor; returns a Future of its `CPResult`."""
        if not isinstance(st, SparseTensor):
            raise TypeError(
                f"submit expects a SparseTensor, got {type(st).__name__}")
        with self._lock:
            if self._closed:
                raise RuntimeError("DecomposeService is closed")
            self._stats.n_requests += 1
        fut: Future = Future()
        self._queue.put((st, fut, time.perf_counter()))
        return fut

    def decompose(self, st: SparseTensor, timeout: float | None = None) -> CPResult:
        """Synchronous convenience: `submit` and wait."""
        return self.submit(st).result(timeout=timeout)

    def stats(self) -> ServeStats:
        """A deep snapshot of the service counters: every container field is
        copied, so mutating the returned stats (or the service continuing to
        run) never aliases into a previously-taken snapshot."""
        latency = {name: self._latency_ms(h) for name, h in (
            ("queue_wait_ms", self._h_queue_wait),
            ("dispatch_ms", self._h_dispatch),
            ("request_ms", self._h_request))}
        with self._lock:
            return dataclasses.replace(
                self._stats,
                n_bucket_decisions=dict(self._stats.n_bucket_decisions),
                **latency)

    @staticmethod
    def _latency_ms(h) -> dict[str, float]:
        if h.count == 0:
            return {}
        return {"p50": h.percentile(50) * 1e3, "p99": h.percentile(99) * 1e3}

    def close(self, *, timeout: float | None = None) -> None:
        """Stop accepting requests, drain the queue, join the worker."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._queue.put(None)
        self._worker.join(timeout=timeout)

    def __enter__(self) -> DecomposeService:
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker ------------------------------------------------------------
    def _collect(self) -> list | None:
        """Block for the first request, then linger: return the coalesced
        [(tensor, future), ...] batch, or None on shutdown."""
        first = self._queue.get()
        if first is None:
            return None
        batch = [first]
        deadline = time.monotonic() + self.max_wait_ms / 1e3
        while len(batch) < self.max_batch:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                item = self._queue.get(timeout=remaining)
            except queue.Empty:
                break
            if item is None:
                # Shutdown mid-linger: dispatch what we have, then have the
                # next _collect() see the sentinel again and exit.
                self._queue.put(None)
                break
            batch.append(item)
        return batch

    def _run(self) -> None:
        while True:
            batch = self._collect()
            if batch is None:
                return
            self._dispatch(batch)

    def _dispatch(self, batch: list) -> None:
        tensors = [st for st, _, _ in batch]
        futures = [fut for _, fut, _ in batch]
        submits = [ts for _, _, ts in batch]
        t0 = time.perf_counter()
        for ts in submits:
            self._h_queue_wait.observe(t0 - ts)
        batch_sp = span("serve.batch", n_requests=len(batch))
        try:
            # The batch span runs on the worker thread, so the bucket tune
            # decision and the batched iterations nest under it.
            with batch_sp:
                results = cp_als_batched(
                    tensors, self.rank, self.n_iters, tune=self.tune,
                    norm=self.norm, seed=self.seed, plans=self.plans)
        except Exception as e:
            # A batch-level failure (mixed dtypes, every kernel broken)
            # fails every request in the batch with the same cause.
            dt = time.perf_counter() - t0
            self._h_dispatch.observe(dt)
            with self._lock:
                self._stats.n_batches += 1
                self._stats.n_failed += len(futures)
                self._stats.max_batch_seen = max(self._stats.max_batch_seen,
                                                 len(futures))
                self._stats.dispatch_seconds += dt
            for fut in futures:
                fut.set_exception(e)
            return
        t1 = time.perf_counter()
        dt = t1 - t0
        self._h_dispatch.observe(dt)
        for ts in submits:
            self._h_request.observe(t1 - ts)
        if tracing_enabled():
            self._record_request_spans(batch_sp, submits, t0, t1)
        reports = {}
        for r in results:
            if r.tune_report is not None:
                reports[id(r.tune_report)] = r.tune_report
        with self._lock:
            s = self._stats
            s.n_batches += 1
            s.n_completed += len(futures)
            s.max_batch_seen = max(s.max_batch_seen, len(futures))
            s.dispatch_seconds += dt
            s.n_buckets += len(reports)  # one shared report per bucket
            for rep in reports.values():
                s.n_probes += rep.n_probes
                src = rep.source or "measured"
                s.n_bucket_decisions[src] = s.n_bucket_decisions.get(src, 0) + 1
        for fut, res in zip(futures, results, strict=True):
            fut.set_result(res)

    @staticmethod
    def _record_request_spans(batch_sp, submits: list[float],
                              t0: float, t1: float) -> None:
        """One `serve.request` root per request (submit→result) with its
        `serve.queue_wait` child (submit→dispatch-start); both recorded from
        already-taken perf_counter readings, and tagged with the batch
        span's id so the trace links each request to the `serve.batch`
        subtree (tune decision + iterations) that served it."""
        bid = getattr(batch_sp, "span_id", 0)
        for i, ts in enumerate(submits):
            rid = record_span("serve.request", t1 - ts, t_start=ts,
                              parent_id=0, index=i, batch_span=bid)
            record_span("serve.queue_wait", t0 - ts, t_start=ts,
                        parent_id=rid)
