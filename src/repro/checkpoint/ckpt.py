"""Checkpoint/restore for fault tolerance (DESIGN.md §4).

Layout: <dir>/step_<N>/arrays.npz + meta.json, with an atomic COMMIT marker
written last — a half-written checkpoint (host died mid-save) is never
restored.  `AsyncCheckpointer` overlaps serialization with training via a
background thread (double-buffered; the paper-scale analogue is writing to
a parallel FS while the next step runs).

Restore is elastic: arrays are loaded as host numpy and re-placed with
whatever sharding the *current* mesh prescribes, so a job can resume on a
different device count after failures (launch/elastic.py).
"""
from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "AsyncCheckpointer"]

_COMMIT = "COMMITTED"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, step: int, tree) -> str:
    d = os.path.join(path, f"step_{step:08d}")
    os.makedirs(d, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(d, "arrays.npz"), **arrays)
    with open(os.path.join(d, "meta.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves),
                   "treedef": str(treedef)}, f)
    with open(os.path.join(d, _COMMIT), "w") as f:
        f.write("ok")
    return d


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = []
    for name in os.listdir(path):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(path, name, _COMMIT)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, like_tree, shardings=None):
    """Restore into the structure of `like_tree`; optionally re-place with
    `shardings` (a matching tree of NamedSharding) for elastic resume."""
    d = os.path.join(path, f"step_{step:08d}")
    assert os.path.exists(os.path.join(d, _COMMIT)), f"uncommitted ckpt {d}"
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves, treedef = _flatten(like_tree)
    restored = [data[f"a{i}"] for i in range(len(leaves))]
    tree = jax.tree_util.tree_unflatten(treedef, restored)
    if shardings is not None:
        tree = jax.tree.map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (last write wins)."""

    def __init__(self, path: str):
        self.path = path
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree):
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint, args=(self.path, step, host_tree))
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
