"""repro — PRISM sparse-MTTKRP tensor decomposition, reproduced on JAX.

The supported product surface, re-exported from the subsystems:

- `repro.core`    — `SparseTensor`, CP-ALS (`cp_als`), the MTTKRP kernels'
                    reference implementations, fixed-point `QFormat`s.
- `repro.engine`  — `build_engine`/`autotune_engine` (backend registry,
                    persistent autotuner, calibrated cost prior) and
                    `TunePolicy`, the one bundle of tuning knobs every
                    tuning-aware entry point accepts as `tune=`.
- `repro.formats` — pluggable sparse layouts (COO/CSF/ALTO) + `FormatStats`.
- `repro.sweep`   — offline design-space sweeps shipping warm tuning stores.
- `repro.batch`   — many-small-tensor batched CP-ALS (`cp_als_batched`):
                    bucket by (shape class, nnz band), vmap the kernel, one
                    autotune decision per bucket.
- `repro.serve`   — `DecomposeService`, the coalescing request loop over
                    the batched path.
- `repro.obs`     — span tracing (`span`/`traced`/`enable_tracing`) and
                    `MetricsRegistry` counters/gauges/histograms, wired
                    through the tune/decompose/serve stack; traces export
                    to Perfetto (docs/observability.md).

Everything importable from `repro` directly is API; subpackages not
re-exported here (`repro.models`, `repro.configs`, the LM launch/optim/data
stack) are quarantined growth-seed scaffolding kept only for their seed
tests — see docs/static-analysis.md#import-orphans.
"""
from __future__ import annotations

from repro.batch import cp_als_batched
from repro.core import (
    TABLE1,
    CPResult,
    QFormat,
    SparseTensor,
    cp_als,
    random_tensor,
    table1_tensor,
)
from repro.engine import (
    AutotuneReport,
    TunePolicy,
    TuningStore,
    autotune_engine,
    build_engine,
    register_backend,
    registered_backends,
)
from repro.formats import (
    FormatCache,
    FormatStats,
    register_format,
    registered_formats,
)
from repro.obs import (
    MetricsRegistry,
    enable_tracing,
    get_tracer,
    span,
    traced,
)
from repro.serve import DecomposeService
from repro.sweep import SweepConfig, load_config, pareto_report, run_sweep

__all__ = [
    "TABLE1",
    "AutotuneReport",
    "CPResult",
    "DecomposeService",
    "FormatCache",
    "FormatStats",
    "MetricsRegistry",
    "QFormat",
    "SparseTensor",
    "SweepConfig",
    "TunePolicy",
    "TuningStore",
    "autotune_engine",
    "build_engine",
    "cp_als",
    "cp_als_batched",
    "enable_tracing",
    "get_tracer",
    "load_config",
    "pareto_report",
    "random_tensor",
    "register_backend",
    "register_format",
    "registered_backends",
    "registered_formats",
    "run_sweep",
    "span",
    "table1_tensor",
    "traced",
]
