"""repro — PRISM sparse-MTTKRP tensor decomposition, reproduced on JAX.

The supported product surface, re-exported from the four subsystems:

- `repro.core`    — `SparseTensor`, CP-ALS (`cp_als`), the MTTKRP kernels'
                    reference implementations, fixed-point `QFormat`s.
- `repro.engine`  — `build_engine`/`autotune_engine` (backend registry,
                    persistent autotuner, calibrated cost prior).
- `repro.formats` — pluggable sparse layouts (COO/CSF/ALTO) + `FormatStats`.
- `repro.sweep`   — offline design-space sweeps shipping warm tuning stores.

Everything importable from `repro` directly is API; subpackages not
re-exported here (`repro.models`, `repro.configs`, the LM launch/optim/data
stack) are quarantined growth-seed scaffolding kept only for their seed
tests — see docs/static-analysis.md#import-orphans.
"""
from __future__ import annotations

from repro.core import (
    TABLE1,
    CPResult,
    QFormat,
    SparseTensor,
    cp_als,
    random_tensor,
    table1_tensor,
)
from repro.engine import (
    AutotuneReport,
    TuningStore,
    autotune_engine,
    build_engine,
    register_backend,
    registered_backends,
)
from repro.formats import (
    FormatCache,
    FormatStats,
    register_format,
    registered_formats,
)
from repro.sweep import SweepConfig, load_config, pareto_report, run_sweep

__all__ = [
    "TABLE1",
    "AutotuneReport",
    "CPResult",
    "FormatCache",
    "FormatStats",
    "QFormat",
    "SparseTensor",
    "SweepConfig",
    "TuningStore",
    "autotune_engine",
    "build_engine",
    "cp_als",
    "load_config",
    "pareto_report",
    "random_tensor",
    "register_backend",
    "register_format",
    "registered_backends",
    "registered_formats",
    "run_sweep",
    "table1_tensor",
]
