from .pipeline import SyntheticBatches, SyntheticTokens, host_shard_slice
