from .pipeline import SyntheticTokens, SyntheticBatches, host_shard_slice
