"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — any host can
recompute any shard.  This is the straggler/elasticity story: a replacement
host joining mid-run (or a fast host covering for a slow one) regenerates
its shard without coordination or data-server state (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticTokens", "SyntheticBatches", "host_shard_slice"]


def host_shard_slice(global_batch: int, n_hosts: int, host_id: int) -> slice:
    per = global_batch // n_hosts
    return slice(host_id * per, (host_id + 1) * per)


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    """Zipf-distributed token stream with local n-gram structure so the loss
    actually decreases during example training runs."""
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard: slice | None = None) -> np.ndarray:
        sl = shard or slice(0, self.global_batch)
        rows = range(sl.start, sl.stop)
        out = np.empty((len(rows), self.seq_len), np.int32)
        for i, r in enumerate(rows):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 65_537 + r)
            # zipf head + repeated motif gives learnable structure
            base = rng.zipf(1.5, size=self.seq_len).astype(np.int64)
            motif = rng.integers(0, self.vocab, size=8)
            pos = rng.integers(0, max(self.seq_len - 8, 1), size=self.seq_len // 16)
            row = np.minimum(base, self.vocab - 1)
            for p in pos:
                row[p:p + 8] = motif
            out[i] = row.astype(np.int32)
        return out


@dataclasses.dataclass(frozen=True)
class SyntheticBatches:
    """Arch-aware batch maker (tokens / frames / image embeds)."""
    cfg: "object"           # ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, shard: slice | None = None) -> dict:
        cfg = self.cfg
        toks = SyntheticTokens(cfg.vocab, self.seq_len, self.global_batch,
                               self.seed)
        sl = shard or slice(0, self.global_batch)
        n = sl.stop - sl.start
        rng = np.random.default_rng(self.seed * 7919 + step)
        if cfg.encoder_decoder:
            dec = max(self.seq_len // cfg.dec_ratio, 16)
            return {
                "frames": rng.standard_normal(
                    (n, self.seq_len, cfg.d_model)).astype(np.float32) * 0.02,
                "tokens": SyntheticTokens(cfg.vocab, dec, self.global_batch,
                                          self.seed).batch(step, sl),
            }
        if cfg.n_image_tokens:
            text = max(self.seq_len - cfg.n_image_tokens, 16)
            return {
                "tokens": SyntheticTokens(cfg.vocab, text, self.global_batch,
                                          self.seed).batch(step, sl),
                "image_embeds": rng.standard_normal(
                    (n, cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02,
            }
        return {"tokens": toks.batch(step, sl)}
