"""Batched CP-ALS: one ALS loop decomposing a whole bucket at once.

The math is member-wise identical to the sequential `repro.core.cp_als`:
every step (MTTKRP, gram Hadamard, pinv solve, normalization, the sparse
fit identity) is the same computation with a leading batch axis, and each
member's factors are initialized from `init_factors(member.shape, rank,
seed)` — the sequential initializer on the member's TRUE shape, zero-padded
to the bucket dims.  Padded factor rows receive zero MTTKRP contributions,
solve to zero, and never disturb column norms or grams, so the per-member
results match the sequential path to float tolerance (gated at 1e-5 in
`benchmarks/serve_bench.py`).

Where the sequential driver re-decides its engine per tensor, this one
makes ONE decision per bucket (`tune.autotune_bucket`): the first member
probes, everyone after dispatches warm with zero probes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.cpals import CPResult, init_factors
from ..engine.tunepolicy import TunePolicy
from ..obs.tracing import span
from .bucketing import Bucket, bucket_tensors, pad_bucket
from .tune import BucketPlanCache, autotune_bucket

__all__ = ["cp_als_batched"]


def _normalize_batched(f: jnp.ndarray, norm: str):
    """Batched `repro.core.cpals._normalize`: f (B, I, R) → (f/λ, λ (B, R))."""
    if norm == "linf":
        lam = jnp.max(jnp.abs(f), axis=1)
    elif norm == "l2":
        lam = jnp.linalg.norm(f, axis=1)
    else:
        raise ValueError(norm)
    lam = jnp.where(lam == 0, 1.0, lam)
    return f / lam[:, None, :], lam


def _fit_batched(norm_x2, factors, lam, mlast):
    """Batched sparse fit identity (see `repro.core.cpals.fit_value`):
    ||X - X̂||² = ||X||² - 2<X, X̂> + ||X̂||², with the <X, X̂> fast path from
    the last mode's MTTKRP output — every batched kernel is exact, so the
    fast path always qualifies.  Returns (B,) fits, on device."""
    had = lam[:, :, None] * lam[:, None, :]
    for f in factors:
        had = had * jnp.einsum("bir,bis->brs", f, f)
    norm_approx2 = jnp.sum(had, axis=(1, 2))
    inner = jnp.sum(mlast * (factors[-1] * lam[:, None, :]), axis=(1, 2))
    resid = jnp.maximum(norm_x2 - 2.0 * inner + norm_approx2, 0.0)
    return 1.0 - jnp.sqrt(resid) / jnp.maximum(jnp.sqrt(norm_x2), 1e-30)


def _diff_batched(values, mask, nnz, coords, factors, lam):
    """Nonzero-only mean |X - X̂| per member, masking the padded slots (the
    reconstruction is NOT zero at a padded slot's (0,...,0) coordinate, so
    the mask — not the padded values — keeps padding out of the metric).
    Returns (B,) on device."""
    prod = lam[:, None, :]
    for m, f in enumerate(factors):
        prod = prod * jnp.take_along_axis(f, coords[:, :, m][..., None], axis=1)
    recon = jnp.sum(prod, axis=2)
    return jnp.sum(jnp.abs(values - recon) * mask, axis=1) / jnp.maximum(nnz, 1)


def _init_batched(bucket: Bucket, rank: int, seed: int) -> list[np.ndarray]:
    """Sequential-compatible init: each member draws
    `init_factors(member.shape, rank, seed)` — byte-identical to what
    `cp_als(member, rank, seed=seed)` starts from — zero-padded to the
    bucket dims and stacked over the batch axis."""
    stacked = []
    for m, dim in enumerate(bucket.dims):
        rows = np.zeros((bucket.size, dim, rank), dtype=np.float32)
        stacked.append(rows)
    for i, t in enumerate(bucket.tensors):
        for m, f in enumerate(init_factors(t.shape, rank, seed)):
            stacked[m][i, : f.shape[0]] = np.asarray(f)
    return stacked


def cp_als_batched(
    tensors,
    rank: int,
    n_iters: int = 5,
    *,
    tune: TunePolicy | None = None,
    norm: str = "linf",
    seed: int = 0,
    track_diff: bool = False,
    plans: BucketPlanCache | None = None,
) -> list[CPResult]:
    """Decompose many small tensors with one ALS loop per bucket.

    Tensors are grouped by (shape class, nnz band) — see
    `repro.batch.bucketing` — padded within each bucket, and driven through
    a `vmap`-batched MTTKRP kernel chosen by ONE autotune decision per
    bucket (`tune=` carries the `TunePolicy`; with a `store` in the policy,
    the bucket's first-ever member probes and every later member — in any
    process — dispatches with zero probes).

    Returns one `CPResult` per input, in input order.  Per-result notes:
    `engine` is the bucket's winning batched kernel (e.g. ``"batched:ref"``),
    `tune_report` is the BUCKET's report (shared by every member of the
    bucket — `n_probes` is the bucket's total, charged once, not per
    member), and `iter_times` are bucket-level wall-clock seconds (the
    whole batch's iteration, not a per-member share).  `diff_history` is
    tracked only when `track_diff=True` (off by default — it is a
    diagnostic pass over every nonzero per iteration) and uses the
    nonzero-only metric for every member.  Convergence `tol` is not
    supported: members of one batch would converge at different iterations.

    `plans` is an optional in-process `BucketPlanCache` so repeat
    dispatches of a decided bucket skip even the store read (the serving
    loop passes a per-service cache).
    """
    policy = tune if tune is not None else TunePolicy()
    buckets = bucket_tensors(tensors)
    results: list[CPResult | None] = [None] * sum(
        b.size for b in buckets.values())
    for bucket in buckets.values():
        for idx, res in zip(bucket.indices,
                            _decompose_bucket(bucket, rank, n_iters,
                                              policy=policy, norm=norm,
                                              seed=seed,
                                              track_diff=track_diff,
                                              plans=plans), strict=True):
            results[idx] = res
    return results


def _decompose_bucket(
    bucket: Bucket,
    rank: int,
    n_iters: int,
    *,
    policy: TunePolicy,
    norm: str,
    seed: int,
    track_diff: bool,
    plans: BucketPlanCache | None,
) -> list[CPResult]:
    pb = pad_bucket(bucket)
    bucket_sp = span("cp_als_batched.bucket", dims=list(pb.dims),
                     band=pb.band, size=pb.size, rank=rank, n_iters=n_iters)
    with bucket_sp:
        engine, report = autotune_bucket(pb, rank, policy, seed=seed,
                                         plans=plans)
        bucket_sp.set(engine=report.chosen, tune_source=report.source)
        n = len(pb.dims)

        factors = [jnp.asarray(f) for f in _init_batched(bucket, rank, seed)]
        lam = jnp.ones((pb.size, rank), jnp.float32)
        values = jnp.asarray(pb.values)
        norm_x2 = jnp.sum(values * values, axis=1)
        mask = jnp.asarray(pb.mask)
        coords = jnp.asarray(pb.coords)
        nnz = jnp.asarray(pb.nnz, jnp.float32)

        fit_rows: list[np.ndarray] = []
        diff_rows: list[np.ndarray] = []
        iter_times: list[float] = []
        for it in range(n_iters):
            iter_sp = span("cp_als_batched.iter", iter=it)
            with iter_sp:
                t0 = time.perf_counter()
                mlast = None
                for mode in range(n):
                    m = engine(factors, mode)
                    v = jnp.ones((pb.size, rank, rank), jnp.float32)
                    for k in range(n):
                        if k == mode:
                            continue
                        fk = factors[k]
                        v = v * jnp.einsum("bir,bis->brs", fk, fk)
                    a = m @ jnp.linalg.pinv(v)
                    a, lam = _normalize_batched(a, norm)
                    factors[mode] = a
                    mlast = m
                # repro-lint: disable=host-sync -- timing barrier: iter_times must measure completed device work, not dispatch
                jax.block_until_ready(factors[-1])
                dt = time.perf_counter() - t0
                # Same measurement the CPResults report as iter_times.
                iter_times.append(dt)
                iter_sp.set(seconds=dt)
            fits = _fit_batched(norm_x2, factors, lam, mlast)
            fit_rows.append(np.asarray(fits))
            if track_diff:
                diffs = _diff_batched(values, mask, nnz, coords, factors,
                                      lam)
                diff_rows.append(np.asarray(diffs))

    host_factors = [np.asarray(f) for f in factors]
    host_lam = np.asarray(lam)
    out: list[CPResult] = []
    for i, t in enumerate(bucket.tensors):
        out.append(CPResult(
            factors=[host_factors[m][i, : t.shape[m]] for m in range(n)],
            lam=host_lam[i],
            fit_history=[float(row[i]) for row in fit_rows],
            diff_history=[float(row[i]) for row in diff_rows],
            iter_times=list(iter_times),
            engine=report.chosen,
            quant_error=None,
            tune_report=report,
        ))
    return out
