"""Batched many-small-tensor CP-ALS (ROADMAP: the million-user regime).

The paper targets one large sparse tensor per spMTTKRP invocation; the
production scenario is the opposite — millions of *small* per-user tensors
(arxiv 2503.18198 accelerates exactly this regime by batching many small
decompositions onto one device).  This package:

  1. buckets incoming tensors by (shape class, nnz band) — `bucketing`;
  2. zero-pads every member to the bucket's common geometry (padded values
     are 0.0, a no-op in every scatter-add MTTKRP) — `bucketing`;
  3. `vmap`s the MTTKRP kernel over the batch dimension — `kernels`;
  4. makes ONE autotune decision per bucket: the first member probes, every
     later member (and every later process) hits the `TuningStore`
     fingerprint with zero probes — `tune`;
  5. runs the whole bucket through one batched CP-ALS — `cpals` — whose
     per-member factors match the sequential `cp_als` path to float
     tolerance (the batched math is member-wise identical; padding rows are
     zero and never disturb grams, norms, or the fit identity).

Public surface: `cp_als_batched` (also re-exported from `repro.core` and
`repro`), plus the bucketing/tuning primitives the serving loop
(`repro.serve`) composes.
"""
from __future__ import annotations

from .bucketing import (
    Bucket,
    BucketKey,
    PaddedBatch,
    bucket_tensors,
    nnz_band,
    pad_bucket,
    shape_class,
)
from .cpals import cp_als_batched
from .kernels import batched_kernel_names, build_batched_kernel
from .tune import BucketPlanCache, autotune_bucket, bucket_workload_key

__all__ = [
    "Bucket",
    "BucketKey",
    "BucketPlanCache",
    "PaddedBatch",
    "autotune_bucket",
    "batched_kernel_names",
    "bucket_tensors",
    "bucket_workload_key",
    "build_batched_kernel",
    "cp_als_batched",
    "nnz_band",
    "pad_bucket",
    "shape_class",
]
