"""One autotune decision per bucket, amortized through the `TuningStore`.

PRISM's lesson — amortize per-workload tuning across similar workloads —
already lives in the store's fingerprint matching.  The batched path
extends it from "one tensor, many modes" to "one bucket, many tensors": a
bucket's tuning fingerprint (`bucket_workload_key`) is *canonical* — built
from the bucket's padded dims and the nnz band's lower edge, never from any
member's true stats — so every member of the bucket, in this process or
any later one, computes the byte-identical exact-match key.  The first
member to arrive probes the batched kernels and records the winners; the
2nd..Nth members (and a fresh process loading the store) dispatch with
``n_probes == 0``.

Bucket candidate ids are spelled ``"batched:<kernel>"`` in the fingerprint
and the recorded timings, which keeps bucket entries disjoint from every
single-tensor workload key and lets the cost-model calibration exclude
them from its fit (batch-level timings are not single-tensor training
rows — see `repro.engine.calibrate`).

`BucketPlanCache` is the in-process layer above the store — the bucket
analogue of the engine's `PlanCache`: a dispatch that already decided a
bucket this process skips even the store read.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time

import jax
import numpy as np

from ..engine.autotune import AutotuneReport
from ..engine.persist import (
    StoredEntry,
    TuningStore,
    WorkloadKey,
    device_fingerprint,
    resolve_store,
)
from ..engine.tunepolicy import TunePolicy
from ..formats import FormatStats
from ..obs.tracing import record_span, span
from .bucketing import PaddedBatch
from .kernels import batched_kernel_names, build_batched_kernel

__all__ = [
    "BucketPlanCache",
    "autotune_bucket",
    "bucket_workload_key",
]

_PREFIX = "batched:"


def _candidate_id(name: str) -> str:
    return name if name.startswith(_PREFIX) else _PREFIX + name


def _kernel_name(candidate: str) -> str:
    return candidate.removeprefix(_PREFIX)


def bucket_workload_key(dims: tuple[int, ...], band: int, rank: int,
                        names) -> WorkloadKey:
    """The bucket's canonical tuning fingerprint.

    Uses the band's lower edge (``2^band``) as the nominal nnz — NOT any
    member's true count — so every member of the bucket builds the same
    exact-match key regardless of where in the band it sits (bands are
    wider than the store's near-match tolerance, so member-keyed
    fingerprints would miss each other)."""
    nominal_nnz = 0 if band < 0 else 1 << band
    return WorkloadKey(
        shape=tuple(int(d) for d in dims),
        nnz=nominal_nnz,
        density=nominal_nnz / math.prod(dims),
        ndim=len(dims),
        rank=int(rank),
        candidates=tuple(sorted(_candidate_id(n) for n in names)),
        device=tuple(sorted(device_fingerprint().items())),
        capacity=None,
    )


@dataclasses.dataclass
class BucketPlanCache:
    """In-process (bucket key → tuning decision) cache with hit counters —
    the bucket-level analogue of `repro.engine.PlanCache`.  A decided
    bucket skips the store read entirely on repeat dispatches."""

    entries: dict[WorkloadKey, StoredEntry] = dataclasses.field(
        default_factory=dict)
    hits: int = 0
    misses: int = 0

    def get(self, key: WorkloadKey) -> StoredEntry | None:
        entry = self.entries.get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: WorkloadKey, entry: StoredEntry) -> None:
        self.entries[key] = entry

    def clear(self) -> None:
        self.entries.clear()


def _time_batched(engine, factors, mode: int, *, warmup: int, reps: int) -> float:
    for _ in range(warmup):
        # repro-lint: disable=host-sync -- timing harness: warmup drains compilation before the measured reps
        jax.block_until_ready(engine(factors, mode))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        # repro-lint: disable=host-sync -- timing harness: the barrier IS the measurement boundary
        jax.block_until_ready(engine(factors, mode))
        best = min(best, time.perf_counter() - t0)
    return best


def _resolve_names(policy: TunePolicy) -> list[str]:
    registered = batched_kernel_names()
    if policy.candidates is None:
        return registered
    names = [_kernel_name(c) for c in policy.candidates]
    unknown = sorted(set(names) - set(registered))
    if unknown:
        raise ValueError(
            f"unknown batched kernel(s) {unknown}; registered: {registered}")
    return sorted(set(names))


def autotune_bucket(
    pb: PaddedBatch,
    rank: int,
    policy: TunePolicy | None = None,
    *,
    seed: int = 0,
    plans: BucketPlanCache | None = None,
):
    """Pick the batched MTTKRP kernel for one bucket — probing at most once
    per (bucket fingerprint, store).

    Returns ``(engine, report)`` where ``engine(factors, mode)`` maps the
    batched factors (list of ``(B, dims[m], R)``) to ``(B, dims[mode], R)``
    and ``report`` is an `AutotuneReport` (``source="measured"`` with
    probes charged for the bucket's first decision, ``"persisted"`` for a
    store hit, ``"cached"`` for an in-process `BucketPlanCache` hit — the
    latter two with ``n_probes == 0``).

    Policy fields consumed: candidates (``"batched:"`` prefixes optional),
    warmup, reps, store, max_probes.  `accuracy_budget` raises — every
    batched kernel is exact, there is nothing to budget; prior/elide are
    single-tensor cost-model machinery and are ignored here (the batched
    candidate space is two kernels, not a (backend × preset) grid).
    """
    policy = policy if policy is not None else TunePolicy()
    if policy.accuracy_budget is not None:
        raise ValueError(
            "accuracy_budget does not apply to the batched path: every "
            "batched kernel is exact (lossless); drop it from the policy")
    names = _resolve_names(policy)
    modes = list(range(len(pb.dims)))
    key = bucket_workload_key(pb.dims, pb.band, rank, names)
    store = resolve_store(policy.store)

    entry, source = None, None
    if plans is not None:
        entry = plans.get(key)
        source = "cached" if entry is not None else None
    if entry is None and store is not None:
        # Exact-match only (nnz_tol=0): the canonical fingerprint makes
        # every member's key byte-identical, and adjacent bands must never
        # serve each other.
        entry = store.lookup(key, nnz_tol=0.0, budget=None)
        source = "persisted" if entry is not None else None

    if entry is not None:
        winners = {m: entry.winners[m] for m in modes if m in entry.winners}
        if set(winners) == set(modes):
            built = {c: build_batched_kernel(_kernel_name(c), pb)
                     for c in sorted(set(winners.values()))}
            report = AutotuneReport(
                winners=winners,
                timings={n: dict(p) for n, p in entry.timings.items()},
                candidates=[_candidate_id(n) for n in names], skipped={},
                warmup=entry.warmup, reps=entry.reps,
                source=source, n_probes=0,
                store_path=store.path if store is not None else None)
            if plans is not None:
                plans.put(key, entry)
            record_span("autotune.bucket", 0.0, source=source,
                        chosen=report.chosen, band=pb.band,
                        dims=list(pb.dims), size=pb.size, probes=0)
            return _dispatch(built, winners), report

    # -- cold: probe every candidate on every mode -------------------------
    rng = np.random.default_rng(seed)
    factors = [np.asarray(rng.uniform(0, 1, size=(pb.size, d, rank)),
                          dtype=np.float32) for d in pb.dims]
    probe_list = list(names)
    skipped: dict[str, str] = {}
    if policy.max_probes is not None and policy.max_probes < len(probe_list):
        for n in probe_list[policy.max_probes:]:
            skipped[_candidate_id(n)] = (
                f"pruned (max_probes={policy.max_probes})")
        probe_list = probe_list[: policy.max_probes]

    timings: dict[str, dict[int, float]] = {}
    n_probes = 0
    for name in probe_list:
        cid = _candidate_id(name)
        try:
            engine = build_batched_kernel(name, pb)
            per_mode = {}
            for m in modes:
                probe_sp = span("autotune.probe", candidate=cid, mode=m,
                                provenance="measured")
                with probe_sp:
                    per_mode[m] = _time_batched(engine, factors, m,
                                                warmup=policy.warmup,
                                                reps=policy.reps)
                    probe_sp.set(seconds=per_mode[m])
        except Exception as e:  # blind by design: one broken kernel must not kill the bucket
            skipped[cid] = f"{type(e).__name__}: {e}"
            continue
        timings[cid] = per_mode
        n_probes += len(per_mode)
    if not timings:
        raise RuntimeError(f"autotune_bucket: every candidate failed: {skipped}")

    winners = {m: min(timings, key=lambda n, m=m: (timings[n][m], n))
               for m in modes}
    report = AutotuneReport(
        winners=winners, timings=timings,
        candidates=[_candidate_id(n) for n in names], skipped=skipped,
        warmup=policy.warmup, reps=policy.reps,
        source="measured", n_probes=n_probes,
        store_path=store.path if store is not None else None)

    entry = StoredEntry(key=key, winners=dict(winners),
                        timings={n: dict(p) for n, p in timings.items()},
                        warmup=policy.warmup, reps=policy.reps)
    if store is not None:
        # An unwritable store degrades to per-process tuning.  The nominal-
        # nnz FormatStats estimate rides along (schema v4) so the entry
        # documents the bucket's layout statistics like any other workload.
        with contextlib.suppress(OSError):
            entry = store.record(
                key, winners, timings,
                warmup=policy.warmup, reps=policy.reps,
                format_stats=FormatStats.estimate(pb.dims, key.nnz).to_json())
    if plans is not None:
        plans.put(key, entry)
    record_span("autotune.bucket", 0.0, source="measured",
                chosen=report.chosen, band=pb.band, dims=list(pb.dims),
                size=pb.size, probes=n_probes)

    built = {c: build_batched_kernel(_kernel_name(c), pb)
             for c in sorted(set(winners.values()))}
    return _dispatch(built, winners), report


def _dispatch(built: dict, winners: dict[int, str]):
    """Route each batched MTTKRP call to its per-mode winning kernel."""
    def engine(factors, mode: int):
        name = winners.get(mode)
        if name is None:
            raise ValueError(
                f"bucket engine has no kernel for mode {mode}: tuned modes "
                f"are {sorted(winners)}")
        return built[name](factors, mode)
    return engine
