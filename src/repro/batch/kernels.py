"""Batched MTTKRP kernels: `vmap` over the bucket's batch dimension.

Each batched kernel wraps one of the single-tensor kernels from
`repro.core.mttkrp` with `jax.vmap` over a leading batch axis — the bucket
members' geometry is identical after padding (`bucketing.pad_bucket`), so
one compiled program serves the whole bucket and XLA fuses the per-member
work into batched gathers/scatters.

Candidates:

  ref   — vmapped `mttkrp_coo`.  Padded slots carry value 0.0, so their
          scatter-add contribution is exactly zero.
  alto  — vmapped `mttkrp_alto`.  The bit-interleave positions depend only
          on the *shape*, and every bucket member shares the padded shape
          class — so one static `positions` tuple serves the whole batch,
          exactly the property that makes ALTO batchable.  (CSF is not a
          candidate: its fiber count is a per-member static, which would
          force one compilation per member and defeat the batching.)

A builder takes the bucket's `PaddedBatch`, moves the batch arrays to
device once, and returns ``engine(factors, mode) -> (B, dims[mode], R)``
with ``factors`` a list of ``(B, dims[m], R)`` batched factor matrices.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mttkrp import mttkrp_alto, mttkrp_coo
from ..core.sptensor import SparseTensor
from ..formats.alto import build_alto
from .bucketing import PaddedBatch

__all__ = ["batched_kernel_names", "build_batched_kernel"]


@partial(jax.jit, static_argnames=("mode", "out_dim"))
def _batched_mttkrp_coo(factors, coords, values, *, mode: int, out_dim: int):
    """factors: tuple of (B, I_m, R); coords (B, P, N) int32; values (B, P)
    f32.  Returns (B, out_dim, R) f32."""
    return jax.vmap(
        lambda f, c, v: mttkrp_coo(f, c, v, mode=mode, out_dim=out_dim)
    )(factors, coords, values)


@partial(jax.jit, static_argnames=("mode", "positions", "out_dim"))
def _batched_mttkrp_alto(factors, key_words, values, *, mode: int,
                         positions, out_dim: int):
    """factors: tuple of (B, I_m, R); key_words (B, P, W) uint32 (each
    member's rows sorted by its own key); values (B, P) f32 in key order.
    `positions` is shared by the whole batch — it depends only on the
    padded shape class."""
    return jax.vmap(
        lambda f, k, v: mttkrp_alto(f, k, v, mode=mode, positions=positions,
                                    out_dim=out_dim)
    )(factors, key_words, values)


def _build_ref(pb: PaddedBatch):
    coords = jnp.asarray(pb.coords)
    values = jnp.asarray(pb.values)
    dims = pb.dims

    def engine(factors, mode: int):
        return _batched_mttkrp_coo(tuple(jnp.asarray(f) for f in factors),
                                   coords, values,
                                   mode=int(mode), out_dim=dims[mode])
    return engine


def _build_alto(pb: PaddedBatch):
    # Linearize each member against the PADDED dims: the interleave
    # positions are a function of the shape alone, so the whole bucket
    # shares one static decode — padded slots (coords 0, value 0) sort to
    # the front as key 0 and contribute zero to the segment sum.
    alto = [build_alto(SparseTensor(pb.coords[i], pb.values[i], pb.dims))
            for i in range(pb.size)]
    key_words = jnp.asarray(np.stack([a.key_words for a in alto]))
    values = jnp.asarray(np.stack([a.values for a in alto]))
    positions = alto[0].positions
    dims = pb.dims

    def engine(factors, mode: int):
        return _batched_mttkrp_alto(tuple(jnp.asarray(f) for f in factors),
                                    key_words, values, mode=int(mode),
                                    positions=positions, out_dim=dims[mode])
    return engine


#: name -> builder(PaddedBatch) -> engine.  Enumerations go through
#: `batched_kernel_names()` (sorted) so registration order never leaks into
#: probe order or tie-breaks.
_BATCHED_BUILDERS = {
    "alto": _build_alto,
    "ref": _build_ref,
}


def batched_kernel_names() -> list[str]:
    """The registered batched kernels, sorted by name."""
    return sorted(_BATCHED_BUILDERS)


def build_batched_kernel(name: str, pb: PaddedBatch):
    """Build the named batched kernel against one bucket's padded arrays."""
    try:
        builder = _BATCHED_BUILDERS[name]
    except KeyError:
        raise ValueError(
            f"unknown batched kernel {name!r}; registered: "
            f"{batched_kernel_names()}") from None
    return builder(pb)
