"""Bucketing: group small tensors whose geometry can share one compiled
batched kernel and one autotune decision.

Two tensors land in the same bucket iff they agree on

  * **shape class** — every dimension rounded up to the next power of two
    (`shape_class`).  Pow-2 rounding keeps the number of distinct compiled
    kernel geometries logarithmic in the dimension range while bounding the
    padding waste per dimension below 2x.
  * **nnz band** — the power-of-two band ``[2^k, 2^{k+1})`` holding the
    nonzero count (`nnz_band`; a count sitting exactly on a boundary
    ``2^k`` belongs to band ``k``, computed with integer ``bit_length`` so
    no float rounding can flip it).  Banding bounds the nonzero padding a
    member pays to the bucket maximum, and gives every member the same
    canonical tuning fingerprint (`tune.bucket_workload_key`).

Within a bucket, every member is zero-padded to the common geometry
(`pad_bucket`): coordinates pad with 0 and values with 0.0, so padded slots
contribute ``0 * F[0] * ...`` to every scatter-add/segment-sum MTTKRP —
a no-op — and factor rows beyond a member's true dimension stay exactly
zero through ALS (a zero MTTKRP row solves to a zero factor row; L-inf/L2
column norms are unaffected by extra zero rows).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.sptensor import SparseTensor

__all__ = [
    "Bucket",
    "BucketKey",
    "PaddedBatch",
    "bucket_tensors",
    "nnz_band",
    "pad_bucket",
    "shape_class",
]


def _next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length() if n > 1 else 1


def shape_class(shape: tuple[int, ...]) -> tuple[int, ...]:
    """The bucket's common dimensions: each dim rounded up to a power of
    two (identity on dims that already are one)."""
    return tuple(_next_pow2(int(d)) for d in shape)


def nnz_band(nnz: int) -> int:
    """Band index k with ``2^k <= nnz < 2^{k+1}``; -1 for an all-zero
    tensor.  `bit_length` keeps the boundary exact: nnz=2^k is band k,
    nnz=2^k - 1 is band k-1."""
    if nnz < 0:
        raise ValueError(f"nnz must be >= 0 (got {nnz})")
    return int(nnz).bit_length() - 1


#: A bucket's identity: (shape class dims, nnz band index).
BucketKey = tuple[tuple[int, ...], int]


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One (shape class, nnz band) group of input tensors.

    `indices` are the members' positions in the original input list, so
    results can be scattered back into input order after the per-bucket
    dispatch."""

    dims: tuple[int, ...]        # shape class (pow-2 padded dims)
    band: int                    # nnz band index (nnz_band)
    tensors: tuple[SparseTensor, ...]
    indices: tuple[int, ...]

    @property
    def key(self) -> tuple[tuple[int, ...], int]:
        return (self.dims, self.band)

    @property
    def size(self) -> int:
        return len(self.tensors)


@dataclasses.dataclass(frozen=True)
class PaddedBatch:
    """A bucket materialized as batched arrays, ready to `vmap` over.

    coords — (B, P, N) int32, rows past a member's true nnz are 0.
    values — (B, P) float32, entries past a member's true nnz are 0.0
             (a zero value makes the padded slot a no-op in every
             scatter-add / segment-sum MTTKRP).
    mask   — (B, P) float32, 1.0 on true nonzeros, 0.0 on padding — for
             metrics that must not count the padded slots (diff tracking).
    nnz    — per-member true nonzero counts.
    """

    dims: tuple[int, ...]
    band: int
    coords: np.ndarray
    values: np.ndarray
    mask: np.ndarray
    shapes: tuple[tuple[int, ...], ...]   # members' true shapes
    nnz: tuple[int, ...]

    @property
    def size(self) -> int:
        return self.values.shape[0]

    @property
    def pad_nnz(self) -> int:
        return self.values.shape[1]


def _check_dtypes(tensors) -> None:
    """Reject mixed dtypes up front: `np.stack` would silently upcast a
    stray float64 member and every member would pay for it — and int64
    coordinates would defeat the device int32 contract."""
    vdtypes = sorted({str(t.values.dtype) for t in tensors})
    if len(vdtypes) > 1:
        raise TypeError(
            f"cp_als_batched: mixed value dtypes across the batch "
            f"({', '.join(vdtypes)}); cast every tensor's values to one "
            "dtype (float32) before batching")
    cdtypes = sorted({str(t.coords.dtype) for t in tensors})
    if len(cdtypes) > 1:
        raise TypeError(
            f"cp_als_batched: mixed coordinate dtypes across the batch "
            f"({', '.join(cdtypes)}); cast every tensor's coords to one "
            "dtype (int32) before batching")


def bucket_tensors(tensors) -> dict[tuple[tuple[int, ...], int], Bucket]:
    """Group `tensors` into buckets keyed by (shape class, nnz band).

    Every input must be a `SparseTensor`; all members of the batch must
    share one ndim-independent value dtype and one coordinate dtype
    (mixed dtypes raise `TypeError` — see `_check_dtypes`).  Buckets come
    back ordered by key so downstream dispatch is deterministic.
    """
    tensors = list(tensors)
    for i, t in enumerate(tensors):
        if not isinstance(t, SparseTensor):
            raise TypeError(
                f"cp_als_batched: input {i} is {type(t).__name__}, "
                "expected SparseTensor")
    if not tensors:
        return {}
    _check_dtypes(tensors)
    groups: dict[tuple[tuple[int, ...], int], list[int]] = {}
    for i, t in enumerate(tensors):
        groups.setdefault((shape_class(t.shape), nnz_band(t.nnz)), []).append(i)
    return {
        key: Bucket(dims=key[0], band=key[1],
                    tensors=tuple(tensors[i] for i in idx),
                    indices=tuple(idx))
        for key, idx in sorted(groups.items())
    }


def pad_bucket(bucket: Bucket) -> PaddedBatch:
    """Materialize a bucket as batched, zero-padded host arrays.

    The nonzero dimension pads to the bucket's max member nnz (at least 1,
    so an all-zero bucket still has a non-degenerate kernel geometry).
    """
    b = bucket.size
    pad_nnz = max(1, *(t.nnz for t in bucket.tensors))
    n = len(bucket.dims)
    coords = np.zeros((b, pad_nnz, n), dtype=np.int32)
    values = np.zeros((b, pad_nnz), dtype=np.float32)
    mask = np.zeros((b, pad_nnz), dtype=np.float32)
    for i, t in enumerate(bucket.tensors):
        k = t.nnz
        coords[i, :k] = t.coords.astype(np.int32, copy=False)
        values[i, :k] = t.values.astype(np.float32, copy=False)
        mask[i, :k] = 1.0
    return PaddedBatch(
        dims=bucket.dims, band=bucket.band,
        coords=coords, values=values, mask=mask,
        shapes=tuple(t.shape for t in bucket.tensors),
        nnz=tuple(t.nnz for t in bucket.tensors))
